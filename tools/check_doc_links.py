#!/usr/bin/env python
"""Docs link-check: every local markdown link / referenced repo path must
exist.  Used by CI (`.github/workflows/ci.yml`) so README/docs references
stay valid.

    python tools/check_doc_links.py README.md docs
"""
from __future__ import annotations

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
# inline-code path mentions like `src/repro/dse/` or `examples/quickstart.py`
CODE_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_./-]*)`")
# repo paths resolve against the repo root (this script's parent dir), not
# the CWD, so the check works from any working directory
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def md_files(args):
    for a in args:
        if os.path.isdir(a):
            for root, _, names in os.walk(a):
                yield from (os.path.join(root, n) for n in names
                            if n.endswith(".md"))
        else:
            yield a


def check(path: str) -> list:
    errors = []
    text = open(path).read()
    base = os.path.dirname(path)
    for m in LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link -> {target}")
    for m in CODE_PATH.finditer(text):
        target = m.group(1)
        # only flag things that look like repo paths (known top-level dirs)
        if target.split("/")[0] in ("src", "docs", "examples", "tests",
                                    "benchmarks", "tools"):
            if not os.path.exists(os.path.join(REPO_ROOT, target.rstrip("/"))):
                errors.append(f"{path}: missing repo path -> {target}")
    return errors


def main(argv) -> int:
    errors = []
    for f in md_files(argv or ["README.md", "docs"]):
        errors += check(f)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked docs links: {'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
