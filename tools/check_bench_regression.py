"""Bench-smoke regression gate for the channel-scaling benchmark.

Compares a freshly measured ``BENCH_engine.json`` against the committed
baseline:

  * the fresh 1->4 channel aggregate cycles/sec speedup must not drop
    below the noise-padded floor recorded at merge time
    (``speedup_floor_1_to_4``, derived from the merge-time 1->2/1->4
    speedups — the cliff this guards against is PR 3's 4-channel collapse);
  * the scan-carry reduction of the windowed-ring split must stay >= 3x
    vs the dense-ring baseline for DDR5 and HBM3;
  * the heterogeneous (DDR5 + CXL-DDR4, 2 spec groups) engine rate,
    relative to the same box's homogeneous 4-channel rate, must not fall
    below the floor recorded at merge time (``hetero_floor_vs_4ch``);
  * windowed-telemetry capture (4-channel engine, window=256) must cost
    at most the committed ceiling (``telemetry_overhead_ceiling``, 5% at
    merge time) over the telemetry-off run of the same box;
  * the scale-out ratios — the channel-sharded (shard_map) 4-channel
    engine and the 64-point device-sharded sweep, each measured at forced
    host device counts 1 vs 4 in subprocesses — must not drop below the
    merge-time floors (``sharded_speedup_floor_1_to_4``,
    ``sweep_speedup_floor_1_to_4``).

Usage: python tools/check_bench_regression.py --baseline BENCH_engine.json \
           --fresh results/bench_fresh.json
"""
from __future__ import annotations

import argparse
import json
import sys


def check(baseline: dict, fresh: dict) -> list:
    errors = []
    floor = baseline.get("speedup_floor_1_to_4")
    s14 = fresh.get("channel_scaling_speedup_1_to_4")
    if floor is None:
        errors.append("baseline has no speedup_floor_1_to_4 "
                      "(re-run benchmarks/run.py --only engine)")
    elif s14 is None:
        errors.append("fresh results have no channel_scaling_speedup_1_to_4")
    elif s14 < floor:
        errors.append(
            f"1->4 channel speedup regressed: {s14} < merge-time floor "
            f"{floor} (baseline measured "
            f"{baseline.get('channel_scaling_speedup_1_to_4')})")

    for std in ("DDR5", "HBM3"):
        cb = fresh.get("carry_bytes", {}).get(std)
        if cb is None:
            errors.append(f"fresh results carry no carry_bytes for {std}")
        elif cb["reduction"] < 3.0:
            errors.append(
                f"{std} scan-carry reduction {cb['reduction']}x < 3x "
                f"(table+ring {cb['table_ring']}B vs dense ring "
                f"{cb['dense_ring_baseline']}B)")

    # heterogeneous (2-spec-group) engine rate, relative to the
    # homogeneous 4-channel run of the same box — the ratio is what
    # stays stable across noisy shared runners
    het = fresh.get("hetero")
    het_floor = baseline.get("hetero_floor_vs_4ch")
    if het is None:
        errors.append("fresh results carry no hetero (2-spec-group) "
                      "benchmark — re-run benchmarks/run.py --only engine")
    elif het_floor is not None \
            and het.get("vs_4ch_homogeneous", 0.0) < het_floor:
        errors.append(
            f"heterogeneous engine rate regressed: "
            f"{het.get('vs_4ch_homogeneous')} of the homogeneous 4ch rate "
            f"< merge-time floor {het_floor} (baseline measured "
            f"{baseline.get('hetero', {}).get('vs_4ch_homogeneous')})")

    # windowed-telemetry overhead vs the committed ceiling — both runs of
    # the ratio happen on the same box back to back, so the ratio is
    # stable where raw rates are not
    tel = fresh.get("telemetry")
    ceiling = baseline.get("telemetry_overhead_ceiling")
    if tel is None:
        errors.append("fresh results carry no telemetry overhead "
                      "measurement — re-run benchmarks/run.py --only engine")
    elif ceiling is None:
        errors.append("baseline has no telemetry_overhead_ceiling "
                      "(re-run benchmarks/run.py --only engine)")
    elif tel.get("overhead", 1.0) > ceiling:
        errors.append(
            f"telemetry overhead regressed: {100 * tel.get('overhead'):.1f}%"
            f" slowdown at window={tel.get('window')} > ceiling "
            f"{100 * ceiling:.0f}% (baseline measured "
            f"{100 * baseline.get('telemetry', {}).get('overhead', 0):.1f}%)")

    # scale-out: the sharded-channel and sharded-sweep 1->4 device
    # speedups — both ratios measure the same workload back to back at
    # forced device counts on one box, so they are stable where raw
    # rates are not
    for key, floor_key, label in (
            ("channel_scaling_sharded", "sharded_speedup_floor_1_to_4",
             "sharded 1->4 channel aggregate speedup"),
            ("sweep_scaling", "sweep_speedup_floor_1_to_4",
             "1->4 device sweep wall-clock speedup")):
        fresh_e = fresh.get(key)
        floor = baseline.get(floor_key)
        if fresh_e is None:
            errors.append(f"fresh results carry no {key} measurement — "
                          "re-run benchmarks/run.py --only engine")
        elif floor is None:
            errors.append(f"baseline has no {floor_key} "
                          "(re-run benchmarks/run.py --only engine)")
        elif fresh_e.get("speedup_1_to_4", 0.0) < floor:
            errors.append(
                f"{label} regressed: {fresh_e.get('speedup_1_to_4')} < "
                f"merge-time floor {floor} (baseline measured "
                f"{baseline.get(key, {}).get('speedup_1_to_4')})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_engine.json (merge-time floors)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_engine.json from this run")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    errors = check(baseline, fresh)
    s = fresh.get("channel_scaling_speedup_1_to_4")
    het = fresh.get("hetero", {})
    print(f"fresh 1->4 speedup: {s}  "
          f"(floor {baseline.get('speedup_floor_1_to_4')});  carry: "
          + ", ".join(f"{k} {v['reduction']}x"
                      for k, v in fresh.get("carry_bytes", {}).items())
          + f";  hetero vs 4ch: {het.get('vs_4ch_homogeneous')} "
          f"(floor {baseline.get('hetero_floor_vs_4ch')});  telemetry "
          f"overhead: {fresh.get('telemetry', {}).get('overhead')} "
          f"(ceiling {baseline.get('telemetry_overhead_ceiling')});  "
          f"sharded 1->4: "
          f"{fresh.get('channel_scaling_sharded', {}).get('speedup_1_to_4')}"
          f" (floor {baseline.get('sharded_speedup_floor_1_to_4')});  "
          f"sweep 1->4: "
          f"{fresh.get('sweep_scaling', {}).get('speedup_1_to_4')} "
          f"(floor {baseline.get('sweep_speedup_floor_1_to_4')})")
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
