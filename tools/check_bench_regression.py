"""Bench-smoke regression gate for the channel-scaling benchmark.

Compares a freshly measured ``BENCH_engine.json`` against the committed
baseline:

  * the fresh 1->4 channel aggregate cycles/sec speedup must not drop
    below the noise-padded floor recorded at merge time
    (``speedup_floor_1_to_4``, derived from the merge-time 1->2/1->4
    speedups — the cliff this guards against is PR 3's 4-channel collapse);
  * the scan-carry reduction of the windowed-ring split must stay >= 3x
    vs the dense-ring baseline for DDR5 and HBM3;
  * the heterogeneous (DDR5 + CXL-DDR4, 2 spec groups) engine rate,
    relative to the same box's homogeneous 4-channel rate, must not fall
    below the floor recorded at merge time (``hetero_floor_vs_4ch``);
  * windowed-telemetry capture (4-channel engine, window=256) must cost
    at most the committed ceiling (``telemetry_overhead_ceiling``, 5% at
    merge time) over the telemetry-off run of the same box;
  * the scale-out ratios — the channel-sharded (shard_map) 4-channel
    engine and the 64-point device-sharded sweep, each measured at forced
    host device counts 1 vs 4 in subprocesses — must not drop below the
    merge-time floors (``sharded_speedup_floor_1_to_4``,
    ``sweep_speedup_floor_1_to_4``).  These two floors are HARDWARE
    RELATIVE: forcing 4 host devices onto fewer than 4 physical cores
    time-slices one core instead of parallelizing (the 0.219 "speedup"
    recorded on the ROADMAP's 1-core box is scheduling noise, not a
    property of the code), so on boxes with fewer physical cores than
    the probe's device count the floors are annotated and relaxed by
    ``TIMESLICE_RELAX`` instead of misfiring;
  * the event-horizon fast-forward wall-clock speedup at the low-rate
    operating point (``fast_forward.speedup``, on vs off at the same
    interval on the same box) must not drop below the merge-time floor
    (``fast_forward_speedup_floor``).

Usage: python tools/check_bench_regression.py --baseline BENCH_engine.json \
           --fresh results/bench_fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: how much of a scale-out floor survives on a box that cannot actually
#: parallelize the probe (fewer physical cores than forced devices) —
#: the ratio still catches order-of-magnitude collapses while ignoring
#: time-slicing jitter
TIMESLICE_RELAX = 0.5


def physical_cores() -> int:
    """Physical core count: distinct (physical id, core id) pairs from
    /proc/cpuinfo, so SMT siblings and forced host devices don't inflate
    it.  Falls back to the scheduler's usable-CPU count (itself capped
    by os.cpu_count()) where cpuinfo is unavailable (macOS, containers
    with masked /proc)."""
    pairs = set()
    try:
        with open("/proc/cpuinfo") as f:
            phys = core = None
            for line in f:
                key = line.split(":")[0].strip()
                if key == "physical id":
                    phys = line.split(":", 1)[1].strip()
                elif key == "core id":
                    core = line.split(":", 1)[1].strip()
                elif not line.strip():
                    if core is not None:
                        pairs.add((phys, core))
                    phys = core = None
            if core is not None:
                pairs.add((phys, core))
    except OSError:
        pass
    if pairs:
        return len(pairs)
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


def check(baseline: dict, fresh: dict, cores: int | None = None,
          notes: list | None = None) -> list:
    errors = []
    notes = notes if notes is not None else []
    cores = physical_cores() if cores is None else cores
    floor = baseline.get("speedup_floor_1_to_4")
    s14 = fresh.get("channel_scaling_speedup_1_to_4")
    if floor is None:
        errors.append("baseline has no speedup_floor_1_to_4 "
                      "(re-run benchmarks/run.py --only engine)")
    elif s14 is None:
        errors.append("fresh results have no channel_scaling_speedup_1_to_4")
    elif s14 < floor:
        errors.append(
            f"1->4 channel speedup regressed: {s14} < merge-time floor "
            f"{floor} (baseline measured "
            f"{baseline.get('channel_scaling_speedup_1_to_4')})")

    for std in ("DDR5", "HBM3"):
        cb = fresh.get("carry_bytes", {}).get(std)
        if cb is None:
            errors.append(f"fresh results carry no carry_bytes for {std}")
        elif cb["reduction"] < 3.0:
            errors.append(
                f"{std} scan-carry reduction {cb['reduction']}x < 3x "
                f"(table+ring {cb['table_ring']}B vs dense ring "
                f"{cb['dense_ring_baseline']}B)")

    # heterogeneous (2-spec-group) engine rate, relative to the
    # homogeneous 4-channel run of the same box — the ratio is what
    # stays stable across noisy shared runners
    het = fresh.get("hetero")
    het_floor = baseline.get("hetero_floor_vs_4ch")
    if het is None:
        errors.append("fresh results carry no hetero (2-spec-group) "
                      "benchmark — re-run benchmarks/run.py --only engine")
    elif het_floor is not None \
            and het.get("vs_4ch_homogeneous", 0.0) < het_floor:
        errors.append(
            f"heterogeneous engine rate regressed: "
            f"{het.get('vs_4ch_homogeneous')} of the homogeneous 4ch rate "
            f"< merge-time floor {het_floor} (baseline measured "
            f"{baseline.get('hetero', {}).get('vs_4ch_homogeneous')})")

    # windowed-telemetry overhead vs the committed ceiling — both runs of
    # the ratio happen on the same box back to back, so the ratio is
    # stable where raw rates are not
    tel = fresh.get("telemetry")
    ceiling = baseline.get("telemetry_overhead_ceiling")
    if tel is None:
        errors.append("fresh results carry no telemetry overhead "
                      "measurement — re-run benchmarks/run.py --only engine")
    elif ceiling is None:
        errors.append("baseline has no telemetry_overhead_ceiling "
                      "(re-run benchmarks/run.py --only engine)")
    elif tel.get("overhead", 1.0) > ceiling:
        errors.append(
            f"telemetry overhead regressed: {100 * tel.get('overhead'):.1f}%"
            f" slowdown at window={tel.get('window')} > ceiling "
            f"{100 * ceiling:.0f}% (baseline measured "
            f"{100 * baseline.get('telemetry', {}).get('overhead', 0):.1f}%)")

    # scale-out: the sharded-channel and sharded-sweep 1->4 device
    # speedups — both ratios measure the same workload back to back at
    # forced device counts on one box, so they are stable where raw
    # rates are not
    for key, floor_key, label in (
            ("channel_scaling_sharded", "sharded_speedup_floor_1_to_4",
             "sharded 1->4 channel aggregate speedup"),
            ("sweep_scaling", "sweep_speedup_floor_1_to_4",
             "1->4 device sweep wall-clock speedup")):
        fresh_e = fresh.get(key)
        floor = baseline.get(floor_key)
        if fresh_e is None:
            errors.append(f"fresh results carry no {key} measurement — "
                          "re-run benchmarks/run.py --only engine")
            continue
        if floor is None:
            errors.append(f"baseline has no {floor_key} "
                          "(re-run benchmarks/run.py --only engine)")
            continue
        # forced-device scale-out on a box with fewer physical cores
        # than devices measures the OS scheduler, not the code: the 1->4
        # "speedup" is time-slicing noise, so the merge-time floor (itself
        # possibly recorded on better hardware) only gates order-of-
        # magnitude collapses here
        if cores < 4:
            relaxed = round(floor * TIMESLICE_RELAX, 3)
            notes.append(
                f"{floor_key}: {cores} physical core(s) < 4 forced "
                f"devices — 1->4 ratio is time-slicing noise; floor "
                f"relaxed {floor} -> {relaxed}")
            floor = relaxed
        if fresh_e.get("speedup_1_to_4", 0.0) < floor:
            errors.append(
                f"{label} regressed: {fresh_e.get('speedup_1_to_4')} < "
                f"merge-time floor {floor} (baseline measured "
                f"{baseline.get(key, {}).get('speedup_1_to_4')}; "
                f"{cores} physical cores)")

    # event-horizon fast-forward: the on/off wall-clock ratio at the
    # low-injection operating point — both sides run on the same box
    # back to back, so the ratio is hardware-independent and gates at
    # the merge-time floor everywhere (no core-count relaxation)
    ffr = fresh.get("fast_forward")
    ff_floor = baseline.get("fast_forward_speedup_floor")
    if ffr is None:
        errors.append("fresh results carry no fast_forward measurement — "
                      "re-run benchmarks/run.py --only engine")
    elif ff_floor is None:
        errors.append("baseline has no fast_forward_speedup_floor "
                      "(re-run benchmarks/run.py --only engine)")
    elif ffr.get("speedup", 0.0) < ff_floor:
        errors.append(
            f"fast-forward low-rate speedup regressed: "
            f"{ffr.get('speedup')} < merge-time floor {ff_floor} "
            f"(baseline measured "
            f"{baseline.get('fast_forward', {}).get('speedup')} at "
            f"interval {ffr.get('interval')}, "
            f"{100 * ffr.get('idle_fraction', 0):.0f}% cycles skipped)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_engine.json (merge-time floors)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_engine.json from this run")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    notes: list = []
    errors = check(baseline, fresh, notes=notes)
    s = fresh.get("channel_scaling_speedup_1_to_4")
    het = fresh.get("hetero", {})
    print(f"physical cores: {physical_cores()}")
    for note in notes:
        print(f"NOTE: {note}")
    print(f"fresh 1->4 speedup: {s}  "
          f"(floor {baseline.get('speedup_floor_1_to_4')});  carry: "
          + ", ".join(f"{k} {v['reduction']}x"
                      for k, v in fresh.get("carry_bytes", {}).items())
          + f";  hetero vs 4ch: {het.get('vs_4ch_homogeneous')} "
          f"(floor {baseline.get('hetero_floor_vs_4ch')});  telemetry "
          f"overhead: {fresh.get('telemetry', {}).get('overhead')} "
          f"(ceiling {baseline.get('telemetry_overhead_ceiling')});  "
          f"sharded 1->4: "
          f"{fresh.get('channel_scaling_sharded', {}).get('speedup_1_to_4')}"
          f" (floor {baseline.get('sharded_speedup_floor_1_to_4')});  "
          f"sweep 1->4: "
          f"{fresh.get('sweep_scaling', {}).get('speedup_1_to_4')} "
          f"(floor {baseline.get('sweep_speedup_floor_1_to_4')});  "
          f"fast-forward: "
          f"{fresh.get('fast_forward', {}).get('speedup')} "
          f"(floor {baseline.get('fast_forward_speedup_floor')})")
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
