"""Design-space exploration with the vmapped engine (paper §3.1 workflow):
sweep load x read-ratio points for two standards in single compiled
programs, print the latency-throughput table, and render a command-trace
visualization (paper §4.1).

    PYTHONPATH=src python examples/dse_sweep.py
"""
import time

import jax

from repro.core import (Simulator, avg_probe_latency_ns, peak_gbps,
                        throughput_gbps, viz)

INTERVALS = [32.0, 8.0, 4.0, 2.0, 1.0]
RATIOS = [1.0, 0.5]

for std, org, tim in [("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
                      ("HBM3", "HBM3_16Gb", "HBM3_5200")]:
    sim = Simulator(std, org, tim)
    t0 = time.perf_counter()
    pts, batch = sim.run_batch(10_000, INTERVALS, RATIOS)
    dt = time.perf_counter() - t0
    print(f"\n=== {std}: {len(pts)} design points in {dt:.1f}s "
          f"(one vmapped program) ===")
    print(f"{'interval':>9} {'rd%':>5} {'GB/s':>8} {'peak%':>6} {'lat ns':>8}")
    for i, (interval, rr) in enumerate(pts):
        st = jax.tree.map(lambda a: a[i], batch)
        tp = throughput_gbps(sim.cspec, st)
        lat = avg_probe_latency_ns(sim.cspec, st)
        print(f"{interval:9.1f} {int(rr * 100):5d} {tp:8.2f} "
              f"{100 * tp / peak_gbps(sim.cspec):6.1f} {lat:8.1f}")

# trace visualization of a short saturated window
sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200")
stats, trace = sim.run(2_000, interval=1.0, read_ratio=0.7, trace=True)
path = viz.write_html("results/hbm3_trace.html", sim.cspec, trace,
                      title="HBM3 @ saturation (dual C/A)")
print(f"\ncommand-trace visualizer written to {path}")
