"""Design-space exploration with the `repro.dse` subsystem (paper §3.1
workflow): declare a two-standard x load x read-ratio sweep, execute it
with one compiled program per standard, print the latency-throughput
table, persist the curve artifact, and render a command-trace
visualization (paper §4.1).

    PYTHONPATH=src python examples/dse_sweep.py

The same sweep is available as a CLI: ``python -m repro.dse.sweep``.
"""
from repro.core import Simulator, viz
from repro.dse import SweepSpec, execute

spec = SweepSpec(
    systems=(("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
             ("HBM3", "HBM3_16Gb", "HBM3_5200")),
    intervals=(32.0, 8.0, 4.0, 2.0, 1.0),
    read_ratios=(1.0, 0.5),
    n_cycles=10_000,
)
result = execute(spec)

print(f"=== {result.meta['n_points']} design points, "
      f"{result.meta['n_groups']} compiled programs, "
      f"{result.meta['wall_s']}s ===")
print(result.to_table())
for cv in result.curves():
    print(f"{cv.system:>6} rd={cv.read_ratio:g}: "
          f"peak_frac={cv.peak_fraction:.3f} "
          f"knee@interval={cv.intervals[cv.knee]:g}")
path = result.save("results/dse_sweep_example")
print(f"curve artifact written to {path}")

# trace visualization of a short saturated window
sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200")
stats, trace = sim.run(2_000, interval=1.0, read_ratio=0.7, trace=True)
path = viz.write_html("results/hbm3_trace.html", sim.cspec, trace,
                      title="HBM3 @ saturation (dual C/A)")
print(f"\ncommand-trace visualizer written to {path}")
