"""Paper §4.1 / Fig. 2: record a DRAM command trace and render the
two-view HTML visualizer (bus utilization + command trace).

    PYTHONPATH=src python examples/visualize_trace.py [standard]
"""
import sys

from repro.core import Simulator, viz

std, org, tim = {
    "DDR5": ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    "HBM3": ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    "LPDDR5": ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    "GDDR7": ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32"),
}[sys.argv[1] if len(sys.argv) > 1 else "LPDDR5"]

sim = Simulator(std, org, tim)
stats, trace = sim.run(3_000, interval=2.0, read_ratio=0.75, trace=True)
recs = viz.trace_to_records(sim.cspec, trace)
path = viz.write_html(f"results/{std.lower()}_trace.html", sim.cspec, trace,
                      title=f"{std} command trace ({tim})")
print(f"{len(recs)} commands rendered -> {path}")
print("open in a browser: zoom/offset sliders, hover for per-command info")
