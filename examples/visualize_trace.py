"""Paper §4 / Fig. 2: capture a DRAM command trace, audit it against the
timing model, and render the two-view HTML visualizer (bus utilization +
command trace + audit overlay).

    PYTHONPATH=src python examples/visualize_trace.py [standard]
"""
import sys

from repro.core import Simulator
from repro.trace import audit, capture, save, write_html

std, org, tim = {
    "DDR5": ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    "HBM3": ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    "LPDDR5": ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    "GDDR7": ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32"),
}[sys.argv[1] if len(sys.argv) > 1 else "LPDDR5"]

sim = Simulator(std, org, tim)
stats, dense = sim.run(3_000, interval=2.0, read_ratio=0.75, trace=True)
trace = capture(sim.cspec, dense, controller=sim.controller,
                frontend=sim.frontend)
report = audit(sim.cspec, trace)
npz = save(trace, f"results/{std.lower()}_trace.npz")
path = write_html(f"results/{std.lower()}_trace.html", trace, sim.cspec,
                  report, title=f"{std} command trace ({tim})")
print(f"{len(trace)} commands captured -> {npz}")
print(report.summary())
print(f"visualizer -> {path}")
print("open in a browser: zoom/offset sliders, hover for per-command info")
