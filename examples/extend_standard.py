"""Paper Listing 1, verbatim spirit: extend DDR5 with a Victim-Row-Refresh
(VRR) command + timing constraints in ~18 lines of user code, then verify
the new command's timing behavior on the device under test.

    PYTHONPATH=src python examples/extend_standard.py
"""
import math

from repro.core.spec import Command, TimingConstraint, KIND_ROW, register
from repro.core.standards.ddr5 import DDR5


# ---- the user extension (the paper's Listing 1) --------------------------
@register
class DDR5_VRR_Example(DDR5):
    name = "DDR5_VRR_Example"
    command_meta = dict(DDR5.command_meta, VRR=Command("VRR", "bank", KIND_ROW))
    commands = DDR5.commands + ["VRR"]
    timing_params = DDR5.timing_params + ["nVRR"]
    timing_constraints = DDR5.timing_constraints + [
        TimingConstraint(level="bank", preceding=["VRR"], following=["ACT"],
                         latency="nVRR"),
        TimingConstraint(level="bank", preceding=["ACT"], following=["VRR"],
                         latency="nRC"),
        TimingConstraint(level="rank", preceding=["PRE", "PREab"],
                         following=["VRR"], latency="nRP"),
    ]
    org_presets = DDR5.org_presets
    timing_presets = {}


for _name, _timings in DDR5.timing_presets.items():
    _vrr = dict(_timings)
    _vrr["nVRR"] = math.ceil(280_000 / _timings["tCK_ps"])
    DDR5_VRR_Example.timing_presets[_name] = _vrr
# ---- end extension --------------------------------------------------------


from repro.core import DeviceUnderTest  # noqa: E402

dut = DeviceUnderTest("DDR5_VRR_Example", "DDR5_16Gb_x8", "DDR5_4800B")
addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=5, Column=0)
print("nVRR =", dut.timings["nVRR"], "cycles")
dut.issue("VRR", addr, clk=0)
blocked = dut.probe("ACT", addr, clk=dut.timings["nVRR"] - 1)
legal = dut.probe("ACT", addr, clk=dut.timings["nVRR"])
print(f"ACT at nVRR-1: timing_OK={blocked.timing_OK}  (expect False)")
print(f"ACT at nVRR:   timing_OK={legal.timing_OK}  (expect True)")
assert not blocked.timing_OK and legal.timing_OK
print("VRR extension behaves correctly.")
