"""The workload->simulator bridge (DESIGN.md §3): estimate the *achieved*
HBM bandwidth for an LM training step's access pattern by replaying its
per-device byte traffic through the HBM3 model, refining the roofline
memory term.

    PYTHONPATH=src python examples/memsys_aware_roofline.py
"""
import glob
import json
import os

from repro.core import Simulator, throughput_gbps, peak_gbps

results = sorted(glob.glob("results/dryrun/*train_4k__16x16.json"))
if not results:
    print("run `PYTHONPATH=src python -m repro.launch.dryrun --all` first")
    raise SystemExit(0)

# HBM3 channel model as the per-chip memory system stand-in
sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200")

print(f"{'arch':32s} {'HLO bytes/dev':>14} {'naive t_mem':>12} "
      f"{'achieved-BW t_mem':>18}")
for f in results[:4]:
    rec = json.load(open(f))
    bytes_dev = rec["cost"]["bytes_accessed"]
    # streaming-dominant access pattern of a training step: high row
    # locality, ~2:1 read:write -> measure achieved BW at that mix
    stats = sim.run(20_000, interval=1.0, read_ratio=0.66)
    achieved = throughput_gbps(sim.cspec, stats) * 1e9
    peak = peak_gbps(sim.cspec) * 1e9
    hbm_bw = 819e9
    t_naive = bytes_dev / hbm_bw
    t_ach = bytes_dev / (hbm_bw * achieved / peak)
    print(f"{rec['arch']:32s} {bytes_dev:14.3e} {t_naive:12.4f}s "
          f"{t_ach:18.4f}s  (x{t_ach / t_naive:.2f})")
print("\nachieved/peak from the simulated latency-throughput knee "
      f"= {achieved / peak:.3f}")
