"""Quickstart: the paper's core artifact in ~40 lines.

1. configure a DDR5 system through the auto-generated Python proxies,
2. run a cycle-level simulation (jitted lax.scan engine),
3. probe fine-grained timing behavior (Listing-2 API),
4. dump the YAML equivalent of the configuration.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import DeviceUnderTest, throughput_gbps, peak_gbps, \
    avg_probe_latency_ns
from repro.core.proxy import PROXIES, System

# --- 1. configure via proxies (paper §3.1) --------------------------------
system = System(
    "DDR5", "DDR5_16Gb_x8", "DDR5_4800B",
    controller=PROXIES["Controller"](scheduler="FRFCFS", queue_depth=32),
    frontend=PROXIES["Frontend"](interval=2.0, read_ratio=0.8),
    n_cycles=20_000,
)
print("=== YAML equivalent (for non-Python embedders) ===")
print(system.to_yaml())

# --- 2. simulate ----------------------------------------------------------
# Stats.summary(spec) is the group-aware formatter (GB/s vs peak, probe
# latency in ns, row-hit rate); the raw helpers (throughput_gbps,
# peak_gbps, avg_probe_latency_ns) stay available for programmatic use —
# all of them take the Stats of ONE run (scalar fields; batched Stats
# from run_batch need repro.dse.results' *_array variants)
sim = system.build()
stats = sim.run(system.n_cycles)
print("\n=== simulation ===")
print(stats.summary(sim.cspec))
assert throughput_gbps(sim.cspec, stats) <= peak_gbps(sim.cspec)
assert avg_probe_latency_ns(sim.cspec, stats) > 0

# --- 3. fine-grained probing (paper Listing 2) ----------------------------
dut = DeviceUnderTest("DDR5", org_preset="DDR5_16Gb_x8",
                      timing_preset="DDR5_4800B")
addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=12, Column=0)
print("\n=== probe API ===")
print("RD on closed bank:", dut.probe("RD", addr, clk=0))
dut.issue("ACT", addr, clk=0)
print("RD before nRCD:  ", dut.probe("RD", addr, clk=dut.timings["nRCD"] - 1))
print("RD at nRCD:      ", dut.probe("RD", addr, clk=dut.timings["nRCD"]))
