"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_launcher

serve_launcher.main(["--arch", "llama3.2-1b", "--reduced",
                     "--batch", "4", "--prompt-len", "32",
                     "--max-new", "16"])
