"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline, with checkpoints and restart.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]

This exercises the full production path (data -> model -> loss -> AdamW ->
checkpoint manager -> fault-tolerant driver); on a TPU pod the same driver
runs under the production mesh via launch/train.py.
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.configs.base import register_arch
from repro.launch import train as train_launcher

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

# ~100M-param llama3-family config (public-shape: 12 x 512 x 8H, ff 2048)
base = get_arch("llama3.2-1b")
cfg100m = dataclasses.replace(
    base, name="llama3-100m", n_layers=16, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2816, head_dim=64, vocab=32_000,
    tie_embeddings=True)   # ~102M params
register_arch(cfg100m)
print(f"params: {cfg100m.param_count() / 1e6:.1f}M")

state, log = train_launcher.main([
    "--arch", "llama3-100m", "--steps", str(args.steps),
    "--seq", str(args.seq), "--batch", str(args.batch),
    "--lr", "6e-4", "--ckpt-dir", "results/ckpt_100m",
    "--ckpt-interval", "100",
])
first = sum(l["loss"] for l in log[:10]) / max(len(log[:10]), 1)
last = sum(l["loss"] for l in log[-10:]) / max(len(log[-10:]), 1)
print(f"loss: first10={first:.3f} last10={last:.3f} "
      f"({'improved' if last < first else 'NOT improved'})")
