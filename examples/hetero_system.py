"""Heterogeneous (CXL-style) memory system: DDR5 + CXL-attached DDR4
behind one address mapper.

Builds a 2-spec-group system — two native DDR5 channels plus two
CXL-attached DDR4 channels with 80 cycles of link latency each way —
runs it as ONE compiled `lax.scan` program, prints group-correct
metrics, audits the command trace per group, and sweeps the link
latency as a first-class DSE axis.

    PYTHONPATH=src python examples/hetero_system.py
"""
from repro.core import (Simulator, channel_breakdown, compile_system,
                        peak_gbps, throughput_gbps)
from repro.trace import audit, capture

N_CYCLES = 20_000


def main():
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    print(f"memory system: {msys.label} "
          f"({msys.n_channels} channels, {msys.n_groups} spec groups, "
          f"{len(msys.cmd_names)} merged commands)")

    sim = Simulator(system=msys)
    stats, dense = sim.run(N_CYCLES, interval=1.0, read_ratio=0.7,
                           trace=True)
    # Stats.summary is group-aware: per-group GB/s on each group's own
    # clock, per-channel rows labeled by the owning standard
    print("\n" + stats.summary(msys))
    assert throughput_gbps(msys, stats) <= peak_gbps(msys)
    assert len(channel_breakdown(msys, stats)) == msys.n_channels

    # per-group audit: each channel replays against its OWN constraint
    # table; DDR5 commands never constrain CXL-DDR4 commands
    tr = capture(msys, dense, controller=sim.controller,
                 frontend=sim.frontend)
    rep = audit(msys, tr)
    print(f"\naudit: {rep.summary()}")
    assert rep.ok

    # link latency as a DSE axis: sweep the CXL link from 40 to 320 cycles
    from repro.dse import Composition, SweepSpec, execute
    spec = SweepSpec(
        systems=tuple(Composition((("DDR5", 2), ("DDR4", 2, link)))
                      for link in (40, 80, 160, 320)),
        intervals=(8.0, 2.0), read_ratios=(1.0,), n_cycles=4_000)
    res = execute(spec)
    print("\nlink-latency sweep (probe latency is the CXL round trip):")
    print(res.to_table())


if __name__ == "__main__":
    main()
