"""Multi-channel memory system in one compiled program.

Builds a 4-channel HBM3 system, runs it under load, prints per-channel
and aggregate stats, captures a trace, audits it per channel, and then
drives a 2-channel DDR4 system with the trace-driven frontend
(replaying a synthetic linear-address stream decoded through the
2-channel mapper).

    PYTHONPATH=src python examples/multichannel.py
"""
import numpy as np

from repro.core import (FrontendConfig, ReplayStream, Simulator,
                        channel_breakdown, peak_gbps, throughput_gbps)
from repro.trace import audit, capture, to_replay

# -- 4-channel HBM3: one jax trace, per-channel + aggregate stats --------
quad = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=4,
                 mapper="RoBaRaCoCh")
stats, dense = quad.run(10_000, interval=0.5, read_ratio=0.9, trace=True)
print(f"aggregate: {throughput_gbps(quad.cspec, stats):.1f} GB/s of "
      f"{peak_gbps(quad.cspec):.1f} peak "
      f"({int(stats.reads_done)} reads, {int(stats.writes_done)} writes)")
for c, row in channel_breakdown(quad.cspec, stats).items():
    print(f"  ch{c}: {row['throughput_gbps']:6.1f} GB/s  "
          f"bus util {row['bus_util']:.2f}")

# -- per-channel audit ----------------------------------------------------
trace = capture(quad.cspec, dense, controller=quad.controller,
                frontend=quad.frontend)
report = audit(quad.cspec, trace)
print(report.summary())

# a capture replays directly on the SAME channel layout:
same_system_replay = to_replay(trace, quad.cspec)
print(f"derived {len(same_system_replay)}-request replay stream from the "
      "capture")

# -- trace-driven frontend on a different system -------------------------
# For a different channel count, decode a linear-address stream through
# the target system's own mapper instead of reusing captured channels.
ddr = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
rng = np.random.default_rng(0)
addrs = rng.integers(0, 1 << 28, 8_000).astype(np.int64) \
    * ddr.cspec.access_bytes
rs = ReplayStream.from_addresses(ddr.cspec, addrs,
                                 is_write=rng.random(8_000) < 0.3)
ddr_replay = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                       frontend=FrontendConfig(pattern="trace",
                                               probes=False),
                       replay=rs)
st = ddr_replay.run(10_000, interval=1.0)
print(f"replayed {int(st.reads_done)} reads / {int(st.writes_done)} "
      f"writes onto 2-channel DDR4; per-channel reads: "
      f"{st.per_channel.reads_done.tolist()}")
