"""Paper Table 1: lines-of-source-code per DRAM standard.

Counts non-blank, non-comment LOC of each Python standard in this repo and
compares against the C++ LOC of Ramulator 2.0 as reported by the paper.
The claim under reproduction: authoring standards in Python + codegen cuts
LOC by ~2/3 (66.3% total in the paper).
"""
from __future__ import annotations

import inspect

# Ramulator 2.0 C++ LOC, from the paper's Table 1
PAPER_V20_CPP = {
    "DDR3": 325, "DDR4": 354, "DDR5": 402, "GDDR6": 327, "HBM1": 287,
    "HBM2": 289, "LPDDR5": 395, "DDR4_VRR": 375, "DDR5_VRR": 445,
}
# paper's v2.1 Python LOC (for the comparison column)
PAPER_V21_PY = {
    "DDR3": 129, "DDR4": 161, "DDR5": 132, "GDDR6": 199, "HBM1": 133,
    "HBM2": 146, "LPDDR5": 143, "DDR4_VRR": 18, "DDR5_VRR": 18,
}


def count_loc(obj) -> int:
    src = inspect.getsource(obj)
    return len([l for l in src.splitlines()
                if l.strip() and not l.strip().startswith("#")
                and not l.strip().startswith('"""')
                and not l.strip().startswith("'''")])


def table() -> list:
    from repro.core import get_standard
    from repro.core.standards import vrr

    rows = []
    for name in ("DDR3", "DDR4", "DDR5", "GDDR6", "HBM2", "LPDDR5"):
        ours = count_loc(get_standard(name))
        ref = PAPER_V20_CPP.get(name)
        rows.append((name, ref, PAPER_V21_PY.get(name), ours))
    # VRR variants: count only the extension body (_with_vrr), as the paper
    # counts only the 18 added lines
    vrr_loc = count_loc(vrr._with_vrr)
    for name in ("DDR4_VRR", "DDR5_VRR"):
        rows.append((name, PAPER_V20_CPP[name], PAPER_V21_PY[name], vrr_loc))
    return rows


def run(report):
    rows = table()
    tot_cpp = sum(r[1] for r in rows)
    tot_ours = sum(r[3] for r in rows)
    for name, cpp, paper_py, ours in rows:
        red = 100.0 * (1 - ours / cpp)
        report(f"loc_{name}", ours,
               f"v2.0_cpp={cpp} paper_v2.1_py={paper_py} reduction={red:.1f}%")
    report("loc_total_reduction_pct",
           round(100.0 * (1 - tot_ours / tot_cpp), 1),
           f"ours={tot_ours} vs v2.0_cpp={tot_cpp} (paper: 66.3%)")
