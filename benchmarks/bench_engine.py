"""Simulator-engine performance: cycles/second of the jitted lax.scan
engine vs the scalar python oracle, vmap DSE scaling, and channel-scaling
of the vmapped multi-channel memory system (the TPU-native payoff claimed
in DESIGN.md §2).

Emits ``BENCH_engine.json`` (scalar, batched, and channel-scaling
cycles/sec) so the performance trajectory is recorded run over run.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def run(report, n_cycles: int = 20_000, json_path: str = "BENCH_engine.json"):
    import jax
    from repro.core import DeviceUnderTest, Simulator, compile_spec
    from repro.core import device as D
    from repro.core.frontend import FrontendConfig

    results: dict = {"n_cycles": n_cycles}
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")

    # scan-carry footprint of the timing state: the split (dense last-issue
    # table + windowed ring) vs the old dense per-(node, cmd) ring baseline.
    # This is the cache-pressure number behind the channel-scaling curve.
    results["carry_bytes"] = {}
    for std, org, tim in (("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
                          ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
                          ("HBM3", "HBM3_16Gb", "HBM3_5200")):
        cs = compile_spec(std, org, tim)
        slim, dense = D.carry_nbytes(cs), D.dense_ring_nbytes(cs)
        results["carry_bytes"][std] = {
            "table_ring": slim, "dense_ring_baseline": dense,
            "reduction": round(dense / slim, 2)}
        report(f"carry_bytes_{std}", slim,
               f"per channel; dense-ring baseline {dense} "
               f"({dense / slim:.1f}x reduction)")

    # jitted engine, steady-state rate (exclude compile: the run cache
    # keys on n_cycles, so warm with the exact timed program)
    sim.run(n_cycles)
    t0 = time.perf_counter()
    sim.run(n_cycles)
    dt = time.perf_counter() - t0
    rate = n_cycles / dt
    report("engine_cycles_per_sec", int(rate), f"{n_cycles} cycles in {dt:.2f}s")
    results["scalar_cycles_per_sec"] = int(rate)

    # scalar oracle rate (issue/probe loop)
    dut = DeviceUnderTest("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=1, Column=0)
    n_oracle = 2_000
    t0 = time.perf_counter()
    clk = 0
    for i in range(n_oracle):
        r = dut.probe("RD", addr, clk=clk)
        if r.ready:
            dut.issue("RD", addr, clk=clk)
        elif dut.probe(r.preq, addr, clk=clk).timing_OK:
            dut.issue(r.preq, addr, clk=clk)
        clk += 2
    dt_o = time.perf_counter() - t0
    report("oracle_cycles_per_sec", int(2 * n_oracle / dt_o),
           "scalar numpy reference")

    # trace-capture overhead: the "high-performance" claim of the trace
    # subsystem, measured — trace=True cycles/sec vs the plain engine,
    # plus the dense->columnar compaction cost (repro.trace.capture)
    from repro.trace.capture import capture
    # warm the exact timed program: the run cache keys on n_cycles, so a
    # short warm-up run would leave compile time inside the measurement
    sim.run(n_cycles, trace=True)
    t0 = time.perf_counter()
    _, dense = sim.run(n_cycles, trace=True)
    dt_t = time.perf_counter() - t0
    report("engine_trace_cycles_per_sec", int(n_cycles / dt_t),
           f"trace=True; {100 * (dt_t - dt) / dt:+.0f}% vs trace=False")
    results["trace_cycles_per_sec"] = int(n_cycles / dt_t)
    t0 = time.perf_counter()
    tr = capture(sim.cspec, dense)
    dt_c = time.perf_counter() - t0
    report("trace_capture_ms", round(1e3 * dt_c, 2),
           f"{len(tr)} commands compacted from {n_cycles}x2 dense cells")

    # vmap DSE scaling: N configs in one compiled program.  The first call
    # per batch shape is compile-dominated (recorded as wall_s /
    # config_cycles_per_sec, the historical trajectory fields); the warm
    # re-run isolates steady-state execution throughput.
    results["batched"] = {}
    for n_pts in (1, 8, 32):
        intervals = [1.0 + 0.5 * i for i in range(n_pts)]
        t0 = time.perf_counter()
        sim.run_batch(4_000, intervals, [1.0])
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim.run_batch(4_000, intervals, [1.0])
        dt_warm = time.perf_counter() - t0
        report(f"dse_batch_{n_pts}_configs_s", round(dt, 2),
               f"{n_pts * 4_000} simulated cycles total "
               f"({n_pts * 4_000 / dt:,.0f} config-cycles/s incl compile; "
               f"{n_pts * 4_000 / dt_warm:,.0f} warm)")
        results["batched"][str(n_pts)] = {
            "wall_s": round(dt, 3),
            "config_cycles_per_sec": int(n_pts * 4_000 / dt),
            "warm_config_cycles_per_sec": int(n_pts * 4_000 / dt_warm)}

    # channel scaling: C vmapped per-channel controllers inside one scan,
    # batched over 8 load points — aggregate simulated channel-cycles/sec
    # as the channel axis widens.  This is the new multi-channel benchmark
    # scenario.  Measurement is interleaved best-of-N: per-run wall times
    # on small shared CPUs swing 2x run-to-run, so each channel count's
    # best of several alternating timed runs is recorded.
    bcycles = max(n_cycles // 5, 2_000)
    b_intervals = [1.0 + 0.5 * i for i in range(8)]
    chans = (1, 2, 4)
    sims = {}
    for c in chans:
        sims[c] = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=c,
                            frontend=FrontendConfig(probes=False))
        sims[c].run_batch(bcycles, b_intervals, [1.0])    # warm the program
    best = {c: float("inf") for c in chans}
    for _ in range(3):
        for c in chans:
            t0 = time.perf_counter()
            sims[c].run_batch(bcycles, b_intervals, [1.0])
            best[c] = min(best[c], time.perf_counter() - t0)
    results["channel_scaling"] = {}
    for c in chans:
        agg = len(b_intervals) * bcycles * c / best[c]
        report(f"channel_scaling_{c}ch_cycles_per_sec", int(agg),
               f"{len(b_intervals)} load points x {bcycles} cycles x "
               f"{c} channels in {best[c]:.2f}s (batched, best of 3)")
        results["channel_scaling"][str(c)] = {
            "wall_s": round(best[c], 3),
            "aggregate_channel_cycles_per_sec": int(agg),
            "carry_bytes_per_channel": D.carry_nbytes(sims[c].cspec)}
    # explicit per-entry speedup vs the 1-channel run of the SAME box/run
    # (reviewers previously re-derived this by hand from the raw rates)
    agg1 = results["channel_scaling"]["1"]["aggregate_channel_cycles_per_sec"]
    for c in chans:
        entry = results["channel_scaling"][str(c)]
        entry["aggregate_speedup"] = round(
            entry["aggregate_channel_cycles_per_sec"] / max(agg1, 1), 3)

    # windowed-telemetry overhead: the tentpole's "low-overhead" claim,
    # measured — scalar 4-channel engine with telemetry window=256 vs
    # telemetry off, end to end (in-scan accumulators + snapshot emission
    # + host-side window diffing), on warm programs.  Shared boxes have
    # multi-second load phases that swing single runs +-20%, so mean- or
    # median-based estimators are unreliable; the floor (min over many
    # interleaved runs) of each side IS stable, so the reported overhead
    # is the ratio of interleaved minima.  The cycle count is fixed (not
    # scaled by --quick): short runs make the per-call fixed cost (extra
    # dispatch + host window diffing, ~10ms) masquerade as per-cycle
    # overhead, and long runs are what windowed telemetry is for.
    # The committed ceiling is what tools/check_bench_regression.py gates.
    tw, tn = 256, 60_000
    tsim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    tsim.run(tn)                                   # warm telemetry-off
    tsim.run(tn, telemetry=tw)                     # warm telemetry-on
    rounds = 8
    t_min = {0: float("inf"), tw: float("inf")}
    for _ in range(rounds):
        for tel in (0, tw):
            t0 = time.perf_counter()
            tsim.run(tn, telemetry=tel)
            t_min[tel] = min(t_min[tel], time.perf_counter() - t0)
    overhead = t_min[tw] / t_min[0] - 1.0
    report("telemetry_overhead_pct", round(100 * overhead, 2),
           f"4ch DDR4, window={tw}, {tn} cycles: floor {t_min[tw]:.3f}s on"
           f" vs {t_min[0]:.3f}s off (interleaved min of {rounds})")
    results["telemetry"] = {
        "window": tw, "channels": 4, "cycles": tn, "rounds": rounds,
        "off_wall_s": round(t_min[0], 4), "on_wall_s": round(t_min[tw], 4),
        "overhead": round(overhead, 4)}
    #: the CI gate: windowed capture may cost at most 5% engine slowdown
    results["telemetry_overhead_ceiling"] = 0.05
    # heterogeneous composition: DDR5x2 + CXL-attached DDR4x2 (link 80)
    # behind one mapper — the 2-spec-group scenario of the hetero-smoke CI
    # job, measured the same interleaved best-of-N way and recorded so
    # future PRs gate on it (tools/check_bench_regression.py).
    from repro.core import compile_system
    hsys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    hsim = Simulator(system=hsys, frontend=FrontendConfig(probes=False))
    hsim.run_batch(bcycles, b_intervals, [1.0])          # warm the program
    best_h = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        hsim.run_batch(bcycles, b_intervals, [1.0])
        best_h = min(best_h, time.perf_counter() - t0)
    h_agg = len(b_intervals) * bcycles * hsys.n_channels / best_h
    homo4 = results["channel_scaling"]["4"][
        "aggregate_channel_cycles_per_sec"]
    h_ratio = h_agg / max(homo4, 1)
    report("hetero_2grp_cycles_per_sec", int(h_agg),
           f"{hsys.label}: {len(b_intervals)} load points x {bcycles} "
           f"cycles x {hsys.n_channels} channels in {best_h:.2f}s "
           f"({100 * h_ratio:.0f}% of the homogeneous 4ch rate)")
    results["hetero"] = {
        "label": hsys.label,
        "wall_s": round(best_h, 3),
        "aggregate_channel_cycles_per_sec": int(h_agg),
        "vs_4ch_homogeneous": round(h_ratio, 3),
    }
    # noise-padded floor for the gate: the 2-group engine may never fall
    # below half its merge-time rate relative to the homogeneous 4ch run
    results["hetero_floor_vs_4ch"] = round(0.5 * h_ratio, 3)

    # event-horizon fast-forward: wall-clock ratio of the same low-rate
    # workload with fast-forward on vs off.  interval=64 sits well below
    # 20% of DDR4-2400 saturation, the regime every latency-throughput
    # sweep spends half its points in — mostly idle cycles the horizon
    # stepper skips in closed form.  Both sides are warm programs on the
    # same box measured as interleaved minima (the only stable estimator
    # on shared runners, same rationale as the telemetry ratio above),
    # and the ratio is what tools/check_bench_regression.py gates.
    ff_n, ff_interval, ff_rounds = 60_000, 64.0, 6
    fsim = {
        True: Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                        fast_forward=True),
        False: Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                         fast_forward=False),
    }
    ff_stats = {}
    for ff, s in fsim.items():
        ff_stats[ff] = s.run(ff_n, interval=ff_interval)       # warm
    ff_min = {True: float("inf"), False: float("inf")}
    for _ in range(ff_rounds):
        for ff, s in fsim.items():
            t0 = time.perf_counter()
            jax.block_until_ready(s.run(ff_n, interval=ff_interval))
            ff_min[ff] = min(ff_min[ff], time.perf_counter() - t0)
    ff_speedup = ff_min[False] / max(ff_min[True], 1e-9)
    skipped = int(ff_stats[True].skipped_cycles)
    report("fast_forward_speedup", round(ff_speedup, 2),
           f"interval={ff_interval}, {ff_n} cycles: "
           f"{ff_min[False]:.3f}s off vs {ff_min[True]:.3f}s on "
           f"({100 * skipped / ff_n:.0f}% cycles skipped, "
           f"{int(ff_stats[True].scan_steps)} scan steps)")
    results["fast_forward"] = {
        "interval": ff_interval, "cycles": ff_n, "rounds": ff_rounds,
        "off_wall_s": round(ff_min[False], 4),
        "on_wall_s": round(ff_min[True], 4),
        "skipped_cycles": skipped,
        "scan_steps": int(ff_stats[True].scan_steps),
        "idle_fraction": round(skipped / ff_n, 4),
        "speedup": round(ff_speedup, 3)}
    # noise-padded merge-time floor for the CI gate (same pattern as the
    # hetero floor: half this box's measured ratio)
    results["fast_forward_speedup_floor"] = round(0.5 * ff_speedup, 3)

    # scale-out: the channel-sharded engine (shard_map over the channel
    # mesh) and the device-sharded sweep, at forced host device counts
    # {1, 4}.  XLA fixes the device count at backend init, so each
    # measurement is a subprocess that pins XLA_FLAGS before importing
    # jax (this file's --scale-probe entry point).  The ratios compare
    # the SAME workloads across the two device counts on the same box.
    probe = {}
    here = os.path.abspath(__file__)
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    src = os.path.join(os.path.dirname(os.path.dirname(here)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    for ndev in (1, 4):
        r = subprocess.run(
            [sys.executable, here, "--scale-probe", "--devices", str(ndev),
             "--cycles", str(n_cycles), "--points", "64"],
            env=env, capture_output=True, text=True, timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"scale probe (devices={ndev}) failed:\n"
                               + r.stderr[-2000:])
        probe[ndev] = json.loads(r.stdout.strip().splitlines()[-1])
    ch1, ch4 = probe[1]["channel"], probe[4]["channel"]
    sw1, sw4 = probe[1]["sweep"], probe[4]["sweep"]
    ch_speedup = (ch4["aggregate_channel_cycles_per_sec"]
                  / max(ch1["aggregate_channel_cycles_per_sec"], 1))
    sw_speedup = sw1["wall_s"] / max(sw4["wall_s"], 1e-9)
    results["channel_scaling_sharded"] = {
        "1": ch1, "4": ch4, "speedup_1_to_4": round(ch_speedup, 3)}
    results["sweep_scaling"] = {
        "points": sw1["points"], "1": sw1, "4": sw4,
        "speedup_1_to_4": round(sw_speedup, 3)}
    report("channel_scaling_sharded_speedup_1_to_4", round(ch_speedup, 2),
           f"4ch scalar engine, shard_map d={ch4['shard']} vs "
           f"single-device vmap ({ch4['wall_s']}s vs {ch1['wall_s']}s)")
    report("sweep_scaling_speedup_1_to_4", round(sw_speedup, 2),
           f"{sw1['points']}-point sweep, 4 forced host devices vs 1 "
           f"({sw4['wall_s']}s vs {sw1['wall_s']}s)")
    # merge-time floors for the CI gate: forced host devices on a small
    # runner time-slice one physical core rather than parallelize, so the
    # floor is a noise-padded capture of THIS box's measured ratio (the
    # same pattern as speedup_floor_1_to_4 below) — on real multi-core
    # boxes the recorded speedups, and hence the floors, rise with the
    # hardware that measured them
    results["sharded_speedup_floor_1_to_4"] = round(0.75 * ch_speedup, 3)
    results["sweep_speedup_floor_1_to_4"] = round(0.75 * sw_speedup, 3)

    cs = results["channel_scaling"]
    for hi in (2, 4):
        speedup = (cs[str(hi)]["aggregate_channel_cycles_per_sec"]
                   / max(cs["1"]["aggregate_channel_cycles_per_sec"], 1))
        report(f"channel_scaling_speedup_1_to_{hi}", round(speedup, 2),
               f"aggregate simulated-cycles/sec, {hi}ch vs 1ch")
        results[f"channel_scaling_speedup_1_to_{hi}"] = round(speedup, 3)
    # the regression floor the bench-smoke CI job enforces on future runs:
    # the 1->4 speedup may never drop below a noise-padded floor of the
    # speedups recorded at merge time (capped by the 1->2 speedup — the
    # cliff PR 3 measured was 4ch falling far below the 2ch trend)
    s12 = results["channel_scaling_speedup_1_to_2"]
    s14 = results["channel_scaling_speedup_1_to_4"]
    results["speedup_floor_1_to_4"] = round(0.75 * min(s12, s14), 3)

    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)
    report("bench_engine_json", json_path, "perf trajectory artifact")


def _scale_probe(n_devices: int, n_cycles: int, n_points: int) -> dict:
    """Runs in a subprocess (one per forced device count): measure the
    channel-sharded scalar engine and the device-sharded streamed sweep
    under exactly ``n_devices`` host devices.  Must only be called after
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is pinned
    (the ``--scale-probe`` entry point does)."""
    import jax
    from repro.core import Simulator
    from repro.core import engine as E
    from repro.core.frontend import FrontendConfig
    from repro.dse import SweepSpec, execute

    assert jax.device_count() == n_devices, jax.device_count()
    out = {"devices": n_devices}

    # channel axis: a 4-channel scalar run.  With >1 device the channel
    # axis auto-shards over the mesh (shard_map, d=4); with 1 device the
    # same workload stays on the vmap path — the gate's aggregate-speedup
    # ratio compares exactly these two placements.
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4,
                    frontend=FrontendConfig(probes=False))
    shard = sim._resolved_shard()
    jax.block_until_ready(sim.run(n_cycles))            # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(sim.run(n_cycles))
        best = min(best, time.perf_counter() - t0)
    out["channel"] = {
        "channels": 4, "shard": int(shard) if shard else 0,
        "wall_s": round(best, 4),
        "aggregate_channel_cycles_per_sec": int(4 * n_cycles / best)}

    # sweep axis: one compile group, ``n_points`` load points sharded
    # across the device mesh with donated carries + streamed collection
    spec = SweepSpec(
        systems=("DDR4",),
        intervals=tuple(1.0 + 0.5 * i for i in range(n_points // 4)),
        read_ratios=(1.0, 0.9, 0.8, 0.7),
        n_cycles=max(n_cycles // 5, 2_000))
    cache = E.RunCache()
    execute(spec, cache=cache)                          # compile + warm
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        execute(spec, cache=cache)
        best = min(best, time.perf_counter() - t0)
    out["sweep"] = {"points": spec.n_points, "wall_s": round(best, 4)}
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale-probe", action="store_true",
                    help="subprocess mode: measure under a forced host "
                         "device count and print one JSON line")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--cycles", type=int, default=20_000)
    ap.add_argument("--points", type=int, default=64)
    a = ap.parse_args()
    if a.scale_probe:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={a.devices}")
        print(json.dumps(_scale_probe(a.devices, a.cycles, a.points)))
    else:
        run(lambda name, value, derived="":
            print(f"{name},{value},{derived}", flush=True))
