"""Simulator-engine performance: cycles/second of the jitted lax.scan
engine vs the scalar python oracle, vmap DSE scaling, and channel-scaling
of the vmapped multi-channel memory system (the TPU-native payoff claimed
in DESIGN.md §2).

Emits ``BENCH_engine.json`` (scalar, batched, and channel-scaling
cycles/sec) so the performance trajectory is recorded run over run.
"""
from __future__ import annotations

import json
import time


def run(report, n_cycles: int = 20_000, json_path: str = "BENCH_engine.json"):
    import jax
    from repro.core import DeviceUnderTest, Simulator, compile_spec
    from repro.core import device as D
    from repro.core.frontend import FrontendConfig

    results: dict = {"n_cycles": n_cycles}
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")

    # scan-carry footprint of the timing state: the split (dense last-issue
    # table + windowed ring) vs the old dense per-(node, cmd) ring baseline.
    # This is the cache-pressure number behind the channel-scaling curve.
    results["carry_bytes"] = {}
    for std, org, tim in (("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
                          ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
                          ("HBM3", "HBM3_16Gb", "HBM3_5200")):
        cs = compile_spec(std, org, tim)
        slim, dense = D.carry_nbytes(cs), D.dense_ring_nbytes(cs)
        results["carry_bytes"][std] = {
            "table_ring": slim, "dense_ring_baseline": dense,
            "reduction": round(dense / slim, 2)}
        report(f"carry_bytes_{std}", slim,
               f"per channel; dense-ring baseline {dense} "
               f"({dense / slim:.1f}x reduction)")

    # jitted engine, steady-state rate (exclude compile: the run cache
    # keys on n_cycles, so warm with the exact timed program)
    sim.run(n_cycles)
    t0 = time.perf_counter()
    sim.run(n_cycles)
    dt = time.perf_counter() - t0
    rate = n_cycles / dt
    report("engine_cycles_per_sec", int(rate), f"{n_cycles} cycles in {dt:.2f}s")
    results["scalar_cycles_per_sec"] = int(rate)

    # scalar oracle rate (issue/probe loop)
    dut = DeviceUnderTest("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=1, Column=0)
    n_oracle = 2_000
    t0 = time.perf_counter()
    clk = 0
    for i in range(n_oracle):
        r = dut.probe("RD", addr, clk=clk)
        if r.ready:
            dut.issue("RD", addr, clk=clk)
        elif dut.probe(r.preq, addr, clk=clk).timing_OK:
            dut.issue(r.preq, addr, clk=clk)
        clk += 2
    dt_o = time.perf_counter() - t0
    report("oracle_cycles_per_sec", int(2 * n_oracle / dt_o),
           "scalar numpy reference")

    # trace-capture overhead: the "high-performance" claim of the trace
    # subsystem, measured — trace=True cycles/sec vs the plain engine,
    # plus the dense->columnar compaction cost (repro.trace.capture)
    from repro.trace.capture import capture
    # warm the exact timed program: the run cache keys on n_cycles, so a
    # short warm-up run would leave compile time inside the measurement
    sim.run(n_cycles, trace=True)
    t0 = time.perf_counter()
    _, dense = sim.run(n_cycles, trace=True)
    dt_t = time.perf_counter() - t0
    report("engine_trace_cycles_per_sec", int(n_cycles / dt_t),
           f"trace=True; {100 * (dt_t - dt) / dt:+.0f}% vs trace=False")
    results["trace_cycles_per_sec"] = int(n_cycles / dt_t)
    t0 = time.perf_counter()
    tr = capture(sim.cspec, dense)
    dt_c = time.perf_counter() - t0
    report("trace_capture_ms", round(1e3 * dt_c, 2),
           f"{len(tr)} commands compacted from {n_cycles}x2 dense cells")

    # vmap DSE scaling: N configs in one compiled program.  The first call
    # per batch shape is compile-dominated (recorded as wall_s /
    # config_cycles_per_sec, the historical trajectory fields); the warm
    # re-run isolates steady-state execution throughput.
    results["batched"] = {}
    for n_pts in (1, 8, 32):
        intervals = [1.0 + 0.5 * i for i in range(n_pts)]
        t0 = time.perf_counter()
        sim.run_batch(4_000, intervals, [1.0])
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim.run_batch(4_000, intervals, [1.0])
        dt_warm = time.perf_counter() - t0
        report(f"dse_batch_{n_pts}_configs_s", round(dt, 2),
               f"{n_pts * 4_000} simulated cycles total "
               f"({n_pts * 4_000 / dt:,.0f} config-cycles/s incl compile; "
               f"{n_pts * 4_000 / dt_warm:,.0f} warm)")
        results["batched"][str(n_pts)] = {
            "wall_s": round(dt, 3),
            "config_cycles_per_sec": int(n_pts * 4_000 / dt),
            "warm_config_cycles_per_sec": int(n_pts * 4_000 / dt_warm)}

    # channel scaling: C vmapped per-channel controllers inside one scan,
    # batched over 8 load points — aggregate simulated channel-cycles/sec
    # as the channel axis widens.  This is the new multi-channel benchmark
    # scenario.  Measurement is interleaved best-of-N: per-run wall times
    # on small shared CPUs swing 2x run-to-run, so each channel count's
    # best of several alternating timed runs is recorded.
    bcycles = max(n_cycles // 5, 2_000)
    b_intervals = [1.0 + 0.5 * i for i in range(8)]
    chans = (1, 2, 4)
    sims = {}
    for c in chans:
        sims[c] = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=c,
                            frontend=FrontendConfig(probes=False))
        sims[c].run_batch(bcycles, b_intervals, [1.0])    # warm the program
    best = {c: float("inf") for c in chans}
    for _ in range(3):
        for c in chans:
            t0 = time.perf_counter()
            sims[c].run_batch(bcycles, b_intervals, [1.0])
            best[c] = min(best[c], time.perf_counter() - t0)
    results["channel_scaling"] = {}
    for c in chans:
        agg = len(b_intervals) * bcycles * c / best[c]
        report(f"channel_scaling_{c}ch_cycles_per_sec", int(agg),
               f"{len(b_intervals)} load points x {bcycles} cycles x "
               f"{c} channels in {best[c]:.2f}s (batched, best of 3)")
        results["channel_scaling"][str(c)] = {
            "wall_s": round(best[c], 3),
            "aggregate_channel_cycles_per_sec": int(agg),
            "carry_bytes_per_channel": D.carry_nbytes(sims[c].cspec)}
    # explicit per-entry speedup vs the 1-channel run of the SAME box/run
    # (reviewers previously re-derived this by hand from the raw rates)
    agg1 = results["channel_scaling"]["1"]["aggregate_channel_cycles_per_sec"]
    for c in chans:
        entry = results["channel_scaling"][str(c)]
        entry["aggregate_speedup"] = round(
            entry["aggregate_channel_cycles_per_sec"] / max(agg1, 1), 3)

    # windowed-telemetry overhead: the tentpole's "low-overhead" claim,
    # measured — scalar 4-channel engine with telemetry window=256 vs
    # telemetry off, end to end (in-scan accumulators + snapshot emission
    # + host-side window diffing), on warm programs.  Shared boxes have
    # multi-second load phases that swing single runs +-20%, so mean- or
    # median-based estimators are unreliable; the floor (min over many
    # interleaved runs) of each side IS stable, so the reported overhead
    # is the ratio of interleaved minima.  The cycle count is fixed (not
    # scaled by --quick): short runs make the per-call fixed cost (extra
    # dispatch + host window diffing, ~10ms) masquerade as per-cycle
    # overhead, and long runs are what windowed telemetry is for.
    # The committed ceiling is what tools/check_bench_regression.py gates.
    tw, tn = 256, 60_000
    tsim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    tsim.run(tn)                                   # warm telemetry-off
    tsim.run(tn, telemetry=tw)                     # warm telemetry-on
    rounds = 8
    t_min = {0: float("inf"), tw: float("inf")}
    for _ in range(rounds):
        for tel in (0, tw):
            t0 = time.perf_counter()
            tsim.run(tn, telemetry=tel)
            t_min[tel] = min(t_min[tel], time.perf_counter() - t0)
    overhead = t_min[tw] / t_min[0] - 1.0
    report("telemetry_overhead_pct", round(100 * overhead, 2),
           f"4ch DDR4, window={tw}, {tn} cycles: floor {t_min[tw]:.3f}s on"
           f" vs {t_min[0]:.3f}s off (interleaved min of {rounds})")
    results["telemetry"] = {
        "window": tw, "channels": 4, "cycles": tn, "rounds": rounds,
        "off_wall_s": round(t_min[0], 4), "on_wall_s": round(t_min[tw], 4),
        "overhead": round(overhead, 4)}
    #: the CI gate: windowed capture may cost at most 5% engine slowdown
    results["telemetry_overhead_ceiling"] = 0.05
    # heterogeneous composition: DDR5x2 + CXL-attached DDR4x2 (link 80)
    # behind one mapper — the 2-spec-group scenario of the hetero-smoke CI
    # job, measured the same interleaved best-of-N way and recorded so
    # future PRs gate on it (tools/check_bench_regression.py).
    from repro.core import compile_system
    hsys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    hsim = Simulator(system=hsys, frontend=FrontendConfig(probes=False))
    hsim.run_batch(bcycles, b_intervals, [1.0])          # warm the program
    best_h = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        hsim.run_batch(bcycles, b_intervals, [1.0])
        best_h = min(best_h, time.perf_counter() - t0)
    h_agg = len(b_intervals) * bcycles * hsys.n_channels / best_h
    homo4 = results["channel_scaling"]["4"][
        "aggregate_channel_cycles_per_sec"]
    h_ratio = h_agg / max(homo4, 1)
    report("hetero_2grp_cycles_per_sec", int(h_agg),
           f"{hsys.label}: {len(b_intervals)} load points x {bcycles} "
           f"cycles x {hsys.n_channels} channels in {best_h:.2f}s "
           f"({100 * h_ratio:.0f}% of the homogeneous 4ch rate)")
    results["hetero"] = {
        "label": hsys.label,
        "wall_s": round(best_h, 3),
        "aggregate_channel_cycles_per_sec": int(h_agg),
        "vs_4ch_homogeneous": round(h_ratio, 3),
    }
    # noise-padded floor for the gate: the 2-group engine may never fall
    # below half its merge-time rate relative to the homogeneous 4ch run
    results["hetero_floor_vs_4ch"] = round(0.5 * h_ratio, 3)

    cs = results["channel_scaling"]
    for hi in (2, 4):
        speedup = (cs[str(hi)]["aggregate_channel_cycles_per_sec"]
                   / max(cs["1"]["aggregate_channel_cycles_per_sec"], 1))
        report(f"channel_scaling_speedup_1_to_{hi}", round(speedup, 2),
               f"aggregate simulated-cycles/sec, {hi}ch vs 1ch")
        results[f"channel_scaling_speedup_1_to_{hi}"] = round(speedup, 3)
    # the regression floor the bench-smoke CI job enforces on future runs:
    # the 1->4 speedup may never drop below a noise-padded floor of the
    # speedups recorded at merge time (capped by the 1->2 speedup — the
    # cliff PR 3 measured was 4ch falling far below the 2ch trend)
    s12 = results["channel_scaling_speedup_1_to_2"]
    s14 = results["channel_scaling_speedup_1_to_4"]
    results["speedup_floor_1_to_4"] = round(0.75 * min(s12, s14), 3)

    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)
    report("bench_engine_json", json_path, "perf trajectory artifact")
