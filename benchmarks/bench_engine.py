"""Simulator-engine performance: cycles/second of the jitted lax.scan
engine vs the scalar python oracle, and vmap DSE scaling (the TPU-native
payoff claimed in DESIGN.md §2)."""
from __future__ import annotations

import time


def run(report, n_cycles: int = 20_000):
    import jax
    from repro.core import DeviceUnderTest, Simulator
    from repro.core import device as D
    from repro.core.frontend import FrontendConfig

    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")

    # jitted engine, steady-state rate (exclude compile: the run cache
    # keys on n_cycles, so warm with the exact timed program)
    sim.run(n_cycles)
    t0 = time.perf_counter()
    sim.run(n_cycles)
    dt = time.perf_counter() - t0
    rate = n_cycles / dt
    report("engine_cycles_per_sec", int(rate), f"{n_cycles} cycles in {dt:.2f}s")

    # scalar oracle rate (issue/probe loop)
    dut = DeviceUnderTest("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=1, Column=0)
    n_oracle = 2_000
    t0 = time.perf_counter()
    clk = 0
    for i in range(n_oracle):
        r = dut.probe("RD", addr, clk=clk)
        if r.ready:
            dut.issue("RD", addr, clk=clk)
        elif dut.probe(r.preq, addr, clk=clk).timing_OK:
            dut.issue(r.preq, addr, clk=clk)
        clk += 2
    dt_o = time.perf_counter() - t0
    report("oracle_cycles_per_sec", int(2 * n_oracle / dt_o),
           "scalar numpy reference")

    # trace-capture overhead: the "high-performance" claim of the trace
    # subsystem, measured — trace=True cycles/sec vs the plain engine,
    # plus the dense->columnar compaction cost (repro.trace.capture)
    from repro.trace.capture import capture
    # warm the exact timed program: the run cache keys on n_cycles, so a
    # short warm-up run would leave compile time inside the measurement
    sim.run(n_cycles, trace=True)
    t0 = time.perf_counter()
    _, dense = sim.run(n_cycles, trace=True)
    dt_t = time.perf_counter() - t0
    report("engine_trace_cycles_per_sec", int(n_cycles / dt_t),
           f"trace=True; {100 * (dt_t - dt) / dt:+.0f}% vs trace=False")
    t0 = time.perf_counter()
    tr = capture(sim.cspec, dense)
    dt_c = time.perf_counter() - t0
    report("trace_capture_ms", round(1e3 * dt_c, 2),
           f"{len(tr)} commands compacted from {n_cycles}x2 dense cells")

    # vmap DSE scaling: N configs in one compiled program
    for n_pts in (1, 8, 32):
        intervals = [1.0 + 0.5 * i for i in range(n_pts)]
        t0 = time.perf_counter()
        sim.run_batch(4_000, intervals, [1.0])
        dt = time.perf_counter() - t0
        report(f"dse_batch_{n_pts}_configs_s", round(dt, 2),
               f"{n_pts * 4_000} simulated cycles total "
               f"({n_pts * 4_000 / dt:,.0f} config-cycles/s)")
