"""Paper Fig. 1: latency-throughput curves for every modeled DRAM standard.

For each standard: sweep the streaming interval (load) at several read
ratios; record average random-probe latency vs achieved throughput.  The
validation criteria from the paper: (1) achieved throughput reaches the
theoretical peak, (2) the curve follows the knee shape.  Writes the full
curve data to results/latency_throughput.csv.
"""
from __future__ import annotations

import os

STANDARDS = [
    ("DDR3", "DDR3_8Gb_x8", "DDR3_1600K"),
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    ("LPDDR6", "LPDDR6_16Gb_x16", "LPDDR6_8533"),
    ("GDDR6", "GDDR6_8Gb_x16", "GDDR6_16"),
    ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32"),
    ("HBM2", "HBM2_8Gb", "HBM2_2Gbps"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    ("HBM4", "HBM4_24Gb", "HBM4_8000"),
    ("DDR5_VRR", "DDR5_16Gb_x8", "DDR5_4800B"),
]

INTERVALS = [64.0, 16.0, 8.0, 4.0, 2.0, 1.0]
READ_RATIOS = [1.0, 0.8, 0.5]


def run(report, n_cycles: int = 20_000, out_csv: str = "results/latency_throughput.csv"):
    from repro.core import (Simulator, avg_probe_latency_ns, peak_gbps,
                            throughput_gbps)
    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    rows = ["standard,read_ratio,interval,throughput_gbps,latency_ns,peak_gbps"]
    for std, org, tim in STANDARDS:
        sim = Simulator(std, org, tim)
        pk = peak_gbps(sim.cspec)
        best = 0.0
        knee_ok = True
        lat0 = latN = None
        for rr in READ_RATIOS:
            pts, batch = sim.run_batch(n_cycles, INTERVALS, [rr])
            import jax
            for i, (interval, _) in enumerate(pts):
                st = jax.tree.map(lambda a: a[i], batch)
                tp = throughput_gbps(sim.cspec, st)
                lat = avg_probe_latency_ns(sim.cspec, st)
                rows.append(f"{std},{rr},{interval},{tp:.3f},{lat:.1f},{pk:.3f}")
                best = max(best, tp)
                if rr == 1.0 and interval == INTERVALS[0]:
                    lat0 = lat
                if rr == 1.0 and interval == INTERVALS[-1]:
                    latN = lat
        frac = best / pk
        knee = latN / lat0 if lat0 else float("nan")
        report(f"latency_throughput_{std}", round(frac, 3),
               f"peak_frac={frac:.3f} knee_lat_ratio={knee:.2f} "
               f"peak={pk:.1f}GB/s")
    with open(out_csv, "w") as f:
        f.write("\n".join(rows) + "\n")
    report("latency_throughput_csv", len(rows) - 1, out_csv)
