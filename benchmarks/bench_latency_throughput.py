"""Paper Fig. 1: latency-throughput curves for every modeled DRAM standard.

One declarative `repro.dse` sweep covers all standards x intervals x read
ratios; each standard compiles exactly once and vmaps its whole load grid.
The validation criteria from the paper: (1) achieved throughput reaches
the theoretical peak, (2) the curve follows the knee shape.  Writes the
full curve data to results/latency_throughput.csv plus the binary sweep
artifact results/latency_throughput.{npz,json}.
"""
from __future__ import annotations

import os

STANDARDS = [
    ("DDR3", "DDR3_8Gb_x8", "DDR3_1600K"),
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    ("LPDDR6", "LPDDR6_16Gb_x16", "LPDDR6_8533"),
    ("GDDR6", "GDDR6_8Gb_x16", "GDDR6_16"),
    ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32"),
    ("HBM2", "HBM2_8Gb", "HBM2_2Gbps"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    ("HBM4", "HBM4_24Gb", "HBM4_8000"),
    ("DDR5_VRR", "DDR5_16Gb_x8", "DDR5_4800B"),
]

INTERVALS = (64.0, 16.0, 8.0, 4.0, 2.0, 1.0)
READ_RATIOS = (1.0, 0.8, 0.5)


def run(report, n_cycles: int = 20_000,
        out_csv: str = "results/latency_throughput.csv"):
    from repro.dse import SweepSpec, execute

    spec = SweepSpec(systems=tuple(STANDARDS), intervals=INTERVALS,
                     read_ratios=READ_RATIOS, n_cycles=n_cycles)
    result = execute(spec)

    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    # skipped_frac shows where event-horizon fast-forward is active along
    # each curve: ~high on the low-load half, ->0 toward saturation
    rows = ["standard,read_ratio,interval,throughput_gbps,latency_ns,"
            "peak_gbps,skipped_frac"]
    for i, pt in enumerate(result.points):
        sk = result.skipped_cycles[i] / max(result.cycles[i], 1)
        rows.append(f"{pt.system.standard},{pt.read_ratio},{pt.interval},"
                    f"{result.throughput_gbps[i]:.3f},"
                    f"{result.latency_ns[i]:.1f},{result.peak_gbps[i]:.3f},"
                    f"{sk:.3f}")
    with open(out_csv, "w") as f:
        f.write("\n".join(rows) + "\n")

    curves = {(c.system, c.read_ratio): c for c in result.curves()}
    for std, _, _ in STANDARDS:
        cv = curves[(std, 1.0)]
        best = max(curves[(std, rr)].throughput_gbps.max()
                   for rr in READ_RATIOS)
        frac = best / cv.peak_gbps
        lat0, latN = cv.latency_ns[0], cv.latency_ns[-1]
        knee = latN / lat0 if lat0 else float("nan")
        report(f"latency_throughput_{std}", round(float(frac), 3),
               f"peak_frac={frac:.3f} knee_lat_ratio={knee:.2f} "
               f"peak={cv.peak_gbps:.1f}GB/s")
    ffm = result.meta.get("profile", {}).get("fast_forward", {})
    report("latency_throughput_skipped_frac",
           ffm.get("idle_fraction", 0.0),
           f"fast-forwarded {ffm.get('skipped_cycles', 0):,} of "
           f"{int(result.cycles.sum()):,} cycles across the sweep")
    report("latency_throughput_csv", len(rows) - 1, out_csv)
    npz = result.save(os.path.splitext(out_csv)[0])
    report("latency_throughput_npz", result.meta["n_points"],
           f"{npz} groups={result.meta['n_groups']} "
           f"compiles={result.meta['compile_cache_misses']}")
