"""Roofline assembly (EXPERIMENTS.md §Roofline): read every dry-run JSON
and derive the three terms per (arch x shape x mesh):

  compute    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory     = HLO_bytes_per_device / HBM_bw                [s]
  collective = collective_bytes_per_device / ICI_link_bw    [s]

cost_analysis is per-SPMD-module, i.e. per device (verified in
EXPERIMENTS.md §Dry-run); dry-runs are lowered with unrolled scans so loop
bodies are fully counted (models/flags.py).  mLSTM/sLSTM token scans stay
rolled; their per-step state FLOPs are added analytically here.

MODEL_FLOPS uses the assignment convention 6·N·D (dense train) /
6·N_active·D (MoE train) and 2·N(_active)·D for single-token decode.
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def model_flops(rec: dict) -> float:
    """6·N·D train / 2·N decode (N = active params, D = tokens)."""
    n = rec["n_params_active"]
    tokens = rec["global_batch"] * (rec["seq_len"]
                                    if rec["kind"] != "decode" else 1)
    per_tok = 6 * n if rec["kind"] == "train" else 2 * n
    return float(per_tok) * tokens


def _lstm_scan_correction(rec: dict) -> float:
    """Analytic per-device FLOPs of the rolled mLSTM/sLSTM token scans."""
    if not rec["arch"].startswith("xlstm"):
        return 0.0
    from repro.configs import get_arch
    cfg = get_arch(rec["arch"])
    H, hd = cfg.n_heads, cfg.head_dim
    T = rec["seq_len"] if rec["kind"] != "decode" else 1
    Bg = rec["global_batch"]
    n_m = sum(1 for k in cfg.pattern_layers() if k == "mlstm")
    n_s = sum(1 for k in cfg.pattern_layers() if k == "slstm")
    # mLSTM step: C update + retrieval ~ 6·H·hd^2; sLSTM: recurrent R ~ 8·H·hd^2
    per_tok = n_m * 6 * H * hd * hd + n_s * 8 * H * hd * hd
    total = per_tok * T * Bg
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    mult = 3.0 if rec["kind"] == "train" else 1.0   # fwd+bwd
    return mult * total / chips


def derive(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec["cost"]["flops"] + _lstm_scan_correction(rec)
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["collectives"]["total_bytes"]
    # the (B2-B1) probe extrapolation can go negative when GSPMD picks
    # different strategies for the 1- vs 2-group probes; clamp to the
    # rolled artifact's lower bound and flag (EXPERIMENTS.md §Perf)
    rolled = rec.get("collectives_rolled", {}).get("total_bytes", 0)
    if coll_dev < rolled:
        coll_dev = rolled
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = flops_dev * chips
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
        "bound_step_s": max(terms.values()),
        "roofline_frac": (terms["compute"] / max(terms.values())
                          if max(terms.values()) > 0 else float("nan")),
        "mem_gib_dev": (rec["memory"]["argument_bytes"]
                        + rec["memory"]["temp_bytes"]) / 2**30,
    }


def load_all(results_dir: str = RESULTS_DIR) -> list:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        rec["derived"] = derive(rec)
        out.append(rec)
    return out


def markdown_table(recs: list) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | useful ratio | mem GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        d = r["derived"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {d['t_compute_s']:.3e} | {d['t_memory_s']:.3e} "
            f"| {d['t_collective_s']:.3e} | **{d['bottleneck']}** "
            f"| {d['useful_ratio']:.2f} | {d['mem_gib_dev']:.1f} |")
    return "\n".join(lines)


def run(report):
    recs = load_all()
    if not recs:
        report("roofline_cells", 0, "no dry-run results yet — run "
               "`python -m repro.launch.dryrun --all`")
        return
    for r in recs:
        d = r["derived"]
        report(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
               round(d["roofline_frac"], 3),
               f"bottleneck={d['bottleneck']} useful={d['useful_ratio']:.2f}")
    worst = min(recs, key=lambda r: r["derived"]["roofline_frac"])
    report("roofline_worst_cell",
           round(worst["derived"]["roofline_frac"], 3),
           f"{worst['arch']} x {worst['shape']} x {worst['mesh']}")


if __name__ == "__main__":
    print(markdown_table(load_all()))
