"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,value,derived`` CSV lines.  ``--quick`` shrinks cycle counts
for CI-speed runs; the full run reproduces the paper artifacts.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced cycle counts (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (bench_engine, bench_features,
                            bench_latency_throughput, bench_loc, roofline)
    benches = {
        "loc": lambda rep: bench_loc.run(rep),                 # Table 1
        "latency_throughput": lambda rep: bench_latency_throughput.run(
            rep, n_cycles=6_000 if args.quick else 20_000),    # Fig. 1
        "features": lambda rep: bench_features.run(
            rep, n_cycles=6_000 if args.quick else 12_000),    # §2
        "engine": lambda rep: bench_engine.run(
            rep, n_cycles=6_000 if args.quick else 20_000),    # DSE perf
        "roofline": lambda rep: roofline.run(rep),             # §Roofline
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,value,derived")

    def report(name, value, derived=""):
        print(f"{name},{value},{derived}", flush=True)

    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(report)
            report(f"bench_{name}_wall_s", round(time.time() - t0, 1), "ok")
        except Exception as e:   # noqa: BLE001
            report(f"bench_{name}_FAILED", 0, repr(e))
            raise


if __name__ == "__main__":
    main()
