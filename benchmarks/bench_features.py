"""Paper §2 feature ablations: dual C/A bus, split activation overhead,
WCK sync overhead, BlockHammer/PRAC predicate cost."""
from __future__ import annotations


def run(report, n_cycles: int = 12_000):
    from repro.core import ControllerConfig, FrontendConfig, Simulator, \
        throughput_gbps
    from repro.core.spec import register
    from repro.dse import SweepSpec, execute
    import repro.core.standards.hbm3 as h3

    # --- dual C/A vs single C/A under command-bus pressure ---
    # one declarative sweep over both standard variants; the executor
    # compiles each variant once
    class HBM3_single(h3.HBM3):
        name = "HBM3_single_bench"
        dual_command_bus = False
    try:
        register(HBM3_single)
    except Exception:
        pass
    overrides = {"nBL": 1, "nCCD_S": 1, "nCCD_L": 1}
    res = execute(SweepSpec(
        systems=(("HBM3", "HBM3_16Gb", "HBM3_5200", overrides),
                 ("HBM3_single_bench", "HBM3_16Gb", "HBM3_5200", overrides)),
        intervals=(1.0,), read_ratios=(1.0,), n_cycles=n_cycles))
    lats = {pt.system.standard: res.latency_ns[i]
            for i, pt in enumerate(res.points)}
    gain = lats["HBM3_single_bench"] / lats["HBM3"]
    report("dual_ca_probe_latency_gain", round(float(gain), 3),
           f"dual={lats['HBM3']:.0f}ns single={lats['HBM3_single_bench']:.0f}ns")

    # --- WCK sync overhead: sparse vs dense traffic CAS rate ---
    res = execute(SweepSpec(
        systems=(("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),),
        intervals=(64.0, 2.0), read_ratios=(1.0,), n_cycles=n_cycles,
        frontend=FrontendConfig(probe_gap=64)))

    def cas_per_rd(i):
        return res.cmd_count(i, "CAS_RD") / max(res.cmd_count(i, "RD"), 1)
    report("wck_cas_per_rd_sparse", round(cas_per_rd(0), 3),
           "clock expires between requests")
    report("wck_cas_per_rd_dense", round(cas_per_rd(1), 3),
           "clock stays on under load")

    # --- BlockHammer: deferral under hammer, neutrality under benign ---
    ham = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(blockhammer_threshold=8),
                    frontend=FrontendConfig(pattern="random", probes=False))
    ham.cspec.rows = 2
    st = ham.run(n_cycles, interval=2.0, read_ratio=1.0)
    report("blockhammer_deferrals", int(st.deferred), "hammer pattern, thr=8")

    ben = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(blockhammer_threshold=1024),
                    frontend=FrontendConfig(probes=False))
    plain = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      frontend=FrontendConfig(probes=False))
    t1 = throughput_gbps(ben.cspec, ben.run(n_cycles, interval=2.0))
    t2 = throughput_gbps(plain.cspec, plain.run(n_cycles, interval=2.0))
    report("blockhammer_benign_tput_ratio", round(t1 / t2, 3),
           "should be ~1.0")

    # --- PRAC: recovery REFabs on a hot-row pattern ---
    prac = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     controller=ControllerConfig(prac_threshold=16),
                     frontend=FrontendConfig(pattern="random", probes=False))
    prac.cspec.rows = 4
    st = prac.run(n_cycles, interval=2.0, read_ratio=1.0)
    c = dict(zip(prac.cspec.cmd_names, st.cmd_counts.tolist()))
    report("prac_recovery_refabs", int(c["REFab"]),
           f"vs time-based ~{n_cycles // prac.cspec.timings['nREFI']}")
