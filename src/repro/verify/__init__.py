"""``repro.verify`` — the verification harness.

Three coordinated layers turn the audit substrate into a gate:

* :mod:`repro.verify.explore` — bounded-depth exhaustive exploration of
  ``controller_step`` on tiny geometries, cross-checked command-by-
  command against the scalar oracle, with counterexample minimization
  and replayable ``.npz`` artifacts;
* :mod:`repro.verify.properties` — property-based scheduler invariants
  (refresh deadlines, starvation bounds, window constraints) over
  adversarial request streams, on full organizations including
  multi-channel and heterogeneous systems;
* :mod:`repro.verify.differential` — differential accuracy comparison
  against pinned upstream-format command-stream fixtures;

plus :mod:`repro.verify.mutation`, the auditor's own detector test: a
matrix of injected single-cycle violations that must ALL be flagged.

CLI: ``python -m repro.verify {explore,mutate,diff} ...`` (see
``docs/verification.md``).
"""
from .differential import (DiffReport, accuracy_table, compare_streams,
                           diff_against_fixture, dump_cmd_stream,
                           golden_run, parse_cmd_stream, write_fixture)
from .explore import (Counterexample, Divergence, ExploreResult,
                      default_alphabet, explore, load_counterexample,
                      loosen_constraint, smoke, tiny_spec)
from .mutation import (CLASSES, Injection, detected, inject, matrix_table,
                       mutation_matrix)
from .properties import (STREAMS, PropertyReport, bursty_stream,
                         check_faw_windows, check_refresh_deadline,
                         check_starvation, refresh_deadline_bound,
                         refresh_starving_stream, row_conflict_stream,
                         starvation_bound, verify_properties)

__all__ = [
    "CLASSES", "Counterexample", "DiffReport", "Divergence",
    "ExploreResult", "Injection", "PropertyReport", "STREAMS",
    "accuracy_table", "bursty_stream", "check_faw_windows",
    "check_refresh_deadline", "check_starvation", "compare_streams",
    "default_alphabet", "detected", "diff_against_fixture",
    "dump_cmd_stream", "explore", "golden_run", "inject",
    "load_counterexample", "loosen_constraint", "matrix_table",
    "mutation_matrix", "parse_cmd_stream", "refresh_deadline_bound",
    "refresh_starving_stream", "row_conflict_stream", "smoke",
    "starvation_bound", "tiny_spec", "verify_properties", "write_fixture",
]
