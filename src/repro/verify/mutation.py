"""Mutation-sensitivity matrix for the trace auditor.

``repro.trace.audit`` is the gatekeeper for every golden trace the
engine emits; this module asks the converse question — *does the
auditor actually catch violations?* — by injecting single-cycle timing
violations into legal command streams, one per constraint class:

* ``pairwise``  — a plain two-command latency row (window == 1),
* ``window``    — a sliding-window row (tFAW-style, window > 1),
* ``refresh``   — a row anchored on the all-bank refresh command.

Each injection is engineered so the violated row's slack is exactly
``-1`` (one cycle early), the hardest-to-detect violation, and the
matrix asserts the auditor reports THAT row (matched by previous/next
command, latency, and window) — a 100%-detection requirement across
constraint classes and standards.

Injections mutate a legal trace in one of two ways: in-place (retime an
existing command pair, like the hand-written DDR4 tests this module
generalizes) or by appending a quiescent-tail pair — two commands added
after a long idle gap so the injected pair is the only new timing
relation that matters.  Appending realizes every class on every
standard regardless of what a finite golden run happened to exercise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.spec import KIND_REF
from repro.trace.audit import audit as _audit, constraint_name
from repro.trace.capture import CommandTrace

from .explore import node_of


# ---------------------------------------------------------------------------
# Trace surgery helpers
# ---------------------------------------------------------------------------

_COLS = ("clk", "cmd", "bank", "row", "bus", "arrive", "hit_ready")


def _reorder_by_clk(tr: CommandTrace) -> CommandTrace:
    order = np.argsort(tr.clk, kind="stable")
    cols = {f: getattr(tr, f)[order] for f in _COLS}
    for f in ("chan", "group"):
        if getattr(tr, f) is not None:
            cols[f] = getattr(tr, f)[order]
    return dataclasses.replace(tr, **cols)


def _append(tr: CommandTrace, rows: list[dict]) -> CommandTrace:
    """Append events (dicts over _COLS) and re-sort; extends n_cycles."""
    cols = {}
    for f in _COLS:
        add = np.asarray([r[f] for r in rows], np.int32)
        cols[f] = np.concatenate([getattr(tr, f), add])
    for f in ("chan", "group"):
        if getattr(tr, f) is not None:
            cols[f] = np.concatenate(
                [getattr(tr, f), np.zeros(len(rows), np.int32)])
    n_cycles = max(int(tr.n_cycles), int(cols["clk"].max()) + 1)
    return _reorder_by_clk(dataclasses.replace(tr, n_cycles=n_cycles,
                                               **cols))


def _ev(clk, cmd, bank=0, row=0):
    return dict(clk=int(clk), cmd=int(cmd), bank=int(bank), row=int(row),
                bus=0, arrive=-1, hit_ready=0)


# ---------------------------------------------------------------------------
# Constraint-class row selection
# ---------------------------------------------------------------------------

def _rows_of_class(cspec, klass: str) -> list[int]:
    """Eligible constraint-table rows for one mutation class, best-first
    (largest latency first — the most head-room for clean injection)."""
    names = list(cspec.cmd_names)
    kind = np.asarray(cspec.cmd_kind)
    out = []
    for i in range(len(cspec.ct_prev)):
        p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
        lat, win = int(cspec.ct_lat[i]), int(cspec.ct_win[i])
        if lat < 2 or int(cspec.ct_level[i]) > int(cspec.cmd_scope[p]):
            continue
        is_ref = (kind[p] == KIND_REF) or (kind[f] == KIND_REF) \
            or "REF" in names[p] or "REF" in names[f]
        if klass == "pairwise" and win == 1 and not is_ref:
            out.append(i)
        elif klass == "window" and win > 1:
            out.append(i)
        elif klass == "refresh" and win == 1 and is_ref:
            out.append(i)
    return sorted(out, key=lambda i: -int(cspec.ct_lat[i]))


@dataclasses.dataclass
class Injection:
    """One injected single-cycle violation and how to recognize it."""
    klass: str
    row: int                  # constraint-table row index
    prev: str
    next: str
    lat: int
    win: int
    mode: str                 # "inplace" | "append"
    trace: CommandTrace

    @property
    def constraint(self) -> str:
        return f"lat={self.lat}" + (f" [window={self.win}]"
                                    if self.win > 1 else "")


def detected(cspec, inj: Injection, report=None) -> bool:
    """Audit the mutated trace; True iff the injected row is flagged at
    slack -1 (previous/next commands, latency and window all match)."""
    rep = report or _audit(cspec, inj.trace, check_fingerprint=False)
    want_name = constraint_name(cspec, inj.row)
    return any(v.prev_cmd == inj.prev and v.cmd == inj.next
               and v.slack == -1 and v.constraint == want_name
               for v in rep.violations)


# ---------------------------------------------------------------------------
# Injections
# ---------------------------------------------------------------------------

def _inject_inplace_pairwise(cspec, tr, i) -> CommandTrace | None:
    """Retime an existing (prev, next) pair at the same constraint node
    to slack -1 — the generalization of the hand-written ACT->RD test."""
    p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
    lat, level = int(cspec.ct_lat[i]), int(cspec.ct_level[i])
    if tr.chan is not None and len(np.unique(tr.chan)) > 1:
        return None                       # in-place surgery: 1-channel only
    prev_idx = np.nonzero(tr.cmd == p)[0]
    if not len(prev_idx):
        return None
    nodes = np.asarray([node_of(cspec, b, level) for b in tr.bank])
    for j in np.nonzero(tr.cmd == f)[0]:
        before = prev_idx[(tr.clk[prev_idx] < tr.clk[j])
                          & (nodes[prev_idx] == nodes[j])]
        if not len(before):
            continue
        a = before[np.argmax(tr.clk[before])]     # most recent prev
        target = int(tr.clk[a]) + lat - 1
        if target <= int(tr.clk[a]) or target >= int(tr.clk[j]):
            continue                              # must move strictly earlier
        clk = tr.clk.copy()
        clk[j] = target
        return _reorder_by_clk(dataclasses.replace(tr, clk=clk))
    return None


def _inject_append_pairwise(cspec, tr, i) -> CommandTrace:
    """Quiescent-tail injection: prev at t0 (far past all activity),
    next at t0 + lat - 1."""
    p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
    lat = int(cspec.ct_lat[i])
    gap = 2 * max(int(np.max(cspec.ct_lat)), 1) + 8
    t0 = int(tr.clk.max()) + gap
    return _append(tr, [_ev(t0, p), _ev(t0 + lat - 1, f)])


def _inject_append_window(cspec, tr, i) -> CommandTrace | None:
    """tFAW-style: ``win`` legally-spaced prev commands on DISTINCT
    banks of the same window node (so per-bank cycle constraints like
    nRC never bind), then the following command on a fresh bank one
    cycle inside the closing window."""
    p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
    lat, win = int(cspec.ct_lat[i]), int(cspec.ct_win[i])
    level = int(cspec.ct_level[i])
    nb = int(cspec.n_banks)
    if nb <= win:
        return None                      # not enough banks to spread over
    banks = list(range(win))
    vbank = win                          # violator on a bank not used above
    # deepest level every participant still shares a node at: only
    # pairwise rows at those levels constrain the cross-bank spacing
    shared = 0
    for lvl in range(len(cspec.level_counts)):
        if len({node_of(cspec, b, lvl) for b in banks + [vbank]}) == 1:
            shared = lvl
        else:
            break
    if shared < level:
        return None                      # participants leave the window node
    spacing = 1
    for j in range(len(cspec.ct_prev)):
        if int(cspec.ct_win[j]) == 1 and int(cspec.ct_prev[j]) == p \
                and int(cspec.ct_next[j]) in (p, f) \
                and int(cspec.ct_level[j]) <= shared:
            spacing = max(spacing, int(cspec.ct_lat[j]))
    gap = 2 * max(int(np.max(cspec.ct_lat)), 1) + 8
    t0 = int(tr.clk.max()) + gap
    # the violator's window-th most recent prev is t0 -> earliest legal
    # issue is t0 + lat; go one cycle early (strictly after every prev)
    t_bad = t0 + lat - 1
    if t_bad <= t0 + (win - 1) * spacing:
        return None                      # window not binding at this spacing
    rows = [_ev(t0 + k * spacing, p, bank=banks[k]) for k in range(win)]
    rows.append(_ev(t_bad, f, bank=vbank))
    return _append(tr, rows)


def _inject_append_refresh(cspec, tr, i) -> CommandTrace:
    """REFab-anchored: refresh at t0, the constrained follower one cycle
    inside the recovery latency."""
    p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
    lat = int(cspec.ct_lat[i])
    gap = 2 * max(int(np.max(cspec.ct_lat)), 1) + 8
    t0 = int(tr.clk.max()) + gap
    return _append(tr, [_ev(t0, p, row=-1), _ev(t0 + lat - 1, f)])


CLASSES = ("pairwise", "window", "refresh")


def inject(cspec, tr: CommandTrace, klass: str) -> Injection | None:
    """Inject one slack -1 violation of ``klass`` into a legal trace.
    Returns None when the standard has no eligible constraint row."""
    for i in _rows_of_class(cspec, klass):
        names = list(cspec.cmd_names)
        mutated = None
        mode = "append"
        if klass == "pairwise":
            mutated = _inject_inplace_pairwise(cspec, tr, i)
            mode = "inplace"
            if mutated is None:
                mutated = _inject_append_pairwise(cspec, tr, i)
                mode = "append"
        elif klass == "window":
            mutated = _inject_append_window(cspec, tr, i)
        elif klass == "refresh":
            mutated = _inject_append_refresh(cspec, tr, i)
        else:
            raise ValueError(f"unknown mutation class {klass!r}")
        if mutated is None:
            continue
        return Injection(klass=klass, row=i,
                         prev=names[int(cspec.ct_prev[i])],
                         next=names[int(cspec.ct_next[i])],
                         lat=int(cspec.ct_lat[i]),
                         win=int(cspec.ct_win[i]), mode=mode, trace=mutated)
    return None


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------

def mutation_matrix(traces: dict, classes=CLASSES) -> dict:
    """``traces`` maps standard -> (cspec, legal CommandTrace).  Returns
    {(standard, class): "detected" | "MISSED:<...>" | "skipped:<...>"}."""
    out = {}
    for std, (cspec, tr) in traces.items():
        for klass in classes:
            inj = inject(cspec, tr, klass)
            if inj is None:
                out[(std, klass)] = "skipped: no eligible constraint row"
                continue
            if detected(cspec, inj):
                out[(std, klass)] = "detected"
            else:
                out[(std, klass)] = (f"MISSED: {inj.prev}->{inj.next} "
                                     f"{inj.constraint} ({inj.mode})")
    return out


def matrix_table(matrix: dict) -> str:
    """Render the detection matrix as markdown."""
    stds = sorted({k[0] for k in matrix})
    classes = [c for c in CLASSES if any(k[1] == c for k in matrix)]
    lines = ["| standard | " + " | ".join(classes) + " |",
             "|---|" + "---|" * len(classes)]
    for s in stds:
        cells = [matrix.get((s, c), "-") for c in classes]
        lines.append(f"| {s} | " + " | ".join(cells) + " |")
    return "\n".join(lines)
