"""Verification-harness CLI — the CI ``verify-smoke`` entry points.

    # bounded exploration (zero divergences required), smoke matrix
    python -m repro.verify explore --standards DDR4 DDR5 HBM3

    # demonstrate counterexample extraction on a miscompiled spec
    python -m repro.verify explore --standard DDR4 --loosen ACT:RD \
        --expect-counterexample --artifact-dir results/verify

    # mutation-sensitivity matrix (100% detection required)
    python -m repro.verify mutate --standards DDR4 DDR5 HBM3

    # differential comparison against pinned fixtures
    python -m repro.verify diff --fixtures tests/verify/fixtures \
        --table results/verify/accuracy.md

Exit status is non-zero whenever the checked property fails, so each
sub-command is CI-gateable on its own.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys

from repro.core.controller import ControllerConfig

from .differential import (accuracy_table, diff_against_fixture,
                           write_fixture)
from .explore import explore, loosen_constraint, smoke, tiny_spec
from .mutation import matrix_table, mutation_matrix


def _cmd_explore(args) -> int:
    if args.loosen:
        prev, following = args.loosen.split(":")
        oracle = tiny_spec(args.standard, banks=args.banks, fast=True)
        bad, row = loosen_constraint(oracle, prev, following)
        print(f"loosened constraint row {row}: {prev}->{following} by 1")
        res = explore(bad, oracle=oracle, depth=args.depth,
                      ccfg=ControllerConfig(queue_depth=args.queue_depth),
                      check_tables=False, artifact_dir=args.artifact_dir,
                      standard=args.standard,
                      config_doc=dict(standard=args.standard,
                                      banks=args.banks, rows=8, columns=8,
                                      fast=True))
        print(res)
        cex = res.counterexample
        if args.expect_counterexample:
            if cex is None:
                print("FAIL: loosened spec produced no counterexample")
                return 1
            print(f"minimized path: {list(cex.path)}")
            print(f"divergence: {cex.divergence}")
            print(f"artifact: {cex.artifact}")
            return 0
        return 0 if res.ok else 1

    stds = args.standards or [args.standard]
    results = smoke(standards=stds, max_frontier=args.max_frontier)
    fail = False
    for (std, cfg), res in sorted(results.items()):
        print(f"{cfg:>12}  {res}")
        if not res.ok:
            fail = True
            for d in res.divergences[:3]:
                print(f"              {d}")
            if res.counterexample and args.artifact_dir:
                print(f"              artifact: "
                      f"{res.counterexample.artifact}")
    total_cmds = sum(r.commands_checked for r in results.values())
    total_states = sum(r.states_explored for r in results.values())
    print(f"explored {total_states} states / checked {total_cmds} "
          f"commands across {len(results)} configs: "
          f"{'FAIL' if fail else 'OK'}")
    return 1 if fail else 0


def _cmd_mutate(args) -> int:
    from repro.trace.capture import capture
    from repro.core.engine import Simulator
    from repro.dse.spec import DEFAULT_SYSTEMS
    stds = args.standards or sorted(DEFAULT_SYSTEMS)
    traces = {}
    for std in stds:
        org, tim = DEFAULT_SYSTEMS[std]
        sim = Simulator(std, org, tim, controller=ControllerConfig())
        _, dense = sim.run(args.cycles, interval=2.0, read_ratio=0.7,
                           trace=True)
        traces[std] = (sim.cspec, capture(sim.cspec, dense,
                                          controller=sim.controller,
                                          frontend=sim.frontend))
    matrix = mutation_matrix(traces)
    print(matrix_table(matrix))
    missed = {k: v for k, v in matrix.items() if v.startswith("MISSED")}
    print(f"mutation matrix: {len(matrix) - len(missed)}/{len(matrix)} "
          f"detected — {'FAIL' if missed else 'OK (100%)'}")
    return 1 if missed else 0


def _cmd_diff(args) -> int:
    stds = args.standards
    if not stds:
        stds = sorted(os.path.basename(p).rsplit(".", 1)[0]
                      for p in glob.glob(os.path.join(args.fixtures,
                                                      "*.cmdstream")))
    if args.write:
        for std in stds:
            p = write_fixture(std, os.path.join(args.fixtures,
                                                f"{std}.cmdstream"))
            print(f"wrote {p}")
        return 0
    reports = []
    for std in stds:
        r = diff_against_fixture(std, os.path.join(args.fixtures,
                                                   f"{std}.cmdstream"))
        reports.append(r)
        print(r)
    table = accuracy_table(reports)
    print(table)
    if args.table:
        os.makedirs(os.path.dirname(args.table) or ".", exist_ok=True)
        with open(args.table, "w") as f:
            f.write("# Differential accuracy vs pinned fixtures\n\n"
                    + table + "\n")
        print(f"table -> {args.table}")
    return 0 if all(r.exact for r in reports) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.verify",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("explore", help="bounded-depth exploration")
    ex.add_argument("--standard", default="DDR4")
    ex.add_argument("--standards", nargs="*", default=None)
    ex.add_argument("--banks", type=int, default=2)
    ex.add_argument("--depth", type=int, default=12)
    ex.add_argument("--queue-depth", type=int, default=2)
    ex.add_argument("--max-frontier", type=int, default=128)
    ex.add_argument("--loosen", metavar="PREV:NEXT",
                    help="miscompile: loosen this constraint by 1 cycle")
    ex.add_argument("--expect-counterexample", action="store_true")
    ex.add_argument("--artifact-dir", default=None)

    mu = sub.add_parser("mutate", help="audit mutation-sensitivity matrix")
    mu.add_argument("--standards", nargs="*", default=None)
    mu.add_argument("--cycles", type=int, default=3000)

    df = sub.add_parser("diff", help="differential fixture comparison")
    df.add_argument("--fixtures", default="tests/verify/fixtures")
    df.add_argument("--standards", nargs="*", default=None)
    df.add_argument("--write", action="store_true",
                    help="(re)generate fixtures instead of comparing")
    df.add_argument("--table", default=None,
                    help="write the accuracy table to this markdown file")

    args = ap.parse_args(argv)
    return {"explore": _cmd_explore, "mutate": _cmd_mutate,
            "diff": _cmd_diff}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
