"""Differential accuracy comparison against golden command-stream
fixtures.

The upstream Ramulator 2 simulator dumps its issued commands as a plain
text stream — one line per command: issue cycle, command name, then the
address vector down the hierarchy (channel, rank/pseudochannel, ...,
bank, row, column).  This module reads and writes that format so the
JAX engine's command streams can be pinned as golden fixtures
(``tests/verify/fixtures/*.cmdstream``) and re-compared on every PR:
the comparator reports the first diverging command, a per-command-type
count delta, and an aggregate positional match fraction, and
:func:`accuracy_table` renders the result as the markdown table CI
publishes.

Fixtures are deterministic: one canonical (controller, frontend, seed,
n_cycles) configuration per standard, so any engine change that moves
even one command one cycle shows up as a concrete divergence with its
index and both lines printed.
"""
from __future__ import annotations

import dataclasses
import io
import os

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.engine import Simulator
from repro.trace.capture import CommandTrace, capture, spec_fingerprint_hex

from .explore import bank_sub

#: canonical fixture run: every knob pinned so the stream is a pure
#: function of the engine + spec
FIXTURE_RUN = dict(n_cycles=1500, interval=2.0, read_ratio=0.7, seed=0x1234)


def golden_run(standard: str, *, n_cycles: int | None = None):
    """The canonical fixture configuration for one standard."""
    from repro.dse.spec import DEFAULT_SYSTEMS
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim, controller=ControllerConfig())
    run = dict(FIXTURE_RUN)
    if n_cycles is not None:
        run["n_cycles"] = n_cycles
    _, dense = sim.run(run["n_cycles"], interval=run["interval"],
                       read_ratio=run["read_ratio"], trace=True,
                       seed=run["seed"])
    tr = capture(sim.cspec, dense, controller=sim.controller,
                 frontend=sim.frontend)
    return sim.cspec, tr


# ---------------------------------------------------------------------------
# The upstream-style text format
# ---------------------------------------------------------------------------

def dump_cmd_stream(cspec, tr: CommandTrace, path: str | None = None) -> str:
    """Render a captured trace as an upstream-style command dump."""
    out = io.StringIO()
    out.write("# ramulator2-style command stream\n")
    out.write(f"# standard={cspec.standard} org={cspec.org_preset} "
              f"timing={cspec.timing_preset}\n")
    out.write(f"# n_cycles={tr.n_cycles} "
              f"fingerprint={spec_fingerprint_hex(cspec)}\n")
    out.write("# clk cmd " +
              " ".join(lv.lower() for lv in cspec.levels) + " row col\n")
    chan = np.zeros(len(tr.clk), np.int64) if tr.chan is None else tr.chan
    for i in range(len(tr.clk)):
        sub = bank_sub(cspec, int(tr.bank[i]))
        fields = [int(chan[i])] + [int(v) for v in sub]
        out.write(f"{int(tr.clk[i])} {tr.cmd_names[int(tr.cmd[i])]} "
                  + " ".join(str(v) for v in fields)
                  + f" {int(tr.row[i])} 0\n")
    text = out.getvalue()
    if path is not None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
    return text


def parse_cmd_stream(source: str) -> dict:
    """Parse a command dump (path or literal text) into columns:
    ``{"meta": {...}, "clk": [...], "cmd": [...], "addr": [[...], ...]}``
    where each addr vector is ``[channel, ..., bank, row, col]``."""
    if "\n" not in source and os.path.exists(source):
        with open(source) as f:
            text = f.read()
    else:
        text = source
    meta, clk, cmd, addr = {}, [], [], []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            for tok in line[1:].split():
                if "=" in tok:
                    k, v = tok.split("=", 1)
                    meta[k] = v
            continue
        parts = line.split()
        clk.append(int(parts[0]))
        cmd.append(parts[1])
        addr.append([int(x) for x in parts[2:]])
    return {"meta": meta, "clk": clk, "cmd": cmd, "addr": addr}


def _rows_of(parsed: dict) -> list[tuple]:
    return [(c, n, tuple(a))
            for c, n, a in zip(parsed["clk"], parsed["cmd"], parsed["addr"])]


# ---------------------------------------------------------------------------
# Comparison
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DiffReport:
    standard: str
    n_golden: int
    n_current: int
    first_divergence: int          # row index, -1 when streams agree
    match_fraction: float          # positional matches over max length
    per_cmd: dict                  # name -> (golden_count, current_count)
    divergence_detail: str = ""

    @property
    def exact(self) -> bool:
        return self.first_divergence < 0 and self.n_golden == self.n_current

    def __str__(self):
        if self.exact:
            return (f"diff[{self.standard}]: exact match "
                    f"({self.n_golden} commands)")
        return (f"diff[{self.standard}]: diverges at row "
                f"{self.first_divergence} "
                f"(match {self.match_fraction:.4f}) "
                f"{self.divergence_detail}")


def compare_streams(standard: str, golden: dict, current: dict) -> DiffReport:
    g, c = _rows_of(golden), _rows_of(current)
    n = min(len(g), len(c))
    first, detail = -1, ""
    for i in range(n):
        if g[i] != c[i]:
            first, detail = i, f"golden={g[i]} current={c[i]}"
            break
    if first < 0 and len(g) != len(c):
        first = n
        detail = (f"length mismatch: golden={len(g)} current={len(c)}")
    matches = sum(1 for i in range(n) if g[i] == c[i])
    per_cmd = {}
    for name in sorted({r[1] for r in g} | {r[1] for r in c}):
        per_cmd[name] = (sum(1 for r in g if r[1] == name),
                         sum(1 for r in c if r[1] == name))
    return DiffReport(standard=standard, n_golden=len(g), n_current=len(c),
                      first_divergence=first,
                      match_fraction=matches / max(len(g), len(c), 1),
                      per_cmd=per_cmd, divergence_detail=detail)


def diff_against_fixture(standard: str, fixture_path: str) -> DiffReport:
    """Re-run the canonical config and compare to the pinned fixture."""
    golden = parse_cmd_stream(fixture_path)
    cspec, tr = golden_run(standard)
    current = parse_cmd_stream(dump_cmd_stream(cspec, tr))
    return compare_streams(standard, golden, current)


def write_fixture(standard: str, fixture_path: str) -> str:
    cspec, tr = golden_run(standard)
    dump_cmd_stream(cspec, tr, fixture_path)
    return fixture_path


def accuracy_table(reports: list[DiffReport]) -> str:
    """The accuracy table CI publishes: per-standard positional match
    fraction plus command-count deltas."""
    lines = ["| standard | commands (golden/current) | match | "
             "first divergence | cmd-count deltas |",
             "|---|---|---|---|---|"]
    for r in sorted(reports, key=lambda r: r.standard):
        deltas = ", ".join(f"{k}:{g}->{c}"
                           for k, (g, c) in r.per_cmd.items() if g != c)
        lines.append(
            f"| {r.standard} | {r.n_golden}/{r.n_current} "
            f"| {r.match_fraction:.4f} "
            f"| {'-' if r.first_divergence < 0 else r.first_divergence} "
            f"| {deltas or '-'} |")
    return "\n".join(lines)
