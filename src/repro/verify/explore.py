"""Bounded-depth exhaustive exploration of the controller against the
scalar oracle.

For tiny device geometries (one bank group, two banks, a 2–4 deep
queue) the reachable state space of ``controller_step`` under a small
request-injection alphabet is small enough to enumerate breadth-first
to a depth bound.  Every command the vectorized JAX controller issues
along ANY reachable path is cross-checked against the scalar numpy
oracle (:class:`repro.core.dut.DeviceUnderTest`) with ``check=True`` —
an independent re-derivation of prerequisite and timing legality — and
(optionally) the full ``earliest_ready_table`` of every unique state is
compared entry-for-entry against ``DeviceUnderTest.earliest``.

A divergence yields a counterexample: the injection path is shrunk by
greedy delta-debugging (replace injections with no-ops while the
failure persists, then truncate at the failing cycle) and the minimized
command prefix is exported as a replayable ``CommandTrace`` ``.npz``
artifact that ``repro.trace.audit`` and :func:`load_counterexample` can
consume without this module in the loop.

The exploration is exhaustive over the injection alphabet up to
``depth`` (modulo state dedup, which is sound: identical controller
state at the same cycle ⇒ identical futures) with an explicit
``max_frontier`` cap — when the cap trips the result says so via
``truncated`` instead of silently under-covering.
"""
from __future__ import annotations

import copy
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core import device as D
from repro.core.controller import ControllerConfig
from repro.core.dut import DeviceUnderTest
from repro.core.spec import Organization, get_standard
from repro.trace.capture import CommandTrace, base_meta, spec_fingerprint_hex
from repro.trace import format as TF


# ---------------------------------------------------------------------------
# Tiny device geometries
# ---------------------------------------------------------------------------

#: conservative fast-timing overrides for exploration: every key present
#: in a standard's timing preset is shrunk so interesting command
#: interleavings (activate/precharge churn, refresh) fit inside a small
#: depth bound.  Engine and oracle compile from the SAME overridden
#: preset, so the cross-check is unaffected — this only densifies the
#: reachable behaviors per cycle of depth.
FAST_TIMINGS = {
    "nRCD": 4, "nRCDRD": 4, "nRCDWR": 5, "nRP": 4, "nRPab": 5, "nRPpb": 4,
    "nRAS": 8, "nRC": 12, "nCL": 4, "nCWL": 3, "nRL": 4, "nWL": 3,
    "nBL": 2, "nCCD": 2, "nCCDS": 2, "nCCDL": 3, "nCCDMIN": 2,
    "nRRD": 2, "nRRDS": 2, "nRRDL": 3, "nWR": 4, "nRTP": 3, "nPPD": 2,
    "nFAW": 10, "nREFI": 48, "nRFC": 12, "nRFCab": 12, "nRFCpb": 8,
    "nRTRS": 2, "nWTRS": 2, "nWTRL": 3, "nWTR": 3, "nCS": 1,
}


def tiny_spec(standard: str, *, banks: int = 2, rows: int = 8,
              columns: int = 8, fast: bool = False, nrefi: int | None = None,
              timing_overrides: dict | None = None):
    """Compile ``standard`` at a tiny organization: every hierarchy level
    below the channel collapsed to one node except the bank level, which
    gets ``banks`` banks.

    The tiny organization is attached to an UNREGISTERED subclass of the
    standard (org preset name ``"TINY"``), so the registry and every
    other consumer of the real presets are untouched.  ``fast=True``
    applies :data:`FAST_TIMINGS` (key-intersected with the preset);
    ``nrefi`` force-overrides the refresh interval on top.
    """
    from repro.dse.spec import DEFAULT_SYSTEMS
    std = get_standard(standard)
    org_name, tim_name = DEFAULT_SYSTEMS[std.name]
    base_org = std.org_presets[org_name]
    counts = {lv: 1 for lv in std.levels[1:]}
    counts[std.levels[-1]] = banks
    tiny_org = Organization(density_mb=base_org.density_mb, dq=base_org.dq,
                            counts=counts, rows=rows, columns=columns)
    tiny_std = type(std.__name__, (std,),
                    {"org_presets": dict(std.org_presets, TINY=tiny_org)})
    overrides = {}
    if fast:
        preset = std.timing_presets[tim_name]
        overrides.update({k: v for k, v in FAST_TIMINGS.items()
                          if k in preset})
    if timing_overrides:
        overrides.update(timing_overrides)
    if nrefi is not None:
        overrides["nREFI"] = nrefi
    from repro.core.compile import compile_spec
    return compile_spec(tiny_std, "TINY", tim_name, overrides or None)


# ---------------------------------------------------------------------------
# Address helpers (flat bank id <-> per-level indices)
# ---------------------------------------------------------------------------

def bank_sub(cspec, bank: int) -> np.ndarray:
    """Flat bank id -> per-level sub indices below the channel."""
    counts = [int(c) for c in cspec.level_counts]
    idxs, b = [], int(bank)
    for i in range(len(counts) - 1, 0, -1):
        idxs.append(b % counts[i])
        b //= counts[i]
    return np.asarray(idxs[::-1], np.int32)


def addr_from_bank(cspec, bank: int, row: int) -> dict:
    """Flat bank id + row -> the oracle's address dict."""
    sub = bank_sub(cspec, bank)
    addr = {lv: int(v) for lv, v in zip(cspec.levels[1:], sub)}
    addr["row"] = int(row) if row >= 0 else 0
    addr["col"] = 0
    return addr


def node_of(cspec, bank: int, level: int) -> int:
    """Ancestor node index (within the channel) of a flat bank at a
    hierarchy level — events share a constraint node iff this matches."""
    div = 1
    for i in range(level + 1, len(cspec.level_counts)):
        div *= int(cspec.level_counts[i])
    return int(bank) // div


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Divergence:
    """One disagreement between the JAX controller and the scalar oracle."""
    kind: str                 # "illegal_issue" | "earliest_mismatch"
    depth: int                # cycle at which it was observed
    cmd: str
    bank: int
    row: int
    detail: str
    path: tuple               # injection-choice indices, one per cycle

    def __str__(self):
        return (f"[{self.kind}] clk={self.depth} {self.cmd} "
                f"bank={self.bank} row={self.row}: {self.detail}")


@dataclasses.dataclass
class Counterexample:
    """A minimized failing injection path and its replayable trace."""
    path: tuple
    divergence: Divergence
    trace: CommandTrace
    artifact: str | None = None


@dataclasses.dataclass
class ExploreResult:
    standard: str
    depth: int
    states_explored: int      # unique states kept across all layers
    edges: int                # (state, injection) transitions evaluated
    commands_checked: int     # oracle-checked issued commands
    tables_checked: int       # earliest-ready tables compared in full
    truncated: bool           # frontier cap trimmed the search
    divergences: list
    counterexample: Counterexample | None = None

    @property
    def ok(self) -> bool:
        return not self.divergences

    def __str__(self):
        status = "ok" if self.ok else f"{len(self.divergences)} divergence(s)"
        extra = " [truncated]" if self.truncated else ""
        return (f"explore[{self.standard}] depth={self.depth} "
                f"states={self.states_explored} edges={self.edges} "
                f"cmds={self.commands_checked} tables={self.tables_checked}"
                f"{extra}: {status}")


# ---------------------------------------------------------------------------
# Injection alphabet
# ---------------------------------------------------------------------------

def default_alphabet(cspec) -> tuple:
    """Per-cycle injection choices: index 0 MUST be the no-op (the
    minimizer shrinks toward it).  Entries are (bank, row, is_write)."""
    nb = int(cspec.n_banks)
    return (None,
            (0, 0, False),            # same-bank same-row (hit pressure)
            (0, 1, False),            # same-bank other-row (conflict)
            (nb - 1, 0, True))        # far bank write (turnaround)


def _encode_alphabet(cspec, alphabet):
    if alphabet[0] is not None:
        raise ValueError("alphabet[0] must be None (the no-op injection)")
    L = len(cspec.levels) - 1
    want = np.asarray([a is not None for a in alphabet])
    wr = np.asarray([bool(a[2]) if a else False for a in alphabet])
    sub = np.stack([bank_sub(cspec, a[0]) if a else np.zeros(L, np.int32)
                    for a in alphabet]).astype(np.int32)
    row = np.asarray([a[1] if a else 0 for a in alphabet], np.int32)
    return want, wr, sub, row


# ---------------------------------------------------------------------------
# The (vmapped) transition: inject one request, step the controller
# ---------------------------------------------------------------------------

def _make_step(cspec, ccfg):
    """Compile the exploration transition once per (spec, config):
    ``(state, injection, clk) -> (state', events, earliest_table)``.
    Mirrors the engine's per-cycle order exactly — the frontend inserts
    into the queue first, the controller steps second."""
    dp = D.dyn_params(cspec)

    def step_one(cs, want, is_write, sub, row, clk):
        q, _ = C.queue_insert(cs.queue, is_write, jnp.asarray(False),
                              sub, row, jnp.int32(0), clk, want)
        cs = cs._replace(queue=q)
        cs, ev = C.controller_step(cspec, dp, ccfg, cs, clk)
        table = D.earliest_ready_table(cspec, dp, cs.dev)
        return cs, ev, table

    vstep = jax.jit(jax.vmap(step_one, in_axes=(0, 0, 0, 0, 0, None)))
    sstep = jax.jit(step_one)
    return vstep, sstep


def _np_tree(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *trees)


def _state_key(cs) -> bytes:
    return b"".join(np.ascontiguousarray(leaf).tobytes()
                    for leaf in jax.tree_util.tree_leaves(cs))


# ---------------------------------------------------------------------------
# Oracle-side checks
# ---------------------------------------------------------------------------

def _dut_issue_checked(dut, cspec, cmd_id, bank, row, clk):
    """Issue one engine event on the oracle with legality checking.
    Returns an error string on disagreement, None when legal."""
    name = cspec.cmd_names[int(cmd_id)]
    addr = addr_from_bank(dut.cspec, int(bank), int(row))
    try:
        dut.issue(name, addr, clk=int(clk), check=True)
    except AssertionError as e:
        return str(e)
    return None


def _table_mismatch(dut, cspec, table) -> str | None:
    """Compare the engine's full earliest-ready table against the oracle.
    Returns a description of the first mismatch, None when identical."""
    for b in range(int(cspec.n_banks)):
        addr = addr_from_bank(dut.cspec, b, 0)
        for ci, name in enumerate(cspec.cmd_names):
            want = int(dut.earliest(name, addr))
            got = int(table[ci, b])
            if got != want:
                return (f"earliest_ready[{name}, bank={b}] "
                        f"engine={got} oracle={want}")
    return None


# ---------------------------------------------------------------------------
# Scalar path replay (minimization + counterexample export)
# ---------------------------------------------------------------------------

def _run_path(cspec, oracle_cspec, ccfg, sstep, alphabet, path,
              check_tables=False):
    """Replay one injection path scalar-ly; stop at the first divergence.
    Returns (events, divergence|None) where events are the issued
    commands up to and including the failing one."""
    want_a, wr_a, sub_a, row_a = _encode_alphabet(cspec, alphabet)
    cs = C.init_ctrl_state(cspec, ccfg.queue_depth)
    dut = DeviceUnderTest.from_compiled(oracle_cspec)
    events = []
    for d, choice in enumerate(path):
        cs, ev, table = sstep(cs, jnp.asarray(bool(want_a[choice])),
                              jnp.asarray(bool(wr_a[choice])),
                              jnp.asarray(sub_a[choice]),
                              jnp.int32(int(row_a[choice])), jnp.int32(d))
        ev = _np_tree(ev)
        for slot in range(ev.cmd.shape[0]):
            ci = int(ev.cmd[slot])
            if ci < 0:
                continue
            rec = dict(clk=d, cmd=ci, bank=int(ev.bank[slot]),
                       row=int(ev.row[slot]), bus=slot,
                       arrive=int(ev.arrive[slot]),
                       hit_ready=int(ev.hit_ready[slot]))
            events.append(rec)
            err = _dut_issue_checked(dut, cspec, ci, rec["bank"],
                                     rec["row"], d)
            if err is not None:
                return events, Divergence(
                    "illegal_issue", d, cspec.cmd_names[ci], rec["bank"],
                    rec["row"], err, tuple(path))
        if check_tables:
            err = _table_mismatch(dut, cspec, np.asarray(table))
            if err is not None:
                return events, Divergence("earliest_mismatch", d, "-", -1,
                                          -1, err, tuple(path))
    return events, None


def minimize_path(path, fails) -> tuple:
    """Greedy delta-debug: replace each injection with the no-op while
    the failure persists, then truncate to the failing depth."""
    cur = list(path)
    for i in range(len(cur)):
        if cur[i] == 0:
            continue
        trial = cur[:i] + [0] + cur[i + 1:]
        if fails(trial) is not None:
            cur = trial
    div = fails(cur)
    assert div is not None, "minimization lost the failure"
    return tuple(cur[:div.depth + 1])


def _counterexample_trace(oracle_cspec, events, n_cycles, ccfg,
                          engine_cspec, path, divergence,
                          config: dict | None) -> CommandTrace:
    col = lambda k: np.asarray([e[k] for e in events], np.int32)
    meta = base_meta(
        oracle_cspec, controller=ccfg,
        counterexample={
            "path": [int(c) for c in path],
            "divergence": {"kind": divergence.kind,
                           "clk": divergence.depth,
                           "cmd": divergence.cmd,
                           "bank": divergence.bank,
                           "row": divergence.row,
                           "detail": divergence.detail},
            "engine_fingerprint": spec_fingerprint_hex(engine_cspec),
            "config": config or {},
        })
    return CommandTrace(clk=col("clk"), cmd=col("cmd"), bank=col("bank"),
                        row=col("row"), bus=col("bus"), arrive=col("arrive"),
                        hit_ready=col("hit_ready"), n_cycles=int(n_cycles),
                        cmd_names=list(oracle_cspec.cmd_names), meta=meta)


def load_counterexample(path: str):
    """Rebuild (oracle_cspec, trace) from a counterexample artifact.

    The tiny ``"TINY"`` organization is not registered, so the artifact
    embeds its reconstruction recipe in ``meta["counterexample"]
    ["config"]`` — enough for ``tiny_spec`` to recompile the oracle the
    counterexample should be audited against."""
    tr = TF.load(path)
    cfg = tr.meta.get("counterexample", {}).get("config", {})
    if not cfg:
        raise ValueError(f"{path}: no counterexample config in meta")
    cspec = tiny_spec(cfg["standard"], banks=cfg["banks"], rows=cfg["rows"],
                      columns=cfg["columns"], fast=cfg.get("fast", False),
                      nrefi=cfg.get("nrefi"),
                      timing_overrides=cfg.get("timing_overrides"))
    return cspec, tr


# ---------------------------------------------------------------------------
# Deliberate miscompilation (negative-path harness input)
# ---------------------------------------------------------------------------

def loosen_constraint(cspec, prev: str = "ACT", following: str = "RD",
                      amount: int = 1):
    """Return a copy of ``cspec`` with the (prev, following) pairwise
    constraint loosened by ``amount`` cycles, plus the row index.  The
    oracle keeps the pristine table, so exploration must catch the
    engine issuing ``following`` one cycle early."""
    names = list(cspec.cmd_names)
    cand = [i for i in range(len(cspec.ct_prev))
            if names[int(cspec.ct_prev[i])] == prev
            and names[int(cspec.ct_next[i])] == following
            and int(cspec.ct_win[i]) == 1 and int(cspec.ct_lat[i]) > amount]
    if not cand:
        raise ValueError(f"no loosenable {prev}->{following} row")
    i = max(cand, key=lambda j: int(cspec.ct_lat[j]))
    lat = np.array(cspec.ct_lat, np.int64).copy()
    lat[i] -= amount
    return dataclasses.replace(cspec, ct_lat=lat), i


# ---------------------------------------------------------------------------
# The explorer
# ---------------------------------------------------------------------------

class _Node:
    __slots__ = ("cs", "dut", "path")

    def __init__(self, cs, dut, path):
        self.cs, self.dut, self.path = cs, dut, path


def _copy_dut(dut: DeviceUnderTest) -> DeviceUnderTest:
    d = DeviceUnderTest.from_compiled(dut.cspec)
    d.last_issue = dut.last_issue.copy()
    d.win_ring = dut.win_ring.copy()
    d.row_state = dut.row_state.copy()
    d.act1_row = dut.act1_row.copy()
    d.act1_clk = dut.act1_clk.copy()
    d.clock_until = dut.clock_until.copy()
    d.history = list(dut.history)
    return d


def explore(cspec, *, depth: int = 10, ccfg: ControllerConfig | None = None,
            oracle=None, alphabet=None, max_frontier: int = 128,
            check_tables: bool = True, artifact_dir: str | None = None,
            chunk: int = 64, config_doc: dict | None = None,
            standard: str | None = None) -> ExploreResult:
    """Breadth-first exploration of ``controller_step`` to ``depth``.

    ``oracle`` (a CompiledSpec, default ``cspec``) is what the scalar
    DUT compiles from — pass the pristine spec here and a loosened one
    as ``cspec`` to demonstrate counterexample extraction.  On the first
    divergence the injection path is minimized and exported (into
    ``artifact_dir`` when given)."""
    ccfg = ccfg or ControllerConfig(queue_depth=2)
    oracle_cspec = oracle if oracle is not None else cspec
    alphabet = tuple(alphabet) if alphabet is not None \
        else default_alphabet(cspec)
    want_a, wr_a, sub_a, row_a = _encode_alphabet(cspec, alphabet)
    A = len(alphabet)
    vstep, sstep = _make_step(cspec, ccfg)

    cs0 = _np_tree(C.init_ctrl_state(cspec, ccfg.queue_depth))
    frontier = [_Node(cs0, DeviceUnderTest.from_compiled(oracle_cspec), ())]

    res = ExploreResult(standard=standard or cspec.standard, depth=depth,
                        states_explored=1, edges=0, commands_checked=0,
                        tables_checked=0, truncated=False, divergences=[])

    for d in range(depth):
        edges = [(ni, a) for ni in range(len(frontier)) for a in range(A)]
        res.edges += len(edges)
        next_frontier, layer_seen = [], set()
        for lo in range(0, len(edges), chunk):
            batch = edges[lo:lo + chunk]
            pad = chunk - len(batch)
            padded = batch + [batch[0]] * pad
            cs_b = _tree_stack([frontier[ni].cs for ni, _ in padded])
            ch = np.asarray([a for _, a in padded])
            new_cs, ev, tables = vstep(
                cs_b, jnp.asarray(want_a[ch]), jnp.asarray(wr_a[ch]),
                jnp.asarray(sub_a[ch]), jnp.asarray(row_a[ch]), jnp.int32(d))
            new_cs, ev, tables = (_np_tree(new_cs), _np_tree(ev),
                                  np.asarray(tables))
            for e, (ni, a) in enumerate(batch):
                parent = frontier[ni]
                path2 = parent.path + (a,)
                dut2 = _copy_dut(parent.dut)
                bad = None
                for slot in range(ev.cmd.shape[1]):
                    ci = int(ev.cmd[e, slot])
                    if ci < 0:
                        continue
                    res.commands_checked += 1
                    err = _dut_issue_checked(dut2, cspec, ci,
                                             ev.bank[e, slot],
                                             ev.row[e, slot], d)
                    if err is not None:
                        bad = Divergence("illegal_issue", d,
                                         cspec.cmd_names[ci],
                                         int(ev.bank[e, slot]),
                                         int(ev.row[e, slot]), err, path2)
                        break
                if bad is None and check_tables:
                    res.tables_checked += 1
                    err = _table_mismatch(dut2, cspec, tables[e])
                    if err is not None:
                        bad = Divergence("earliest_mismatch", d, "-", -1,
                                         -1, err, path2)
                if bad is not None:
                    res.divergences.append(bad)
                    continue          # do not expand past a divergence
                if len(next_frontier) >= max_frontier:
                    res.truncated = True
                    continue
                child = _tree_index(new_cs, e)
                key = _state_key(child)
                if key in layer_seen:
                    continue
                layer_seen.add(key)
                next_frontier.append(_Node(child, dut2, path2))
            if res.divergences:
                break
        if res.divergences:
            break
        res.states_explored += len(next_frontier)
        frontier = next_frontier
        if not frontier:
            break

    if res.divergences:
        first = res.divergences[0]

        def fails(trial):
            _, div = _run_path(cspec, oracle_cspec, ccfg, sstep, alphabet,
                               trial, check_tables=(first.kind ==
                                                    "earliest_mismatch"))
            return div

        mpath = minimize_path(list(first.path), fails)
        events, div = _run_path(cspec, oracle_cspec, ccfg, sstep, alphabet,
                                mpath, check_tables=(first.kind ==
                                                     "earliest_mismatch"))
        trace = _counterexample_trace(oracle_cspec, events, len(mpath),
                                      ccfg, cspec, mpath, div, config_doc)
        artifact = None
        if artifact_dir:
            os.makedirs(artifact_dir, exist_ok=True)
            artifact = TF.save(trace, os.path.join(
                artifact_dir,
                f"counterexample_{res.standard}_d{div.depth}"))
        res.counterexample = Counterexample(mpath, div, trace, artifact)
    return res


# ---------------------------------------------------------------------------
# Smoke-tier sweep (CI entry point)
# ---------------------------------------------------------------------------

#: the small configs of the smoke tier: (name, tiny_spec kwargs,
#: controller kwargs, explore kwargs)
SMOKE_CONFIGS = (
    ("b2-q2", dict(banks=2), dict(queue_depth=2), dict(depth=10)),
    ("b2-q3-fast", dict(banks=2, fast=True), dict(queue_depth=3),
     dict(depth=14)),
    ("b4-q2", dict(banks=4), dict(queue_depth=2), dict(depth=8)),
)


def smoke(standards=("DDR4", "DDR5", "HBM3"), configs=SMOKE_CONFIGS,
          **kw) -> dict:
    """Run the bounded-exploration smoke matrix; {(standard, cfg): result}."""
    results = {}
    for std in standards:
        for name, tkw, ckw, ekw in configs:
            cspec = tiny_spec(std, **tkw)
            cfg_doc = dict(standard=std, rows=int(cspec.rows),
                           columns=int(cspec.columns), **tkw)
            results[(std, name)] = explore(
                cspec, ccfg=ControllerConfig(**ckw), standard=std,
                config_doc=cfg_doc, **dict(ekw, **kw))
    return results
