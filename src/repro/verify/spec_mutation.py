"""Mutation-seeded negative fixtures for the spec linter.

``repro.analysis.speclint`` gates every authored standard; this module
asks the converse question — *does the linter actually catch broken
specs?* — the spec-level sibling of :mod:`repro.verify.mutation` (which
seeds the trace auditor).  Each mutator derives a single-defect variant
of a clean registered standard, one per statically-detectable rule
class:

* ``trc-shrink``       — override ``nRC`` to ``nRAS + nRP - 1``
                         (derived-timing inequality),
* ``dominated-inject`` — append a same-scope constraint row strictly
                         looser than an existing one (dead table row),
* ``coverage-delete``  — delete the bank ``PRE -> opener`` constraint
                         (zero-latency precharge-to-activate hazard),
* ``refresh-shrink``   — override ``nREFI`` below ``nRFC``
                         (unschedulable refresh),
* ``unknown-token``    — append a constraint referencing an undeclared
                         timing parameter,
* ``override-typo``    — pass a ``timing_overrides`` key outside the
                         standard's parameter namespace,
* ``ring-corrupt``     — shrink the compiled windowed-ring depth below
                         the deepest reachable window.

Every mutator is engineered so its target rule fires **exactly once**;
:func:`spec_mutation_matrix` asserts detection across standards the
same way ``mutation_matrix`` does for the auditor.
"""
from __future__ import annotations

import dataclasses

from repro.core import spec as S
from repro.core.compile import compile_spec, resolve_latency
from repro.analysis.report import ERROR, LintReport
from repro.analysis.speclint import (default_presets, lint_compiled,
                                     lint_spec)


@dataclasses.dataclass
class SpecInjection:
    """One seeded spec defect and the lint rule expected to catch it."""
    mutator: str
    rule: str                  # lint rule id expected to fire
    standard: str
    detail: str
    report: LintReport

    def hits(self) -> list:
        """Error-severity findings of the expected rule."""
        return [f for f in self.report.findings
                if f.rule == self.rule and f.severity == ERROR]


def detected(inj: SpecInjection) -> bool:
    """True iff the expected rule fired exactly once, at error severity."""
    return len(inj.hits()) == 1


def _variant(std, **attrs):
    """An unregistered single-defect subclass of a standard (same name:
    the mutation models a bad edit to that standard, and family-gated
    rules must keep applying)."""
    return type(f"{std.name}_mutant", (std,), attrs)


def _base_timings(std) -> dict:
    _, tim = default_presets(std)
    return dict(std.timing_presets[tim])


# ---------------------------------------------------------------------------
# Mutators — each returns a SpecInjection, or None when the standard
# lacks the ingredients (e.g. no windowed constraint to corrupt).
# ---------------------------------------------------------------------------

def mutate_trc_shrink(std) -> SpecInjection | None:
    t = _base_timings(std)
    if not all(k in t for k in ("nRC", "nRAS", "nRP")):
        return None
    bad = int(t["nRAS"]) + int(t["nRP"]) - 1
    rep = lint_spec(std, timing_overrides={"nRC": bad})
    return SpecInjection("trc-shrink", "trc-decomposition", std.name,
                         f"nRC={bad} < nRAS+nRP={bad + 1}", rep)


def mutate_dominated_inject(std) -> SpecInjection | None:
    t = _base_timings(std)
    for tc in std.timing_constraints:
        if tc.window != 1 or len(tc.preceding) != 1 \
                or len(tc.following) != 1:
            continue
        lat = resolve_latency(tc.latency, t)
        if lat < 2:
            continue
        loose = S.TimingConstraint(
            level=tc.level, preceding=tc.preceding, following=tc.following,
            latency=lat - 1, window=1, note="mutant: shadowed duplicate")
        mut = _variant(std, timing_constraints=(
            tuple(std.timing_constraints) + (loose,)))
        rep = lint_spec(mut)
        return SpecInjection(
            "dominated-inject", "dominated-row", std.name,
            f"{list(tc.preceding)}->{list(tc.following)}@{tc.level} "
            f"lat={lat - 1} shadowed by lat={tc.latency!r}", rep)
    return None


def mutate_coverage_delete(std) -> SpecInjection | None:
    kept, dropped = [], None
    for tc in std.timing_constraints:
        if dropped is None and tc.level == "bank" \
                and list(tc.preceding) == ["PRE"]:
            dropped = tc
            continue
        kept.append(tc)
    if dropped is None:
        return None
    mut = _variant(std, timing_constraints=tuple(kept))
    rep = lint_spec(mut)
    return SpecInjection(
        "coverage-delete", "coverage-hole", std.name,
        f"deleted bank PRE->{list(dropped.following)} "
        f"({dropped.latency!r})", rep)


def mutate_refresh_shrink(std) -> SpecInjection | None:
    t = _base_timings(std)
    if not all(k in t for k in ("nRFC", "nREFI")):
        return None
    bad = int(t["nRFC"])            # nRFC >= nREFI: unschedulable
    rep = lint_spec(std, timing_overrides={"nREFI": bad})
    return SpecInjection("refresh-shrink", "refresh-headroom", std.name,
                         f"nREFI={bad} <= nRFC={t['nRFC']}", rep)


def mutate_unknown_token(std) -> SpecInjection | None:
    bogus = S.TimingConstraint(
        level="bank", preceding=["PRE"], following=["PRE"],
        latency="nBOGUS", note="mutant: undeclared parameter")
    mut = _variant(std, timing_constraints=(
        tuple(std.timing_constraints) + (bogus,)))
    rep = lint_spec(mut)
    return SpecInjection("unknown-token", "unknown-token", std.name,
                         "constraint references undeclared 'nBOGUS'", rep)


def mutate_override_typo(std) -> SpecInjection | None:
    rep = lint_spec(std, timing_overrides={"tRRD": 4})
    return SpecInjection("override-typo", "unknown-override", std.name,
                         "override key 'tRRD' (not a timing parameter)",
                         rep)


def mutate_ring_corrupt(std) -> SpecInjection | None:
    org, tim = default_presets(std)
    cspec = compile_spec(std, org, tim)
    if cspec.n_ring == 0 or cspec.ring_depth <= 1:
        return None
    bad = dataclasses.replace(cspec, ring_depth=cspec.ring_depth - 1)
    rep = lint_compiled(bad, target=f"{std.name}[ring-corrupt]")
    return SpecInjection(
        "ring-corrupt", "ring-capacity", std.name,
        f"ring_depth {cspec.ring_depth} -> {bad.ring_depth} below the "
        "deepest reachable window", rep)


MUTATORS = {
    "trc-shrink": mutate_trc_shrink,
    "dominated-inject": mutate_dominated_inject,
    "coverage-delete": mutate_coverage_delete,
    "refresh-shrink": mutate_refresh_shrink,
    "unknown-token": mutate_unknown_token,
    "override-typo": mutate_override_typo,
    "ring-corrupt": mutate_ring_corrupt,
}


def inject(standard, mutator: str) -> SpecInjection | None:
    """Run one named mutator against a standard (name or class)."""
    if isinstance(standard, str):
        standard = S.get_standard(standard)
    return MUTATORS[mutator](standard)


def spec_mutation_matrix(standards, mutators=None) -> dict:
    """{(standard, mutator): "detected" | "MISSED:..." | "skipped:..."}.

    Detection requires the expected rule to fire exactly once at error
    severity — the 100%-detection requirement, spec edition."""
    out = {}
    for name in standards:
        for mname in (mutators or MUTATORS):
            inj = inject(name, mname)
            if inj is None:
                out[(name, mname)] = "skipped: ingredient missing"
            elif detected(inj):
                out[(name, mname)] = "detected"
            else:
                n = len(inj.hits())
                out[(name, mname)] = (f"MISSED: rule {inj.rule} fired "
                                      f"{n}x ({inj.detail})")
    return out
