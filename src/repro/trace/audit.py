"""Trace auditing: vectorized replay of a captured command trace against the
compiled constraint table, plus scheduler-behavior invariants.

This is the "fine-grained validation" pillar of the paper (§4.2) in
post-hoc form, following the re-evaluation methodology of Bostancı et al.:
instead of trusting the engine's in-loop timing checks, an *independent*
replay walks the captured command stream and re-derives, for every issued
command, the earliest cycle each timing constraint would have allowed it —
any command that issued early is reported with the exact violated
constraint, the preceding command issue it raced, and the (negative) slack.

The replay is fully vectorized: no Python loop over cycles or commands.
For each constraint ``(prev, next, level, lat, window)`` the preceding
events are bucketed by their *channel-qualified* level-``level`` hierarchy
node (``chan * num_nodes + node``; the node is a division of the flat bank
id — the trace's issue order is already time-sorted), and one
``searchsorted`` per constraint locates, for every following event, the
``window``-th most recent preceding event at the same node.  Multi-channel
traces are thereby audited per channel in the same vectorized pass —
commands on different channels never constrain each other — and the
report carries an explicit per-channel violation count (``by_channel``).
Heterogeneous traces (``group`` column, npz v3) are split by spec group
first: every channel replays against its OWN group's constraint table
(merged command ids mapped back to the group's local namespace), and the
report additionally carries a per-group count (``by_group``).  Cost is
O(n_constraints · N log N) for N commands, independent of cycle count and
channel count.

Scheduler checks replay two invariants of the modeled schedulers over the
request information embedded in the trace:

* **row-hit-first** (FR-FCFS): whenever a post-predicate row-hit candidate
  existed (the engine records this per selection pass as ``hit_ready``),
  the issued queue command must be a column/sync command — FR-FCFS never
  spends the slot on a row command while a ready hit waits;
* **age order**: among served column commands to the same (bank, row,
  command), request arrival times must be non-decreasing — both FCFS and
  FR-FCFS pick the oldest among equally-maskable candidates.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spec as S
from repro.core.compile import CompiledSpec, MemorySystemSpec, as_system
from repro.trace.capture import CommandTrace, spec_fingerprint_hex


@dataclasses.dataclass
class Violation:
    """One audit finding.  ``slack`` is issue clock minus earliest legal
    clock — negative means the command issued ``-slack`` cycles early.
    ``chan`` is the memory-system channel the command issued on and
    ``group`` its spec group (0 for homogeneous systems)."""
    check: str          # "timing" | "scheduler"
    constraint: str     # e.g. "ACT->RD @ bank lat=22" or "row_hit_first"
    clk: int            # cycle the offending command issued
    cmd: str
    bank: int
    bus: int
    prev_cmd: str = ""
    prev_clk: int = -1
    slack: int = 0
    chan: int = 0
    group: int = 0

    def __str__(self):
        s = (f"[{self.check}] {self.constraint}: {self.cmd} @ clk "
             f"{self.clk} ch {self.chan} bank {self.bank}")
        if self.prev_cmd:
            s += f" after {self.prev_cmd} @ clk {self.prev_clk}"
        if self.slack:
            s += f" (slack {self.slack})"
        return s


@dataclasses.dataclass
class AuditReport:
    n_commands: int
    n_pairs_checked: int            # (preceding, following) pairs examined
    checks: dict                    # check name -> violation count
    violations: list                # list[Violation], possibly truncated
    truncated: bool = False
    #: channel -> total violation count (every audited channel appears,
    #: so a clean multi-channel report shows an explicit zero per channel)
    by_channel: dict = dataclasses.field(default_factory=dict)
    #: spec group -> total violation count (heterogeneous systems: every
    #: group appears, each replayed against its OWN constraint table)
    by_group: dict = dataclasses.field(default_factory=dict)
    #: spec group -> display label ("DDR5", "DDR4@ll80", ...)
    group_labels: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return sum(self.checks.values()) == 0

    @property
    def n_violations(self) -> int:
        return sum(self.checks.values())

    def summary(self) -> str:
        head = (f"audited {self.n_commands} commands, "
                f"{self.n_pairs_checked} constraint pairs: ")
        if self.ok:
            tail = "clean"
        else:
            parts = [f"{n} {name}" for name, n in sorted(self.checks.items())
                     if n]
            tail = f"{self.n_violations} violations ({', '.join(parts)})"
        if len(self.by_group) > 1:
            per = ", ".join(
                f"g{g} {self.group_labels.get(g, '')}: {n}".replace("  ", " ")
                for g, n in sorted(self.by_group.items()))
            tail += f" [{per}]"
        if len(self.by_channel) > 1:
            per = ", ".join(f"ch{c}: {n}"
                            for c, n in sorted(self.by_channel.items()))
            tail += f" [{per}]"
        return head + tail


def constraint_name(cspec: CompiledSpec, i: int) -> str:
    """Human-readable identity of constraint-table row ``i``."""
    p = cspec.cmd_names[int(cspec.ct_prev[i])]
    f = cspec.cmd_names[int(cspec.ct_next[i])]
    lv = cspec.levels[int(cspec.ct_level[i])]
    name = f"{p}->{f} @ {lv} lat={int(cspec.ct_lat[i])}"
    if int(cspec.ct_win[i]) > 1:
        name += f" window={int(cspec.ct_win[i])}"
    return name


def _nodes_at(cspec: CompiledSpec, level: int, bank: np.ndarray) -> np.ndarray:
    """Level-``level`` hierarchy-node id for events at flat bank ids."""
    if level == 0:
        return np.zeros(bank.shape, np.int64)
    denom = int(np.prod(cspec.level_counts[level + 1:], dtype=np.int64))
    return int(cspec.level_offsets[level]) + bank.astype(np.int64) // denom


def _audit_timing(cspec: CompiledSpec, trace: CommandTrace, violations: list,
                  max_violations: int, by_channel: np.ndarray):
    """Replay every constraint-table row over the trace.  Hierarchy nodes
    are channel-qualified (``chan * num_nodes + node``): every constraint
    is replayed independently per memory-system channel, and commands on
    different channels never constrain each other.  Returns
    (n_violations, n_pairs_checked)."""
    N = len(trace)
    cmd = trace.cmd.astype(np.int64)
    bank = trace.bank.astype(np.int64)
    clk = trace.clk.astype(np.int64)
    chan = trace.chan.astype(np.int64)
    order = np.arange(N, dtype=np.int64)
    names = trace.cmd_names
    n_viol = 0
    n_pairs = 0
    for i in range(len(cspec.ct_prev)):
        p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
        level, lat = int(cspec.ct_level[i]), int(cspec.ct_lat[i])
        win = int(cspec.ct_win[i])
        if level > int(cspec.cmd_scope[p]):
            continue        # preceding command never stamps this level
        p_sel = np.nonzero(cmd == p)[0]
        f_sel = np.nonzero(cmd == f)[0]
        if len(p_sel) == 0 or len(f_sel) == 0:
            continue
        p_nodes = chan[p_sel] * cspec.num_nodes \
            + _nodes_at(cspec, level, bank[p_sel])
        f_nodes = chan[f_sel] * cspec.num_nodes \
            + _nodes_at(cspec, level, bank[f_sel])
        # bucket preceding events by node, keeping issue order inside each
        # bucket: composite key = node * (N+1) + order (order < N+1)
        key_p = p_nodes * (N + 1) + order[p_sel]
        sort = np.argsort(key_p, kind="stable")
        key_p = key_p[sort]
        clk_p = clk[p_sel][sort]
        # position of each following event inside its node's bucket
        query = f_nodes * (N + 1) + order[f_sel]
        pos = np.searchsorted(key_p, query)      # p events strictly before
        j = pos - win                            # window-th most recent
        valid = j >= 0
        same_node = np.zeros(len(f_sel), bool)
        same_node[valid] = (key_p[j[valid]] // (N + 1)) == f_nodes[valid]
        valid &= same_node
        n_pairs += int(np.count_nonzero(valid))
        t_prev = np.where(valid, clk_p[np.clip(j, 0, None)], np.int64(-1))
        early = valid & (clk[f_sel] < t_prev + lat)
        if not early.any():
            continue
        cname = constraint_name(cspec, i)
        np.add.at(by_channel, chan[f_sel][early], 1)
        for k in np.nonzero(early)[0]:
            n_viol += 1
            if len(violations) < max_violations:
                e = int(f_sel[k])
                violations.append(Violation(
                    check="timing", constraint=cname,
                    clk=int(clk[e]), cmd=names[int(cmd[e])],
                    bank=int(bank[e]), bus=int(trace.bus[e]),
                    prev_cmd=names[p], prev_clk=int(t_prev[k]),
                    slack=int(clk[e] - (t_prev[k] + lat)),
                    chan=int(chan[e])))
    return n_viol, n_pairs


def _audit_row_hit_first(cspec: CompiledSpec, trace: CommandTrace,
                         violations: list, max_violations: int,
                         by_channel: np.ndarray) -> int:
    """FR-FCFS invariant: when a maskable row hit existed, the issued queue
    command must be a column (or data-clock sync) command.  The engine
    records ``hit_ready`` per (channel, bus-slot) selection pass, so the
    check is channel-local by construction."""
    kind = np.asarray(cspec.cmd_kind)[trace.cmd]
    queue_issued = trace.arrive >= 0
    is_col = (kind == S.KIND_COL) | (kind == S.KIND_SYNC)
    bad = queue_issued & (trace.hit_ready != 0) & ~is_col
    names = trace.cmd_names
    np.add.at(by_channel, trace.chan[bad].astype(np.int64), 1)
    for e in np.nonzero(bad)[0]:
        if len(violations) < max_violations:
            violations.append(Violation(
                check="scheduler", constraint="row_hit_first",
                clk=int(trace.clk[e]), cmd=names[int(trace.cmd[e])],
                bank=int(trace.bank[e]), bus=int(trace.bus[e]),
                chan=int(trace.chan[e])))
    return int(np.count_nonzero(bad))


def _audit_age_order(cspec: CompiledSpec, trace: CommandTrace,
                     violations: list, max_violations: int,
                     by_channel: np.ndarray) -> int:
    """Served column commands to one (channel, bank, row, command) must
    serve requests in arrival order — each channel's controller schedules
    independently, so age order only binds within a channel."""
    fx = np.asarray(cspec.cmd_fx)[trace.cmd]
    final = (fx & (S.FX_FINAL_RD | S.FX_FINAL_WR)) != 0
    sel = np.nonzero(final & (trace.arrive >= 0))[0]
    if len(sel) < 2:
        return 0
    # stable sort by (chan, bank, row, cmd) keeps issue order per group
    keys = np.lexsort((sel, trace.cmd[sel], trace.row[sel],
                       trace.bank[sel], trace.chan[sel]))
    s = sel[keys]
    same = ((trace.chan[s][1:] == trace.chan[s][:-1])
            & (trace.bank[s][1:] == trace.bank[s][:-1])
            & (trace.row[s][1:] == trace.row[s][:-1])
            & (trace.cmd[s][1:] == trace.cmd[s][:-1]))
    regress = same & (trace.arrive[s][1:] < trace.arrive[s][:-1])
    names = trace.cmd_names
    np.add.at(by_channel, trace.chan[s][1:][regress].astype(np.int64), 1)
    for k in np.nonzero(regress)[0]:
        if len(violations) < max_violations:
            e, prev = int(s[k + 1]), int(s[k])
            violations.append(Violation(
                check="scheduler", constraint="age_order",
                clk=int(trace.clk[e]), cmd=names[int(trace.cmd[e])],
                bank=int(trace.bank[e]), bus=int(trace.bus[e]),
                prev_cmd=names[int(trace.cmd[prev])],
                prev_clk=int(trace.clk[prev]),
                slack=int(trace.arrive[e] - trace.arrive[prev]),
                chan=int(trace.chan[e])))
    return int(np.count_nonzero(regress))


def _audit_one_spec(cspec: CompiledSpec, trace: CommandTrace,
                    scheduler: str | None, max_violations: int):
    """Run the three checks of one homogeneous (sub-)trace against one
    constraint table.  Returns (checks, n_pairs, violations, ch_counts)."""
    n_channels = max(int(getattr(cspec, "n_channels", 1)),
                     int(trace.chan.max()) + 1 if len(trace) else 1)
    ch_counts = np.zeros(n_channels, np.int64)
    violations: list = []
    checks = {}
    checks["timing"], n_pairs = _audit_timing(cspec, trace, violations,
                                              max_violations, ch_counts)

    has_requests = bool(np.any(trace.arrive >= 0))
    if has_requests and scheduler == "FRFCFS":
        checks["row_hit_first"] = _audit_row_hit_first(
            cspec, trace, violations, max_violations, ch_counts)
    if has_requests and scheduler in ("FRFCFS", "FCFS"):
        checks["age_order"] = _audit_age_order(cspec, trace, violations,
                                               max_violations, ch_counts)
    return checks, n_pairs, violations, ch_counts


def _audit_system(msys: MemorySystemSpec, trace: CommandTrace,
                  scheduler: str | None,
                  max_violations: int) -> AuditReport:
    """Heterogeneous audit: each spec group's commands are carved out of
    the system trace (``group`` column), mapped back from the merged
    command namespace into the group's local ids, and replayed against
    the group's OWN constraint table — commands on different groups (or
    different channels of one group) never constrain each other.  Channel
    attribution in the merged report is system-wide."""
    n_names = len(trace.cmd_names)
    checks: dict = {}
    n_pairs = 0
    violations: list = []
    ch_counts = np.zeros(msys.n_channels, np.int64)
    by_group: dict = {}
    labels: dict = {}
    for g, grp in enumerate(msys.groups):
        base = int(msys.chan_base[g])
        labels[g] = grp.cspec.standard or grp.cspec.name
        if grp.link_latency:
            labels[g] += f"@ll{grp.link_latency}"
        m = np.nonzero(trace.group == g)[0]
        # merged-id -> group-local-id map; commands of other groups never
        # appear under this group's mask, so -1 entries are unreachable
        to_local = np.full(n_names, -1, np.int64)
        to_local[msys.group_cmd_maps[g]] = np.arange(
            len(msys.group_cmd_maps[g]))
        local_cmd = to_local[trace.cmd[m]]
        if np.any(local_cmd < 0):
            raise ValueError(
                f"trace rows of group {g} carry command ids outside the "
                "group's namespace — group column and cmd ids disagree")
        sub = CommandTrace(
            clk=trace.clk[m], cmd=local_cmd.astype(np.int32),
            bank=trace.bank[m], row=trace.row[m], bus=trace.bus[m],
            arrive=trace.arrive[m], hit_ready=trace.hit_ready[m],
            chan=(trace.chan[m] - base).astype(np.int32),
            n_cycles=trace.n_cycles, cmd_names=list(grp.cspec.cmd_names),
            meta=dict(trace.meta, n_channels=grp.channels))
        g_checks, g_pairs, g_viols, g_counts = _audit_one_spec(
            grp.cspec, sub, scheduler, max_violations - len(violations))
        for v in g_viols:
            v.chan += base
            v.group = g
        violations.extend(g_viols)
        n_pairs += g_pairs
        for k, n in g_checks.items():
            checks[k] = checks.get(k, 0) + n
        by_group[g] = int(sum(g_checks.values()))
        ch_counts[base:base + grp.channels] += g_counts[:grp.channels]
    total = sum(checks.values())
    return AuditReport(n_commands=len(trace), n_pairs_checked=n_pairs,
                       checks=checks, violations=violations,
                       truncated=total > len(violations),
                       by_channel={c: int(n)
                                   for c, n in enumerate(ch_counts)},
                       by_group=by_group, group_labels=labels)


def audit(spec, trace: CommandTrace, *,
          check_fingerprint: bool = True, scheduler: str | None = None,
          max_violations: int = 256) -> AuditReport:
    """Audit a captured trace against its constraint table(s).

    ``spec`` may be a :class:`CompiledSpec`, a heterogeneous
    :class:`repro.core.compile.MemorySystemSpec` (each channel is then
    replayed against its own group's constraint table), or None — the
    spec/system is then recompiled from the trace's embedded provenance.
    When ``check_fingerprint`` is set (default), a provided spec must
    match the fingerprint the trace was captured under.  ``scheduler``
    defaults to the controller scheduler recorded in the trace metadata;
    the row-hit-first check only applies to FR-FCFS.
    """
    if spec is None:
        spec = trace.compiled_system() if trace.n_groups > 1 \
            else trace.compiled_spec()
    elif check_fingerprint and trace.fingerprint:
        got = spec_fingerprint_hex(spec)
        if got != trace.fingerprint:
            raise ValueError(
                f"spec fingerprint {got} does not match trace fingerprint "
                f"{trace.fingerprint}; audit would be meaningless "
                "(pass check_fingerprint=False to override)")

    if scheduler is None:
        scheduler = trace.meta.get("controller", {}).get("scheduler")

    if isinstance(spec, MemorySystemSpec):
        if spec.n_groups > 1:
            return _audit_system(spec, trace, scheduler, max_violations)
        spec = spec.groups[0].cspec
    cspec = spec

    checks, n_pairs, violations, ch_counts = _audit_one_spec(
        cspec, trace, scheduler, max_violations)
    total = sum(checks.values())
    return AuditReport(n_commands=len(trace), n_pairs_checked=n_pairs,
                       checks=checks, violations=violations,
                       truncated=total > len(violations),
                       by_channel={c: int(n)
                                   for c, n in enumerate(ch_counts)},
                       by_group={0: total})
