"""Trace CLI: ``python -m repro.trace`` — simulate, capture, audit, render.

    # simulate -> capture -> audit -> artifact + HTML
    PYTHONPATH=src python -m repro.trace --standard DDR4 --cycles 20000 \\
        --out trace.npz --html trace.html

    # heterogeneous (CXL-style) composition: repeatable --group
    # STD[:CHANNELS[:LINK_LATENCY]] — per-group zero-violation audit
    PYTHONPATH=src python -m repro.trace --group DDR5:2 --group DDR4:2:80 \\
        --cycles 20000 --fail-on-violations

    # re-audit and re-render a saved artifact
    PYTHONPATH=src python -m repro.trace --load trace.npz --html trace.html

CI uses ``--fail-on-violations`` to turn any audit finding into a nonzero
exit status.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.dse.spec import DEFAULT_SYSTEMS


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="DRAM command-trace capture, audit, and visualization.")
    src = ap.add_argument_group("trace source")
    src.add_argument("--standard", default="DDR4",
                     help="standard to simulate "
                          f"(known: {','.join(sorted(DEFAULT_SYSTEMS))})")
    src.add_argument("--org", default=None,
                     help="org preset (default: the standard's default)")
    src.add_argument("--timing", default=None,
                     help="timing preset (default: the standard's default)")
    src.add_argument("--cycles", default=20_000, type=int)
    src.add_argument("--channels", default=1, type=int,
                     help="memory-system channel count")
    src.add_argument("--group", default=None, action="append",
                     metavar="STD[:CHANNELS[:LINK]]",
                     help="heterogeneous spec group (repeatable): standard"
                          " name from the default systems, channel count, "
                          "CXL link latency in cycles — e.g. "
                          "--group DDR5:2 --group DDR4:2:80.  Overrides "
                          "--standard/--channels")
    src.add_argument("--mapper", default=None,
                     help="address-mapper order (see repro.core.addrmap."
                          "MAPPERS); default: the frontend's")
    src.add_argument("--interval", default=4.0, type=float,
                     help="streaming inter-arrival interval in cycles")
    src.add_argument("--ratio", default=1.0, type=float, help="read ratio")
    src.add_argument("--scheduler", default="FRFCFS",
                     choices=("FRFCFS", "FCFS"))
    src.add_argument("--seed", default=0x1234, type=int)
    src.add_argument("--load", default=None, metavar="TRACE_NPZ",
                     help="audit/render a saved artifact instead of "
                          "simulating")
    out = ap.add_argument_group("outputs")
    out.add_argument("--out", default=None, metavar="TRACE_NPZ",
                     help="write the captured trace artifact here")
    out.add_argument("--html", default=None,
                     help="render the visualizer HTML here")
    out.add_argument("--jsonl", default=None,
                     help="stream the trace as JSON Lines here")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the audit pass")
    ap.add_argument("--fail-on-violations", action="store_true",
                    help="exit nonzero when the audit finds violations")
    ap.add_argument("--max-violations", default=20, type=int,
                    help="violations to print (report stores up to 256)")
    return ap


def _parse_group(text: str) -> dict:
    parts = text.split(":")
    std = parts[0]
    if std not in DEFAULT_SYSTEMS:
        raise SystemExit(f"no default org/timing for {std!r}; known: "
                         f"{sorted(DEFAULT_SYSTEMS)}")
    org, tim = DEFAULT_SYSTEMS[std]
    return dict(standard=std, org_preset=org, timing_preset=tim,
                channels=int(parts[1]) if len(parts) > 1 else 1,
                link_latency=int(parts[2]) if len(parts) > 2 else 0)


def _simulate_system(args):
    from repro.core import ControllerConfig, Simulator, compile_system
    from repro.trace.capture import capture
    msys = compile_system([_parse_group(g) for g in args.group])
    sim = Simulator(system=msys,
                    controller=ControllerConfig(scheduler=args.scheduler),
                    mapper=args.mapper)
    stats, dense = sim.run(args.cycles, interval=args.interval,
                           read_ratio=args.ratio, trace=True,
                           seed=args.seed)
    trace = capture(
        msys, dense, controller=sim.controller, frontend=sim.frontend,
        n_cycles_requested=args.cycles, interval=args.interval,
        read_ratio=args.ratio, seed=args.seed)
    print(f"simulated {args.cycles} cycles of {msys.label} "
          f"({msys.n_channels} channels, {msys.n_groups} spec groups): "
          f"{len(trace)} commands")
    print(stats.summary(msys))
    return msys, trace


def _simulate(args):
    from repro.core import ControllerConfig, Simulator
    from repro.trace.capture import capture
    if args.org is None or args.timing is None:
        if args.standard not in DEFAULT_SYSTEMS:
            raise SystemExit(
                f"no default org/timing for {args.standard!r}; pass --org "
                f"and --timing (known defaults: {sorted(DEFAULT_SYSTEMS)})")
        org, tim = DEFAULT_SYSTEMS[args.standard]
        org = args.org or org
        tim = args.timing or tim
    else:
        org, tim = args.org, args.timing
    sim = Simulator(args.standard, org, tim,
                    controller=ControllerConfig(scheduler=args.scheduler),
                    channels=args.channels, mapper=args.mapper)
    stats, dense = sim.run(args.cycles, interval=args.interval,
                           read_ratio=args.ratio, trace=True,
                           seed=args.seed)
    trace = capture(
        sim.cspec, dense, controller=sim.controller, frontend=sim.frontend,
        n_cycles_requested=args.cycles, interval=args.interval,
        read_ratio=args.ratio, seed=args.seed)
    print(f"simulated {args.cycles} cycles of {args.standard} ({org}/{tim}"
          f", {args.channels} channel{'s' if args.channels > 1 else ''})"
          f": {len(trace)} commands")
    print(stats.summary(sim.cspec))
    return sim.cspec, trace


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro import trace as T

    if args.load:
        trace = T.load(args.load)
        cspec = trace.compiled_system()
        label = cspec.label if "system" in trace.meta \
            else trace.meta["standard"]
        print(f"loaded {args.load}: {len(trace)} commands over "
              f"{trace.n_cycles} cycles of {label} "
              f"(fingerprint {trace.fingerprint})")
    elif args.group:
        cspec, trace = _simulate_system(args)
    else:
        cspec, trace = _simulate(args)

    if args.out:
        d = os.path.dirname(args.out)
        if d:
            os.makedirs(d, exist_ok=True)
        path = T.save(trace, args.out)
        print(f"trace artifact written to {path}")
    if args.jsonl:
        n = T.write_jsonl(trace, args.jsonl)
        print(f"{n} JSONL records written to {args.jsonl}")

    report = None
    if not args.no_audit:
        report = T.audit(cspec, trace)
        print(report.summary())
        for v in report.violations[:args.max_violations]:
            print(f"  {v}")
        if len(report.violations) > args.max_violations:
            print(f"  ... {report.n_violations - args.max_violations} more")

    if args.html:
        d = os.path.dirname(args.html)
        if d:
            os.makedirs(d, exist_ok=True)
        T.write_html(args.html, trace, cspec, report)
        print(f"visualizer written to {args.html}")

    if args.fail_on_violations and report is not None and not report.ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
