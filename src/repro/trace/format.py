"""Trace artifact persistence: ``.npz`` round-trip + streaming JSONL export.

One ``.npz`` file holds the whole artifact: the columnar int32 arrays plus a
JSON-encoded metadata blob (spec provenance, resolved timings, fingerprint,
run configuration) — no sidecar files, so a trace artifact can be moved or
attached to a CI run as a single object.  ``save``/``load`` are exact
round-trips (tested field-for-field).

JSONL export streams one record per issued command for interop with external
trace tooling; ``iter_records`` is the shared record iterator.
"""
from __future__ import annotations

import json

import numpy as np

from repro.trace.capture import FIELDS, CommandTrace

#: v2 added the ``chan`` (memory-system channel) column; v3 added the
#: ``group`` (spec group) column for heterogeneous systems.  v1/v2
#: artifacts load with all-zero channel/group columns.
_FORMAT_VERSION = 3


def save(trace: CommandTrace, path: str) -> str:
    """Write one self-contained ``.npz`` trace artifact.  Returns ``path``."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    np.savez_compressed(
        path,
        __version__=np.int32(_FORMAT_VERSION),
        n_cycles=np.int64(trace.n_cycles),
        cmd_names=np.array(trace.cmd_names),   # numpy infers the U width
        meta_json=np.array(json.dumps(trace.meta)),
        group=trace.group,
        **{f: getattr(trace, f) for f in FIELDS})
    return path


def load(path: str) -> CommandTrace:
    """Load a trace artifact written by :func:`save` (any version up to
    the current one)."""
    with np.load(path, allow_pickle=False) as z:
        version = int(z["__version__"])
        if version > _FORMAT_VERSION:
            raise ValueError(f"trace artifact version {version} is newer "
                             f"than supported {_FORMAT_VERSION}")
        cols = {f: np.ascontiguousarray(z[f], np.int32)
                for f in FIELDS if f in z}   # v1: no chan column
        if "group" in z:                     # v3: spec-group column
            cols["group"] = np.ascontiguousarray(z["group"], np.int32)
        return CommandTrace(
            n_cycles=int(z["n_cycles"]),
            cmd_names=[str(n) for n in z["cmd_names"]],
            meta=json.loads(str(z["meta_json"])),
            **cols)


def iter_records(trace: CommandTrace, start: int = 0,
                 stop: int | None = None):
    """Yield ``{clk, cmd, bank, row, bus, arrive}`` dicts (command names
    resolved) for commands with ``start <= clk < stop``, in issue order."""
    names = trace.cmd_names
    clk = trace.clk
    lo = int(np.searchsorted(clk, start, side="left"))
    hi = len(clk) if stop is None else \
        int(np.searchsorted(clk, stop, side="left"))
    for i in range(lo, hi):
        yield {"clk": int(clk[i]), "cmd": names[int(trace.cmd[i])],
               "bank": int(trace.bank[i]), "row": int(trace.row[i]),
               "bus": int(trace.bus[i]), "arrive": int(trace.arrive[i]),
               "chan": int(trace.chan[i]), "group": int(trace.group[i])}


def write_jsonl(trace: CommandTrace, path_or_file) -> int:
    """Stream the trace as JSON Lines: a header line with the metadata,
    then one line per command.  Returns the number of command lines."""
    own = isinstance(path_or_file, str)
    f = open(path_or_file, "w") if own else path_or_file
    try:
        header = {"type": "trace_header", "n_cycles": trace.n_cycles,
                  "n_commands": len(trace), "meta": trace.meta}
        f.write(json.dumps(header) + "\n")
        n = 0
        for rec in iter_records(trace):
            f.write(json.dumps(rec, separators=(",", ":")) + "\n")
            n += 1
        return n
    finally:
        if own:
            f.close()


def read_jsonl(path_or_file) -> CommandTrace:
    """Rebuild a :class:`CommandTrace` from :func:`write_jsonl` output."""
    own = isinstance(path_or_file, str)
    f = open(path_or_file) if own else path_or_file
    try:
        header = json.loads(f.readline())
        if header.get("type") != "trace_header":
            raise ValueError("missing trace_header line")
        meta = header["meta"]
        recs = [json.loads(line) for line in f if line.strip()]
    finally:
        if own:
            f.close()
    # command names come from the resolved spec/system in the metadata
    if "system" in meta:
        from repro.trace.capture import system_from_meta
        names = list(system_from_meta(meta).cmd_names)
    else:
        from repro.core.compile import compile_spec
        cspec = compile_spec(meta["standard"], meta["org_preset"],
                             meta["timing_preset"],
                             {k: int(v) for k, v in meta["timings"].items()},
                             channels=int(meta.get("n_channels", 1)))
        names = list(cspec.cmd_names)
    i32 = lambda k, d=0: np.asarray([r.get(k, d) for r in recs], np.int32)
    return CommandTrace(
        clk=i32("clk"), cmd=np.asarray([names.index(r["cmd"]) for r in recs],
                                       np.int32),
        bank=i32("bank"), row=i32("row"), bus=i32("bus"),
        arrive=i32("arrive", -1),
        hit_ready=np.zeros(len(recs), np.int32),   # not exported to JSONL
        chan=i32("chan"), group=i32("group"),
        n_cycles=int(header["n_cycles"]), cmd_names=names, meta=meta)
