"""Command-trace visualizer (paper §4.1, Fig. 2) on top of ``CommandTrace``.

Standalone HTML, two linked views as in the paper — (a) bus utilization,
(b) per-bank command trace — plus an audit-violation overlay lane fed by
:mod:`repro.trace.audit`.

Scale: the renderer is level-of-detail (LOD) binned.  Python precomputes a
fixed number of time bins (per-bin C/A and data-bus occupancy, and per
(bin, lane) dominant-command densities); raw per-command records are only
embedded when the trace is small enough (``raw_limit``).  Zoomed out — or
for multi-million-command traces with no raw records at all — the command
view draws the binned densities; zoomed in with raw records available it
draws exact per-command rectangles.  Payload size and draw cost are
therefore bounded by the bin count, not the trace length.

Bus-utilization denominators are *derived*, not hardcoded: a bin of
``bw`` cycles offers ``bw x n_command_buses x n_channels`` C/A slots (two
buses for dual-C/A standards such as HBM3/GDDR7, times the memory-system
channel count) and ``bw x n_channels`` data-bus cycles, of which each
final RD/WR occupies ``nBL``.

Multi-channel traces render as stacked per-channel lane groups (each
channel: its banks + a refresh-engine lane), labeled ``chN bM`` /
``chN ref``; the audit-violation overlay lane is shared at the bottom.
"""
from __future__ import annotations

import json
import math

import numpy as np

from repro.core import spec as S
from repro.core.compile import as_system
from repro.trace.capture import CommandTrace

PALETTE = ["#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f",
           "#edc948", "#b07aa1", "#ff9da7", "#9c755f", "#bab0ac",
           "#86bcb6", "#d37295"]

#: Cap on violations embedded in the overlay payload.
MAX_OVERLAY_VIOLATIONS = 500


class _View:
    """Per-system-channel display geometry: lane bases, bank counts, data
    burst lengths, and lane labels — possibly different per channel for a
    heterogeneous system (lanes are labeled by standard)."""

    def __init__(self, msys, trace: CommandTrace):
        self.msys = msys
        nch = msys.n_channels
        self.n_banks = np.asarray(
            [msys.groups[g].cspec.n_banks for g in msys.chan_group],
            np.int64)
        self.nbl = np.asarray(
            [int(msys.groups[g].cspec.timings["nBL"])
             for g in msys.chan_group], np.int64)
        self.lane_base = np.concatenate(
            [[0], np.cumsum(self.n_banks + 1)[:-1]])
        self.n_lanes = int(np.sum(self.n_banks + 1))
        self.n_cmd_buses = sum(
            (2 if msys.groups[g].cspec.dual_command_bus else 1)
            for g in msys.chan_group)
        # merged-namespace command kinds (groups agree on shared names'
        # kinds in practice; first writer wins) for the legacy fallback
        kind = np.zeros(len(trace.cmd_names), np.int64)
        for g in range(msys.n_groups - 1, -1, -1):
            kind[msys.group_cmd_maps[g]] = msys.groups[g].cspec.cmd_kind
        self.kind = kind
        hetero = msys.n_groups > 1
        self.lane_names = []
        for c in range(nch):
            std = msys.groups[msys.chan_group[c]].cspec.standard
            for b in range(int(self.n_banks[c])):
                if nch == 1:
                    self.lane_names.append(f"bank {b}")
                elif hetero:
                    self.lane_names.append(f"ch{c} {std} b{b}")
                else:
                    self.lane_names.append(f"ch{c} b{b}")
            if nch == 1:
                self.lane_names.append("refresh")
            elif hetero:
                self.lane_names.append(f"ch{c} {std} ref")
            else:
                self.lane_names.append(f"ch{c} ref")

    def lanes(self, trace: CommandTrace) -> np.ndarray:
        """Display lane per command: channel-major — each channel
        contributes its banks plus one refresh-engine lane.  Traces
        without request info (legacy 3-array captures have ``arrive == -1``
        everywhere) fall back to command kind, and negative banks are
        always routed to their channel's refresh lane."""
        if bool(np.any(trace.arrive >= 0)):
            refresh = trace.arrive < 0
        else:
            refresh = self.kind[trace.cmd] == S.KIND_REF
        nb = self.n_banks[trace.chan]
        local = np.where(refresh | (trace.bank < 0), nb,
                         np.minimum(trace.bank, nb))
        return self.lane_base[trace.chan] + local


def _bin_payload(trace: CommandTrace, view: _View, n_bins: int) -> dict:
    """Precompute the LOD summaries: per-bin bus occupancy and per
    (bin, lane) dominant command + count.  Data-bus occupancy weighs each
    final RD/WR by its OWN channel's burst length (heterogeneous groups
    have different nBL)."""
    T = max(1, trace.n_cycles)
    bw = max(1, math.ceil(T / n_bins))
    nb = math.ceil(T / bw)
    n_lanes = view.n_lanes
    b = trace.clk // bw

    ca = np.bincount(b, minlength=nb)
    msys = view.msys
    n_names = len(trace.cmd_names)
    fx_lut = np.zeros((msys.n_groups, n_names), np.int64)
    for g, grp in enumerate(msys.groups):
        fx_lut[g, msys.group_cmd_maps[g]] = grp.cspec.cmd_fx
    fx = fx_lut[trace.group, trace.cmd]
    final = (fx & (S.FX_FINAL_RD | S.FX_FINAL_WR)) != 0
    data = np.bincount(b[final], weights=view.nbl[trace.chan[final]],
                       minlength=nb).astype(np.int64)

    lane = view.lanes(trace)
    flat = b.astype(np.int64) * n_lanes + lane
    counts = np.zeros((n_names, nb * n_lanes), np.int32)
    for c in range(n_names):
        m = trace.cmd == c
        if m.any():
            counts[c] = np.bincount(flat[m], minlength=nb * n_lanes)
    dom = counts.argmax(axis=0).astype(np.int32)
    cnt = counts.sum(axis=0).astype(np.int32)
    dom[cnt == 0] = -1
    return {"bw": bw, "nb": nb, "n_lanes": n_lanes,
            "ca": ca.tolist(), "data": data.tolist(),
            "dom": dom.tolist(), "cnt": cnt.tolist()}


def render_html(trace: CommandTrace, cspec=None, report=None,
                title: str = "", n_bins: int = 2048,
                raw_limit: int = 100_000) -> str:
    """Render the two-view HTML.  ``cspec`` may be a CompiledSpec, a
    :class:`repro.core.compile.MemorySystemSpec` (heterogeneous traces
    label their lanes by standard), or None (recompiled from the trace).
    ``report`` (an :class:`repro.trace.audit.AuditReport`) adds the
    violation overlay."""
    if cspec is None:
        msys = trace.compiled_system()
    else:
        msys = as_system(cspec)
    view = _View(msys, trace)
    colors = {name: PALETTE[i % len(PALETTE)]
              for i, name in enumerate(trace.cmd_names)}

    recs = None
    if len(trace) <= raw_limit:
        lane = view.lanes(trace)
        recs = {"clk": trace.clk.tolist(), "cmd": trace.cmd.tolist(),
                "lane": lane.tolist(), "row": trace.row.tolist(),
                "bus": trace.bus.tolist()}

    viols = []
    if report is not None:
        for v in report.violations[:MAX_OVERLAY_VIOLATIONS]:
            viols.append({"clk": v.clk, "cmd": v.cmd,
                          "label": f"{v.check}: {v.constraint}"})
    payload = {
        "title": title or f"{msys.label} command trace",
        "standard": msys.label,
        "n_channels": int(msys.n_channels),
        "n_cycles": int(trace.n_cycles),
        "n_commands": len(trace),
        "n_cmd_buses": view.n_cmd_buses,    # summed across channels
        "cmd_names": list(trace.cmd_names),
        "colors": colors,
        "lane_names": view.lane_names,
        "bins": _bin_payload(trace, view, n_bins),
        "recs": recs,
        "viols": viols,
        "n_violations": 0 if report is None else report.n_violations,
        "audited": report is not None,
    }
    return _TEMPLATE.replace("__PAYLOAD__", json.dumps(payload))


def write_html(path: str, trace: CommandTrace, cspec=None, report=None,
               title: str = "", n_bins: int = 2048,
               raw_limit: int = 100_000) -> str:
    html = render_html(trace, cspec, report, title, n_bins, raw_limit)
    with open(path, "w") as f:
        f.write(html)
    return path


_TEMPLATE = """<!doctype html>
<html><head><meta charset="utf-8"><title>Ramulator-JAX trace</title>
<style>
 body{font-family:system-ui,sans-serif;margin:12px;background:#fafafa}
 h2{margin:4px 0} .views{display:flex;flex-direction:column;gap:12px}
 canvas{background:#fff;border:1px solid #ccc;width:100%}
 #tip{position:fixed;background:#222;color:#fff;padding:4px 8px;
      border-radius:4px;font-size:12px;pointer-events:none;display:none;
      max-width:480px}
 .legend span{display:inline-block;margin-right:10px;font-size:12px}
 .legend i{display:inline-block;width:10px;height:10px;margin-right:3px}
 .bar{display:flex;gap:16px;align-items:center;font-size:13px}
 .viol{color:#c0392b;font-weight:600}
</style></head><body>
<h2 id="title"></h2>
<div class="bar">
  <label>zoom <input id="zoom" type="range" min="0" max="100" value="35"></label>
  <label>offset <input id="off" type="range" min="0" max="1000" value="0"></label>
  <span id="stats"></span>
</div>
<div class="views">
 <div><b>(a) bus utilization</b><canvas id="bus" height="140"></canvas></div>
 <div><b>(b) command trace</b><canvas id="cmds" height="460"></canvas></div>
</div>
<div class="legend" id="legend"></div>
<div id="tip"></div>
<script>
const D = __PAYLOAD__;
const ML = 60;                       // left margin for lane labels
document.getElementById('title').textContent = D.title;
const maxClk = Math.max(1, D.n_cycles);
const legend = document.getElementById('legend');
for (const [name,col] of Object.entries(D.colors)) {
  const s=document.createElement('span');
  s.innerHTML='<i style="background:'+col+'"></i>'+name; legend.appendChild(s);
}
const busC = document.getElementById('bus'), cmdC = document.getElementById('cmds');
const tip = document.getElementById('tip');
// zoom slider is log-scale: 0 -> whole trace fits, 100 -> 10 px per clk
let pxPerClk = 1, off = 0;
function zoomVal(v){
  const fit = (cmdC.width - ML - 10) / maxClk;
  return fit * Math.pow(10/fit, v/100);
}
document.getElementById('zoom').oninput = e=>{
  pxPerClk = zoomVal(+e.target.value); draw();};
document.getElementById('off').oninput = e=>{
  off = +e.target.value/1000*maxClk; draw();};
function layout(){
  busC.width = busC.clientWidth; cmdC.width = cmdC.clientWidth;
  pxPerClk = zoomVal(+document.getElementById('zoom').value); draw();
}
const N_LANES = D.lane_names.length; // channel lane groups (possibly
                                     // heterogeneous bank counts)
function laneGeom(){
  // per-channel lane groups + one shared audit-violation lane
  const lanes = N_LANES + 1;
  const laneH = Math.max(5, Math.floor((cmdC.height-24)/lanes));
  return {lanes, laneH};
}
function laneName(l){
  return (l >= N_LANES) ? 'audit' : D.lane_names[l];
}
function drawCmds(){
  const W = cmdC.width, {lanes, laneH} = laneGeom();
  const g = cmdC.getContext('2d'); g.clearRect(0,0,W,cmdC.height);
  g.font='10px sans-serif'; g.fillStyle='#888';
  for (let l=0;l<N_LANES;l++)
    g.fillText(laneName(l), 2, 8+l*laneH+laneH*0.7);
  g.fillStyle='#c0392b';
  g.fillText('audit', 2, 8+N_LANES*laneH+laneH*0.7);
  const x0 = clk => (clk-off)*pxPerClk + ML;
  const rawMode = D.recs && pxPerClk >= 0.5;
  if (rawMode){
    const recs = D.recs, n = recs.clk.length;
    // visible clk range -> index range (clk array is sorted)
    const lo = off - 2/pxPerClk, hi = off + (W-ML)/pxPerClk + 2;
    let i0 = lowerBound(recs.clk, lo), i1 = lowerBound(recs.clk, hi);
    for (let i=i0;i<i1;i++){
      const x = x0(recs.clk[i]);
      g.fillStyle = D.colors[D.cmd_names[recs.cmd[i]]]||'#000';
      g.fillRect(x, 8+recs.lane[i]*laneH,
                 Math.max(2,pxPerClk*0.9), laneH-2);
    }
  } else {
    const B = D.bins, bw = B.bw;
    for (let i=0;i<B.nb;i++){
      const x = x0(i*bw), w = Math.max(1, bw*pxPerClk);
      if (x+w < ML-10 || x > W) continue;
      for (let l=0;l<B.n_lanes;l++){
        const c = B.cnt[i*B.n_lanes+l];
        if (!c) continue;
        const name = D.cmd_names[B.dom[i*B.n_lanes+l]];
        g.fillStyle = D.colors[name]||'#000';
        g.globalAlpha = Math.min(1, 0.25 + c/bw);
        g.fillRect(x, 8+l*laneH, w, laneH-2);
      }
    }
    g.globalAlpha = 1;
  }
  // audit-violation overlay lane
  const vy = 8+(D.n_channels*CH_LANES)*laneH;
  for (const v of D.viols){
    const x = x0(v.clk);
    if (x < ML-10 || x > W) continue;
    g.fillStyle='#c0392b';
    g.fillRect(x, vy, Math.max(2,pxPerClk*0.9), laneH-2);
  }
  const mode = rawMode ? 'exact' : ('binned x'+D.bins.bw);
  const v = D.audited
    ? (D.n_violations ? ' — '+D.n_violations+' audit violations' : ' — audit clean')
    : '';
  const st = document.getElementById('stats');
  st.innerHTML = D.n_commands+' commands, '+maxClk+' cycles ['+mode+']'
    + (D.n_violations ? '<span class="viol">'+v+'</span>' : v);
}
function drawBus(){
  const bg = busC.getContext('2d');
  bg.clearRect(0,0,busC.width,busC.height);
  const B = D.bins, bw = B.bw;
  // derived denominators: each channel contributes its own C/A + data bus
  // D.n_cmd_buses is already summed across channels
  const caCap = bw * D.n_cmd_buses;       // C/A slots per bin
  const dataCap = bw * D.n_channels;      // data-bus cycles per bin
  const w = Math.max(1, (busC.width-ML-10)/B.nb);
  bg.fillStyle='#888'; bg.font='10px sans-serif';
  bg.fillText('C/A bus', 2, 30); bg.fillText('data bus', 2, 100);
  for (let i=0;i<B.nb;i++){
    const u = Math.min(1, B.ca[i]/caCap);
    const d = Math.min(1, B.data[i]/dataCap);
    bg.fillStyle='#4e79a7';
    bg.fillRect(ML+i*w, 50-40*u, Math.max(1,w-0.5), 40*u);
    bg.fillStyle='#e15759';
    bg.fillRect(ML+i*w, 120-40*d, Math.max(1,w-0.5), 40*d);
  }
}
function draw(){ drawCmds(); drawBus(); }
function lowerBound(a, x){
  let lo=0, hi=a.length;
  while (lo<hi){ const m=(lo+hi)>>1; if (a[m]<x) lo=m+1; else hi=m; }
  return lo;
}
cmdC.onmousemove = e=>{
  const rect = cmdC.getBoundingClientRect();
  const clk = Math.round((e.clientX-rect.left-ML)/pxPerClk + off);
  const lines = [];
  const vnear = D.viols.filter(v=>Math.abs(v.clk-clk)<=Math.max(1,1/pxPerClk));
  for (const v of vnear) lines.push('VIOLATION '+v.label+' @ clk '+v.clk);
  if (D.recs){
    const recs = D.recs;
    const i0 = lowerBound(recs.clk, clk-1), i1 = lowerBound(recs.clk, clk+2);
    for (let i=i0;i<i1 && lines.length<8;i++)
      lines.push(D.cmd_names[recs.cmd[i]]+'@clk'+recs.clk[i]
                 +' '+laneName(recs.lane[i])
                 +(recs.row[i]>=0?' row'+recs.row[i]:''));
  } else {
    const B = D.bins, b = Math.floor(clk/B.bw);
    if (b>=0 && b<B.nb)
      lines.push('bin '+b+': '+B.ca[b]+' cmds, data '+B.data[b]+'/'+B.bw);
  }
  if (lines.length && clk>=0 && clk<=maxClk){
    tip.style.display='block'; tip.style.left=(e.clientX+12)+'px';
    tip.style.top=(e.clientY+12)+'px';
    tip.textContent = lines.join(' | ');
  } else tip.style.display='none';
};
cmdC.onmouseleave = ()=>{tip.style.display='none';};
window.onresize = layout; layout();
</script></body></html>
"""
