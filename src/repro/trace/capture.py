"""Compact columnar command-trace capture (paper §4.1 + §4.2).

The engine's ``trace=True`` path emits *dense* ``[T, 2]`` per-cycle arrays
(``repro.core.engine.TraceArrays``) that are mostly ``-1`` idle sentinels —
O(n_cycles) memory regardless of how many commands actually issued.
:func:`capture` compacts them into a :class:`CommandTrace`: one int32 column
per field, one entry per *issued* command, in exact issue order (cycle-major,
column bus before row bus — the order the engine mutates device state in).

The capture embeds everything needed to re-audit the trace later without the
original ``Simulator``: the spec provenance (standard / org / timing preset
names), the fully *resolved* timing table, and a fingerprint of the compiled
spec as the engine traced it (`repro.core.engine.spec_fingerprint`), so a
trace artifact can never be silently replayed against a different device
model.

Batched sweeps vmap the engine, so their trace arrays are ``[B, T, 2]``;
``capture(..., point=j)`` extracts one sweep point without materializing
per-point dense copies.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import engine as E
from repro.core.compile import (CompiledSpec, MemorySystemSpec, compile_spec,
                                compile_system)

#: Columnar int32 fields of a CommandTrace, in save/load order.  The
#: ``group`` column (npz format v3) is persisted separately so v1/v2
#: hashes over these fields stay stable.
FIELDS = ("clk", "cmd", "bank", "row", "bus", "arrive", "hit_ready", "chan")


def spec_fingerprint_hex(spec) -> str:
    """Stable hex digest of the compiled-spec — or memory-system — identity
    the engine keys compilations on (per group: standard/org/timing names +
    resolved timing table + geometry + channels + link latency).  A
    1-group zero-link system digests identically to its bare spec."""
    return hashlib.sha256(
        repr(E.system_fingerprint(spec)).encode()).hexdigest()[:16]


@dataclasses.dataclass
class CommandTrace:
    """Columnar DRAM command trace: one row per issued command.

    All columns are ``(N,)`` numpy arrays in issue order.  ``bank`` is the
    flat bank id (refresh-unit-scoped commands carry their unit's
    representative bank).  ``arrive`` is the served request's arrival clock
    and -1 for refresh-engine commands; ``hit_ready`` records whether a
    post-predicate row-hit candidate existed at selection time (the
    scheduler-audit observable).  ``meta`` carries spec provenance, the
    resolved timing table, the spec fingerprint, and any run configuration
    the caller supplied (controller / frontend / load point).
    """
    clk: np.ndarray
    cmd: np.ndarray
    bank: np.ndarray
    row: np.ndarray
    bus: np.ndarray
    arrive: np.ndarray
    hit_ready: np.ndarray       # int32 0/1 (npz-friendly)
    n_cycles: int
    cmd_names: list
    #: memory-system channel of each command (all-zero for single-channel
    #: traces; defaults to zeros when omitted for backward compatibility)
    chan: np.ndarray | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    #: spec group of each command (npz v3; all-zero for homogeneous
    #: traces and when loading v1/v2 artifacts).  For heterogeneous
    #: systems ``cmd`` ids index the MERGED ``cmd_names`` table; the
    #: auditor maps them back to each group's local namespace.
    group: np.ndarray | None = None

    def __post_init__(self):
        if self.chan is None:
            self.chan = np.zeros_like(np.asarray(self.clk, np.int32))
        if self.group is None:
            self.group = np.zeros_like(np.asarray(self.clk, np.int32))

    def __len__(self) -> int:
        return int(self.clk.shape[0])

    @property
    def n_channels(self) -> int:
        return int(self.meta.get("n_channels", 1))

    @property
    def n_groups(self) -> int:
        return len(self.meta.get("system", ())) or 1

    @property
    def fingerprint(self) -> str:
        return self.meta.get("fingerprint", "")

    def cmd_count(self, name: str) -> int:
        if name not in self.cmd_names:
            return 0
        return int(np.count_nonzero(self.cmd == self.cmd_names.index(name)))

    def compiled_spec(self) -> CompiledSpec:
        """Recompile the spec this trace was captured from.  The stored
        resolved timing table is replayed as overrides, so post-hoc preset
        edits round-trip exactly; the result is fingerprint-checked.
        Heterogeneous traces have no single spec — use
        :meth:`compiled_system`."""
        if self.n_groups > 1:
            raise ValueError(
                "this trace was captured from a heterogeneous memory "
                "system — use compiled_system()")
        if "system" in self.meta:
            # 1-group system capture (e.g. an all-CXL group with link
            # latency): provenance lives in the system block
            return self.compiled_system().groups[0].cspec
        m = self.meta
        cspec = compile_spec(m["standard"], m["org_preset"],
                             m["timing_preset"],
                             {k: int(v) for k, v in m["timings"].items()},
                             channels=int(m.get("n_channels", 1)))
        # replay post-compile geometry edits (benchmarks mutate rows/
        # columns in place; the fingerprint covers them)
        cspec.rows = int(m.get("rows", cspec.rows))
        cspec.columns = int(m.get("columns", cspec.columns))
        got = spec_fingerprint_hex(cspec)
        if m.get("fingerprint") and got != m["fingerprint"]:
            raise ValueError(
                f"recompiled spec fingerprint {got} != captured "
                f"{m['fingerprint']} — standard definition changed since "
                "capture")
        return cspec

    def compiled_system(self) -> MemorySystemSpec:
        """Recompile the (possibly heterogeneous) memory system this trace
        was captured from, fingerprint-checked — the system twin of
        :meth:`compiled_spec` (which it wraps for plain-spec traces)."""
        from repro.core.compile import as_system
        if "system" not in self.meta:
            return as_system(self.compiled_spec())
        msys = system_from_meta(self.meta)
        got = spec_fingerprint_hex(msys)
        if self.fingerprint and got != self.fingerprint:
            raise ValueError(
                f"recompiled system fingerprint {got} != captured "
                f"{self.fingerprint} — a standard definition changed "
                "since capture")
        return msys


def config_doc(cfg) -> dict:
    """JSON-representable scalar fields of a config dataclass (callables —
    e.g. ``extra_predicates`` — can't round-trip and are dropped).  The one
    serialization rule for run configs, shared with `repro.dse.results`."""
    if cfg is None:
        return {}
    out = {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if isinstance(v, (int, float, str, bool)) or v is None:
            out[f.name] = v
    return out


def system_from_meta(meta: dict) -> MemorySystemSpec:
    """Rebuild a memory system from a capture's ``meta["system"]`` block
    (the one shared reconstruction used by :meth:`CommandTrace.
    compiled_system` and ``repro.trace.format.read_jsonl``).  Post-compile
    geometry edits (rows/columns) are replayed per group."""
    msys = compile_system([
        dict(standard=g["standard"], org_preset=g["org_preset"],
             timing_preset=g["timing_preset"],
             timing_overrides={k: int(v) for k, v in g["timings"].items()},
             channels=int(g["channels"]),
             link_latency=int(g.get("link_latency", 0)))
        for g in meta["system"]])
    for g, doc in zip(msys.groups, meta["system"]):
        g.cspec.rows = int(doc.get("rows", g.cspec.rows))
        g.cspec.columns = int(doc.get("columns", g.cspec.columns))
    return msys


def _group_doc(cspec: CompiledSpec, channels: int, link_latency: int) -> dict:
    return {
        "standard": cspec.standard or cspec.name,
        "org_preset": cspec.org_preset,
        "timing_preset": cspec.timing_preset,
        "timings": {k: int(v) for k, v in cspec.timings.items()},
        "rows": int(cspec.rows),
        "columns": int(cspec.columns),
        "tCK_ps": int(cspec.tCK_ps),
        "n_banks": int(cspec.n_banks),
        "channels": int(channels),
        "link_latency": int(link_latency),
        "dual_command_bus": bool(cspec.dual_command_bus),
    }


def base_meta(spec, controller=None, frontend=None, **extra) -> dict:
    """Metadata block embedded in every capture: provenance + resolved
    timings + fingerprint + optional run configuration.  ``spec`` may be a
    :class:`CompiledSpec` or a :class:`repro.core.compile.MemorySystemSpec`
    — any non-trivial system (multiple groups, or a link latency) embeds
    one provenance block per spec group under ``"system"``; the
    homogeneous zero-link case keeps the historical flat spec block."""
    if isinstance(spec, MemorySystemSpec) and not spec.homogeneous:
        meta = {
            "system": [_group_doc(g.cspec, g.channels, g.link_latency)
                       for g in spec.groups],
            "fingerprint": spec_fingerprint_hex(spec),
            "n_channels": int(spec.n_channels),
            "n_groups": int(spec.n_groups),
        }
    else:
        if isinstance(spec, MemorySystemSpec):
            spec = spec.groups[0].cspec
        cspec = spec
        meta = {
            "standard": cspec.standard or cspec.name,
            "org_preset": cspec.org_preset,
            "timing_preset": cspec.timing_preset,
            "timings": {k: int(v) for k, v in cspec.timings.items()},
            "fingerprint": spec_fingerprint_hex(cspec),
            "rows": int(cspec.rows),
            "columns": int(cspec.columns),
            "tCK_ps": int(cspec.tCK_ps),
            "n_banks": int(cspec.n_banks),
            "n_channels": int(cspec.n_channels),
            "dual_command_bus": bool(cspec.dual_command_bus),
        }
    if controller is not None:
        meta["controller"] = config_doc(controller)
    if frontend is not None:
        meta["frontend"] = config_doc(frontend)
    meta.update({k: v for k, v in extra.items() if v is not None})
    return meta


def _normalize(trace):
    """Accept a ``TraceArrays``, or any 3/5-sequence of dense arrays."""
    parts = tuple(trace)
    if len(parts) < 3:
        raise ValueError("trace needs at least (cmd, bank, row) arrays")
    cmd, bank, row = (np.asarray(p) for p in parts[:3])
    arrive = np.asarray(parts[3]) if len(parts) > 3 \
        else np.full_like(cmd, -1)
    hit_ready = np.asarray(parts[4]) if len(parts) > 4 \
        else np.zeros(cmd.shape, bool)
    return cmd, bank, row, arrive, hit_ready


def capture(spec, trace, *, point: int | None = None,
            controller=None, frontend=None, **extra_meta) -> CommandTrace:
    """Compact dense engine trace arrays into a :class:`CommandTrace`.

    ``spec`` is the :class:`CompiledSpec` (homogeneous) or
    :class:`repro.core.compile.MemorySystemSpec` the run was built from.
    ``trace`` is the second element of ``Simulator.run(..., trace=True)``
    (dense ``[T, 2]`` arrays), or the vmapped ``[B, T, 2]`` stack a batched
    sweep produces — pass ``point=j`` to extract sweep point ``j``.
    Compaction is one vectorized ``nonzero`` over the issued mask; the
    resulting row order (cycle-major, bus 0 before bus 1) is exactly the
    order the engine applied the commands to device state in, which the
    auditor relies on.  Heterogeneous captures resolve the engine's
    group-local command ids into the system's merged ``cmd_names`` table
    and attach the ``group`` column.
    """
    if isinstance(spec, MemorySystemSpec):
        if not spec.homogeneous:
            # multiple groups, or a 1-group system behind a link: the
            # identity (fingerprint, provenance) is the SYSTEM tuple
            return _capture_system(spec, trace, point=point,
                                   controller=controller, frontend=frontend,
                                   **extra_meta)
        spec = spec.groups[0].cspec
    cspec = spec
    cmd, bank, row, arrive, hit_ready = _normalize(trace)
    n_channels = int(getattr(cspec, "n_channels", 1))
    # single-channel traces are [T, 2] (batched: [B, T, 2]); multi-channel
    # traces carry the channel axis in the middle: [T, C, 2] / [B, T, C, 2]
    scalar_ndim = 2 if n_channels == 1 else 3
    if cmd.ndim == scalar_ndim + 1:
        if point is None:
            raise ValueError(
                f"batched {'[B, T, 2]' if n_channels == 1 else '[B, T, C, 2]'}"
                " trace: pass point=<batch index>")
        sel = lambda a: a[point] if a.ndim == scalar_ndim + 1 else a
        cmd, bank, row = sel(cmd), sel(bank), sel(row)
        arrive, hit_ready = sel(arrive), sel(hit_ready)
    if cmd.ndim != scalar_ndim:
        raise ValueError(f"expected {scalar_ndim}-d trace arrays for a "
                         f"{n_channels}-channel spec, got {cmd.shape}")
    n_cycles = int(cmd.shape[0])

    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    if n_channels == 1:
        idx = np.nonzero(cmd >= 0)           # row-major == issue order
        t_idx, bus_idx = idx
        chan = np.zeros(len(t_idx), np.int32)
    else:
        idx = np.nonzero(cmd >= 0)           # cycle-major, channel, bus
        t_idx, chan, bus_idx = idx
    return CommandTrace(
        clk=i32(t_idx), cmd=i32(cmd[idx]),
        bank=i32(bank[idx]), row=i32(row[idx]),
        bus=i32(bus_idx), arrive=i32(arrive[idx]),
        hit_ready=i32(hit_ready[idx].astype(np.int32)),
        chan=i32(chan),
        n_cycles=n_cycles, cmd_names=list(cspec.cmd_names),
        meta=base_meta(cspec, controller=controller, frontend=frontend,
                       **extra_meta))


def _capture_system(msys: MemorySystemSpec, trace, *, point=None,
                    controller=None, frontend=None,
                    **extra_meta) -> CommandTrace:
    """System twin of :func:`capture`: dense ``[T, C_total, 2]`` arrays
    whose command ids are group-local, resolved per event through the
    channel→group map into the merged namespace."""
    cmd, bank, row, arrive, hit_ready = _normalize(trace)
    if msys.n_channels == 1:
        # single-channel systems keep the engine's squeezed [T, 2]
        # ([B, T, 2] batched) shape — restore the channel axis
        expand = lambda a: np.expand_dims(a, axis=-2)
        cmd, bank, row = expand(cmd), expand(bank), expand(row)
        arrive, hit_ready = expand(arrive), expand(hit_ready)
    if cmd.ndim == 4:
        if point is None:
            raise ValueError("batched [B, T, C, 2] trace: pass "
                             "point=<batch index>")
        sel = lambda a: a[point] if a.ndim == 4 else a
        cmd, bank, row = sel(cmd), sel(bank), sel(row)
        arrive, hit_ready = sel(arrive), sel(hit_ready)
    if cmd.ndim != 3 or cmd.shape[1] != msys.n_channels:
        raise ValueError(
            f"expected [T, {msys.n_channels}, 2] trace arrays for "
            f"{msys.label}, got {cmd.shape}")
    n_cycles = int(cmd.shape[0])
    idx = np.nonzero(cmd >= 0)               # cycle-major, channel, bus
    t_idx, chan, bus_idx = idx
    group = msys.chan_group[chan]
    # lift group-local command ids into the merged namespace: one lut row
    # per group, indexed per event by (group, local id)
    max_local = max(len(m) for m in msys.group_cmd_maps)
    lut = np.zeros((msys.n_groups, max_local), np.int64)
    for g, m in enumerate(msys.group_cmd_maps):
        lut[g, :len(m)] = m
    gcmd = lut[group, cmd[idx]]
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return CommandTrace(
        clk=i32(t_idx), cmd=i32(gcmd),
        bank=i32(bank[idx]), row=i32(row[idx]),
        bus=i32(bus_idx), arrive=i32(arrive[idx]),
        hit_ready=i32(hit_ready[idx].astype(np.int32)),
        chan=i32(chan), group=i32(group),
        n_cycles=n_cycles, cmd_names=list(msys.cmd_names),
        meta=base_meta(msys, controller=controller, frontend=frontend,
                       **extra_meta))


def _unflatten_banks(cspec: CompiledSpec, bank: np.ndarray,
                     width: int) -> np.ndarray:
    """Flat bank ids -> (N, width) sub-level indices (zero-padded)."""
    counts = cspec.level_counts
    b = bank.astype(np.int64)
    subs = []
    for i in range(len(counts) - 1, 0, -1):
        subs.append(b % int(counts[i]))
        b = b // int(counts[i])
    sub = np.stack(subs[::-1], axis=-1)
    if sub.shape[-1] < width:
        pad = np.zeros(sub.shape[:-1] + (width - sub.shape[-1],), np.int64)
        sub = np.concatenate([sub, pad], axis=-1)
    return sub


def _replay_deps(chan, bank, row, is_wr) -> np.ndarray:
    """Same-address RAW/WAR dependency index per request, -1 = none.

    Addresses are (chan, bank, row) — ``to_replay`` zeroes the column, so
    the dependency granularity is the DRAM row.  A read depends on the
    most recent earlier write to its row (RAW); a write depends on the
    most recent earlier read (WAR).  Producers always precede their
    dependents in the (arrival-ordered) stream."""
    dep = np.full(len(chan), -1, np.int64)
    last_w: dict = {}
    last_r: dict = {}
    for k in range(len(chan)):
        key = (int(chan[k]), int(bank[k]), int(row[k]))
        if is_wr[k]:
            dep[k] = last_r.get(key, -1)
            last_w[key] = k
        else:
            dep[k] = last_w.get(key, -1)
            last_r[key] = k
    return dep


def to_replay(trace: CommandTrace, spec=None, *, deps: bool = False):
    """Derive a trace-driven-frontend :class:`repro.core.ReplayStream`
    from a captured trace's served column commands (final RD/WR with
    request info), channel — and, for heterogeneous traces, spec-group —
    attribution included.  The captured ``arrive`` clocks ride along
    (sorted into arrival order), so replay paces injection by the
    original inter-arrival gaps rather than the streaming interval.  With
    ``deps=True`` the stream additionally carries same-address RAW/WAR
    dependencies (``ReplayStream.dep``): the frontend then holds each
    dependent request until its producer has been served (conservatively:
    until every earlier stream request has been — sound under FR-FCFS
    reordering), instead of replaying them as independent arrivals.  Feed
    the result to
    ``Simulator(..., frontend=FrontendConfig(pattern="trace"),
    replay=...)`` to re-drive any memory system with the same per-channel
    address stream."""
    from repro.core import spec as S
    from repro.core.compile import as_system
    from repro.core.frontend import ReplayStream
    if spec is None:
        msys = trace.compiled_system()
    else:
        msys = as_system(spec)
    # per-event fx flags in the trace's command namespace: for a system
    # trace the namespace is merged, so resolve fx through each group
    if msys.n_groups == 1:
        fx = np.asarray(msys.groups[0].cspec.cmd_fx)[trace.cmd]
    else:
        n_names = len(trace.cmd_names)
        fx_lut = np.zeros((msys.n_groups, n_names), np.int64)
        for g, grp in enumerate(msys.groups):
            fx_lut[g, msys.group_cmd_maps[g]] = grp.cspec.cmd_fx
        fx = fx_lut[trace.group, trace.cmd]
    is_wr = (fx & S.FX_FINAL_WR) != 0
    sel = np.nonzero((((fx & S.FX_FINAL_RD) != 0) | is_wr)
                     & (trace.arrive >= 0))[0]
    if len(sel) == 0:
        raise ValueError("trace has no served column commands to replay")
    # the frontend injects sequentially, so replay requests in ARRIVAL
    # order (issue order is scheduler-permuted under FR-FCFS) — this is
    # also what makes the arrive column a monotone pacing schedule
    sel = sel[np.argsort(trace.arrive[sel], kind="stable")]
    width = max(len(g.cspec.levels) - 1 for g in msys.groups)
    if msys.n_groups == 1:
        sub = _unflatten_banks(msys.groups[0].cspec, trace.bank[sel], width)
    else:
        sub = np.zeros((len(sel), width), np.int64)
        gsel = trace.group[sel]
        for g, grp in enumerate(msys.groups):
            m = gsel == g
            if np.any(m):
                sub[m] = _unflatten_banks(grp.cspec, trace.bank[sel][m],
                                          width)
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    chan = i32(trace.chan[sel])
    row = i32(np.maximum(trace.row[sel], 0))
    dep = None
    if deps:
        dep = i32(_replay_deps(chan, trace.bank[sel], row, is_wr[sel]))
    return ReplayStream(
        chan=chan, sub=i32(sub), row=row,
        col=np.zeros(len(sel), np.int32), is_write=i32(is_wr[sel]),
        arrive=i32(trace.arrive[sel]), dep=dep)
