"""Compact columnar command-trace capture (paper §4.1 + §4.2).

The engine's ``trace=True`` path emits *dense* ``[T, 2]`` per-cycle arrays
(``repro.core.engine.TraceArrays``) that are mostly ``-1`` idle sentinels —
O(n_cycles) memory regardless of how many commands actually issued.
:func:`capture` compacts them into a :class:`CommandTrace`: one int32 column
per field, one entry per *issued* command, in exact issue order (cycle-major,
column bus before row bus — the order the engine mutates device state in).

The capture embeds everything needed to re-audit the trace later without the
original ``Simulator``: the spec provenance (standard / org / timing preset
names), the fully *resolved* timing table, and a fingerprint of the compiled
spec as the engine traced it (`repro.core.engine.spec_fingerprint`), so a
trace artifact can never be silently replayed against a different device
model.

Batched sweeps vmap the engine, so their trace arrays are ``[B, T, 2]``;
``capture(..., point=j)`` extracts one sweep point without materializing
per-point dense copies.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import engine as E
from repro.core.compile import CompiledSpec, compile_spec

#: Columnar int32 fields of a CommandTrace, in save/load order.
FIELDS = ("clk", "cmd", "bank", "row", "bus", "arrive", "hit_ready", "chan")


def spec_fingerprint_hex(cspec: CompiledSpec) -> str:
    """Stable hex digest of the compiled-spec identity the engine keys
    compilations on (standard/org/timing names + resolved timing table +
    geometry)."""
    return hashlib.sha256(
        repr(E.spec_fingerprint(cspec)).encode()).hexdigest()[:16]


@dataclasses.dataclass
class CommandTrace:
    """Columnar DRAM command trace: one row per issued command.

    All columns are ``(N,)`` numpy arrays in issue order.  ``bank`` is the
    flat bank id (refresh-unit-scoped commands carry their unit's
    representative bank).  ``arrive`` is the served request's arrival clock
    and -1 for refresh-engine commands; ``hit_ready`` records whether a
    post-predicate row-hit candidate existed at selection time (the
    scheduler-audit observable).  ``meta`` carries spec provenance, the
    resolved timing table, the spec fingerprint, and any run configuration
    the caller supplied (controller / frontend / load point).
    """
    clk: np.ndarray
    cmd: np.ndarray
    bank: np.ndarray
    row: np.ndarray
    bus: np.ndarray
    arrive: np.ndarray
    hit_ready: np.ndarray       # int32 0/1 (npz-friendly)
    n_cycles: int
    cmd_names: list
    #: memory-system channel of each command (all-zero for single-channel
    #: traces; defaults to zeros when omitted for backward compatibility)
    chan: np.ndarray | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.chan is None:
            self.chan = np.zeros_like(np.asarray(self.clk, np.int32))

    def __len__(self) -> int:
        return int(self.clk.shape[0])

    @property
    def n_channels(self) -> int:
        return int(self.meta.get("n_channels", 1))

    @property
    def fingerprint(self) -> str:
        return self.meta.get("fingerprint", "")

    def cmd_count(self, name: str) -> int:
        if name not in self.cmd_names:
            return 0
        return int(np.count_nonzero(self.cmd == self.cmd_names.index(name)))

    def compiled_spec(self) -> CompiledSpec:
        """Recompile the spec this trace was captured from.  The stored
        resolved timing table is replayed as overrides, so post-hoc preset
        edits round-trip exactly; the result is fingerprint-checked."""
        m = self.meta
        cspec = compile_spec(m["standard"], m["org_preset"],
                             m["timing_preset"],
                             {k: int(v) for k, v in m["timings"].items()},
                             channels=int(m.get("n_channels", 1)))
        # replay post-compile geometry edits (benchmarks mutate rows/
        # columns in place; the fingerprint covers them)
        cspec.rows = int(m.get("rows", cspec.rows))
        cspec.columns = int(m.get("columns", cspec.columns))
        got = spec_fingerprint_hex(cspec)
        if m.get("fingerprint") and got != m["fingerprint"]:
            raise ValueError(
                f"recompiled spec fingerprint {got} != captured "
                f"{m['fingerprint']} — standard definition changed since "
                "capture")
        return cspec


def config_doc(cfg) -> dict:
    """JSON-representable scalar fields of a config dataclass (callables —
    e.g. ``extra_predicates`` — can't round-trip and are dropped).  The one
    serialization rule for run configs, shared with `repro.dse.results`."""
    if cfg is None:
        return {}
    out = {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if isinstance(v, (int, float, str, bool)) or v is None:
            out[f.name] = v
    return out


def base_meta(cspec: CompiledSpec, controller=None, frontend=None,
              **extra) -> dict:
    """Metadata block embedded in every capture: provenance + resolved
    timings + fingerprint + optional run configuration."""
    meta = {
        "standard": cspec.standard or cspec.name,
        "org_preset": cspec.org_preset,
        "timing_preset": cspec.timing_preset,
        "timings": {k: int(v) for k, v in cspec.timings.items()},
        "fingerprint": spec_fingerprint_hex(cspec),
        "rows": int(cspec.rows),
        "columns": int(cspec.columns),
        "tCK_ps": int(cspec.tCK_ps),
        "n_banks": int(cspec.n_banks),
        "n_channels": int(cspec.n_channels),
        "dual_command_bus": bool(cspec.dual_command_bus),
    }
    if controller is not None:
        meta["controller"] = config_doc(controller)
    if frontend is not None:
        meta["frontend"] = config_doc(frontend)
    meta.update({k: v for k, v in extra.items() if v is not None})
    return meta


def _normalize(trace):
    """Accept a ``TraceArrays``, or any 3/5-sequence of dense arrays."""
    parts = tuple(trace)
    if len(parts) < 3:
        raise ValueError("trace needs at least (cmd, bank, row) arrays")
    cmd, bank, row = (np.asarray(p) for p in parts[:3])
    arrive = np.asarray(parts[3]) if len(parts) > 3 \
        else np.full_like(cmd, -1)
    hit_ready = np.asarray(parts[4]) if len(parts) > 4 \
        else np.zeros(cmd.shape, bool)
    return cmd, bank, row, arrive, hit_ready


def capture(cspec: CompiledSpec, trace, *, point: int | None = None,
            controller=None, frontend=None, **extra_meta) -> CommandTrace:
    """Compact dense engine trace arrays into a :class:`CommandTrace`.

    ``trace`` is the second element of ``Simulator.run(..., trace=True)``
    (dense ``[T, 2]`` arrays), or the vmapped ``[B, T, 2]`` stack a batched
    sweep produces — pass ``point=j`` to extract sweep point ``j``.
    Compaction is one vectorized ``nonzero`` over the issued mask; the
    resulting row order (cycle-major, bus 0 before bus 1) is exactly the
    order the engine applied the commands to device state in, which the
    auditor relies on.
    """
    cmd, bank, row, arrive, hit_ready = _normalize(trace)
    n_channels = int(getattr(cspec, "n_channels", 1))
    # single-channel traces are [T, 2] (batched: [B, T, 2]); multi-channel
    # traces carry the channel axis in the middle: [T, C, 2] / [B, T, C, 2]
    scalar_ndim = 2 if n_channels == 1 else 3
    if cmd.ndim == scalar_ndim + 1:
        if point is None:
            raise ValueError(
                f"batched {'[B, T, 2]' if n_channels == 1 else '[B, T, C, 2]'}"
                " trace: pass point=<batch index>")
        sel = lambda a: a[point] if a.ndim == scalar_ndim + 1 else a
        cmd, bank, row = sel(cmd), sel(bank), sel(row)
        arrive, hit_ready = sel(arrive), sel(hit_ready)
    if cmd.ndim != scalar_ndim:
        raise ValueError(f"expected {scalar_ndim}-d trace arrays for a "
                         f"{n_channels}-channel spec, got {cmd.shape}")
    n_cycles = int(cmd.shape[0])

    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    if n_channels == 1:
        idx = np.nonzero(cmd >= 0)           # row-major == issue order
        t_idx, bus_idx = idx
        chan = np.zeros(len(t_idx), np.int32)
    else:
        idx = np.nonzero(cmd >= 0)           # cycle-major, channel, bus
        t_idx, chan, bus_idx = idx
    return CommandTrace(
        clk=i32(t_idx), cmd=i32(cmd[idx]),
        bank=i32(bank[idx]), row=i32(row[idx]),
        bus=i32(bus_idx), arrive=i32(arrive[idx]),
        hit_ready=i32(hit_ready[idx].astype(np.int32)),
        chan=i32(chan),
        n_cycles=n_cycles, cmd_names=list(cspec.cmd_names),
        meta=base_meta(cspec, controller=controller, frontend=frontend,
                       **extra_meta))


def to_replay(trace: CommandTrace, cspec: CompiledSpec | None = None):
    """Derive a trace-driven-frontend :class:`repro.core.ReplayStream`
    from a captured trace's served column commands (final RD/WR with
    request info), channel attribution included.  The captured ``arrive``
    clocks ride along (sorted into arrival order), so replay paces
    injection by the original inter-arrival gaps rather than the
    streaming interval.  Feed the result to ``Simulator(...,
    frontend=FrontendConfig(pattern="trace"), replay=...)`` to re-drive
    any memory system with the same per-channel address stream."""
    from repro.core import spec as S
    from repro.core.frontend import ReplayStream
    if cspec is None:
        cspec = trace.compiled_spec()
    fx = np.asarray(cspec.cmd_fx)[trace.cmd]
    is_wr = (fx & S.FX_FINAL_WR) != 0
    sel = np.nonzero((((fx & S.FX_FINAL_RD) != 0) | is_wr)
                     & (trace.arrive >= 0))[0]
    if len(sel) == 0:
        raise ValueError("trace has no served column commands to replay")
    # the frontend injects sequentially, so replay requests in ARRIVAL
    # order (issue order is scheduler-permuted under FR-FCFS) — this is
    # also what makes the arrive column a monotone pacing schedule
    sel = sel[np.argsort(trace.arrive[sel], kind="stable")]
    counts = cspec.level_counts
    b = trace.bank[sel].astype(np.int64)
    subs = []
    for i in range(len(counts) - 1, 0, -1):
        subs.append(b % int(counts[i]))
        b = b // int(counts[i])
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return ReplayStream(
        chan=i32(trace.chan[sel]), sub=i32(np.stack(subs[::-1], axis=-1)),
        row=i32(np.maximum(trace.row[sel], 0)),
        col=np.zeros(len(sel), np.int32), is_write=i32(is_wr[sel]),
        arrive=i32(trace.arrive[sel]))
