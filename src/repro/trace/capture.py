"""Compact columnar command-trace capture (paper §4.1 + §4.2).

The engine's ``trace=True`` path emits *dense* ``[T, 2]`` per-cycle arrays
(``repro.core.engine.TraceArrays``) that are mostly ``-1`` idle sentinels —
O(n_cycles) memory regardless of how many commands actually issued.
:func:`capture` compacts them into a :class:`CommandTrace`: one int32 column
per field, one entry per *issued* command, in exact issue order (cycle-major,
column bus before row bus — the order the engine mutates device state in).

The capture embeds everything needed to re-audit the trace later without the
original ``Simulator``: the spec provenance (standard / org / timing preset
names), the fully *resolved* timing table, and a fingerprint of the compiled
spec as the engine traced it (`repro.core.engine.spec_fingerprint`), so a
trace artifact can never be silently replayed against a different device
model.

Batched sweeps vmap the engine, so their trace arrays are ``[B, T, 2]``;
``capture(..., point=j)`` extracts one sweep point without materializing
per-point dense copies.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import engine as E
from repro.core.compile import CompiledSpec, compile_spec

#: Columnar int32 fields of a CommandTrace, in save/load order.
FIELDS = ("clk", "cmd", "bank", "row", "bus", "arrive", "hit_ready")


def spec_fingerprint_hex(cspec: CompiledSpec) -> str:
    """Stable hex digest of the compiled-spec identity the engine keys
    compilations on (standard/org/timing names + resolved timing table +
    geometry)."""
    return hashlib.sha256(
        repr(E.spec_fingerprint(cspec)).encode()).hexdigest()[:16]


@dataclasses.dataclass
class CommandTrace:
    """Columnar DRAM command trace: one row per issued command.

    All columns are ``(N,)`` numpy arrays in issue order.  ``bank`` is the
    flat bank id (refresh-unit-scoped commands carry their unit's
    representative bank).  ``arrive`` is the served request's arrival clock
    and -1 for refresh-engine commands; ``hit_ready`` records whether a
    post-predicate row-hit candidate existed at selection time (the
    scheduler-audit observable).  ``meta`` carries spec provenance, the
    resolved timing table, the spec fingerprint, and any run configuration
    the caller supplied (controller / frontend / load point).
    """
    clk: np.ndarray
    cmd: np.ndarray
    bank: np.ndarray
    row: np.ndarray
    bus: np.ndarray
    arrive: np.ndarray
    hit_ready: np.ndarray       # int32 0/1 (npz-friendly)
    n_cycles: int
    cmd_names: list
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return int(self.clk.shape[0])

    @property
    def fingerprint(self) -> str:
        return self.meta.get("fingerprint", "")

    def cmd_count(self, name: str) -> int:
        if name not in self.cmd_names:
            return 0
        return int(np.count_nonzero(self.cmd == self.cmd_names.index(name)))

    def compiled_spec(self) -> CompiledSpec:
        """Recompile the spec this trace was captured from.  The stored
        resolved timing table is replayed as overrides, so post-hoc preset
        edits round-trip exactly; the result is fingerprint-checked."""
        m = self.meta
        cspec = compile_spec(m["standard"], m["org_preset"],
                             m["timing_preset"],
                             {k: int(v) for k, v in m["timings"].items()})
        # replay post-compile geometry edits (benchmarks mutate rows/
        # columns in place; the fingerprint covers them)
        cspec.rows = int(m.get("rows", cspec.rows))
        cspec.columns = int(m.get("columns", cspec.columns))
        got = spec_fingerprint_hex(cspec)
        if m.get("fingerprint") and got != m["fingerprint"]:
            raise ValueError(
                f"recompiled spec fingerprint {got} != captured "
                f"{m['fingerprint']} — standard definition changed since "
                "capture")
        return cspec


def config_doc(cfg) -> dict:
    """JSON-representable scalar fields of a config dataclass (callables —
    e.g. ``extra_predicates`` — can't round-trip and are dropped).  The one
    serialization rule for run configs, shared with `repro.dse.results`."""
    if cfg is None:
        return {}
    out = {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if isinstance(v, (int, float, str, bool)) or v is None:
            out[f.name] = v
    return out


def base_meta(cspec: CompiledSpec, controller=None, frontend=None,
              **extra) -> dict:
    """Metadata block embedded in every capture: provenance + resolved
    timings + fingerprint + optional run configuration."""
    meta = {
        "standard": cspec.standard or cspec.name,
        "org_preset": cspec.org_preset,
        "timing_preset": cspec.timing_preset,
        "timings": {k: int(v) for k, v in cspec.timings.items()},
        "fingerprint": spec_fingerprint_hex(cspec),
        "rows": int(cspec.rows),
        "columns": int(cspec.columns),
        "tCK_ps": int(cspec.tCK_ps),
        "n_banks": int(cspec.n_banks),
        "dual_command_bus": bool(cspec.dual_command_bus),
    }
    if controller is not None:
        meta["controller"] = config_doc(controller)
    if frontend is not None:
        meta["frontend"] = config_doc(frontend)
    meta.update({k: v for k, v in extra.items() if v is not None})
    return meta


def _normalize(trace):
    """Accept a ``TraceArrays``, or any 3/5-sequence of dense arrays."""
    parts = tuple(trace)
    if len(parts) < 3:
        raise ValueError("trace needs at least (cmd, bank, row) arrays")
    cmd, bank, row = (np.asarray(p) for p in parts[:3])
    arrive = np.asarray(parts[3]) if len(parts) > 3 \
        else np.full_like(cmd, -1)
    hit_ready = np.asarray(parts[4]) if len(parts) > 4 \
        else np.zeros(cmd.shape, bool)
    return cmd, bank, row, arrive, hit_ready


def capture(cspec: CompiledSpec, trace, *, point: int | None = None,
            controller=None, frontend=None, **extra_meta) -> CommandTrace:
    """Compact dense engine trace arrays into a :class:`CommandTrace`.

    ``trace`` is the second element of ``Simulator.run(..., trace=True)``
    (dense ``[T, 2]`` arrays), or the vmapped ``[B, T, 2]`` stack a batched
    sweep produces — pass ``point=j`` to extract sweep point ``j``.
    Compaction is one vectorized ``nonzero`` over the issued mask; the
    resulting row order (cycle-major, bus 0 before bus 1) is exactly the
    order the engine applied the commands to device state in, which the
    auditor relies on.
    """
    cmd, bank, row, arrive, hit_ready = _normalize(trace)
    if cmd.ndim == 3:
        if point is None:
            raise ValueError(
                "batched [B, T, 2] trace: pass point=<batch index>")
        sel = lambda a: a[point] if a.ndim == 3 else a
        cmd, bank, row = sel(cmd), sel(bank), sel(row)
        arrive, hit_ready = sel(arrive), sel(hit_ready)
    if cmd.ndim != 2:
        raise ValueError(f"expected [T, 2] trace arrays, got {cmd.shape}")
    n_cycles = int(cmd.shape[0])

    t_idx, bus_idx = np.nonzero(cmd >= 0)        # row-major == issue order
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return CommandTrace(
        clk=i32(t_idx), cmd=i32(cmd[t_idx, bus_idx]),
        bank=i32(bank[t_idx, bus_idx]), row=i32(row[t_idx, bus_idx]),
        bus=i32(bus_idx), arrive=i32(arrive[t_idx, bus_idx]),
        hit_ready=i32(hit_ready[t_idx, bus_idx].astype(np.int32)),
        n_cycles=n_cycles, cmd_names=list(cspec.cmd_names),
        meta=base_meta(cspec, controller=controller, frontend=frontend,
                       **extra_meta))
