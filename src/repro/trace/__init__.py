"""repro.trace — command-trace capture, audit, and visualization (paper §4).

The trustworthiness pillar of Ramulator 2.1 as a subsystem with four
layers over the cycle-level engine:

  * :mod:`repro.trace.capture` — compact columnar ``CommandTrace`` from the
    engine's dense trace arrays (scalar runs and batched-sweep points),
    with spec fingerprint + run configuration embedded;
  * :mod:`repro.trace.format` — single-file ``.npz`` artifacts and
    streaming JSONL export, round-trip stable;
  * :mod:`repro.trace.audit` — vectorized independent replay of the
    constraint table plus FR-FCFS scheduler invariants; every violation is
    reported with the exact constraint, commands involved, and slack;
  * :mod:`repro.trace.viz` — level-of-detail HTML visualizer (bus
    utilization + per-bank command lanes + audit-violation overlay).

CLI: ``python -m repro.trace --standard DDR4 --cycles 20000 --out
trace.npz --html trace.html`` (see ``python -m repro.trace --help``).
"""
from repro.trace.audit import AuditReport, Violation, audit
from repro.trace.capture import (CommandTrace, capture,
                                 spec_fingerprint_hex, to_replay)
from repro.trace.format import (iter_records, load, read_jsonl, save,
                                write_jsonl)
from repro.trace.viz import render_html, write_html

__all__ = [
    "AuditReport", "Violation", "audit",
    "CommandTrace", "capture", "spec_fingerprint_hex", "to_replay",
    "iter_records", "load", "read_jsonl", "save", "write_jsonl",
    "render_html", "write_html",
]
