"""Serving steps: prefill (context -> cache + first logits) and decode
(one token against the cache), both pure and pjit-shaped.

``decode_32k`` / ``long_500k`` dry-run cells lower ``decode_step`` — one
new token with a seq_len cache — exactly as the assignment specifies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import (Batch, decode_step, forward, init_cache,
                                last_logits)


def make_prefill_step(cfg, cache_len: int):
    def prefill(params, batch: Batch):
        x, _aux, states = forward(cfg, params, batch, return_states=True,
                                  cache_len=cache_len)
        return last_logits(cfg, params, x), states
    return prefill


def make_decode_step(cfg):
    def step(params, cache, batch: Batch):
        return decode_step(cfg, params, cache, batch)
    return step


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def serve_batch(cfg, params, prompts: jnp.ndarray, max_new: int,
                positions=None):
    """Reference serving loop (prefill + greedy decode) for examples/tests.

    prompts: (B, T) int32 (or (B, T, K) audio).  Returns (B, max_new) tokens.
    """
    B, T = prompts.shape[:2]
    S = T + max_new
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
        if cfg.rope == "mrope":
            positions = jnp.stack(
                [positions, positions // 7, positions % 7], -1)

    # prefill into a cache of size S
    prefill = make_prefill_step(cfg, cache_len=S)
    logits, states = prefill(params, Batch(tokens=prompts,
                                           positions=positions))
    # prefill wrote positions [0, T); decode continues at T
    cache = _pad_states_to_cache(cfg, states, B, S)
    step_fn = make_decode_step(cfg)

    def one(carry, i):
        cache, tok = carry
        pos = T + i
        if cfg.rope == "mrope":
            p = jnp.stack([jnp.full((B, 1), pos, jnp.int32),
                           jnp.full((B, 1), pos // 7, jnp.int32),
                           jnp.full((B, 1), pos % 7, jnp.int32)], -1)
        else:
            p = jnp.full((B, 1), pos, jnp.int32)
        batch = Batch(tokens=tok, positions=p,
                      cache_index=jnp.int32(pos),
                      cache_len=jnp.int32(pos + 1))
        logits, cache = step_fn(params, cache, batch)
        nxt = greedy_sample(logits[:, -1])
        if cfg.frontend == "audio_stub":
            tok_next = nxt.reshape(B, 1, cfg.n_codebooks)
        else:
            tok_next = nxt.reshape(B, 1)
        return (cache, tok_next), nxt

    first = greedy_sample(logits[:, -1])
    tok0 = first.reshape(B, 1, cfg.n_codebooks) \
        if cfg.frontend == "audio_stub" else first.reshape(B, 1)
    (_, _), toks = jax.lax.scan(one, (cache, tok0),
                                jnp.arange(max_new, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1), first


def _pad_states_to_cache(cfg, states, batch, cache_len):
    """Prefill states already have cache_len-sized attn buffers; recurrent
    blocks produced init states from forward() — rebuild those by scanning
    the prompt via decode (only used by the reference loop, not the
    production path; recurrent archs prefill through serve/recurrent.py)."""
    return states
