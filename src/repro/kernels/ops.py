"""jit'd wrappers binding the Pallas kernels to the simulator and models.

``readiness_matrix`` is the kernel-accelerated drop-in for the engine's
per-slot ``earliest_ready`` loop: it computes the earliest-issue cycle for
*every* command x *every* queue slot in one (max,+) matmul, from
  * T — gathered last-issue timestamps (queue-slot x timing-key), and
  * A — the spec-compiled constraint matrix (timing-key x command).
The timing-key set (level, command, window) is another product of the
spec "codegen" step: only keys actually referenced by constraints exist.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device as D
from repro.core.compile import CompiledSpec
from repro.kernels import ref
from repro.kernels.timing_check import maxplus_matmul

NEG = -(1 << 28)


class TimingKeys(NamedTuple):
    """Static (spec-compile-time) key table."""
    key_level: np.ndarray    # (K,)
    key_cmd: np.ndarray      # (K,)
    key_win: np.ndarray      # (K,)
    key_ring: np.ndarray     # (K,) windowed-ring entry base, -1 = dense
    ct_key: np.ndarray       # (C,) constraint -> key index


@functools.lru_cache(maxsize=None)
def _keys_cache(spec_id):
    raise KeyError   # only used via build_keys


def build_keys(cspec: CompiledSpec) -> TimingKeys:
    """Compress (level, prev_cmd, window) triples referenced by constraints
    into a dense key set."""
    triples = {}
    ct_key = np.zeros(len(cspec.ct_prev), np.int32)
    for i in range(len(cspec.ct_prev)):
        t = (int(cspec.ct_level[i]), int(cspec.ct_prev[i]),
             int(cspec.ct_win[i]))
        ct_key[i] = triples.setdefault(t, len(triples))
    keys = sorted(triples, key=triples.get)
    # windowed keys resolve through the compact ring planned by the spec
    # compiler; ordinary keys read the dense (node, cmd) last-issue table
    pair_off = {(p, lvl): off for p, lvl, off, _ in cspec.ring_pairs}
    return TimingKeys(
        key_level=np.array([k[0] for k in keys], np.int32),
        key_cmd=np.array([k[1] for k in keys], np.int32),
        key_win=np.array([k[2] for k in keys], np.int32),
        key_ring=np.array([pair_off.get((k[1], k[0]), -1) if k[2] > 1
                           else -1 for k in keys], np.int32),
        ct_key=ct_key)


def build_A(cspec: CompiledSpec, keys: TimingKeys, ct_lat) -> jnp.ndarray:
    """Constraint matrix A[k, c] = max latency of constraints with key k
    targeting command c, else -inf.  ct_lat may be traced (DSE vmap)."""
    K = len(keys.key_level)
    A = jnp.full((K, cspec.n_cmds), jnp.float32(-3e38))
    A = A.at[jnp.asarray(keys.ct_key), jnp.asarray(cspec.ct_next)].max(
        ct_lat.astype(jnp.float32))
    return A


def gather_T(cspec: CompiledSpec, keys: TimingKeys, state: D.DeviceState,
             subs: jnp.ndarray) -> jnp.ndarray:
    """T[q, k]: key_k's issue timestamp at slot q's level-``level_k`` node —
    the dense last-issue table for window=1 keys, the windowed ring for
    window>1 keys (split state layout, see ``core.device``)."""
    nodes = jax.vmap(functools.partial(D.node_per_level, cspec))(subs)  # (Q, L)
    kl = jnp.asarray(keys.key_level)
    kc = jnp.asarray(keys.key_cmd)
    T = state.last_issue[nodes[:, kl], kc[None, :]]             # (Q, K)
    if np.any(keys.key_ring >= 0):
        kr = jnp.asarray(keys.key_ring)
        kw = jnp.asarray(keys.key_win) - 1
        lvl_off = jnp.asarray(
            np.asarray(cspec.level_offsets, np.int32)[keys.key_level])
        ridx = jnp.clip(kr[None, :] + nodes[:, kl] - lvl_off[None, :],
                        0, cspec.n_ring - 1)
        T = jnp.where((kr >= 0)[None, :], state.win_ring[ridx, kw[None, :]],
                      T)
    # a window>1 key the command never stamps (key_ring == -1) falls back
    # to a dense slot that is never written at that level, i.e. stays NEG
    # never-issued slots map to the max-plus identity so that `ts + lat`
    # cannot surface as a bogus finite bound (matches engine semantics)
    return jnp.where(T <= NEG, jnp.float32(-3e38), T.astype(jnp.float32))


def readiness_matrix(cspec: CompiledSpec, keys: TimingKeys, ct_lat,
                     state: D.DeviceState, subs: jnp.ndarray, *,
                     use_pallas: bool = True,
                     interpret: bool = True) -> jnp.ndarray:
    """(Q, n_cmds) earliest-issue cycles for every slot x command."""
    T = gather_T(cspec, keys, state, subs).astype(jnp.float32)
    # never-issued sentinel: keep NEG so `clk >= earliest` is trivially true
    A = build_A(cspec, keys, ct_lat)
    if use_pallas:
        out = maxplus_matmul(T, A, interpret=interpret)
    else:
        out = ref.maxplus_matmul(T, A)
    return out


def earliest_for(cspec, keys, ct_lat, state, subs, cand_cmds, **kw):
    em = readiness_matrix(cspec, keys, ct_lat, state, subs, **kw)
    return em[jnp.arange(em.shape[0]), cand_cmds]


# ---------------------------------------------------------------------------
# Attention wrapper (GQA-aware)
# ---------------------------------------------------------------------------

def gqa_flash_attention(q, k, v, *, causal: bool = True,
                        sm_scale: float | None = None,
                        interpret: bool = True, use_pallas: bool = True):
    """q: (B, Hq, T, D); k/v: (B, Hkv, T, D) with Hq % Hkv == 0."""
    B, Hq, T, Dh = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               interpret=interpret)
    return ref.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale)
