"""Pallas TPU kernel: flash attention forward (online softmax, causal/full).

The LM workload substrate's compute hot spot.  Grid = (batch*heads,
q_blocks, kv_blocks); the kv axis is innermost, so on TPU the sequential
grid walk lets the kernel carry the online-softmax running state (m, l,
acc) in VMEM scratch across kv steps — the canonical TPU flash schedule
(MaxText/splash style).  Causal blocks strictly above the diagonal are
skipped with @pl.when (no FLOPs, no VMEM traffic).

Blocks are MXU-aligned (128): q/k/v tiles (bq, d) / (bk, d) hit the
128x128 systolic array; d is kept whole per block (head_dim <= 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30   # python float: jnp scalars would be captured consts in-kernel


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, sm_scale: float, bq: int, bk: int,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip kv blocks strictly in the future of this q block
    run = (qi * bq + bq - 1 >= ki * bk) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                             # (bq, bk)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            s = jnp.where(rows >= cols, s, NEG_INF)
        s = jnp.where(cols < kv_len, s, NEG_INF)     # mask padded kv tail

        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "sm_scale", "bq", "bk",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (B, H, T, D) with equal head counts (expand GQA upstream)."""
    B, H, Tq, Dh = q.shape
    Tk = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (Dh ** 0.5)
    bq = min(bq, _rup(Tq, 8))
    bk = min(bk, _rup(Tk, 8))
    Tqp, Tkp = _rup(Tq, bq), _rup(Tk, bk)

    qp = jnp.zeros((B * H, Tqp, Dh), q.dtype).at[:, :Tq].set(
        q.reshape(B * H, Tq, Dh))
    kp = jnp.zeros((B * H, Tkp, Dh), k.dtype).at[:, :Tk].set(
        k.reshape(B * H, Tk, Dh))
    vp = jnp.zeros((B * H, Tkp, Dh), v.dtype).at[:, :Tk].set(
        v.reshape(B * H, Tk, Dh))
    grid = (B * H, Tqp // bq, Tkp // bk)

    kernel = functools.partial(_flash_kernel, causal=causal,
                               sm_scale=float(sm_scale), bq=bq, bk=bk,
                               kv_len=Tk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tqp, Dh), q.dtype),
        scratch_shapes=[
            # (bq, 1) scratch: widen the lane dim to 128 for real-TPU
            # lowering; interpret mode accepts the minimal shape.
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Tq].reshape(B, H, Tq, Dh)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
