"""Pallas TPU kernel: (max,+) matmul — the DRAM timing-readiness hot loop.

TPU adaptation of the paper's per-cycle scheduling workflow (DESIGN.md §2):
instead of pointer-chasing per-constraint checks (the C++ inner loop),
Ramulator-JAX lowers the readiness check to tropical linear algebra:

    earliest[q, c] = max_k ( T[q, k] + A[k, c] )

  T (Q x K): gathered last-issue timestamps per queue slot, one column per
             (level, command, window) "timing key";
  A (K x C): spec-compiled constraint matrix; A[k, c] = latency of the
             constraint keyed k that targets command c, else -inf.

The kernel tiles (Q, K) x (K, C) into VMEM blocks and runs the max-plus
contraction on the VPU with K as the innermost sequential grid axis,
accumulating into the output block (the classic matmul schedule with
(+, *) replaced by (max, +)).  Block sizes default to the VPU/MXU-aligned
128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3e38   # python float: jnp scalars would be captured consts in-kernel


def _maxplus_kernel(t_ref, a_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG)

    t = t_ref[...]            # (bq, bk)
    a = a_ref[...]            # (bk, bc)
    bk = t.shape[1]

    # lane-by-lane (max,+) contraction: keeps the live intermediate at
    # (bq, bc) instead of materializing (bq, bk, bc) in VMEM
    def body(kk, acc):
        return jnp.maximum(acc, t[:, kk][:, None] + a[kk, :][None, :])

    o_ref[...] = jax.lax.fori_loop(0, bk, body, o_ref[...])


@functools.partial(jax.jit, static_argnames=("bq", "bk", "bc", "interpret"))
def maxplus_matmul(T, A, *, bq: int = 128, bk: int = 128, bc: int = 128,
                   interpret: bool = True):
    """out[q, c] = max_k T[q, k] + A[k, c].  float32 in/out.

    Pads every dim to its block multiple with -inf (identity of max-plus),
    so arbitrary (Q, K, C) are accepted.
    """
    Q, K = T.shape
    K2, C = A.shape
    assert K == K2, (T.shape, A.shape)
    bq, bk, bc = min(bq, _rup(Q, 8)), min(bk, _rup(K, 8)), min(bc, _rup(C, 8))
    Qp, Kp, Cp = _rup(Q, bq), _rup(K, bk), _rup(C, bc)
    Tp = jnp.full((Qp, Kp), NEG, jnp.float32).at[:Q, :K].set(
        T.astype(jnp.float32))
    Ap = jnp.full((Kp, Cp), NEG, jnp.float32).at[:K, :C].set(
        A.astype(jnp.float32))

    grid = (Qp // bq, Cp // bc, Kp // bk)
    out = pl.pallas_call(
        _maxplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bc), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Cp), jnp.float32),
        interpret=interpret,
    )(Tp, Ap)
    return out[:Q, :C]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
