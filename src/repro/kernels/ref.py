"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_TS = -(1 << 28)


def maxplus_matmul(T: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """(max,+) matrix product: out[q, c] = max_k (T[q, k] + A[k, c]).

    The DRAM readiness check in tropical algebra: T is the gathered
    last-issue timestamp matrix (queue-slot x timing-key), A the
    spec-compiled constraint matrix (timing-key x command) holding the
    constraint latency or -inf.  out[q, c] is the earliest cycle command c
    may issue for slot q.
    """
    return jnp.max(T[:, :, None] + A[None, :, :], axis=1)


def flash_attention(q, k, v, causal: bool = True, sm_scale: float | None = None):
    """Reference attention: (B, H, Tq, D) x (B, H, Tk, D) -> (B, H, Tq, D)."""
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    if causal:
        tq, tk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((tq, tk), bool), k=tk - tq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def rglru_scan(x, a_log, gate):
    """Reference RG-LRU linear recurrence (recurrentgemma):
       h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (g_t * x_t)
    with a_t = exp(-softplus(a_log) * sigmoid(gate)) per channel.
    Shapes: (B, T, D)."""
    a = jnp.exp(-8.0 * jax.nn.softplus(a_log)[None, None, :]
                * jax.nn.sigmoid(gate))
    gated = x * jax.nn.sigmoid(gate)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    u = beta * gated
    _, hs = jax.lax.scan(step, jnp.zeros_like(x[:, 0]),
                         (a.transpose(1, 0, 2), u.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
