"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter/input declares *logical* axis names; ``choose_spec`` maps
them to mesh axes with the production rules below, skipping any assignment
whose dimension is not divisible by the mesh-axis size (e.g. 40 attention
heads on a 16-way model axis) and falling back to the next candidate dim.
A mesh axis is used at most once per spec.

Rules (single-pod (data, model) / multi-pod (pod, data, model)):
  batch      -> (pod, data)      data parallel across pods x pod-minors
  seq        -> data             sequence parallelism (long-context decode)
  vocab      -> model            embedding/logits TP
  ffn        -> model            MLP TP (megatron style)
  heads      -> model            attention-head TP when divisible
  qkv        -> model            flattened head*dim projection output
  embed_tp   -> model            fallback: shard d_model (FSDP-ish row TP)
  experts    -> model            expert parallelism (MoE)
  layers,embed,head_dim,window,... -> replicated
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = (
        ("batch", ("pod", "data")),
        ("seq_shard", ("data",)),
        ("experts", ("model",)),     # EP first: experts own the model axis
        ("vocab", ("model",)),
        ("ffn", ("model",)),
        ("heads", ("model",)),
        ("qkv", ("model",)),
        ("embed_tp", ("model",)),
        ("kv_heads", ("model",)),
    )

    def candidates(self, logical: str) -> tuple:
        for name, axes in self.rules:
            if name == logical:
                return axes
        return ()


DEFAULT_RULES = ShardingRules()

# FSDP(+TP) rules: weight dims may additionally shard over the *data* axis
# (ZeRO-3 style fully-sharded weights + optimizer moments).  Activations
# keep batch on (pod, data); XLA inserts per-layer weight all-gathers and
# gradient reduce-scatters.  This is the memory lever for the 400B-scale
# cells (EXPERIMENTS.md §Perf).
FSDP_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("seq_shard", ("data",)),
    ("experts", ("model",)),         # EP first: experts own the model axis
    ("vocab", ("model", "data")),
    ("ffn", ("model", "data")),
    ("heads", ("model",)),
    ("qkv", ("model", "data")),
    ("embed_tp", ("model", "data")),
    ("kv_heads", ("model",)),
))


def _axis_size(mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 0


def choose_spec(shape, logical_axes, mesh, rules: ShardingRules = DEFAULT_RULES):
    """Map per-dim logical names to a PartitionSpec.

    logical_axes: one logical name (or None) per dim.  Dims are processed
    left-to-right; each mesh axis is assigned at most once; non-divisible
    assignments are skipped (the dim stays replicated or a later dim takes
    the mesh axis).
    """
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    out: list = [None] * len(shape)
    # assign dims in rule-precedence order (rules list order), so that e.g.
    # "ffn" wins the model axis over the "embed_tp" fallback dim
    prec = {name: i for i, (name, _) in enumerate(rules.rules)}
    order = sorted(range(len(shape)),
                   key=lambda i: prec.get(logical_axes[i], len(prec) + 1))
    for i in order:
        dim, logical = shape[i], logical_axes[i]
        if logical is None:
            continue
        cands = rules.candidates(logical)
        # multi-axis assignment (e.g. batch over (pod, data)): use the
        # largest prefix of available axes whose product divides the dim
        assign = []
        prod = 1
        for ax in cands:
            sz = _axis_size(mesh, ax)
            if sz and ax not in used and dim % (prod * sz) == 0:
                assign.append(ax)
                prod *= sz
        if assign:
            used.update(assign)
            out[i] = tuple(assign) if len(assign) > 1 else assign[0]
    return P(*out)


def spec_tree(defs: dict, mesh, rules: ShardingRules = DEFAULT_RULES) -> dict:
    """defs: {name: ParamDef} -> {name: PartitionSpec} (same tree)."""
    return {k: choose_spec(v.shape, v.logical_axes, mesh, rules)
            for k, v in defs.items()}


def named_sharding(tree, mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))
