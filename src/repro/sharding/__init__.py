from repro.sharding.rules import (ShardingRules, DEFAULT_RULES, choose_spec,
                                  spec_tree, named_sharding)

__all__ = ["ShardingRules", "DEFAULT_RULES", "choose_spec", "spec_tree",
           "named_sharding"]
