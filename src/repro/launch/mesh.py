"""Production meshes.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and
only launch/dryrun.py is allowed to fake 512 host devices.
"""
from __future__ import annotations

import jax

from repro.compat import ensure_jax_sharding_compat

ensure_jax_sharding_compat()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1,) * (len(axes) - 1) + (n,)
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (roofline targets, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
