"""Serving launcher: batched prefill + greedy decode on local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serve.step import serve_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    shape = (args.batch, args.prompt_len)
    if cfg.frontend == "audio_stub":
        shape = shape + (cfg.n_codebooks,)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, shape), jnp.int32)

    t0 = time.perf_counter()
    toks, first = serve_batch(cfg, params, prompts, args.max_new)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    n_tok = args.batch * args.max_new
    print(f"arch={cfg.name} generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks)[0, :10].tolist())
    return toks


if __name__ == "__main__":
    main()
