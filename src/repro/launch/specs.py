"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(arch, shape)`` returns (avals, shardings) for the step being
lowered — weak-type-correct, shardable, zero allocation.  Shardings come
from the divisibility-aware rule chooser; cache/state pytrees are annotated
by (field, rank) via `_STATE_AXES`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Batch, init_cache, param_defs
from repro.models.model import VIS_FRAC
from repro.optim import AdamWConfig, OptState
from repro.sharding import DEFAULT_RULES, choose_spec
from repro.sharding.rules import ShardingRules
from repro.train.step import TrainState

# extend the default rules with the decode-cache sequence axis: for batch=1
# long-context cells the cache seq dim soaks up every idle mesh axis
CELL_RULES = ShardingRules(rules=DEFAULT_RULES.rules + (
    ("cache_seq", ("model", "data", "pod")),
))

# (state field name, rank) -> logical axes (leading G dim already included)
_STATE_AXES = {
    ("k", 5): (None, "batch", "cache_seq", "kv_heads", None),
    ("v", 5): (None, "batch", "cache_seq", "kv_heads", None),
    ("k", 4): ("batch", "cache_seq", "kv_heads", None),
    ("v", 4): ("batch", "cache_seq", "kv_heads", None),
    ("k_scale", 4): (None, "batch", "cache_seq", "kv_heads"),
    ("v_scale", 4): (None, "batch", "cache_seq", "kv_heads"),
    ("k_scale", 3): ("batch", "cache_seq", "kv_heads"),
    ("v_scale", 3): ("batch", "cache_seq", "kv_heads"),
    ("h", 3): (None, "batch", "embed_tp"),
    ("h", 2): ("batch", "embed_tp"),
    ("conv", 4): (None, "batch", None, "embed_tp"),
    ("conv", 3): ("batch", None, "embed_tp"),
    ("C", 5): (None, "batch", "heads", None, None),
    ("C", 4): ("batch", "heads", None, None),
    ("n", 4): (None, "batch", "heads", None),
    ("n", 3): ("batch", "heads", None),
    ("m", 3): (None, "batch", "heads"),
    ("m", 2): ("batch", "heads"),
    ("c", 4): (None, "batch", "heads", None),
    ("c", 3): ("batch", "heads", None),
}
# sLSTM h collides with RG-LRU h on rank: disambiguate by rank 4
_STATE_AXES[("h", 4)] = (None, "batch", "heads", None)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def param_avals_and_shardings(cfg: ModelConfig, mesh, rules=None):
    defs = param_defs(cfg)
    rules = rules or CELL_RULES

    def walk(d):
        avals, shs = {}, {}
        for k, v in d.items():
            if isinstance(v, dict):
                avals[k], shs[k] = walk(v)
            else:
                avals[k] = _sds(v.shape, jnp.bfloat16)
                shs[k] = _ns(mesh, choose_spec(v.shape, v.logical_axes, mesh,
                                               rules))
        return avals, shs

    return walk(defs)


def opt_avals_and_shardings(cfg: ModelConfig, mesh, moment_dtype=jnp.bfloat16,
                            rules=None):
    pav, psh = param_avals_and_shardings(cfg, mesh, rules)
    mom = jax.tree.map(lambda a: _sds(a.shape, moment_dtype), pav)
    return (OptState(step=_sds((), jnp.int32), m=mom, v=mom),
            OptState(step=_ns(mesh, P()), m=psh, v=psh))


def batch_avals_and_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                              *, with_labels: bool, decode: bool):
    B = shape.global_batch
    T = 1 if decode else shape.seq_len
    bspec = choose_spec((B,), ("batch",), mesh, CELL_RULES)
    bax = bspec[0]

    def bsh(*extra):
        return _ns(mesh, P(bax, *extra))

    if cfg.frontend == "audio_stub":
        tokens = _sds((B, T, cfg.n_codebooks), jnp.int32)
        tsh = bsh(None, None)
    else:
        tokens = _sds((B, T), jnp.int32)
        tsh = bsh(None)
    if cfg.rope == "mrope":
        positions = _sds((B, T, 3), jnp.int32)
        psh = bsh(None, None)
    else:
        positions = _sds((B, T), jnp.int32)
        psh = bsh(None)

    labels = lsh = vis = vsh = None
    if with_labels:
        labels, lsh = tokens, tsh
    if cfg.frontend == "vision_stub" and not decode:
        vis = _sds((B, T // VIS_FRAC, cfg.d_model), jnp.bfloat16)
        vsh = bsh(None, None)

    ci = _sds((), jnp.int32) if decode else None
    cish = _ns(mesh, P()) if decode else None
    avals = Batch(tokens=tokens, positions=positions, labels=labels,
                  vis_embeds=vis, cache_index=ci, cache_len=ci)
    shs = Batch(tokens=tsh, positions=psh, labels=lsh, vis_embeds=vsh,
                cache_index=cish, cache_len=cish)
    return avals, shs


def _path_leaf_name(path):
    for p in reversed(path):
        if hasattr(p, "name"):
            return p.name
        if hasattr(p, "key"):
            return p.key
    return ""


def cache_avals_and_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B = shape.global_batch
    S = shape.seq_len
    cache_shape = jax.eval_shape(lambda: init_cache(cfg, B, S))

    def spec_of(path, aval):
        name = _path_leaf_name(path)
        axes = _STATE_AXES.get((name, len(aval.shape)))
        if axes is None:
            axes = (None,) * len(aval.shape)
        return _ns(mesh, choose_spec(aval.shape, axes, mesh, CELL_RULES))

    avals = jax.tree.map(lambda a: _sds(a.shape, a.dtype), cache_shape)
    shs = jax.tree_util.tree_map_with_path(spec_of, cache_shape)
    return avals, shs


class CellSpec(NamedTuple):
    """Everything needed to lower one (arch x shape x mesh) cell."""
    kind: str
    args_avals: tuple
    args_shardings: tuple
    donate: tuple


def cell_spec(arch, shape_name: str, mesh, rules=None) -> CellSpec:
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        pav, psh = param_avals_and_shardings(cfg, mesh, rules)
        oav, osh = opt_avals_and_shardings(cfg, mesh, rules=rules)
        bav, bsh = batch_avals_and_shardings(cfg, shape, mesh,
                                             with_labels=True, decode=False)
        return CellSpec("train",
                        (TrainState(params=pav, opt=oav), bav),
                        (TrainState(params=psh, opt=osh), bsh),
                        donate=(0,))
    if shape.kind == "prefill":
        pav, psh = param_avals_and_shardings(cfg, mesh, rules)
        bav, bsh = batch_avals_and_shardings(cfg, shape, mesh,
                                             with_labels=False, decode=False)
        return CellSpec("prefill", (pav, bav), (psh, bsh), donate=())
    if shape.kind == "decode":
        pav, psh = param_avals_and_shardings(cfg, mesh, rules)
        cav, csh = cache_avals_and_shardings(cfg, shape, mesh)
        bav, bsh = batch_avals_and_shardings(cfg, shape, mesh,
                                             with_labels=False, decode=True)
        return CellSpec("decode", (pav, cav, bav), (psh, csh, bsh),
                        donate=(1,))
    raise KeyError(shape.kind)


def step_fn_for(arch, shape_name: str, microbatch: int = 1):
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        from repro.optim import AdamWConfig
        from repro.train import make_train_step
        return make_train_step(cfg, AdamWConfig(), microbatch=microbatch)
    if shape.kind == "prefill":
        from repro.serve import make_prefill_step
        return make_prefill_step(cfg, cache_len=shape.seq_len)
    from repro.serve import make_decode_step
    return make_decode_step(cfg)
