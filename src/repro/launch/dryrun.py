import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production meshes and extract memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all

Results are cached as JSON under results/dryrun/ (one file per cell) and
consumed by benchmarks/roofline.py and EXPERIMENTS.md.  The XLA_FLAGS line
above MUST precede any jax import: device count locks at first init.
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import SHAPES, get_arch, valid_cells
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8": 1, "s8": 1,
                "u8": 1, "pred": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    by_kind: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        ent = by_kind.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += b
    total = sum(e["bytes"] for e in by_kind.values())
    return {"total_bytes": total, "by_kind": by_kind}


def _lower_and_analyze(cfg, shape_name: str, mesh, donate=True, rules=None,
                       microbatch: int = 1):
    from repro.launch.specs import cell_spec, step_fn_for
    t0 = time.time()
    cs = cell_spec(cfg, shape_name, mesh, rules=rules)
    step = step_fn_for(cfg, shape_name, microbatch=microbatch)
    with mesh:
        lowered = jax.jit(step, in_shardings=cs.args_shardings,
                          donate_argnums=cs.donate if donate else ()
                          ).lower(*cs.args_avals)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {
        "kind": cs.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "collectives": coll,
    }


def _opt_variant(cfg, shape_name: str):
    """Beyond-baseline configuration for --opt runs (EXPERIMENTS.md §Perf):
    FSDP(+TP) weight sharding for train/prefill cells, int8 KV cache for
    decode cells.  Grouped MoE dispatch and causal/window block skipping
    are code-level defaults since the baseline sweep was recorded."""
    import dataclasses
    from repro.configs.base import SHAPES as _S
    from repro.launch.specs import CELL_RULES
    from repro.sharding.rules import FSDP_RULES, ShardingRules
    kind = _S[shape_name].kind
    rules = CELL_RULES
    if kind in ("train", "prefill"):
        rules = ShardingRules(rules=FSDP_RULES.rules + (
            ("cache_seq", ("model", "data", "pod")),))
    if kind == "decode":
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg, rules


def _baseline_variant(cfg, shape_name: str):
    """Paper-faithful-baseline semantics under the *corrected* probe
    measurement (EXPERIMENTS.md §Perf): flat global MoE dispatch, TP-only
    rules, bf16 KV cache.  Block-skip attention is structural in the new
    code; its FLOP effect is reported explicitly in the §Perf log."""
    import dataclasses
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, grouped=False))
    return cfg, None


def _probe_cost(cfg, shape_name: str, mesh, rules=None) -> dict:
    """Exact per-device cost terms via 1-/2-group probe lowerings.

    XLA's cost model counts a while body once regardless of trip count
    (verified in EXPERIMENTS.md §Dry-run), so the production (rolled)
    artifact undercounts in-loop FLOPs/bytes/collectives.  The probes are
    tiny same-width models lowered with every scan unrolled:
        B1 = embed/head + 1 x pattern-group (+ analytic remainder below)
        B2 = embed/head + 2 x pattern-groups
        total = B1 + (G - 1 + rem_frac) x (B2 - B1)
    rem_frac prorates recurrentgemma's trailing partial group.
    """
    import dataclasses
    from repro.models import flags
    pat = len(cfg.block_pattern)
    flags.set_unroll(True)
    # NOTE: probes keep the production attention chunking (512) — forcing
    # full-matrix attention here once distorted GSPMD's collective choices
    # (EXPERIMENTS.md §Perf, refuted hypothesis log).
    try:
        cfg1 = dataclasses.replace(cfg, n_layers=pat)
        cfg2 = dataclasses.replace(cfg, n_layers=2 * pat)
        r1 = _lower_and_analyze(cfg1, shape_name, mesh, donate=False,
                                rules=rules)
        r2 = _lower_and_analyze(cfg2, shape_name, mesh, donate=False,
                                rules=rules)
    finally:
        flags.set_unroll(False)
        flags.set_attn_chunk(None)

    G = cfg.n_groups()
    rem_frac = cfg.n_remainder() / pat     # partial trailing group, prorated

    def asm(path):
        b1 = _dig(r1, path)
        b2 = _dig(r2, path)
        return b1 + (G - 1 + rem_frac) * (b2 - b1)

    return {
        "flops": asm(("cost", "flops")),
        "bytes_accessed": asm(("cost", "bytes_accessed")),
        "collective_bytes": asm(("collectives", "total_bytes")),
        "probe_compile_s": r1["compile_s"] + r2["compile_s"],
        "probe_group_flops": _dig(r2, ("cost", "flops"))
        - _dig(r1, ("cost", "flops")),
    }


def _dig(d, path):
    for p in path:
        d = d[p]
    return d


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False,
             opt: bool = False, baseline_v2: bool = False,
             microbatch: int = 1) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)

    cfg = get_arch(arch)
    rules = None
    if opt:
        cfg, rules = _opt_variant(cfg, shape_name)
    elif baseline_v2:
        cfg, rules = _baseline_variant(cfg, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # microbatching shapes only the production artifact (memory analysis);
    # cost probes keep microbatch=1 — total FLOPs/bytes are invariant and
    # the extra accumulation loop would be undercounted by the cost model
    prod = _lower_and_analyze(cfg, shape_name, mesh, rules=rules,
                              microbatch=microbatch)
    probe = _probe_cost(cfg, shape_name, mesh, rules=rules)

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": prod["kind"], "ok": True,
        "seq_len": SHAPES[shape_name].seq_len,
        "global_batch": SHAPES[shape_name].global_batch,
        "n_params": cfg.param_count(),
        "n_params_active": cfg.active_param_count(),
        "microbatch": microbatch,
        "lower_s": prod["lower_s"], "compile_s": prod["compile_s"],
        "memory": prod["memory"],
        "cost_rolled": prod["cost"],
        "collectives_rolled": prod["collectives"],
        "cost": {"flops": probe["flops"],
                 "bytes_accessed": probe["bytes_accessed"]},
        "collectives": {"total_bytes": probe["collective_bytes"],
                        "by_kind": prod["collectives"]["by_kind"]},
        "probe": {"compile_s": probe["probe_compile_s"],
                  "group_flops": probe["probe_group_flops"]},
    }
    with open(fname, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["16x16", "2x16x16", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-baseline variant (FSDP / int8 KV)")
    ap.add_argument("--baseline-v2", action="store_true",
                    help="baseline semantics under corrected measurement")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="grad-accumulation microbatches (train cells)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()
    if args.opt and args.out == RESULTS_DIR:
        args.out = RESULTS_DIR + "_opt"
    if args.baseline_v2 and args.out == RESULTS_DIR:
        args.out = RESULTS_DIR + "_base2"

    cells = valid_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.mesh == "both" else \
        [args.mesh == "2x16x16"]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, shape, mp, out_dir=args.out,
                             force=args.force, opt=args.opt,
                             baseline_v2=args.baseline_v2,
                             microbatch=args.microbatch)
                mem_gb = (r["memory"]["argument_bytes"]
                          + r["memory"]["temp_bytes"]) / 2**30
                print(f"OK   {tag:60s} compile={r['compile_s']:7.1f}s "
                      f"mem/dev={mem_gb:7.2f}GiB "
                      f"flops/dev={r['cost']['flops']:.3e} "
                      f"coll={r['collectives']['total_bytes']:.3e}B",
                      flush=True)
            except Exception as e:   # noqa: BLE001
                failures.append((tag, str(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         f"{[t for t, _ in failures]}")
    print("all cells passed")


if __name__ == "__main__":
    main()
