"""Training launcher: end-to-end driver with checkpointing, restart, and
straggler monitoring on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 200 --seq 128 --batch 8

On the production cluster the same entry point runs under the multi-pod
mesh (launch/mesh.py); on this box it runs the reduced config on CPU —
the ~100M-param example driver is examples/train_small_lm.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import DataConfig, make_batch
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.runtime import FaultConfig, StragglerMonitor, run_with_restarts
from repro.train import make_train_step, train_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count (with --reduced)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers)
    print(f"arch={cfg.name} params={sum(1 for _ in [0]) and cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} seq={args.seq} batch={args.batch}", flush=True)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state0 = train_init(cfg, params, opt_cfg)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    seed=args.seed)

    step_raw = jax.jit(make_train_step(cfg, opt_cfg,
                                       microbatch=args.microbatch))
    metrics_log = []

    def make_step():
        def step(state, batch):
            t0 = time.perf_counter()
            state, m = step_raw(state, batch)
            loss = float(m["loss"])
            metrics_log.append({"loss": loss, "lr": float(m["lr"]),
                                "dt": time.perf_counter() - t0})
            if len(metrics_log) % args.log_every == 1:
                print(f"step {len(metrics_log):5d} loss={loss:.4f} "
                      f"lr={float(m['lr']):.2e} "
                      f"dt={metrics_log[-1]['dt']:.2f}s", flush=True)
            return state, m
        return step

    manager = CheckpointManager(args.ckpt_dir,
                                interval=args.ckpt_interval)
    monitor = StragglerMonitor(FaultConfig())
    state, history = run_with_restarts(
        make_step=make_step, init_state=state0,
        data_for_step=lambda s: make_batch(cfg, dc, s),
        n_steps=args.steps, manager=manager, monitor=monitor,
        meta={"arch": cfg.name})
    print(json.dumps({"final_loss": metrics_log[-1]["loss"] if metrics_log
                      else None,
                      "restarts": history["restarts"],
                      "straggler_events": history["straggler_events"]}))
    return state, metrics_log


if __name__ == "__main__":
    main()
