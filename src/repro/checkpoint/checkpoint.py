"""Mesh-agnostic checkpointing with atomic writes and elastic restore.

Checkpoints store *logical* arrays (host numpy) plus a manifest
(step, arch, mesh shape, sharding-rule hash).  Restoring onto a different
mesh re-shards via the divisibility-aware rule chooser — the elastic-
scaling path: a job restarted on fewer/more healthy pods resumes from the
same checkpoint with new shardings (DESIGN.md §5).

On a real multi-host deployment the np.savez writer below is replaced by a
per-host shard writer (same manifest format); the restore path is
unchanged because it is already logical-array based.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _encode(a: np.ndarray):
    for name, (dt, view) in _EXOTIC.items():
        if a.dtype == dt:
            return a.view(view), name
    return a, str(a.dtype)


def _decode(a: np.ndarray, dtype_name: str):
    if dtype_name in _EXOTIC:
        return a.view(_EXOTIC[dtype_name][0])
    return a


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}{k}/")
    elif isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_fields") else \
            enumerate(tree)
        for k, v in items:
            yield from _flatten(v, f"{prefix}{k}/")
    else:
        yield prefix.rstrip("/"), tree


def tree_paths(tree) -> dict:
    return dict(_flatten(tree))


def rules_hash(rules) -> str:
    return hashlib.sha1(repr(rules).encode()).hexdigest()[:12]


def save_checkpoint(path: str, step: int, state, meta: dict | None = None):
    """Atomic: write to tmp dir, fsync, rename."""
    flat, dtypes = {}, {}
    for k, v in tree_paths(state).items():
        arr, dt = _encode(np.asarray(v))
        flat[k] = arr
        dtypes[k] = dt
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(path)) or ".")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": int(step), "keys": sorted(flat),
                    "dtypes": dtypes, "format": 1, **(meta or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def load_checkpoint(path: str, like=None):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    dtypes = manifest.get("dtypes", {})
    flat = {k: _decode(arrays[k], dtypes.get(k, str(arrays[k].dtype)))
            for k in manifest["keys"]}
    if like is None:
        return flat, manifest
    return _unflatten_like(like, flat), manifest


def _unflatten_like(like, flat, prefix=""):
    if isinstance(like, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(**{k: _unflatten_like(v, flat, f"{prefix}{k}/")
                             for k, v in like._asdict().items()})
    if isinstance(like, (tuple, list)):
        return type(like)(_unflatten_like(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    key = prefix.rstrip("/")
    arr = flat[key]
    return arr


def reshard_state(state, shardings):
    """Place a (host or differently-sharded) state onto new shardings —
    the elastic-restore step."""
    return jax.device_put(state, shardings)


class CheckpointManager:
    """Rolling checkpoints: save every `interval` steps, keep `keep`."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:010d}")

    def maybe_save(self, step: int, state, meta=None) -> str | None:
        if step % self.interval != 0:
            return None
        p = save_checkpoint(self._path(step), step, state, meta)
        self._gc()
        return p

    def _gc(self):
        ckpts = self.all_steps()
        for s in ckpts[:-self.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore_latest(self, like=None):
        steps = self.all_steps()
        if not steps:
            return None, None
        return load_checkpoint(self._path(steps[-1]), like)
