"""Version-compatibility shims for the installed jax.

``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) moved/disappeared across jax releases: new jax exposes
the enum and accepts the kwarg, while the jax pinned in some environments
has neither.  Call :func:`ensure_jax_sharding_compat` before building
meshes with ``axis_types`` — production code (``repro.launch.mesh``) and
the fault-tolerance layer (``repro.runtime.fault``) invoke it at import,
so test code written against the new API runs unmodified on both.

The shim is additive only: on a jax that already has the API it does
nothing.
"""
from __future__ import annotations

import enum
import functools
import inspect

_installed = False


def ensure_jax_sharding_compat() -> None:
    """Install ``jax.sharding.AxisType`` + ``axis_types=``-tolerant
    ``jax.make_mesh`` on jax versions that predate them.  Idempotent."""
    global _installed
    if _installed:
        return
    import jax

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            """Stand-in for ``jax.sharding.AxisType`` (old jax has only
            implicitly 'auto' mesh axes, so every member degrades to
            that behavior)."""
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" not in params:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *args, axis_types=None,
                      **kwargs):
            # old jax: every mesh axis is implicitly Auto — dropping the
            # kwarg preserves the semantics callers ask for
            return orig(axis_shapes, axis_names, *args, **kwargs)

        jax.make_mesh = make_mesh

    # Compiled.cost_analysis() returned a one-element list of dicts on old
    # jax; new jax returns the dict itself.  Normalize to the new contract.
    try:
        compiled_cls = jax.stages.Compiled
        orig_ca = compiled_cls.cost_analysis

        @functools.wraps(orig_ca)
        def cost_analysis(self):
            out = orig_ca(self)
            if isinstance(out, list):
                return out[0] if out else {}
            return out

        compiled_cls.cost_analysis = cost_analysis
    except AttributeError:
        pass
    _installed = True


_shard_map_installed = False


def ensure_jax_shard_map_compat() -> None:
    """Install a keyword-style ``jax.shard_map`` on jax versions where it
    still lives in ``jax.experimental.shard_map``.  Idempotent.

    New jax exposes ``jax.shard_map(f, mesh=..., in_specs=...,
    out_specs=...)`` directly; the engine's channel-sharded scan
    (``repro.core.engine``) is written against that spelling, and this
    shim makes it run unmodified on the pinned older jax.  The wrapper
    drops ``check_vma``/``check_rep`` strictness (the engine's outputs
    are all explicitly sharded, so the replication checker adds tracing
    cost without catching anything)."""
    global _shard_map_installed
    if _shard_map_installed:
        return
    import jax

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      **kwargs):
            kwargs.pop("check_vma", None)
            kwargs.setdefault("check_rep", False)
            return _shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

        jax.shard_map = shard_map
    _shard_map_installed = True
