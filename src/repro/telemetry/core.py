"""Windowed time-series telemetry: the metrics layer between end-of-run
``Stats`` and full per-cycle command traces.

The engine (``make_run(..., telemetry_window=W)``) restructures its cycle
scan into W-cycle segments and emits one *cumulative* counter snapshot per
window boundary as scan ``ys`` — O(n_windows) device output, no per-cycle
trace cost.  :func:`build` diffs consecutive snapshots on the host into
per-window counters, which therefore sum back to the end-of-run ``Stats``
aggregates *bit-exactly* by construction (the last snapshot IS the final
total).  The final window is ragged when ``n_cycles % W != 0``; rate
metrics divide by each window's true width.

Metric definitions (per window, per channel — docs/observability.md):

- ``reads``/``writes``: requests whose data burst finished in the window.
- bandwidth: ``(reads + writes) * access_bytes / (width * tCK)`` on the
  owning group's own clock.
- ``occ_sum``: cycle-sum of occupied request-queue slots; average queue
  occupancy is ``occ_sum / width``.
- row-hit rate: ``1 - ACT / (RD + WR)`` from the windowed command counts.
- ``lat_hist``: served-probe latency histogram over bucket edges planned
  at spec-compile time (``CompiledSpec.lat_bucket_edges``, in cycles).
- refresh activity: windowed count of ``REF*`` commands.
- ``issued``/``deferred``: commands issued / predicate-deferred
  candidates per window.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.compile import as_system

FORMAT_VERSION = 1


def _diff(a: np.ndarray) -> np.ndarray:
    """Cumulative snapshots -> per-window deltas along axis 0."""
    return np.diff(a, axis=0, prepend=np.zeros((1,) + a.shape[1:], a.dtype))


@dataclasses.dataclass
class GroupTelemetry:
    """Per-window counters of ONE spec group.  Every counter array has a
    leading ``(n_windows, C)`` shape (``C`` = the group's channel count);
    ``cmd_counts`` / ``lat_hist`` append the command / bucket axis."""
    standard: str
    channels: int
    link_latency: int
    tCK_ps: int
    access_bytes: int
    cmd_names: list
    lat_edges: tuple                # bucket upper edges, cycles
    reads: np.ndarray               # (W, C)
    writes: np.ndarray
    probe_lat_sum: np.ndarray
    probe_cnt: np.ndarray
    data_bus_busy: np.ndarray
    deferred: np.ndarray
    occ_sum: np.ndarray
    cmd_counts: np.ndarray          # (W, C, n_cmds) native namespace
    lat_hist: np.ndarray            # (W, C, n_buckets)

    # -- derived rates (given the owning Telemetry's window widths) -------
    def bandwidth_gbps(self, widths: np.ndarray) -> np.ndarray:
        """(W, C) achieved GB/s per window on this group's own clock."""
        seconds = widths[:, None] * self.tCK_ps * 1e-12
        moved = (self.reads + self.writes) * self.access_bytes
        return np.divide(moved, seconds * 1e9, out=np.zeros_like(moved,
                         float), where=seconds > 0)

    def occupancy(self, widths: np.ndarray) -> np.ndarray:
        """(W, C) mean occupied request-queue slots per window."""
        return np.divide(self.occ_sum, widths[:, None],
                         out=np.zeros_like(self.occ_sum, float),
                         where=widths[:, None] > 0)

    def _count(self, pred) -> np.ndarray:
        ids = [i for i, n in enumerate(self.cmd_names) if pred(n)]
        return self.cmd_counts[:, :, ids].sum(axis=2)

    def row_hit_rate(self) -> np.ndarray:
        """(W, C) ``1 - ACT/(RD+WR)`` per window; NaN where no data cmd."""
        act = self._count(lambda n: n.startswith("ACT")).astype(float)
        data = self._count(
            lambda n: n in ("RD", "WR", "RDA", "WRA")).astype(float)
        return np.where(data > 0, 1.0 - act / np.maximum(data, 1), np.nan)

    def refreshes(self) -> np.ndarray:
        """(W, C) refresh commands (``REF*``) per window."""
        return self._count(lambda n: n.startswith("REF"))

    def issued(self) -> np.ndarray:
        """(W, C) total commands issued per window."""
        return self.cmd_counts.sum(axis=2)


@dataclasses.dataclass
class Telemetry:
    """One run's windowed time series: group-indexed counters plus the
    shared window geometry (``t_end`` is each window's exclusive end
    cycle; the final window is ragged when ``n_cycles % window != 0``)."""
    window: int
    n_cycles: int
    t_end: np.ndarray               # (W,) exclusive end cycle
    groups: tuple                   # GroupTelemetry per spec group
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n_windows(self) -> int:
        return len(self.t_end)

    @property
    def widths(self) -> np.ndarray:
        return _diff(self.t_end)

    @property
    def t_start(self) -> np.ndarray:
        return self.t_end - self.widths

    def __len__(self):
        return self.n_windows

    # -- consistency ------------------------------------------------------
    def check(self, stats) -> None:
        """Assert bit-consistency against the same run's end-of-run
        ``Stats``: every windowed counter, summed over all windows, must
        EQUAL the aggregate (and the latency histogram must account for
        every served probe).  Raises ``ValueError`` on any mismatch."""
        errs = []
        for g, gt in enumerate(self.groups):
            ch = stats.per_group[g]
            pairs = [("reads", gt.reads, ch.reads_done),
                     ("writes", gt.writes, ch.writes_done),
                     ("probe_lat_sum", gt.probe_lat_sum, ch.probe_lat_sum),
                     ("probe_cnt", gt.probe_cnt, ch.probe_cnt),
                     ("data_bus_busy", gt.data_bus_busy, ch.data_bus_busy),
                     ("deferred", gt.deferred, ch.deferred),
                     ("cmd_counts", gt.cmd_counts, ch.cmd_counts)]
            for name, win, agg in pairs:
                tot = win.sum(axis=0)
                if not np.array_equal(tot, np.asarray(agg)):
                    errs.append(f"group {g} {name}: sum-over-windows "
                                f"{tot.tolist()} != aggregate "
                                f"{np.asarray(agg).tolist()}")
            hist = gt.lat_hist.sum(axis=(0, 2))
            if not np.array_equal(hist, np.asarray(ch.probe_cnt)):
                errs.append(f"group {g} lat_hist accounts for "
                            f"{hist.tolist()} probes but probe_cnt is "
                            f"{np.asarray(ch.probe_cnt).tolist()}")
            if (gt.lat_hist < 0).any():
                # a negative bucket means the engine's cumulative
                # histogram disagrees with probe_cnt (unpack bug)
                errs.append(f"group {g} lat_hist has negative buckets")
        if errs:
            raise ValueError("windowed telemetry inconsistent with Stats:\n"
                             + "\n".join("  " + e for e in errs))

    # -- presentation -----------------------------------------------------
    def summary(self) -> str:
        """Per-group min/mean/max of the windowed rates."""
        lines = [f"{self.n_windows} windows of {self.window} cycles over "
                 f"{self.n_cycles:,} cycles"
                 + (" (ragged tail)" if self.n_cycles % self.window else "")]
        w = self.widths
        for g, gt in enumerate(self.groups):
            bw = gt.bandwidth_gbps(w).sum(axis=1)     # system GB/s of group
            occ = gt.occupancy(w).mean(axis=1)
            hit = gt.row_hit_rate()
            hit = hit[~np.isnan(hit)]
            lines.append(
                f"group {g} [{gt.standard} x{gt.channels}"
                + (f" link={gt.link_latency}" if gt.link_latency else "")
                + f"]: bw GB/s min/mean/max "
                f"{bw.min():.2f}/{bw.mean():.2f}/{bw.max():.2f}, "
                f"queue occ {occ.mean():.1f}, row-hit "
                + (f"{hit.mean():.1%}" if hit.size else "n/a")
                + f", refreshes {int(gt.refreshes().sum())}, "
                f"deferred {int(gt.deferred.sum())}")
        return "\n".join(lines)


def build(spec, snaps, window: int, n_cycles: int) -> Telemetry:
    """Convert the engine's raw cumulative :class:`GroupWindowSnap` ys
    (already pulled to host numpy) into a :class:`Telemetry` of
    per-window counters.  ``spec`` is the run's CompiledSpec or
    MemorySystemSpec — the source of clocks, namespaces, and bucket
    edges."""
    msys = as_system(spec)
    if len(snaps) != msys.n_groups:
        raise ValueError(f"snapshot tuple has {len(snaps)} groups but the "
                         f"system has {msys.n_groups}")
    n_full, rem = divmod(int(n_cycles), int(window))
    t_end = [window * (i + 1) for i in range(n_full)]
    if rem or not t_end:
        t_end.append(int(n_cycles))
    t_end = np.asarray(t_end, np.int64)
    groups = []
    for grp, snap in zip(msys.groups, snaps):
        ch = snap.ch
        if len(np.asarray(ch.reads_done)) != len(t_end):
            raise ValueError(
                f"snapshot has {len(np.asarray(ch.reads_done))} windows, "
                f"expected {len(t_end)} for n_cycles={n_cycles} "
                f"window={window}")
        d = lambda a: _diff(np.asarray(a))
        # unpack the engine's fused gauge array (W, C, 1 + n_edges):
        # column 0 is the occupancy cycle-sum, the rest a CUMULATIVE
        # latency histogram (count of probes with latency <= edge_k) —
        # diff along the bucket axis recovers the buckets, probe_cnt
        # closes the open top bucket
        tm = d(snap.tm)
        probe_cnt = d(ch.probe_cnt)
        cum = tm[:, :, 1:]
        lat_hist = np.concatenate(
            [cum[:, :, :1], np.diff(cum, axis=2),
             (probe_cnt - cum[:, :, -1])[:, :, None]], axis=2)
        groups.append(GroupTelemetry(
            standard=grp.cspec.standard or grp.cspec.name,
            channels=grp.channels, link_latency=grp.link_latency,
            tCK_ps=grp.cspec.tCK_ps, access_bytes=grp.cspec.access_bytes,
            cmd_names=list(grp.cspec.cmd_names),
            lat_edges=tuple(grp.cspec.lat_bucket_edges),
            reads=d(ch.reads_done), writes=d(ch.writes_done),
            probe_lat_sum=d(ch.probe_lat_sum), probe_cnt=probe_cnt,
            data_bus_busy=d(ch.data_bus_busy), deferred=d(ch.deferred),
            occ_sum=tm[:, :, 0], cmd_counts=d(ch.cmd_counts),
            lat_hist=lat_hist))
    return Telemetry(window=int(window), n_cycles=int(n_cycles),
                     t_end=t_end, groups=tuple(groups),
                     meta={"label": msys.label})


# --------------------------------------------------------------------------
# Artifacts: columnar .npz + JSON Lines
# --------------------------------------------------------------------------

_ARRAYS = ("reads", "writes", "probe_lat_sum", "probe_cnt",
           "data_bus_busy", "deferred", "occ_sum", "cmd_counts", "lat_hist")


def save(telem: Telemetry, path: str) -> str:
    """Write one ``.npz`` artifact: the shared window geometry, every
    group's counter arrays (``g{i}:{name}`` keys), and a JSON meta
    header."""
    cols = {"t_end": telem.t_end}
    gmeta = []
    for gi, gt in enumerate(telem.groups):
        for name in _ARRAYS:
            cols[f"g{gi}:{name}"] = getattr(gt, name)
        gmeta.append({"standard": gt.standard, "channels": gt.channels,
                      "link_latency": gt.link_latency, "tCK_ps": gt.tCK_ps,
                      "access_bytes": gt.access_bytes,
                      "cmd_names": gt.cmd_names,
                      "lat_edges": list(gt.lat_edges)})
    meta = {"format": FORMAT_VERSION, "window": telem.window,
            "n_cycles": telem.n_cycles, "groups": gmeta, **telem.meta}
    cols["meta"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    with open(path, "wb") as f:
        np.savez_compressed(f, **cols)
    return path


def load(path: str) -> Telemetry:
    """Load a :func:`save` artifact back into a :class:`Telemetry`."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode("utf-8"))
        if meta.get("format", 0) > FORMAT_VERSION:
            raise ValueError(f"{path}: telemetry format "
                             f"{meta['format']} is newer than this reader "
                             f"({FORMAT_VERSION})")
        groups = []
        for gi, gm in enumerate(meta.pop("groups")):
            arrs = {name: z[f"g{gi}:{name}"] for name in _ARRAYS}
            groups.append(GroupTelemetry(
                standard=gm["standard"], channels=gm["channels"],
                link_latency=gm["link_latency"], tCK_ps=gm["tCK_ps"],
                access_bytes=gm["access_bytes"],
                cmd_names=list(gm["cmd_names"]),
                lat_edges=tuple(gm["lat_edges"]), **arrs))
        t_end = z["t_end"]
    extra = {k: v for k, v in meta.items()
             if k not in ("format", "window", "n_cycles")}
    return Telemetry(window=meta["window"], n_cycles=meta["n_cycles"],
                     t_end=t_end, groups=tuple(groups), meta=extra)


def write_jsonl(telem: Telemetry, path: str) -> int:
    """Stream one JSON record per window (per-channel lists inside), for
    log pipelines / pandas.  Returns the record count."""
    widths = telem.widths
    with open(path, "w") as f:
        for i in range(telem.n_windows):
            rec = {"window": i, "t_start": int(telem.t_start[i]),
                   "t_end": int(telem.t_end[i]), "groups": []}
            for gt in telem.groups:
                hit = gt.row_hit_rate()[i]
                rec["groups"].append({
                    "standard": gt.standard,
                    "reads": gt.reads[i].tolist(),
                    "writes": gt.writes[i].tolist(),
                    "gbps": [round(x, 4) for x in
                             gt.bandwidth_gbps(widths)[i]],
                    "queue_occ": [round(x, 3) for x in
                                  gt.occupancy(widths)[i]],
                    "row_hit": [None if np.isnan(x) else round(x, 4)
                                for x in hit],
                    "refreshes": gt.refreshes()[i].tolist(),
                    "deferred": gt.deferred[i].tolist(),
                    "lat_hist": gt.lat_hist[i].tolist(),
                })
            f.write(json.dumps(rec) + "\n")
    return telem.n_windows
