"""repro.telemetry — windowed in-scan metrics + host-side run profiler.

The observability layer between end-of-run ``Stats`` and full per-cycle
command traces (docs/observability.md):

- ``Simulator.run(..., telemetry=W)`` -> ``(stats, Telemetry)``: windowed
  per-channel bandwidth / row-hit / occupancy / refresh / latency
  histograms captured inside the single ``lax.scan``.
- :class:`Profiler` / :func:`profile_run`: compile wall-time, RunCache
  hit/miss accounting, warm cycles/sec.
- Artifacts: :func:`save` / :func:`load` (columnar .npz),
  :func:`write_jsonl`, :func:`write_html` (LOD timeline), and the
  ``python -m repro.telemetry`` CLI.
"""
from repro.telemetry.core import (FORMAT_VERSION, GroupTelemetry, Telemetry,
                                  build, load, save, write_jsonl)
from repro.telemetry.profile import Profiler, profile_run
from repro.telemetry.viz import render_html, write_html

__all__ = [
    "FORMAT_VERSION", "GroupTelemetry", "Telemetry", "build", "load",
    "save", "write_jsonl", "Profiler", "profile_run", "render_html",
    "write_html",
]
