"""Telemetry CLI: ``python -m repro.telemetry`` — simulate with windowed
metrics, verify, profile, export.

    # windowed metrics + timeline HTML + artifacts
    PYTHONPATH=src python -m repro.telemetry --standard HBM3 --channels 2 \\
        --cycles 20000 --window 256 --out telem.npz --html telem.html

    # heterogeneous (CXL-style) composition, bit-consistency check
    PYTHONPATH=src python -m repro.telemetry --group DDR5:2 --group \\
        DDR4:2:80 --cycles 20000 --check

    # host-side profile (compile vs warm cost, cycles/sec)
    PYTHONPATH=src python -m repro.telemetry --standard DDR4 --profile

CI uses ``--check`` to turn any window/aggregate mismatch into a nonzero
exit status.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.dse.spec import DEFAULT_SYSTEMS


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Windowed telemetry capture, verification, profiling, "
                    "and timeline rendering.")
    src = ap.add_argument_group("run")
    src.add_argument("--standard", default="DDR4",
                     help="standard to simulate "
                          f"(known: {','.join(sorted(DEFAULT_SYSTEMS))})")
    src.add_argument("--org", default=None)
    src.add_argument("--timing", default=None)
    src.add_argument("--cycles", default=20_000, type=int)
    src.add_argument("--channels", default=1, type=int)
    src.add_argument("--group", default=None, action="append",
                     metavar="STD[:CHANNELS[:LINK]]",
                     help="heterogeneous spec group (repeatable), e.g. "
                          "--group DDR5:2 --group DDR4:2:80; overrides "
                          "--standard/--channels")
    src.add_argument("--mapper", default=None)
    src.add_argument("--interval", default=4.0, type=float)
    src.add_argument("--ratio", default=1.0, type=float)
    src.add_argument("--scheduler", default="FRFCFS",
                     choices=("FRFCFS", "FCFS"))
    src.add_argument("--seed", default=0x1234, type=int)
    src.add_argument("--window", default=256, type=int,
                     help="telemetry window in cycles")
    src.add_argument("--load", default=None, metavar="TELEM_NPZ",
                     help="render/export a saved artifact instead of "
                          "simulating")
    out = ap.add_argument_group("outputs")
    out.add_argument("--out", default=None, metavar="TELEM_NPZ")
    out.add_argument("--jsonl", default=None)
    out.add_argument("--html", default=None)
    ap.add_argument("--check", action="store_true",
                    help="verify sum-over-windows == Stats aggregates; "
                         "exit nonzero on any mismatch")
    ap.add_argument("--profile", action="store_true",
                    help="also print the host-side run profile "
                         "(compile vs warm cost, cycles/sec)")
    return ap


def _make_sim(args):
    from repro.core import ControllerConfig, Simulator, compile_system
    if args.group:
        msys = compile_system([_parse_group(g) for g in args.group])
        return Simulator(system=msys, mapper=args.mapper,
                         controller=ControllerConfig(
                             scheduler=args.scheduler))
    if args.org is None or args.timing is None:
        if args.standard not in DEFAULT_SYSTEMS:
            raise SystemExit(
                f"no default org/timing for {args.standard!r}; pass --org "
                f"and --timing (known defaults: {sorted(DEFAULT_SYSTEMS)})")
        org, tim = DEFAULT_SYSTEMS[args.standard]
        org = args.org or org
        tim = args.timing or tim
    else:
        org, tim = args.org, args.timing
    return Simulator(args.standard, org, tim, channels=args.channels,
                     mapper=args.mapper,
                     controller=ControllerConfig(scheduler=args.scheduler))


def _parse_group(text: str) -> dict:
    parts = text.split(":")
    std = parts[0]
    if std not in DEFAULT_SYSTEMS:
        raise SystemExit(f"no default org/timing for {std!r}; known: "
                         f"{sorted(DEFAULT_SYSTEMS)}")
    org, tim = DEFAULT_SYSTEMS[std]
    return dict(standard=std, org_preset=org, timing_preset=tim,
                channels=int(parts[1]) if len(parts) > 1 else 1,
                link_latency=int(parts[2]) if len(parts) > 2 else 0)


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro import telemetry as T

    stats = None
    if args.load:
        telem = T.load(args.load)
        print(f"loaded {args.load}: {telem.n_windows} windows of "
              f"{telem.window} cycles ({telem.meta.get('label', '?')})")
    else:
        if args.window <= 0:
            raise SystemExit("--window must be a positive cycle count")
        sim = _make_sim(args)
        stats, telem = sim.run(args.cycles, interval=args.interval,
                               read_ratio=args.ratio, seed=args.seed,
                               telemetry=args.window)
        print(f"simulated {args.cycles} cycles of {sim.msys.label} "
              f"(window={args.window})")
        print(stats.summary(sim.msys))

    print(telem.summary())

    if args.check:
        if stats is None:
            raise SystemExit("--check needs a fresh run, not --load")
        try:
            telem.check(stats)
        except ValueError as e:
            print(e)
            return 1
        print("check: sum-over-windows == Stats aggregates "
              f"({telem.n_windows} windows, ragged tail "
              f"{'yes' if args.cycles % args.window else 'no'})")

    if args.profile:
        if args.load:
            raise SystemExit("--profile needs a fresh run, not --load")
        p = T.profile_run(sim, args.cycles, interval=args.interval,
                          read_ratio=args.ratio, telemetry=args.window)
        print(f"profile: first call {p['first_call_s']}s "
              f"(compile ~{p['compile_s']}s), warm {p['warm_s']}s = "
              f"{p['cycles_per_sec']:,.0f} cycles/s; cache {p['cache']}")

    for path, writer, what in ((args.out, T.save, "telemetry artifact"),
                               (args.jsonl, lambda t, p: T.write_jsonl(t, p),
                                "JSONL records"),
                               (args.html, lambda t, p: T.write_html(p, t),
                                "timeline")):
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            writer(telem, path)
            print(f"{what} written to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
