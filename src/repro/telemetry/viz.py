"""Telemetry timeline: standalone HTML rendering of one run's windowed
metrics, in the same level-of-detail (LOD) style as the trace visualizer
(:mod:`repro.trace.viz`, whose palette it shares).

Python precomputes mean-pooled LOD levels (x4 decimation per level) of
every per-window series; the JS canvas renderer picks the coarsest level
that still gives >= ~2 windows per pixel at the current zoom, so payload
size and draw cost stay bounded for million-window runs.  Lanes, top to
bottom: per-channel bandwidth (GB/s, each channel a palette color,
heterogeneous channels labeled by standard), mean queue occupancy,
row-hit rate, refresh + deferred activity, and a served-probe latency
heatmap (bucket edges from ``CompiledSpec.lat_bucket_edges``).  Wheel =
zoom, drag = pan, double-click = reset.
"""
from __future__ import annotations

import json

import numpy as np

from repro.trace.viz import PALETTE

from repro.telemetry.core import Telemetry

#: windows per LOD bin at successive levels (level 0 is raw)
LOD_FACTOR = 4
#: stop adding levels once a level fits in this many bins
LOD_MIN_BINS = 512


def _pool(a: np.ndarray, f: int, how: str) -> np.ndarray:
    """Pool axis 0 of ``a`` by factor ``f`` (ragged tail kept)."""
    n = a.shape[0]
    nb = (n + f - 1) // f
    pad = nb * f - n
    if pad:
        padv = np.concatenate([a, np.full((pad,) + a.shape[1:], np.nan
                               if how == "mean" else 0, float)])
    else:
        padv = a.astype(float)
    r = padv.reshape((nb, f) + a.shape[1:])
    if how == "mean":
        with np.errstate(invalid="ignore"):
            return np.nanmean(r, axis=1)
    return np.nansum(r, axis=1)


def _levels(a: np.ndarray, how: str) -> list:
    """LOD pyramid of ``a``: [raw, x4, x16, ...] as nested lists (NaN ->
    None via json round-trip handled by _clean)."""
    out, cur = [a.astype(float)], a.astype(float)
    while cur.shape[0] > LOD_MIN_BINS:
        cur = _pool(cur, LOD_FACTOR, how)
        out.append(cur)
    return out


def _clean(x):
    """NaN -> None recursively for JSON."""
    if isinstance(x, np.ndarray):
        return _clean(x.tolist())
    if isinstance(x, list):
        return [_clean(v) for v in x]
    if isinstance(x, float) and x != x:
        return None
    return x


def _payload(telem: Telemetry) -> dict:
    widths = telem.widths
    chans, bw, occ, hit = [], [], [], []
    refresh, deferred, hist = [], [], []
    edges = ()
    for g, gt in enumerate(telem.groups):
        b = gt.bandwidth_gbps(widths)
        o = gt.occupancy(widths)
        h = gt.row_hit_rate()
        for c in range(gt.channels):
            label = f"ch{len(chans)}"
            if len(telem.groups) > 1:
                label += f" {gt.standard}"
            chans.append(label)
            bw.append(b[:, c])
            occ.append(o[:, c])
            hit.append(h[:, c])
        refresh.append(gt.refreshes().sum(axis=1))
        deferred.append(gt.deferred.sum(axis=1))
        hist.append(gt.lat_hist.sum(axis=1))       # (W, n_buckets)
        if g == 0:
            edges = gt.lat_edges
    stack = lambda xs: np.stack(xs, axis=1)         # (W, lanes)
    return {
        "window": telem.window, "n_cycles": telem.n_cycles,
        "t_end": telem.t_end.tolist(), "channels": chans,
        "label": telem.meta.get("label", ""),
        "palette": PALETTE,
        "lat_edges": list(edges),
        "bw": _clean(_levels(stack(bw), "mean")),
        "occ": _clean(_levels(stack(occ), "mean")),
        "hit": _clean(_levels(stack(hit), "mean")),
        "refresh": _clean(_levels(stack(refresh).sum(axis=1), "sum")),
        "deferred": _clean(_levels(stack(deferred).sum(axis=1), "sum")),
        "hist": _clean(_levels(sum(hist[1:], hist[0]), "sum")),
    }


_HTML = """<!doctype html>
<meta charset="utf-8">
<title>telemetry — {label}</title>
<style>
 body {{ background:#16191e; color:#cfd6e4; margin:0;
        font:13px/1.4 system-ui, sans-serif; }}
 h1 {{ font-size:15px; margin:10px 14px 2px; }}
 #sub {{ margin:0 14px 8px; color:#8a94a6; }}
 .lane {{ margin:4px 14px; }}
 .lane .t {{ color:#8a94a6; font-size:11px; margin-bottom:2px; }}
 canvas {{ display:block; width:100%; background:#1d2127;
          border:1px solid #2a2f38; border-radius:4px; }}
 #legend span {{ margin-right:12px; }}
 #legend i {{ display:inline-block; width:10px; height:10px;
             border-radius:2px; margin-right:4px; }}
</style>
<h1>windowed telemetry — {label}</h1>
<p id="sub"></p>
<div class="lane" id="legend"></div>
<div class="lane"><div class="t">bandwidth (GB/s, per channel)</div>
 <canvas id="bw" height="140"></canvas></div>
<div class="lane"><div class="t">mean queue occupancy (slots)</div>
 <canvas id="occ" height="90"></canvas></div>
<div class="lane"><div class="t">row-hit rate</div>
 <canvas id="hit" height="90"></canvas></div>
<div class="lane"><div class="t">refresh (bars) + deferred (line), per
 window</div><canvas id="ref" height="70"></canvas></div>
<div class="lane"><div class="t">served-probe latency histogram
 (bucket x window, log color)</div>
 <canvas id="lat" height="110"></canvas></div>
<script>
const D = {payload};
const W0 = D.t_end.length, CYC = D.n_cycles, LODF = {lodf};
let x0 = 0, x1 = CYC;                       // visible cycle span
const sub = document.getElementById('sub');
sub.textContent = W0 + ' windows of ' + D.window + ' cycles over ' +
  CYC.toLocaleString() + ' cycles';
const leg = document.getElementById('legend');
D.channels.forEach((c, i) => {{
  const s = document.createElement('span');
  s.innerHTML = '<i style="background:' +
    D.palette[i % D.palette.length] + '"></i>' + c;
  leg.appendChild(s);
}});
function lvlFor(cv) {{                      // coarsest level, >=2 win/px
  const winSpan = (x1 - x0) / D.window;
  let lvl = 0, per = 1;
  while (lvl + 1 < D.bw.length && winSpan / (per * LODF) > cv.width / 2)
    {{ lvl++; per *= LODF; }}
  return [lvl, per];
}}
function setup(cv) {{
  const r = cv.getBoundingClientRect();
  cv.width = r.width * devicePixelRatio;
  cv.height = cv.getAttribute('height') * devicePixelRatio;
  const g = cv.getContext('2d');
  g.scale(devicePixelRatio, devicePixelRatio);
  return [g, r.width, +cv.getAttribute('height')];
}}
function series(cv, data, opts) {{
  const [g, w, h] = setup(cv), [lvl, per] = lvlFor(cv);
  const rows = data[lvl], lanes = Array.isArray(rows[0]) ? rows[0].length : 1;
  let max = opts.max || 0;
  if (!max) {{ rows.forEach(r => (Array.isArray(r) ? r : [r]).forEach(
      v => {{ if (v != null && v > max) max = v; }})); max = max || 1; }}
  g.clearRect(0, 0, w, h);
  for (let ln = 0; ln < lanes; ln++) {{
    g.strokeStyle = opts.color || D.palette[ln % D.palette.length];
    g.lineWidth = 1.2; g.beginPath(); let pen = false;
    for (let i = 0; i < rows.length; i++) {{
      const cyc = (i + 0.5) * per * D.window;
      if (cyc < x0 - per * D.window || cyc > x1 + per * D.window) continue;
      const v = Array.isArray(rows[i]) ? rows[i][ln] : rows[i];
      if (v == null) {{ pen = false; continue; }}
      const x = (cyc - x0) / (x1 - x0) * w;
      const y = h - 4 - (v / max) * (h - 12);
      pen ? g.lineTo(x, y) : g.moveTo(x, y); pen = true;
    }}
    g.stroke();
  }}
  g.fillStyle = '#8a94a6'; g.font = '10px system-ui';
  g.fillText(opts.fmt ? opts.fmt(max) : max.toFixed(2), 4, 11);
}}
function heat(cv) {{
  const [g, w, h] = setup(cv), [lvl, per] = lvlFor(cv);
  const rows = D.hist[lvl], nb = rows[0].length;
  let max = 1; rows.forEach(r => r.forEach(v => {{ if (v > max) max = v; }}));
  g.clearRect(0, 0, w, h);
  const bh = h / nb;
  for (let i = 0; i < rows.length; i++) {{
    const c0 = i * per * D.window, c1 = (i + 1) * per * D.window;
    if (c1 < x0 || c0 > x1) continue;
    const x = (c0 - x0) / (x1 - x0) * w;
    const bw_ = Math.max((c1 - c0) / (x1 - x0) * w, 1);
    for (let b = 0; b < nb; b++) {{
      const v = rows[i][b]; if (!v) continue;
      const a = Math.log1p(v) / Math.log1p(max);
      g.fillStyle = 'rgba(242,142,43,' + (0.08 + 0.92 * a).toFixed(3) + ')';
      g.fillRect(x, h - (b + 1) * bh, bw_, bh - 0.5);
    }}
  }}
  g.fillStyle = '#8a94a6'; g.font = '10px system-ui';
  g.fillText('<=' + (D.lat_edges[0] || '?') + 'cy', 4, h - 2);
  g.fillText('>' + (D.lat_edges[D.lat_edges.length - 1] || '?') + 'cy',
             4, 11);
}}
function draw() {{
  series(document.getElementById('bw'), D.bw, {{}});
  series(document.getElementById('occ'), D.occ, {{}});
  series(document.getElementById('hit'), D.hit,
         {{max: 1, fmt: v => '100%'}});
  series(document.getElementById('ref'), D.refresh,
         {{color: '#76b7b2', fmt: v => v.toFixed(0)}});
  series(document.getElementById('ref'), D.deferred,
         {{color: '#e15759', fmt: v => ''}});
  heat(document.getElementById('lat'));
}}
let dragX = null;
document.querySelectorAll('canvas').forEach(cv => {{
  cv.addEventListener('wheel', e => {{
    e.preventDefault();
    const r = cv.getBoundingClientRect();
    const fx = (e.clientX - r.left) / r.width;
    const c = x0 + fx * (x1 - x0);
    const z = e.deltaY > 0 ? 1.25 : 0.8;
    x0 = Math.max(0, c - (c - x0) * z);
    x1 = Math.min(CYC, c + (x1 - c) * z);
    draw();
  }}, {{passive: false}});
  cv.addEventListener('mousedown', e => dragX = e.clientX);
  cv.addEventListener('mousemove', e => {{
    if (dragX == null) return;
    const r = cv.getBoundingClientRect();
    const d = (e.clientX - dragX) / r.width * (x1 - x0);
    if (x0 - d >= 0 && x1 - d <= CYC) {{ x0 -= d; x1 -= d; draw(); }}
    dragX = e.clientX;
  }});
  cv.addEventListener('dblclick', () => {{ x0 = 0; x1 = CYC; draw(); }});
}});
window.addEventListener('mouseup', () => dragX = null);
window.addEventListener('resize', draw);
draw();
</script>
"""


def render_html(telem: Telemetry) -> str:
    """Render the standalone timeline HTML for one telemetry series."""
    return _HTML.format(label=telem.meta.get("label", "run"),
                        payload=json.dumps(_payload(telem)),
                        lodf=LOD_FACTOR)


def write_html(path: str, telem: Telemetry) -> str:
    with open(path, "w") as f:
        f.write(render_html(telem))
    return path
