"""Host-side run profiler: where the wall-clock goes *around* the jitted
simulation — trace + XLA compile cost, :class:`repro.core.RunCache`
hit/miss behavior, and warm-run throughput (cycles/second) — the
counterpart of the in-scan windowed telemetry.

Two entry points:

- :class:`Profiler` — a span recorder + cache-accounting delta reader for
  instrumenting arbitrary host code (DSE sweeps, benchmarks).
- :func:`profile_run` — one-shot cold/warm characterization of a
  :class:`~repro.core.Simulator` run configuration.
"""
from __future__ import annotations

import contextlib
import time

import jax

from repro.core import engine as E


class Profiler:
    """Record named wall-time spans and RunCache accounting deltas.

    >>> prof = Profiler()
    >>> with prof.span("sweep"):
    ...     result = run_sweep(spec)
    >>> prof.report()["spans"]["sweep"]        # {"s": ..., "calls": 1}
    >>> prof.report()["cache"]                 # hits/misses/compile since
    ...                                        # construction

    Spans nest and repeat (times accumulate per name).  The cache view is
    a DELTA against the profiler's construction instant, so a process-wide
    warm :data:`repro.core.engine.RUN_CACHE` does not pollute it.
    """

    def __init__(self, cache: E.RunCache | None = None):
        self.cache = cache if cache is not None else E.RUN_CACHE
        self._base = dict(self.cache.stats())
        self._spans: dict = {}
        self._t0 = time.perf_counter()

    @contextlib.contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            dt = time.perf_counter() - t0
            s = self._spans.setdefault(name, {"s": 0.0, "calls": 0})
            s["s"] += dt
            s["calls"] += 1

    def add(self, name: str, seconds: float) -> None:
        """Fold an externally-timed interval into span ``name`` — for
        callers that interleave many short phases (the streaming sweep
        executor's per-group dispatch/collect attribution) where a
        context manager per slice would obscure the control flow."""
        s = self._spans.setdefault(name, {"s": 0.0, "calls": 0})
        s["s"] += float(seconds)
        s["calls"] += 1

    def cache_stats(self) -> dict:
        """RunCache accounting since this profiler was constructed.
        Numeric fields are deltas against the construction instant;
        non-numeric fields (device/topology views) pass through as-is."""
        delta_keys = {"entries", "hits", "misses", "first_call_s"}
        now = self.cache.stats()
        out = {}
        for k, v in now.items():
            if k in delta_keys and isinstance(v, (int, float)):
                base = self._base.get(k, 0)
                out[k] = round(v - base, 3) if isinstance(v, float) \
                    else v - base
            else:
                # topology views ("devices", "shard_topologies", future
                # additions) are states, not counters — pass through
                out[k] = v
        return out

    def report(self) -> dict:
        return {"wall_s": round(time.perf_counter() - self._t0, 3),
                "spans": {k: {"s": round(v["s"], 3), "calls": v["calls"]}
                          for k, v in self._spans.items()},
                "cache": self.cache_stats()}

    def summary(self) -> str:
        r = self.report()
        c = r["cache"]
        lines = [f"wall {r['wall_s']:.3f}s | cache: {c['entries']:+d} "
                 f"programs, {c['hits']} hits / {c['misses']} misses, "
                 f"first-call (trace+compile+run) {c['first_call_s']:.3f}s"]
        for name, s in sorted(r["spans"].items(), key=lambda kv: -kv[1]["s"]):
            lines.append(f"  {name:<24} {s['s']:>9.3f}s x{s['calls']}")
        return "\n".join(lines)


def profile_run(sim, n_cycles: int, repeats: int = 3, **run_kw) -> dict:
    """Cold/warm characterization of one run configuration.

    Times the first (compiling) call and the best of ``repeats`` warm
    calls, both synchronized with ``jax.block_until_ready``.  Returns::

        {"first_call_s", "warm_s", "compile_s",       # first - warm
         "cycles_per_sec",                            # warm throughput
         "scan_steps", "skipped_cycles",              # fast-forward
         "idle_fraction",                             #   accounting
         "cache": {...}}                              # RunCache delta

    ``run_kw`` is forwarded to ``sim.run`` (interval/read_ratio/telemetry
    /trace), so the telemetry-on cost is directly measurable.
    """
    prof = Profiler()
    with prof.span("first_call"):
        out = jax.block_until_ready(sim.run(n_cycles, **run_kw))
    warm = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(sim.run(n_cycles, **run_kw))
        warm.append(time.perf_counter() - t0)
    r = prof.report()
    first = r["spans"]["first_call"]["s"]
    best = min(warm)
    stats = out[0] if isinstance(out, tuple) and not hasattr(
        out, "to_dict") else out
    skipped = int(stats.skipped_cycles)
    return {"first_call_s": round(first, 4), "warm_s": round(best, 4),
            "compile_s": round(max(first - best, 0.0), 4),
            "cycles_per_sec": round(n_cycles / best, 1) if best else None,
            # event-horizon fast-forward accounting (0 skipped when off)
            "scan_steps": int(stats.scan_steps),
            "skipped_cycles": skipped,
            "idle_fraction": round(skipped / n_cycles, 4) if n_cycles
            else 0.0,
            "cache": r["cache"]}
