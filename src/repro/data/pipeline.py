"""Synthetic LM data pipeline: deterministic, shardable, restart-safe.

Batches are a pure function of (seed, step, shard) — the property the
fault-tolerance layer relies on: any host can regenerate any step's batch
after a restart, and straggler shards can be deterministically skipped and
logged without coordination (runtime/fault.py).

Token streams follow a Zipf-ish marginal with a Markov bigram twist so the
loss actually decreases during the example training runs (unlike uniform
noise) while needing no external data.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import VIS_FRAC, Batch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


def _tokens(key, cfg: ModelConfig, dc: DataConfig, shape):
    """Zipf marginal + bigram structure, vectorized (no python loop)."""
    v = cfg.vocab
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish via exponential transform of uniforms
    u = jax.random.uniform(k1, shape, jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor(jnp.exp(jnp.log(u) / (1.0 - dc.zipf_a)) - 1.0)
    base = jnp.clip(ranks, 0, v - 1).astype(jnp.int32)
    # bigram structure: with p=0.5 the next token is f(prev) (learnable)
    nxt = (base * 31 + 7) % v
    coin = jax.random.bernoulli(k2, 0.5, shape)
    shifted = jnp.roll(nxt, 1, axis=-1)
    return jnp.where(coin, shifted, base).astype(jnp.int32)


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int,
               shard: int = 0, n_shards: int = 1) -> Batch:
    """Batch for one step (optionally one data shard of it)."""
    b = dc.global_batch // n_shards
    T = dc.seq_len
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(dc.seed), step), shard)
    shape = (b, T + 1)
    if cfg.frontend == "audio_stub":
        toks = _tokens(key, cfg, dc, (b, T + 1, cfg.n_codebooks))
        tokens, labels = toks[:, :-1], toks[:, 1:]
    else:
        toks = _tokens(key, cfg, dc, shape)
        tokens, labels = toks[:, :-1], toks[:, 1:]

    if cfg.rope == "mrope":
        pos1 = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(b, 0)
        positions = jnp.stack([pos1, pos1 // 7, pos1 % 7], axis=-1)
    else:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(b, 0)

    vis = None
    if cfg.frontend == "vision_stub":
        kv = jax.random.fold_in(key, 99)
        vis = jax.random.normal(kv, (b, T // VIS_FRAC, cfg.d_model),
                                jnp.bfloat16) * 0.02
    return Batch(tokens=tokens, positions=positions, labels=labels,
                 vis_embeds=vis)


def synthetic_stream(cfg: ModelConfig, dc: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, make_batch(cfg, dc, step)
        step += 1
