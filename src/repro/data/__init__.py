from repro.data.pipeline import DataConfig, make_batch, synthetic_stream

__all__ = ["DataConfig", "make_batch", "synthetic_stream"]
