"""Lowering-mode flags.

Production lowering keeps `lax.scan` rolled: O(pattern)-sized HLO, fast
compiles, identical semantics.  XLA's cost model, however, counts a while
body ONCE regardless of trip count (verified in EXPERIMENTS.md §Dry-run),
which would corrupt the roofline terms.  Dry-run cost lowering therefore
sets ``unroll_scans = True``: every counted loop (layer groups, attention
kv/q chunks, loss chunks) lowers with `unroll=length`, making
``cost_analysis()`` exact.  Sequential token scans in mLSTM/sLSTM stay
rolled even then (unrolling 4k+ steps is infeasible); their in-loop FLOPs
are added analytically by benchmarks/roofline.py (documented error < ~12%
of the affected arch's total, 0 for all others).
"""

unroll_scans = False
attn_chunk_override = None    # force attention bq/bk (cost probes)


def set_unroll(on: bool):
    global unroll_scans
    unroll_scans = on


def set_attn_chunk(n):
    global attn_chunk_override
    attn_chunk_override = n


def scan_unroll(length: int) -> int:
    return length if (unroll_scans and length > 0) else 1


def attn_chunk(default: int) -> int:
    return attn_chunk_override if attn_chunk_override else default
