"""Residual blocks: GQA attention, MoE, RG-LRU (recurrentgemma), mLSTM and
sLSTM (xLSTM).  Each block kind provides

    <kind>_defs(cfg)                          -> ParamDef tree
    <kind>_apply(cfg, p, x, ctx)              -> x'           (train/prefill)
    <kind>_decode(cfg, p, x, state, ctx)      -> (x', state') (one token)
    <kind>_init_state(cfg, batch, cache_len)  -> state pytree

ctx carries positions / cache-write index.  State pytrees have static
shapes so the whole model decodes under jit with a ring-buffer cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (NEG_INF, ParamDef, apply_mrope, apply_rope,
                                 decode_attention, ffn_apply, ffn_defs,
                                 flash_attention_xla, rmsnorm)


class Ctx(NamedTuple):
    positions: jnp.ndarray            # (B, T) or (B, T, 3) for mrope
    cache_index: jnp.ndarray          # () write position for decode
    cache_len: jnp.ndarray            # () valid cache length (after write)


# ===========================================================================
# Attention (global or local-window), with optional qk-norm and GQA
# ===========================================================================

def attn_defs(cfg) -> dict:
    d, dq, dkv = cfg.d_model, cfg.d_qkv, cfg.d_kv
    defs = {
        "norm": ParamDef((d,), (None,), init="zeros"),
        "wq": ParamDef((d, dq), ("embed_tp", "qkv")),
        "wk": ParamDef((d, dkv), ("embed_tp", "kv_heads")),
        "wv": ParamDef((d, dkv), ("embed_tp", "kv_heads")),
        "wo": ParamDef((dq, d), ("qkv", "embed_tp")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((cfg.head_dim,), (None,), init="zeros")
        defs["k_norm"] = ParamDef((cfg.head_dim,), (None,), init="zeros")
    ff = cfg.d_ff_dense or cfg.d_ff
    if ff:
        defs["mlp"] = ffn_defs(d, ff)
        defs["mlp_norm"] = ParamDef((d,), (None,), init="zeros")
    return defs


def _qkv(cfg, p, x, ctx, local: bool):
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope == "rope":
        q = apply_rope(q, ctx.positions, cfg.rope_theta)
        k = apply_rope(k, ctx.positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, ctx.positions, cfg.rope_theta)
        k = apply_mrope(k, ctx.positions, cfg.rope_theta)
    return q, k, v


def _attn_core(cfg, p, x, ctx, local: bool):
    q, k, v = _qkv(cfg, p, x, ctx, local)
    window = cfg.attn_window if local else None
    o = flash_attention_xla(q, k, v, causal=True, window=window)
    B, T = x.shape[:2]
    return o.reshape(B, T, cfg.d_qkv) @ p["wo"]


def _block(cfg, p, x, mixer_out):
    x = x + mixer_out
    if "mlp" in p:
        x = x + ffn_apply(p["mlp"], rmsnorm(x, p["mlp_norm"], cfg.norm_eps))
    return x


def attn_apply(cfg, p, x, ctx, local: bool = False):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return _block(cfg, p, x, _attn_core(cfg, p, h, ctx, local))


class AttnState(NamedTuple):
    k: jnp.ndarray    # (B, S, Hkv, Dh) ring buffer (S = window for local)
    v: jnp.ndarray


class QuantAttnState(NamedTuple):
    """int8 KV cache + per-(pos, head) f32 scales — halves decode HBM
    footprint/traffic (the §Perf memory-term fix for big-cache decode)."""
    k: jnp.ndarray        # int8 (B, S, Hkv, Dh)
    v: jnp.ndarray
    k_scale: jnp.ndarray  # f32 (B, S, Hkv)
    v_scale: jnp.ndarray


def kv_quantize(x):
    s = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                    1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def kv_dequantize(q, s):
    return (q.astype(jnp.float32) * s[..., None]).astype(jnp.bfloat16)


def attn_init_state(cfg, batch: int, cache_len: int, local: bool = False):
    S = min(cfg.attn_window, cache_len) if (local and cfg.attn_window) \
        else cache_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        z = jnp.zeros(shape, jnp.int8)
        zs = jnp.zeros(shape[:3], jnp.float32)
        return QuantAttnState(k=z, v=z, k_scale=zs, v_scale=zs)
    z = jnp.zeros(shape, jnp.bfloat16)
    return AttnState(k=z, v=z)


def _cache_update_attend(cfg, q, k, v, state, ctx, local: bool):
    """Shared decode cache machinery (bf16 or int8-quantized ring)."""
    S = state.k.shape[1]
    slot = ctx.cache_index % S if local else ctx.cache_index
    upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), slot, axis=1)
    if cfg.kv_quant:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        state = QuantAttnState(k=upd(state.k, kq), v=upd(state.v, vq),
                               k_scale=upd(state.k_scale, ks),
                               v_scale=upd(state.v_scale, vs))
        k_cache = kv_dequantize(state.k, state.k_scale)
        v_cache = kv_dequantize(state.v, state.v_scale)
    else:
        state = AttnState(k=upd(state.k, k), v=upd(state.v, v))
        k_cache, v_cache = state.k, state.v
    clen = jnp.minimum(ctx.cache_len, S)
    o = decode_attention(q, k_cache, v_cache, clen,
                         window=cfg.attn_window if local else None)
    return o, state


def attn_decode(cfg, p, x, state, ctx, local: bool = False):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, ctx, local)
    o, state = _cache_update_attend(cfg, q, k, v, state, ctx, local)
    B = x.shape[0]
    out = o.reshape(B, 1, cfg.d_qkv) @ p["wo"]
    return _block(cfg, p, x, out), state


# ===========================================================================
# Mixture of Experts (token-choice top-k, capacity, sort-based dispatch)
# ===========================================================================

def moe_defs(cfg) -> dict:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    defs = {
        "norm": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "router": ParamDef((d, E), ("embed_tp", None)),
        "w_gate": ParamDef((E, d, ff), ("experts", None, "ffn"),
                           fan_in_dims=(-2,)),
        "w_up": ParamDef((E, d, ff), ("experts", None, "ffn"),
                         fan_in_dims=(-2,)),
        "w_down": ParamDef((E, ff, d), ("experts", "ffn", None),
                           fan_in_dims=(-2,)),
    }
    if m.shared_expert:
        defs["shared"] = ffn_defs(d, m.d_ff_expert)
    return defs


def _maybe_constraint(x, spec_axes):
    """with_sharding_constraint against the ambient mesh, skipping axes the
    mesh does not have and dims that do not divide (no-op outside pjit)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.shape:
            return x
        from jax.sharding import PartitionSpec as P
        spec = []
        for dim, want in zip(x.shape, spec_axes):
            if want is None:
                spec.append(None)
                continue
            axes = [a for a in (want if isinstance(want, tuple) else (want,))
                    if a in mesh.shape]
            prod = 1
            keep = []
            for a in axes:
                if dim % (prod * mesh.shape[a]) == 0:
                    keep.append(a)
                    prod *= mesh.shape[a]
            spec.append(tuple(keep) if len(keep) > 1 else
                        (keep[0] if keep else None))
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:   # noqa: BLE001 — no mesh context (plain CPU tests)
        return x


MOE_GROUP_SIZE = 256     # tokens per dispatch group (GShard "S")


def moe_ffn_grouped(cfg, p, x):
    """GShard one-hot einsum dispatch over token groups.

    x (B, T, d) is regrouped to (G, n, d) with n = MOE_GROUP_SIZE tokens;
    each group routes independently with per-group capacity.  Dispatch and
    combine are *einsums* against one-hot masks — unlike a scatter across
    the expert-sharded buffer, einsums shard cleanly under GSPMD (groups
    over data, experts over model), so expert compute shards 256-way and
    dispatch lowers to data<->model collectives of activation size (the
    §Perf fix for the MoE train cells; see EXPERIMENTS.md for the refuted
    scatter-based attempt)."""
    m = cfg.moe
    Bs, T, d = x.shape
    n = min(MOE_GROUP_SIZE, T)
    while T % n:
        n //= 2
    G = Bs * (T // n)
    xg = x.reshape(G, n, d)
    E, k = m.num_experts, m.top_k

    # bf16 inputs, f32 accumulation: never materializes an f32 copy of the
    # full activation (that copy once dominated the §Perf collective term)
    logits = jnp.einsum("gnd,de->gne", xg, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (G, n, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    C = int(m.capacity_factor * n * k / E + 0.999)
    C = max(1, min(C, n * k))

    # GShard position assignment: choice slots j = 0..k-1 in priority order
    dispatch = jnp.zeros((G, n, E, C), xg.dtype)
    combine = jnp.zeros((G, n, E, C), jnp.float32)
    count = jnp.zeros((G, 1, E), jnp.float32)      # tokens already placed
    for j in range(k):
        mask = jax.nn.one_hot(expert_idx[..., j], E, dtype=jnp.float32)
        pos = jnp.cumsum(mask, axis=1) - mask + count          # (G, n, E)
        keep = (pos < C) * mask
        count = count + jnp.sum(mask, axis=1, keepdims=True)
        oh_c = jax.nn.one_hot(pos.astype(jnp.int32), C,
                              dtype=jnp.float32)               # (G, n, E, C)
        d_j = keep[..., None] * oh_c
        dispatch = dispatch + d_j.astype(xg.dtype)
        combine = combine + d_j * gate_vals[..., j][..., None, None]

    buf = jnp.einsum("gnec,gnd->gecd", dispatch, xg)           # (G, E, C, d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # (G, E, C, d)
    out = jnp.einsum("gnec,gecd->gnd", combine.astype(xg.dtype), y)

    out = out.reshape(Bs, T, d)
    if m.shared_expert:
        out = out + ffn_apply(p["shared"], x)
    return out, probs.reshape(G * n, E)


def moe_ffn(cfg, p, x_flat):
    """x_flat: (N, d) -> (N, d) via top-k routed experts + optional shared."""
    m = cfg.moe
    N, d = x_flat.shape
    E, k = m.num_experts, m.top_k
    logits = jnp.einsum("nd,de->ne", x_flat, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    C = int(m.capacity_factor * N * k / E + 0.5)
    C = max(8, min(C, N))

    flat_expert = expert_idx.reshape(-1)                   # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                       # stable
    e_sorted = flat_expert[order]
    t_sorted = flat_tok[order]
    g_sorted = flat_gate[order]
    # position within expert segment
    counts = jnp.bincount(e_sorted, length=E)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k, dtype=jnp.int32) - seg_start[e_sorted].astype(jnp.int32)
    keep = pos < C                                         # capacity drop
    slot_e = jnp.where(keep, e_sorted, E - 1)
    slot_c = jnp.where(keep, pos, C - 1)

    buf = jnp.zeros((E, C, d), x_flat.dtype)
    buf = buf.at[slot_e, slot_c].set(
        jnp.where(keep[:, None], x_flat[t_sorted], 0.0))
    # expert MLPs, batched over E (sharded over "model" via w_* specs)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (E, C, d)

    gathered = y[slot_e, slot_c]                           # (N*k, d)
    contrib = jnp.where(keep[:, None], gathered * g_sorted[:, None], 0.0)
    out = jnp.zeros_like(x_flat).at[t_sorted].add(
        contrib.astype(x_flat.dtype))
    if m.shared_expert:
        out = out + ffn_apply(p["shared"], x_flat)
    return out, probs


def moe_aux_loss(probs, cfg):
    """Switch-style load-balancing loss over the last router probs."""
    E = cfg.moe.num_experts
    me = jnp.mean(probs, axis=0)                        # mean prob per expert
    ce = jnp.mean(jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=probs.dtype),
                  axis=0)                               # fraction routed
    return E * jnp.sum(me * ce)


def moe_apply(cfg, p, x, ctx):
    B, T, d = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if cfg.moe.grouped:
        out, _ = moe_ffn_grouped(cfg, p, h)
        return x + out.reshape(B, T, d)
    out, _ = moe_ffn(cfg, p, h.reshape(B * T, d))
    return x + out.reshape(B, T, d)


# ===========================================================================
# RG-LRU (recurrentgemma): conv1d(4) + gated linear recurrence
# ===========================================================================

def rglru_defs(cfg) -> dict:
    d = cfg.d_model
    defs = {
        "norm": ParamDef((d,), (None,), init="zeros"),
        "w_in": ParamDef((d, 2 * d), ("embed_tp", "ffn")),   # [branch, gate]
        "conv_w": ParamDef((4, d), (None, None)),
        "a_log": ParamDef((d,), (None,), init="ones"),
        "w_gate_a": ParamDef((d, d), ("embed_tp", "ffn")),
        "w_gate_x": ParamDef((d, d), ("embed_tp", "ffn")),
        "w_out": ParamDef((d, d), ("ffn", "embed_tp")),
    }
    if cfg.d_ff:
        defs["mlp"] = ffn_defs(d, cfg.d_ff)
        defs["mlp_norm"] = ParamDef((d,), (None,), init="zeros")
    return defs


def _causal_conv4(x, w, carry=None):
    """Depthwise causal conv, window 4.  x: (B, T, D), w: (4, D).
    carry: (B, 3, D) previous inputs for decode continuity."""
    if carry is None:
        pad = jnp.zeros((x.shape[0], 3, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # (B, T+3, D)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(4))
    new_carry = xp[:, -3:]
    return out, new_carry


def _rglru_coeffs(cfg, p, u):
    """Per-step gates: a (decay in (0,1)) and gated input, both (B, T, D)."""
    c = 8.0
    r = jax.nn.sigmoid((u @ p["w_gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_gate_x"]).astype(jnp.float32))
    log_a = -c * r * jax.nn.softplus(p["a_log"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    gated = beta * i * u.astype(jnp.float32)
    return a, gated


def rglru_apply(cfg, p, x, ctx, with_state: bool = False):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    uw = h @ p["w_in"]
    branch, u_pre = jnp.split(uw, 2, axis=-1)
    u, _ = _causal_conv4(u_pre, p["conv_w"])
    a, gated = _rglru_coeffs(cfg, p, u)

    def combine(l, r):
        a1, u1 = l
        a2, u2 = r
        return a1 * a2, u1 * a2 + u2

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = hs.astype(x.dtype) * jax.nn.gelu(branch)
    out = y @ p["w_out"]
    xo = _block(cfg, p, x, out)
    if not with_state:
        return xo, None
    T = x.shape[1]
    conv = jnp.pad(u_pre, ((0, 0), (max(3 - T, 0), 0), (0, 0)))[:, -3:]
    return xo, RGLRUState(h=hs[:, -1], conv=conv.astype(jnp.bfloat16))


class RGLRUState(NamedTuple):
    h: jnp.ndarray       # (B, D) recurrent state (f32)
    conv: jnp.ndarray    # (B, 3, D)


def rglru_init_state(cfg, batch: int, cache_len: int):
    d = cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, d), jnp.float32),
                      conv=jnp.zeros((batch, 3, d), jnp.bfloat16))


def rglru_decode(cfg, p, x, state: RGLRUState, ctx):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    uw = h @ p["w_in"]
    branch, u = jnp.split(uw, 2, axis=-1)
    u, conv = _causal_conv4(u, p["conv_w"], carry=state.conv)
    a, gated = _rglru_coeffs(cfg, p, u)
    hnew = a[:, 0] * state.h + gated[:, 0]           # (B, D)
    y = hnew[:, None, :].astype(x.dtype) * jax.nn.gelu(branch)
    out = y @ p["w_out"]
    return _block(cfg, p, x, out), RGLRUState(h=hnew, conv=conv)


# ===========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ===========================================================================

def mlstm_defs(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "norm": ParamDef((d,), (None,), init="zeros"),
        "wq": ParamDef((d, H * hd), ("embed_tp", "qkv")),
        "wk": ParamDef((d, H * hd), ("embed_tp", "qkv")),
        "wv": ParamDef((d, H * hd), ("embed_tp", "qkv")),
        "w_if": ParamDef((d, 2 * H), ("embed_tp", None)),   # i/f gate logits
        "w_o": ParamDef((d, H * hd), ("embed_tp", "qkv")),  # output gate
        "w_out": ParamDef((H * hd, d), ("qkv", "embed_tp")),
    }


class MLSTMState(NamedTuple):
    C: jnp.ndarray    # (B, H, hd, hd)
    n: jnp.ndarray    # (B, H, hd)
    m: jnp.ndarray    # (B, H)


def mlstm_init_state(cfg, batch: int, cache_len: int):
    H, hd = cfg.n_heads, cfg.head_dim
    return MLSTMState(C=jnp.zeros((batch, H, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, H, hd), jnp.float32),
                      m=jnp.full((batch, H), -30.0, jnp.float32))


def _mlstm_cell(state: MLSTMState, q, k, v, i_log, f_log):
    """One stabilized mLSTM step.  q/k/v: (B, H, hd); gates: (B, H)."""
    m_new = jnp.maximum(f_log + state.m, i_log)
    f_ = jnp.exp(f_log + state.m - m_new)[..., None]
    i_ = jnp.exp(i_log - m_new)[..., None]
    C = f_[..., None] * state.C + i_[..., None] * (v[..., :, None] *
                                                   k[..., None, :])
    n = f_ * state.n + i_ * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    h = jnp.einsum("bhij,bhj->bhi", C, q) / denom
    return MLSTMState(C=C, n=n, m=m_new), h


def _mlstm_qkvg(cfg, p, x):
    B, T, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, H, hd).astype(jnp.float32) / np.sqrt(hd)
    k = (x @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32) / np.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    if_log = (x @ p["w_if"]).reshape(B, T, 2, H).astype(jnp.float32)
    i_log = if_log[:, :, 0]
    f_log = jax.nn.log_sigmoid(if_log[:, :, 1] + 1.0)
    o = jax.nn.sigmoid((x @ p["w_o"]).astype(jnp.float32)).reshape(B, T, H, hd)
    return q, k, v, i_log, f_log, o


def mlstm_apply(cfg, p, x, ctx, with_state: bool = False):
    h0 = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v, i_log, f_log, o = _mlstm_qkvg(cfg, p, h0)
    B = x.shape[0]
    state = mlstm_init_state(cfg, B, 0)

    def step(s, inp):
        qt, kt, vt, it, ft = inp
        s, h = _mlstm_cell(s, qt, kt, vt, it, ft)
        return s, h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_log.swapaxes(0, 1), f_log.swapaxes(0, 1))
    final, hs = jax.lax.scan(step, state, xs)
    h = hs.swapaxes(0, 1) * o                        # (B, T, H, hd)
    out = h.reshape(*x.shape[:2], cfg.d_qkv).astype(x.dtype) @ p["w_out"]
    return _block(cfg, p, x, out), (final if with_state else None)


def mlstm_decode(cfg, p, x, state: MLSTMState, ctx):
    h0 = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v, i_log, f_log, o = _mlstm_qkvg(cfg, p, h0)
    state, h = _mlstm_cell(state, q[:, 0], k[:, 0], v[:, 0], i_log[:, 0],
                           f_log[:, 0])
    h = h[:, None] * o
    out = h.reshape(x.shape[0], 1, cfg.d_qkv).astype(x.dtype) @ p["w_out"]
    return _block(cfg, p, x, out), state


def slstm_defs(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "norm": ParamDef((d,), (None,), init="zeros"),
        "w_zifo": ParamDef((d, 4 * H * hd), ("embed_tp", "qkv")),
        "r_zifo": ParamDef((H, hd, 4 * hd), (None, None, None)),
        "w_out": ParamDef((H * hd, d), ("qkv", "embed_tp")),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray    # (B, H, hd)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray


def slstm_init_state(cfg, batch: int, cache_len: int):
    H, hd = cfg.n_heads, cfg.head_dim
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, H, hd), -30.0,
                                                jnp.float32))


def _slstm_cell(state: SLSTMState, zifo_x, r):
    """zifo_x: (B, H, 4*hd) input contribution; r: (H, hd, 4*hd)."""
    rec = jnp.einsum("bhd,hdk->bhk", state.h, r.astype(jnp.float32))
    z, i_log, f_log, o = jnp.split(zifo_x.astype(jnp.float32) + rec, 4,
                                   axis=-1)
    f_log = jax.nn.log_sigmoid(f_log + 1.0)
    m_new = jnp.maximum(f_log + state.m, i_log)
    f_ = jnp.exp(f_log + state.m - m_new)
    i_ = jnp.exp(i_log - m_new)
    c = f_ * state.c + i_ * jnp.tanh(z)
    n = f_ * state.n + i_
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new), h


def slstm_apply(cfg, p, x, ctx, with_state: bool = False):
    h0 = rmsnorm(x, p["norm"], cfg.norm_eps)
    B, T, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    zifo = (h0 @ p["w_zifo"]).reshape(B, T, H, 4 * hd)
    state = slstm_init_state(cfg, B, 0)

    def step(s, inp):
        return _slstm_cell(s, inp, p["r_zifo"])

    final, hs = jax.lax.scan(step, state, zifo.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                            # (B, T, H, hd)
    out = h.reshape(B, T, H * hd).astype(x.dtype) @ p["w_out"]
    return _block(cfg, p, x, out), (final if with_state else None)


def slstm_decode(cfg, p, x, state: SLSTMState, ctx):
    h0 = rmsnorm(x, p["norm"], cfg.norm_eps)
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    zifo = (h0 @ p["w_zifo"]).reshape(B, 1, H, 4 * hd)
    state, h = _slstm_cell(state, zifo[:, 0], p["r_zifo"])
    out = h[:, None].reshape(B, 1, H * hd).astype(x.dtype) @ p["w_out"]
    return _block(cfg, p, x, out), state


# ===========================================================================
# Block registry
# ===========================================================================

def block_defs(cfg, kind: str) -> dict:
    if kind in ("attn", "local_attn"):
        return attn_defs(cfg)
    if kind == "moe":
        d = attn_defs(cfg)
        d.pop("mlp", None)
        d.pop("mlp_norm", None)
        d["moe"] = moe_defs(cfg)
        return d
    if kind == "rglru":
        return rglru_defs(cfg)
    if kind == "mlstm":
        return mlstm_defs(cfg)
    if kind == "slstm":
        return slstm_defs(cfg)
    raise KeyError(kind)


def block_apply(cfg, kind: str, p, x, ctx, with_state: bool = False):
    """-> (x', aux_loss, state_or_None)."""
    zero = jnp.float32(0.0)
    if kind in ("attn", "local_attn"):
        local = kind == "local_attn"
        xo = attn_apply(cfg, p, x, ctx, local=local)
        return xo, zero, None
    if kind == "moe":
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        x = x + _attn_core(cfg, p, h, ctx, local=False)
        hm = rmsnorm(x, p["moe"]["norm"], cfg.norm_eps)
        Bs, T, d = x.shape
        if cfg.moe.grouped:
            out, probs = moe_ffn_grouped(cfg, p["moe"], hm)
            out = out.reshape(Bs, T, d)
        else:
            out, probs = moe_ffn(cfg, p["moe"], hm.reshape(Bs * T, d))
            out = out.reshape(Bs, T, d)
        x = x + out
        return x, moe_aux_loss(probs, cfg), None
    if kind == "rglru":
        xo, st = rglru_apply(cfg, p, x, ctx, with_state)
        return xo, zero, st
    if kind == "mlstm":
        xo, st = mlstm_apply(cfg, p, x, ctx, with_state)
        return xo, zero, st
    if kind == "slstm":
        xo, st = slstm_apply(cfg, p, x, ctx, with_state)
        return xo, zero, st
    raise KeyError(kind)


def block_init_state(cfg, kind: str, batch: int, cache_len: int):
    if kind == "attn":
        return attn_init_state(cfg, batch, cache_len, local=False)
    if kind == "local_attn":
        return attn_init_state(cfg, batch, cache_len, local=True)
    if kind == "moe":
        return attn_init_state(cfg, batch, cache_len, local=False)
    if kind == "rglru":
        return rglru_init_state(cfg, batch, cache_len)
    if kind == "mlstm":
        return mlstm_init_state(cfg, batch, cache_len)
    if kind == "slstm":
        return slstm_init_state(cfg, batch, cache_len)
    raise KeyError(kind)


def block_decode(cfg, kind: str, p, x, state, ctx):
    if kind == "attn":
        return attn_decode(cfg, p, x, state, ctx, local=False)
    if kind == "local_attn":
        return attn_decode(cfg, p, x, state, ctx, local=True)
    if kind == "moe":
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, p, h, ctx, False)
        o, state = _cache_update_attend(cfg, q, k, v, state, ctx, False)
        x = x + o.reshape(x.shape[0], 1, cfg.d_qkv) @ p["wo"]
        x = moe_apply(cfg, p["moe"], x, ctx)
        return x, state
    if kind == "rglru":
        return rglru_decode(cfg, p, x, state, ctx)
    if kind == "mlstm":
        return mlstm_decode(cfg, p, x, state, ctx)
    if kind == "slstm":
        return slstm_decode(cfg, p, x, state, ctx)
    raise KeyError(kind)
