"""Model assembly: embeddings/frontends -> scanned block groups -> head.

Depth is organized as ``n_groups`` repetitions of ``cfg.block_pattern``
(+ a remainder prefix), with the repeated groups executed under
``jax.lax.scan`` over *stacked* parameters.  This keeps the lowered HLO
size O(pattern) instead of O(n_layers) — both the activation-checkpointing
policy (remat per group) and the reason 64 production-mesh compiles are
tractable on this box.

Interfaces (all pure):
  param_defs(cfg)                      ParamDef tree (shapes + logical axes)
  init_params(cfg, key)                random params (smoke tests / examples)
  forward(cfg, params, batch, *, return_states)
                                       -> (final_hidden, aux_loss[, states])
  logits_and_loss(cfg, params, batch)  chunked-vocab xent (train objective)
  init_cache(cfg, batch, cache_len)    stacked decode state
  decode_step(cfg, params, cache, batch) -> (logits, cache')
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as B
from repro.models import flags
from repro.models.layers import ParamDef, init_tree, rmsnorm

VIS_FRAC = 8      # vision stub: first T/VIS_FRAC positions are patch embeds


class Batch(NamedTuple):
    """Model inputs.  Unused fields are None."""
    tokens: jnp.ndarray                 # (B, T) int32 or (B, T, K) audio
    positions: jnp.ndarray              # (B, T) or (B, T, 3) for mrope
    labels: Optional[jnp.ndarray] = None
    vis_embeds: Optional[jnp.ndarray] = None   # (B, T//VIS_FRAC, D)
    cache_index: Optional[jnp.ndarray] = None  # () decode write slot
    cache_len: Optional[jnp.ndarray] = None    # () valid length after write


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------

def _stack_defs(defs: dict, g: int) -> dict:
    out = {}
    for k, v in defs.items():
        if isinstance(v, dict):
            out[k] = _stack_defs(v, g)
        else:
            out[k] = ParamDef((g,) + v.shape, (None,) + v.logical_axes,
                              init=v.init,
                              fan_in_dims=tuple(d - 1 if d < 0 else d + 1
                                                for d in v.fan_in_dims))
    return out


def param_defs(cfg) -> dict:
    d = cfg.d_model
    defs: dict = {}
    if cfg.frontend == "audio_stub":
        defs["embed"] = ParamDef((cfg.n_codebooks, cfg.vocab, d),
                                 (None, "vocab", None))
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((cfg.n_codebooks, d, cfg.vocab),
                                       (None, None, "vocab"))
    else:
        defs["embed"] = ParamDef((cfg.vocab, d), ("vocab", "embed_tp"))
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((d, cfg.vocab), (None, "vocab"))
    defs["final_norm"] = ParamDef((d,), (None,), init="zeros")

    G = cfg.n_groups()
    if G:
        group = {f"b{j}": B.block_defs(cfg, kind)
                 for j, kind in enumerate(cfg.block_pattern)}
        defs["groups"] = _stack_defs(group, G)
    rem = {}
    for j in range(cfg.n_remainder()):
        rem[f"r{j}"] = B.block_defs(cfg, cfg.block_pattern[j])
    if rem:
        defs["rem"] = rem
    return defs


def init_params(cfg, key) -> dict:
    return init_tree(key, param_defs(cfg))


def count_params(cfg, active_only: bool = False) -> int:
    def walk(defs, path=()):
        total = 0
        for k, v in defs.items():
            if isinstance(v, dict):
                total += walk(v, path + (k,))
            else:
                n = int(np.prod(v.shape))
                if active_only and cfg.moe and "moe" in path and \
                        k in ("w_gate", "w_up", "w_down"):
                    n = n * cfg.moe.top_k // cfg.moe.num_experts
                total += n
        return total
    return walk(param_defs(cfg))


# ---------------------------------------------------------------------------
# Embedding / frontend stubs
# ---------------------------------------------------------------------------

def embed_input(cfg, params, batch: Batch):
    if cfg.frontend == "audio_stub":
        # tokens (B, T, K): sum the K codebook embeddings (MusicGen)
        embeds = params["embed"]                       # (K, V, D)
        x = sum(embeds[k][batch.tokens[..., k]]
                for k in range(cfg.n_codebooks))
    else:
        x = params["embed"][batch.tokens]              # (B, T, D)
    if cfg.frontend == "vision_stub" and batch.vis_embeds is not None:
        nv = batch.vis_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, batch.vis_embeds.astype(x.dtype), 0, axis=1)
    return x


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(cfg, params, batch: Batch, *, return_states: bool = False,
            cache_len: int | None = None):
    x = embed_input(cfg, params, batch)
    ctx = B.Ctx(positions=batch.positions, cache_index=jnp.int32(0),
                cache_len=jnp.int32(0))
    aux = jnp.float32(0.0)
    G = cfg.n_groups()
    states_g = None

    if G:
        def body(carry, gp):
            x, aux = carry
            sts = {}
            for j, kind in enumerate(cfg.block_pattern):
                x, a, st = _apply_with_state(cfg, kind, gp[f"b{j}"], x, ctx,
                                             return_states, cache_len)
                aux = aux + a
                sts[f"b{j}"] = st
            return (x, aux), (sts if return_states else None)

        body = jax.checkpoint(body)
        (x, aux), states_g = jax.lax.scan(body, (x, aux), params["groups"],
                                          unroll=flags.scan_unroll(G))

    states_r = {}
    for j in range(cfg.n_remainder()):
        kind = cfg.block_pattern[j]
        x, a, st = _apply_with_state(cfg, kind, params["rem"][f"r{j}"], x,
                                     ctx, return_states, cache_len)
        aux = aux + a
        states_r[f"r{j}"] = st

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_states:
        states = {}
        if states_g is not None:
            states["groups"] = states_g
        if states_r:
            states["rem"] = states_r
        return x, aux, states
    return x, aux


def _apply_with_state(cfg, kind, p, x, ctx, return_states, cache_len):
    xin = x
    x, aux, st = B.block_apply(cfg, kind, p, x, ctx,
                               with_state=return_states)
    if return_states and kind in ("attn", "local_attn", "moe"):
        # attention caches are recomputed k/v of the prefix, ring-aligned
        st = _prefill_attn_state(cfg, p, ctx, xin, cache_len,
                                 local=(kind == "local_attn"))
    return x, aux, st


def _prefill_attn_state(cfg, p, ctx, x, cache_len, local: bool = False):
    """Recompute k/v for the prefix and lay them into a decode cache."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    _, k, v = B._qkv(cfg, p, h, ctx, local)
    Bs, T = x.shape[:2]
    S = min(cfg.attn_window, cache_len) if (local and cfg.attn_window) \
        else cache_len
    if T >= S:
        ks, vs = k[:, T - S:], v[:, T - S:]
        slots = (jnp.arange(T - S, T, dtype=jnp.int32) % S) if local \
            else jnp.arange(S, dtype=jnp.int32)
    else:
        ks, vs = k, v
        slots = jnp.arange(T, dtype=jnp.int32)
    shape = (Bs, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        kq, ksc = B.kv_quantize(ks)
        vq, vsc = B.kv_quantize(vs)
        z8 = jnp.zeros(shape, jnp.int8)
        zs = jnp.zeros(shape[:3], jnp.float32)
        return B.QuantAttnState(
            k=z8.at[:, slots].set(kq), v=z8.at[:, slots].set(vq),
            k_scale=zs.at[:, slots].set(ksc),
            v_scale=zs.at[:, slots].set(vsc))
    kc = jnp.zeros(shape, jnp.bfloat16).at[:, slots].set(
        ks.astype(jnp.bfloat16))
    vc = jnp.zeros(shape, jnp.bfloat16).at[:, slots].set(
        vs.astype(jnp.bfloat16))
    return B.AttnState(k=kc, v=vc)


# ---------------------------------------------------------------------------
# Loss head (vocab-chunked cross entropy)
# ---------------------------------------------------------------------------

def logits_and_loss(cfg, params, batch: Batch, seq_chunk: int = 512):
    x, aux = forward(cfg, params, batch)
    labels = batch.labels
    Bs, T = x.shape[:2]
    seq_chunk = min(seq_chunk, T)
    Tp = -(-T // seq_chunk) * seq_chunk
    xpad = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
    lpad = jnp.pad(labels, [(0, 0), (0, Tp - T)] +
                   [(0, 0)] * (labels.ndim - 2), constant_values=-1)
    xc = jnp.moveaxis(xpad.reshape(Bs, Tp // seq_chunk, seq_chunk, -1), 1, 0)
    lc = jnp.moveaxis(
        lpad.reshape((Bs, Tp // seq_chunk, seq_chunk) + lpad.shape[2:]), 1, 0)

    def chunk_loss(_, args):
        xch, lch = args
        return None, _xent(cfg, params, xch, lch)

    nchunks = Tp // seq_chunk
    _, (losses, counts) = jax.lax.scan(chunk_loss, None, (xc, lc),
                                       unroll=flags.scan_unroll(nchunks))
    total = jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1)
    return total, aux


def _unembed(cfg, params):
    if cfg.tie_embeddings:
        e = params["embed"]
        return e.swapaxes(-1, -2)    # (V, D) -> (D, V); audio (K, V, D) -> (K, D, V)
    return params["unembed"]


def _xent(cfg, params, x, labels):
    """x: (B, C, D); labels (B, C) or (B, C, K).  Returns (sum, count)."""
    w = _unembed(cfg, params)
    if cfg.frontend == "audio_stub":
        lg = jnp.einsum("bcd,kdv->bckv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    else:
        lg = jnp.einsum("bcd,dv->bcv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    lse = jax.nn.logsumexp(lg, axis=-1)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    true_lg = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - true_lg, 0.0)
    return jnp.sum(nll), jnp.sum(mask)


def last_logits(cfg, params, x):
    """Logits of the final position (prefill / decode head)."""
    xl = x[:, -1:]
    w = _unembed(cfg, params)
    if cfg.frontend == "audio_stub":
        return jnp.einsum("btd,kdv->btkv", xl.astype(jnp.float32),
                          w.astype(jnp.float32))
    return xl.astype(jnp.float32) @ w.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int):
    G = cfg.n_groups()
    cache: dict = {}
    if G:
        grp = {}
        for j, kind in enumerate(cfg.block_pattern):
            st = B.block_init_state(cfg, kind, batch, cache_len)
            grp[f"b{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (G,) + a.shape), st)
        cache["groups"] = grp
    rem = {}
    for j in range(cfg.n_remainder()):
        rem[f"r{j}"] = B.block_init_state(cfg, cfg.block_pattern[j], batch,
                                          cache_len)
    if rem:
        cache["rem"] = rem
    return cache


def decode_step(cfg, params, cache, batch: Batch):
    """One token for every sequence in the batch.  tokens: (B, 1[, K])."""
    x = embed_input(cfg, params, batch)
    ctx = B.Ctx(positions=batch.positions, cache_index=batch.cache_index,
                cache_len=batch.cache_len)
    G = cfg.n_groups()

    if G:
        def body(x, xs):
            gp, st = xs
            new_st = {}
            for j, kind in enumerate(cfg.block_pattern):
                x, s = B.block_decode(cfg, kind, gp[f"b{j}"], x,
                                      st[f"b{j}"], ctx)
                new_st[f"b{j}"] = s
            return x, new_st

        x, new_groups = jax.lax.scan(body, x,
                                     (params["groups"], cache["groups"]),
                                     unroll=flags.scan_unroll(G))
        new_cache = {"groups": new_groups}
    else:
        new_cache = {}

    if cfg.n_remainder():
        rem = {}
        for j in range(cfg.n_remainder()):
            kind = cfg.block_pattern[j]
            x, s = B.block_decode(cfg, kind, params["rem"][f"r{j}"], x,
                                  cache["rem"][f"r{j}"], ctx)
            rem[f"r{j}"] = s
        new_cache["rem"] = rem

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return last_logits(cfg, params, x), new_cache
