"""Core NN layers: params-as-data, RMSNorm, RoPE/M-RoPE, chunked (flash)
attention, SwiGLU — all pure functions over explicit param pytrees.

Layout conventions:
  activations  (B, T, D)      batch -> ("pod","data"), D replicated
  attention    (B, T, H, Dh)  H -> "model" when divisible (GSPMD propagates)
  weights      declared via ParamDef logical axes (sharding/rules.py)

Everything must lower cleanly at 500k sequence length, so attention is
chunked (online softmax over kv blocks) and never materializes (T, T).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import flags

PARAM_DTYPE = jnp.bfloat16
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical_axes: tuple
    init: str = "normal"        # normal | zeros | ones
    fan_in_dims: tuple = (-2,)  # dims whose product scales normal init


def init_param(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, PARAM_DTYPE)
    if d.init == "ones":
        return jnp.ones(d.shape, PARAM_DTYPE)
    fan_in = int(np.prod([d.shape[i] for i in d.fan_in_dims])) or 1
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(
        PARAM_DTYPE)


def init_tree(key, defs: dict) -> dict:
    flat = sorted(_flatten(defs))
    keys = jax.random.split(key, max(len(flat), 1))
    out = {}
    for (path, d), k in zip(flat, keys):
        _set(out, path, init_param(k, d))
    return out


def _flatten(defs, prefix=()):
    for k, v in defs.items():
        if isinstance(v, dict):
            yield from _flatten(v, prefix + (k,))
        else:
            yield (prefix + (k,), v)


def _set(tree, path, value):
    for p in path[:-1]:
        tree = tree.setdefault(p, {})
    tree[path[-1]] = value


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, T, H, D); positions: (B, T) int32."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)                  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10_000.0,
                sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE.  positions3: (B, T, 3) = (t, h, w) ids.
    The half-dim rotary channels are partitioned into `sections`, each
    rotated by its own position stream."""
    half = x.shape[-1] // 2
    sec = np.array(sections, np.int64)
    sec = (sec * half // sec.sum()).tolist()
    sec[-1] += half - sum(sec)                    # absorb rounding
    freqs = _rope_freqs(x.shape[-1], theta)       # (half,)
    # choose the position stream per frequency channel
    stream = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(sec)])
    pos = jnp.take_along_axis(
        positions3, stream[None, None, :].astype(jnp.int32), axis=-1
    ).astype(jnp.float32)                         # (B, T, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash) attention — XLA path (Pallas kernel is the TPU target)
# ---------------------------------------------------------------------------

def flash_attention_xla(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, bq: int = 512,
                        bk: int = 512):
    """Online-softmax chunked attention with static causal/window block
    skipping.

    q: (B, Tq, H, Dh); k, v: (B, Tk, Hkv, Dh).  GQA is handled by
    reshaping q to (B, Tq, Hkv, rep, Dh) and contracting per kv head —
    no materialized repeat of k/v.

    The q-chunk loop is a *python* loop: each chunk slices only the kv
    range it can attend to ([0, hi) causal; [hi-window-bq, hi) local), so
    blocks above the diagonal / outside the window cost no FLOPs — the
    §Perf compute-term fix (the rolled-scan variant computed all blocks
    and masked).
    """
    B, Tq, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    rep = H // Hkv
    scale = 1.0 / np.sqrt(Dh)

    bq = min(flags.attn_chunk(bq), Tq)
    bk = min(flags.attn_chunk(bk), Tk)
    Tqp = -(-Tq // bq) * bq
    Tkp = -(-Tk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tkp - Tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tkp - Tk), (0, 0), (0, 0)))

    def q_chunk(qc, qi, lo, hi):
        """qc (B, bq, Hkv, rep, Dh); attends kv positions [lo, hi)."""
        qc = qc.astype(jnp.float32) * scale
        nkb = (hi - lo) // bk
        ks = kp[:, lo:hi].reshape(B, nkb, bk, Hkv, Dh)
        vs = vp[:, lo:hi].reshape(B, nkb, bk, Hkv, Dh)

        def kv_step(carry, args2):
            m, l, acc = carry
            kc, vc, ki = args2                         # (B, bk, Hkv, Dh)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qc,
                           kc.astype(jnp.float32))     # (B,Hkv,rep,bq,bk)
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = lo + ki * bk + \
                jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = cols < Tk
            if causal:
                mask &= rows >= cols
            if window is not None:
                mask &= (rows - cols) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, bq, Dh), jnp.float32)
        kidx = jnp.arange(nkb, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kidx),
            unroll=flags.scan_unroll(nkb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hkv,rep,bq,Dh)
        return jnp.einsum("bgrqd->bqgrd", out)

    outs = []
    for qi in range(Tqp // bq):
        hi = min((qi + 1) * bq, Tkp) if causal else Tkp
        hi = -(-hi // bk) * bk                        # round up to kv blocks
        lo = 0
        if window is not None:
            # first row of the chunk still needs col >= qi*bq - window + 1
            lo = max(0, qi * bq - (window - 1))
            lo = (lo // bk) * bk                      # round down to blocks
        qc = qp[:, qi * bq:(qi + 1) * bq].reshape(B, bq, Hkv, rep, Dh)
        outs.append(q_chunk(qc, qi, lo, hi))
    out = jnp.concatenate(outs, axis=1).reshape(B, Tqp, H, Dh)
    return out[:, :Tq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-token attention against a cache.

    q: (B, 1, H, Dh); k_cache/v_cache: (B, S, Hkv, Dh); cache_len: ()
    — entries at positions >= cache_len are masked.  For local attention
    the cache is a ring buffer of size window and fully attended."""
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale = 1.0 / np.sqrt(Dh)
    qf = q.reshape(B, Hkv, rep, Dh).astype(jnp.float32) * scale
    s = jnp.einsum("bgrd,bsgd->bgrs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S, dtype=jnp.int32)
    mask = pos[None, None, None, :] < cache_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def ffn_defs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed_tp", "ffn")),
        "w_up": ParamDef((d_model, d_ff), ("embed_tp", "ffn")),
        "w_down": ParamDef((d_ff, d_model), ("ffn", "embed_tp")),
    }


def ffn_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
