from repro.models.model import (Batch, count_params, decode_step, forward,
                                init_cache, init_params, logits_and_loss,
                                param_defs)

__all__ = ["Batch", "count_params", "decode_step", "forward", "init_cache",
           "init_params", "logits_and_loss", "param_defs"]
