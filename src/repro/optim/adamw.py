"""AdamW + cosine schedule + global-norm clipping, pure pytree functions.

Optimizer moments inherit each parameter's sharding (ZeRO-1 falls out of
the parameter sharding specs for free under pjit), stored in bf16 with an
f32 scalar step — the large-model memory budget documented in DESIGN.md.
Optional int8 gradient compression with error feedback lives in
runtime/compress.py and wraps `adamw_update`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "bfloat16"


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, params, state: OptState, cfg: AdamWConfig):
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pnew = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * pf)
        return pnew.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, params, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gnorm}
