from repro.optim.adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                               cosine_lr)

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_lr"]
