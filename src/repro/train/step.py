"""Training step: loss -> grad -> AdamW, with optional microbatching and
gradient compression, shaped for pjit (pure (state, batch) -> (state, metrics)).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Batch, logits_and_loss
from repro.optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update

AUX_WEIGHT = 0.01   # MoE load-balance loss weight


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def train_init(cfg, params, opt_cfg: AdamWConfig) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def loss_fn(cfg, params, batch: Batch):
    loss, aux = logits_and_loss(cfg, params, batch)
    return loss + AUX_WEIGHT * aux, (loss, aux)


def make_train_step(cfg, opt_cfg: AdamWConfig, microbatch: int = 1,
                    grad_transform=None):
    """Build the pure train step.

    microbatch > 1: split the batch on dim 0 and accumulate grads with a
    lax.scan (sequential microbatching — the activation-memory knob).
    grad_transform: optional (grads, carry) -> (grads, carry) hook, used by
    runtime/compress.py for int8 error-feedback compression of the DP
    all-reduce.
    """

    def single(params, batch):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return grads, loss, aux

    def step(state: TrainState, batch: Batch):
        if microbatch > 1:
            def mb(carry, mbatch):
                acc = carry
                g, l, a = single(state.params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, a)

            split = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]) if x is not None else x,
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, auxes) = jax.lax.scan(mb, zeros, split)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss, aux = jnp.mean(losses), jnp.mean(auxes)
        else:
            grads, loss, aux = single(state.params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        params, opt, om = adamw_update(grads, state.params, state.opt,
                                       opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return TrainState(params=params, opt=opt), metrics

    return step
