"""Declarative design-space-exploration sweep specifications.

A `SweepSpec` names the four axes of a Ramulator-style DSE campaign —
DRAM systems (standard x org preset x timing preset, optionally with
timing overrides), controller configurations, and the load grid
(streaming intervals x read ratios) — and `expand()`s them into the full
cartesian list of concrete `RunPoint`s.

The spec layer is pure Python bookkeeping: nothing here touches JAX.  The
executor (`repro.dse.executor`) groups the expanded points by *compile
group* — everything that changes the traced program — and runs each group
as one vmapped, jit-cached call.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core import controller as C
from repro.core import frontend as F

#: Default (org preset, timing preset) per modeled standard, used by
#: `system()` and the `python -m repro.dse.sweep` CLI so callers can name a
#: sweep axis by standard alone.
DEFAULT_SYSTEMS = {
    "DDR3": ("DDR3_8Gb_x8", "DDR3_1600K"),
    "DDR4": ("DDR4_8Gb_x8", "DDR4_2400R"),
    "DDR5": ("DDR5_16Gb_x8", "DDR5_4800B"),
    "LPDDR5": ("LPDDR5_8Gb_x16", "LPDDR5_6400"),
    "LPDDR6": ("LPDDR6_16Gb_x16", "LPDDR6_8533"),
    "GDDR6": ("GDDR6_8Gb_x16", "GDDR6_16"),
    "GDDR7": ("GDDR7_16Gb_x32", "GDDR7_32"),
    "HBM2": ("HBM2_8Gb", "HBM2_2Gbps"),
    "HBM3": ("HBM3_16Gb", "HBM3_5200"),
    "HBM4": ("HBM4_24Gb", "HBM4_8000"),
    "DDR5_VRR": ("DDR5_16Gb_x8", "DDR5_4800B"),
}


@dataclasses.dataclass(frozen=True)
class System:
    """One DRAM system under test: a (standard, org, timing) triple plus
    optional timing overrides (stored frozen so the system is hashable)."""
    standard: str
    org_preset: str
    timing_preset: str
    timing_overrides: tuple = ()    # sorted (name, cycles) pairs

    def __post_init__(self):
        # normalize every construction path (dict, unsorted tuple, list of
        # pairs) to sorted tuples so equal overrides compare/hash equal and
        # never split a compile group or a curve
        ov = self.timing_overrides
        ov = ov.items() if isinstance(ov, dict) else (ov or ())
        object.__setattr__(self, "timing_overrides",
                           tuple(sorted(tuple(kv) for kv in ov)))

    @property
    def overrides_dict(self) -> dict | None:
        return dict(self.timing_overrides) if self.timing_overrides else None

    @property
    def label(self) -> str:
        return self.standard

    @classmethod
    def make(cls, spec) -> "System":
        """Coerce a System, a standard name, or a 3/4-tuple into a System."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return system(spec)
        std, org, tim, *rest = spec
        return cls(std, org, tim, rest[0] if rest else ())


def system(standard: str, timing_overrides: dict | None = None) -> System:
    """Build a `System` from a standard name using `DEFAULT_SYSTEMS`."""
    if standard not in DEFAULT_SYSTEMS:
        raise KeyError(f"no default org/timing for {standard!r}; "
                       f"known: {sorted(DEFAULT_SYSTEMS)}")
    org, tim = DEFAULT_SYSTEMS[standard]
    return System(standard, org, tim, timing_overrides or ())


@dataclasses.dataclass(frozen=True)
class SystemGroup:
    """One spec group of a heterogeneous composition: a `System` fanned
    out over `channels` identical channels, optionally behind a CXL-style
    link adding `link_latency` cycles each way."""
    system: System
    channels: int = 1
    link_latency: int = 0

    def __post_init__(self):
        object.__setattr__(self, "system", System.make(self.system))

    @property
    def label(self) -> str:
        lbl = f"{self.system.label}x{self.channels}"
        if self.link_latency:
            lbl += f"@{self.link_latency}"
        return lbl


@dataclasses.dataclass(frozen=True)
class Composition:
    """A heterogeneous memory system as a first-class sweep axis: an
    ordered tuple of :class:`SystemGroup`s (e.g. DDR5 channels plus
    CXL-attached DDR4 channels behind one mapper).  Compositions go into
    ``SweepSpec.systems`` alongside plain systems; each composition is
    its own compile group.  Entries coerce from `SystemGroup`, a
    ``(standard_or_system, channels[, link_latency])`` tuple, or a bare
    standard name / `System` (one channel, no link).

    >>> Composition((("DDR5", 2), ("DDR4", 2, 80))).label
    'DDR5x2+DDR4x2@80'
    """
    groups: tuple

    def __post_init__(self):
        out = []
        for g in self.groups:
            if isinstance(g, SystemGroup):
                out.append(g)
            elif isinstance(g, (str, System)):
                out.append(SystemGroup(System.make(g)))
            else:
                sy, *rest = g
                out.append(SystemGroup(
                    System.make(sy),
                    int(rest[0]) if rest else 1,
                    int(rest[1]) if len(rest) > 1 else 0))
        if not out:
            raise ValueError("Composition needs at least one group")
        object.__setattr__(self, "groups", tuple(out))

    @property
    def n_channels(self) -> int:
        return sum(g.channels for g in self.groups)

    @property
    def label(self) -> str:
        return "+".join(g.label for g in self.groups)

    @property
    def standard(self) -> str:      # mirror of System.standard for tables
        return self.label


@dataclasses.dataclass(frozen=True)
class RunPoint:
    """One concrete simulation: a system (plain `System` or heterogeneous
    `Composition`) + controller + channel/mapper configuration + one load
    point.  The mapper order rides inside ``frontend.mapper``; for a
    composition ``n_channels`` is the system-wide channel total."""
    system: object                  # System | Composition
    controller: C.ControllerConfig
    frontend: F.FrontendConfig
    n_cycles: int
    interval: float
    read_ratio: float
    n_channels: int = 1

    @property
    def mapper(self) -> str:
        return self.frontend.mapper

    @property
    def label(self) -> str:
        ch = f" {self.n_channels}ch" if self.n_channels != 1 else ""
        return (f"{self.system.label}{ch} {self.controller.scheduler} "
                f"i={self.interval:g} r={self.read_ratio:g}")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative sweep: systems x controllers x channels x mappers x
    intervals x read ratios.

    `systems` entries may be `System` objects, bare standard names (resolved
    via `DEFAULT_SYSTEMS`), (standard, org, timing[, overrides]) tuples, or
    heterogeneous `Composition`s (e.g. DDR5:CXL-DDR4 ratios / link
    latencies as first-class sweep entries).  ``channels`` sweeps the
    memory-system channel count of the PLAIN systems (compositions carry
    their own per-group fan-out and ignore the axis) and ``mappers`` the
    address-mapper order (see ``repro.core.addrmap.MAPPERS``) — all of
    these are compile-group axes: each combination is its own compiled
    program, with the whole load grid still vmapped inside it.

    >>> spec = SweepSpec(systems=("DDR4", "DDR5"),
    ...                  intervals=(16.0, 4.0, 1.0), read_ratios=(1.0, 0.5))
    >>> len(spec.expand())      # 2 * 1 * 1 * 1 * 3 * 2
    12
    >>> hetero = SweepSpec(
    ...     systems=(Composition((("DDR5", 2), ("DDR4", 2, 80))),
    ...              Composition((("DDR5", 2), ("DDR4", 2, 160)))),
    ...     intervals=(4.0, 1.0))        # link latency as a sweep axis
    """
    systems: tuple
    intervals: tuple = (64.0, 16.0, 8.0, 4.0, 2.0, 1.0)
    read_ratios: tuple = (1.0,)
    controllers: tuple = None   # defaults to (ControllerConfig(),)
    channels: tuple = (1,)
    mappers: tuple = None       # defaults to (frontend.mapper,)
    frontend: F.FrontendConfig = dataclasses.field(
        default_factory=F.FrontendConfig)
    n_cycles: int = 20_000
    seed: int = 0x1234
    #: Capture a per-point command trace (``repro.trace.CommandTrace``).
    #: ``True`` keeps traces in-memory on ``SweepResult.traces``; a string
    #: is a directory to additionally persist one ``.npz`` trace artifact
    #: per point next to the curve artifact.  Each compile group then runs
    #: its trace-emitting program — still one compiled program per group,
    #: so ``engine.TRACE_COUNT`` grows exactly as in a no-capture sweep.
    capture_traces: bool | str = False
    #: Windowed-telemetry window in cycles (0 = off).  Each compile group
    #: then runs its telemetry-emitting program (one program per group,
    #: same TRACE_COUNT accounting as capture_traces) and every point
    #: gains a ``repro.telemetry.Telemetry`` on ``SweepResult.telemetry``.
    telemetry: int = 0
    #: Directory to persist one telemetry ``.npz`` artifact per point
    #: (paths land in ``meta["telemetry_artifacts"]``); needs
    #: ``telemetry > 0``.
    telemetry_dir: str | None = None
    #: Run the spec linter (``repro.analysis``) over every distinct
    #: override-carrying system before any compile group is built, so an
    #: invalid design-space corner (``tRC < tRAS + tRP``, an unschedulable
    #: refresh, a typo'd override key) fails fast with a structured
    #: ``LintReport`` instead of producing a silently-wrong curve.  Set
    #: False to opt out (e.g. when deliberately sweeping through
    #: rule-violating corners to map the cliff).
    lint_specs: bool = True

    def __post_init__(self):
        object.__setattr__(self, "systems",
                           tuple(s if isinstance(s, Composition)
                                 else System.make(s) for s in self.systems))
        object.__setattr__(self, "intervals",
                           tuple(float(i) for i in self.intervals))
        object.__setattr__(self, "read_ratios",
                           tuple(float(r) for r in self.read_ratios))
        ctrls = self.controllers
        if ctrls is None:
            ctrls = (C.ControllerConfig(),)
        elif isinstance(ctrls, C.ControllerConfig):
            ctrls = (ctrls,)
        object.__setattr__(self, "controllers", tuple(ctrls))
        chans = self.channels
        if isinstance(chans, int):
            chans = (chans,)
        object.__setattr__(self, "channels", tuple(int(c) for c in chans))
        maps = self.mappers
        if maps is None:
            maps = (self.frontend.mapper,)
        elif isinstance(maps, str):
            maps = (maps,)
        object.__setattr__(self, "mappers", tuple(maps))
        if not self.systems:
            raise ValueError("SweepSpec needs at least one system")
        if not self.channels or any(c < 1 for c in self.channels):
            raise ValueError("SweepSpec needs channel counts >= 1")
        if not self.intervals or not self.read_ratios:
            raise ValueError("SweepSpec needs a non-empty load grid")
        if self.telemetry < 0:
            raise ValueError("telemetry window must be >= 0 cycles")
        if self.telemetry_dir and not self.telemetry:
            raise ValueError("telemetry_dir needs telemetry=W > 0")

    @property
    def grid_shape(self) -> tuple:
        """(n_systems, n_controllers, n_channels, n_mappers, n_intervals,
        n_read_ratios)."""
        return (len(self.systems), len(self.controllers),
                len(self.channels), len(self.mappers),
                len(self.intervals), len(self.read_ratios))

    @property
    def n_points(self) -> int:
        return len(self.expand())

    def expand(self) -> list:
        """The full cartesian grid, in (system, controller, channels,
        mapper, interval, read_ratio) row-major order — the executor
        relies on the load points of one compile group being contiguous.
        Compositions fix their own channel fan-out, so they expand once
        per (controller, mapper, load point) regardless of the
        ``channels`` axis."""
        out = []
        for sy, ct, nc, mp, iv, rr in itertools.product(
                self.systems, self.controllers, self.channels,
                self.mappers, self.intervals, self.read_ratios):
            if isinstance(sy, Composition):
                if nc != self.channels[0]:
                    continue        # the channels axis is a no-op here
                nc = sy.n_channels
            out.append(RunPoint(
                system=sy, controller=ct,
                frontend=dataclasses.replace(self.frontend, mapper=mp),
                n_cycles=self.n_cycles, interval=iv, read_ratio=rr,
                n_channels=nc))
        return out
