"""Sweep CLI: ``python -m repro.dse.sweep``.

Runs a multi-standard latency-throughput sweep in one invocation — each
(system, controller) pair compiles once and vmaps its whole load grid —
prints the table plus compile-cache accounting, and persists the curve
artifact (`.npz` + `.json`) for downstream benchmarks/plots.

    PYTHONPATH=src python -m repro.dse.sweep
    PYTHONPATH=src python -m repro.dse.sweep --standards DDR4,DDR5,HBM3 \
        --intervals 64,16,8,4,2,1 --ratios 1.0,0.5 --cycles 20000
"""
from __future__ import annotations

import argparse

from repro.dse.executor import execute
from repro.dse.results import SweepResult
from repro.dse.spec import DEFAULT_SYSTEMS, SweepSpec


def _floats(csv: str) -> tuple:
    return tuple(float(x) for x in csv.split(",") if x)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.sweep",
        description="Multi-standard DRAM latency-throughput sweep "
                    "(one compiled program per system).")
    ap.add_argument("--standards", default="DDR5,HBM3",
                    help="comma-separated standard names "
                         f"(known: {','.join(sorted(DEFAULT_SYSTEMS))})")
    ap.add_argument("--intervals", default="64,16,8,4,2,1", type=_floats,
                    help="streaming inter-arrival intervals in cycles, "
                         "high interval = low load")
    ap.add_argument("--ratios", default="1.0", type=_floats,
                    help="read ratios in [0,1]")
    ap.add_argument("--channels", default="1",
                    help="comma-separated memory-system channel counts")
    ap.add_argument("--mappers", default=None,
                    help="comma-separated address-mapper orders "
                         "(see repro.core.addrmap.MAPPERS)")
    ap.add_argument("--cycles", default=10_000, type=int,
                    help="simulated cycles per point")
    ap.add_argument("--scheduler", default="FRFCFS",
                    choices=("FRFCFS", "FCFS"))
    ap.add_argument("--out", default="results/dse_sweep",
                    help="artifact basename (writes <out>.npz + <out>.json)")
    ap.add_argument("--seed", default=0x1234, type=int)
    ap.add_argument("--telemetry", default=0, type=int, metavar="W",
                    help="windowed-telemetry window in cycles (0 = off); "
                         "every point gains a Telemetry time series")
    ap.add_argument("--telemetry-dir", default=None,
                    help="persist one telemetry .npz per point here "
                         "(needs --telemetry)")
    return ap


def main(argv=None) -> SweepResult:
    args = build_parser().parse_args(argv)
    from repro.core import ControllerConfig
    spec = SweepSpec(
        systems=tuple(s.strip() for s in args.standards.split(",") if s),
        intervals=args.intervals, read_ratios=args.ratios,
        controllers=(ControllerConfig(scheduler=args.scheduler),),
        channels=tuple(int(c) for c in args.channels.split(",") if c),
        mappers=(tuple(m.strip() for m in args.mappers.split(",") if m)
                 if args.mappers else None),
        n_cycles=args.cycles, seed=args.seed,
        telemetry=args.telemetry, telemetry_dir=args.telemetry_dir)
    print(f"expanding {spec.grid_shape} grid -> {spec.n_points} points")
    result = execute(spec)
    print(result.to_table())
    m = result.meta
    c = m["cache"]
    print(f"\n{m['n_groups']} compiled programs for {m['n_points']} points "
          f"({m['compile_cache_misses']} compiles, "
          f"{m['compile_cache_hits']} cache hits, {m['traces']} traces) "
          f"in {m['wall_s']}s on {m['n_devices']} device(s)")
    print(f"run cache: {c['entries']} live programs, {c['hits']} hits / "
          f"{c['misses']} misses, first-call (trace+compile+run) "
          f"{c['first_call_s']}s; topologies: "
          f"{', '.join(c.get('shard_topologies', ())) or 'none'}")
    spans = m.get("profile", {}).get("spans", {})
    if spans:
        attribution = ", ".join(
            f"{name} {s['s']}s x{s['calls']}"
            for name, s in sorted(spans.items(), key=lambda kv: -kv[1]["s"]))
        print(f"streamed pipeline (max {m['max_in_flight']} in flight): "
              f"{attribution}")
    if m.get("padded_points"):
        print(f"batch padding: {m['padded_points']} repeated point(s) "
              f"simulated for device alignment and dropped")
    if result.telemetry:
        n_art = len(m.get("telemetry_artifacts", []))
        print(f"telemetry: {len(result.telemetry)} per-point series "
              f"(window={spec.telemetry})"
              + (f", {n_art} artifacts in {spec.telemetry_dir}"
                 if n_art else ""))
    for cv in result.curves():
        knee_iv = cv.intervals[cv.knee]
        print(f"  {cv.system:>10} rd={cv.read_ratio:g}: "
              f"peak_frac={cv.peak_fraction:.3f} "
              f"knee@interval={knee_iv:g} "
              f"({cv.latency_ns[cv.knee]:.1f} ns)")
    path = result.save(args.out)
    print(f"curve artifact written to {path} (+ .json)")
    return result


if __name__ == "__main__":
    main()
