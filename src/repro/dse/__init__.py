"""Design-space-exploration orchestration (Ramulator 2.1 §3 workflows).

Declare a sweep, execute it with compile-cached vmapped programs, get
latency-throughput curves back:

    from repro.dse import SweepSpec, execute
    result = execute(SweepSpec(systems=("DDR4", "DDR5"),
                               intervals=(64, 8, 1), n_cycles=10_000))
    for curve in result.curves():
        print(curve.system, curve.peak_fraction, curve.knee)
    result.save("results/my_sweep")

See ``docs/dse.md`` for the full tour and ``python -m repro.dse.sweep``
for the CLI.
"""
from repro.dse.executor import compile_group_key, execute, group_points
from repro.dse.results import (Curve, SweepResult,
                               avg_probe_latency_ns_array, knee_index,
                               throughput_gbps_array)
from repro.dse.spec import (DEFAULT_SYSTEMS, Composition, RunPoint,
                            SweepSpec, System, SystemGroup, system)

__all__ = [
    "SweepSpec", "System", "RunPoint", "system", "DEFAULT_SYSTEMS",
    "Composition", "SystemGroup",
    "execute", "group_points", "compile_group_key",
    "SweepResult", "Curve", "knee_index",
    "throughput_gbps_array", "avg_probe_latency_ns_array",
]
