"""Sweep result post-processing: latency-throughput curves + persistence.

The engine's scalar metric helpers (`repro.core.engine.throughput_gbps`
et al.) `float()`-cast their inputs and therefore reject the stacked
(B,)-shaped Stats a vmapped sweep produces; the `*_array` functions here
are their vectorized numpy equivalents.  `SweepResult` holds one row per
`RunPoint` in columnar numpy form, extracts latency-throughput curves
(with knee detection) per (system, controller, read-ratio) series, and
persists to a `.npz` + `.json` artifact pair for the benchmarks.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.compile import CompiledSpec
from repro.dse.spec import RunPoint, SweepSpec, System
from repro.trace.capture import config_doc

# --------------------------------------------------------------------------
# Vectorized derived metrics (batched counterparts of repro.core.engine's)
# --------------------------------------------------------------------------


def throughput_gbps_array(spec, stats) -> np.ndarray:
    """Achieved GB/s per batched point; works on (B,) or scalar stats.

    ``spec`` may be a CompiledSpec or a MemorySystemSpec — heterogeneous
    systems aggregate each group's bytes on that group's own clock
    (group-correct, never one spec's bandwidth times total channels)."""
    from repro.core.compile import as_system
    from repro.core.engine import _check_system_stats
    msys = as_system(spec)
    _check_system_stats(msys, stats)
    total = None
    for grp, ch in zip(msys.groups, stats.per_group):
        moved = (np.asarray(ch.reads_done, np.float64).sum(axis=-1)
                 + np.asarray(ch.writes_done, np.float64).sum(axis=-1)) \
            * grp.cspec.access_bytes
        seconds = np.asarray(stats.cycles, np.float64) \
            * grp.cspec.tCK_ps * 1e-12
        tp = np.divide(moved / 1e9, seconds,
                       out=np.zeros_like(moved), where=seconds > 0)
        total = tp if total is None else total + tp
    return total


def avg_probe_latency_ns_array(spec, stats) -> np.ndarray:
    """Mean probe latency in ns per batched point; NaN where no probe
    finished.  Probe latencies count on the system's shared cycle index
    and convert with the reference clock (group 0's tCK)."""
    from repro.core.compile import as_system
    cnt = np.asarray(stats.probe_cnt, np.float64)
    lat_sum = np.asarray(stats.probe_lat_sum, np.float64)
    cycles = np.divide(lat_sum, cnt, out=np.full_like(lat_sum, np.nan),
                       where=cnt > 0)
    return cycles * as_system(spec).tCK_ps * 1e-3


def knee_index(latency_ns, knee_factor: float = 2.0) -> int:
    """Index of the curve's knee: the first point (ordered by increasing
    load) whose latency exceeds `knee_factor` x the low-load latency.
    Returns the last index when the curve never blows up."""
    lat = np.asarray(latency_ns, np.float64)
    finite = lat[np.isfinite(lat)]
    if len(finite) == 0:
        return len(lat) - 1
    base = finite[0]
    over = np.where(np.isfinite(lat) & (lat > knee_factor * base))[0]
    return int(over[0]) if len(over) else len(lat) - 1


@dataclasses.dataclass
class Curve:
    """One latency-throughput series at fixed (system, controller,
    read-ratio), ordered by increasing load (decreasing interval)."""
    system: str
    scheduler: str
    read_ratio: float
    intervals: np.ndarray       # (K,) decreasing — load rises along the row
    throughput_gbps: np.ndarray
    latency_ns: np.ndarray
    peak_gbps: float
    knee: int                   # index into the arrays
    n_channels: int = 1

    @property
    def peak_fraction(self) -> float:
        return float(self.throughput_gbps.max() / self.peak_gbps) \
            if self.peak_gbps else 0.0


@dataclasses.dataclass
class SweepResult:
    """Columnar results for every expanded `RunPoint` of one sweep."""
    points: list                        # list[RunPoint]
    throughput_gbps: np.ndarray         # (N,) GB/s
    latency_ns: np.ndarray              # (N,) mean probe latency
    peak_gbps: np.ndarray               # (N,) theoretical peak of the system
    reads_done: np.ndarray              # (N,)
    writes_done: np.ndarray             # (N,)
    probe_cnt: np.ndarray               # (N,)
    deferred: np.ndarray                # (N,)
    cycles: np.ndarray                  # (N,)
    scan_steps: np.ndarray              # (N,) scan-body executions
    skipped_cycles: np.ndarray          # (N,) fast-forwarded idle cycles
    cmd_counts: list                    # per-point (n_cmds,) arrays (ragged)
    cmd_names: list                     # per-point command-name lists
    meta: dict = dataclasses.field(default_factory=dict)
    #: Per-point `repro.trace.CommandTrace` objects when the sweep ran with
    #: `capture_traces`; None otherwise.  Not persisted by `save`/`load` —
    #: trace artifacts are saved separately (one `.npz` per point, paths in
    #: `meta["trace_artifacts"]`) when `capture_traces` names a directory.
    traces: list | None = None
    #: Per-point `repro.telemetry.Telemetry` when the sweep ran with
    #: `SweepSpec(telemetry=W)`; None otherwise.  Not persisted by
    #: `save`/`load` — telemetry artifacts are saved separately (one
    #: `.npz` per point, paths in `meta["telemetry_artifacts"]`) when
    #: `telemetry_dir` names a directory.
    telemetry: list | None = None

    def __len__(self):
        return len(self.points)

    # -- curve extraction -------------------------------------------------
    def curves(self, knee_factor: float = 2.0) -> list:
        """Latency-throughput curves per (system, controller, read-ratio)."""
        from repro.core.engine import _freeze
        series: dict = {}
        for i, pt in enumerate(self.points):
            # key on the FULL controller config (frozen) — two controllers
            # sharing a scheduler name are still distinct series — plus the
            # channel count and mapper order (distinct memory systems)
            key = (pt.system, _freeze(pt.controller), pt.n_channels,
                   pt.mapper, pt.read_ratio)
            series.setdefault(key, []).append(i)
        out = []
        for (sy, _ckey, nch, _mp, rr), idx in series.items():
            sched = self.points[idx[0]].controller.scheduler
            idx = sorted(idx, key=lambda i: -self.points[i].interval)
            lat = self.latency_ns[idx]
            out.append(Curve(
                system=sy.label, scheduler=sched, read_ratio=rr,
                intervals=np.array([self.points[i].interval for i in idx]),
                throughput_gbps=self.throughput_gbps[idx],
                latency_ns=lat,
                peak_gbps=float(self.peak_gbps[idx[0]]),
                knee=knee_index(lat, knee_factor),
                n_channels=nch))
        return out

    def cmd_count(self, i: int, name: str) -> int:
        """Per-point issued count of one command (0 if the standard lacks
        it)."""
        names = self.cmd_names[i]
        return int(self.cmd_counts[i][names.index(name)]) \
            if name in names else 0

    # -- pretty-printing --------------------------------------------------
    def to_table(self) -> str:
        hdr = (f"{'system':>10} {'ch':>3} {'sched':>7} {'interval':>9} "
               f"{'rd%':>5} {'GB/s':>8} {'peak%':>6} {'lat ns':>8}")
        rows = [hdr]
        for i, pt in enumerate(self.points):
            pk = self.peak_gbps[i]
            frac = 100 * self.throughput_gbps[i] / pk if pk else 0.0
            rows.append(
                f"{pt.system.label:>10} {pt.n_channels:>3} "
                f"{pt.controller.scheduler:>7} "
                f"{pt.interval:9.1f} {int(pt.read_ratio * 100):5d} "
                f"{self.throughput_gbps[i]:8.2f} {frac:6.1f} "
                f"{self.latency_ns[i]:8.1f}")
        return "\n".join(rows)

    # -- persistence ------------------------------------------------------
    _COLUMNS = ("throughput_gbps", "latency_ns", "peak_gbps", "reads_done",
                "writes_done", "probe_cnt", "deferred", "cycles",
                "scan_steps", "skipped_cycles")

    def save(self, path: str) -> str:
        """Persist to `<path>.npz` (columnar arrays) + `<path>.json`
        (points, command names, meta).  Returns the npz path."""
        base = path[:-4] if path.endswith(".npz") else path
        d = os.path.dirname(base)
        if d:
            os.makedirs(d, exist_ok=True)
        maxc = max((len(c) for c in self.cmd_counts), default=0)
        padded = np.full((len(self.points), maxc), -1, np.int64)
        for i, c in enumerate(self.cmd_counts):
            padded[i, :len(c)] = c
        arrays = {k: np.asarray(getattr(self, k)) for k in self._COLUMNS}
        np.savez(base + ".npz", cmd_counts=padded, **arrays)
        doc = {
            "points": [_point_doc(pt) for pt in self.points],
            "cmd_names": self.cmd_names,
            "meta": self.meta,
        }
        with open(base + ".json", "w") as f:
            json.dump(doc, f, indent=1, default=str)
        return base + ".npz"

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        base = path[:-4] if path.endswith(".npz") else path
        with np.load(base + ".npz") as z:
            arrays = {k: z[k] for k in cls._COLUMNS if k in z}
            padded = z["cmd_counts"]
        # artifacts predating fast-forward: every cycle was a scan step
        arrays.setdefault("scan_steps", np.array(arrays["cycles"]))
        arrays.setdefault("skipped_cycles",
                          np.zeros_like(np.asarray(arrays["cycles"])))
        with open(base + ".json") as f:
            doc = json.load(f)
        points = [_point_from_doc(p) for p in doc["points"]]
        cmd_names = doc["cmd_names"]
        cmd_counts = [padded[i][padded[i] >= 0] for i in range(len(points))]
        return cls(points=points, cmd_counts=cmd_counts,
                   cmd_names=cmd_names, meta=doc.get("meta", {}), **arrays)


def _point_doc(pt: RunPoint) -> dict:
    from repro.dse.spec import Composition
    if isinstance(pt.system, Composition):
        sy_doc = {"composition": [
            {"standard": g.system.standard,
             "org_preset": g.system.org_preset,
             "timing_preset": g.system.timing_preset,
             "timing_overrides": list(g.system.timing_overrides),
             "channels": g.channels, "link_latency": g.link_latency}
            for g in pt.system.groups]}
    else:
        sy_doc = {
            "standard": pt.system.standard,
            "org_preset": pt.system.org_preset,
            "timing_preset": pt.system.timing_preset,
            "timing_overrides": list(pt.system.timing_overrides),
        }
    return {
        **sy_doc,
        "controller": config_doc(pt.controller),
        "frontend": config_doc(pt.frontend),
        "n_cycles": pt.n_cycles,
        "interval": pt.interval,
        "read_ratio": pt.read_ratio,
        "n_channels": pt.n_channels,
    }


def _point_from_doc(p: dict) -> RunPoint:
    from repro.core import controller as C
    from repro.core import frontend as F
    from repro.dse.spec import Composition, SystemGroup
    if "composition" in p:
        sy = Composition(tuple(
            SystemGroup(System(g["standard"], g["org_preset"],
                               g["timing_preset"],
                               tuple(tuple(kv) for kv
                                     in g.get("timing_overrides", []))),
                        int(g.get("channels", 1)),
                        int(g.get("link_latency", 0)))
            for g in p["composition"]))
    else:
        sy = System(p["standard"], p["org_preset"], p["timing_preset"],
                    tuple(tuple(kv) for kv in p.get("timing_overrides", [])))
    return RunPoint(system=sy,
                    controller=C.ControllerConfig(**p.get("controller", {})),
                    frontend=F.FrontendConfig(**p.get("frontend", {})),
                    n_cycles=p["n_cycles"], interval=p["interval"],
                    read_ratio=p["read_ratio"],
                    n_channels=p.get("n_channels", 1))
