"""Batched sweep executor: group -> compile(cached) -> vmap -> stats,
with streamed cross-group collection.

The executor turns an expanded `SweepSpec` into as few compiled programs
as possible:

  1. group the `RunPoint`s by *compile group* — everything that changes
     the traced program: (standard, org, timing, overrides, controller
     config, frontend config, n_cycles).  Load knobs (interval,
     read ratio) are traced `FrontParams`, so the whole load grid of a
     group is one program;
  2. fetch the jitted batched run callable from the engine's process-wide
     `RUN_CACHE` — identical specs across sweeps (or repeated `execute`
     calls) re-trace exactly zero times.  The program's `FrontParams`
     argument is DONATED (`donate_argnums`): the executor rebuilds the
     stacked load points per group, so the device reuses their buffers
     for the scan carry instead of holding both live;
  3. vmap over the group's load points, sharding the batch across devices
     when more than one is available (padding by repeating the last point
     when the batch does not divide — padded entries are dropped from the
     results and accounted in ``meta["padded_points"]``);
  4. STREAM the groups: each group's program call is dispatched
     asynchronously (jax dispatch returns before the device finishes) and
     its results are harvested — synchronized, unpadded, folded into the
     `SweepResult` columns — only once `max_in_flight` later dispatches
     are in the pipeline or the sweep ends.  Host-side harvesting of one
     group overlaps device execution of the next, and at most
     `max_in_flight` groups' device buffers are ever live, so
     thousands-of-point sweeps never materialize all outputs at once.
     A `repro.telemetry.Profiler` attributes the wall clock to
     ``dispatch`` (compile + async call) vs ``collect`` (device sync +
     host fold) spans, reported in ``meta["profile"]``.

With `SweepSpec(capture_traces=...)` each group runs its *trace-emitting*
program instead — still exactly one compiled program per group (the trace
variant replaces the stats-only variant rather than adding to it, so
`engine.TRACE_COUNT` advances identically to a no-capture sweep) — and the
batched trace arrays are compacted per point into
`repro.trace.CommandTrace` objects, optionally persisted as one `.npz`
artifact per point.  `SweepSpec(telemetry=W)` works the same way for the
windowed-metrics program: every point gains a
`repro.telemetry.Telemetry` time series on `SweepResult.telemetry`, and
`meta["cache"]` reports the public `RunCache.stats()` accounting.
"""
from __future__ import annotations

import os
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device as D
from repro.core import engine as E
from repro.core import frontend as F
from repro.core.compile import as_system, compile_spec, compile_system
from repro.dse import results as R
from repro.dse.spec import Composition, SweepSpec


def lint_sweep_systems(points) -> None:
    """Pre-compile spec-lint gate for a sweep: run the spec linter over
    every distinct override-carrying system (plain or inside a
    composition) and raise :class:`repro.analysis.SpecLintError` with the
    merged report if any has error-severity findings.  Systems without
    overrides are skipped — the registered standards are lint-clean by
    construction (CI gates that separately)."""
    from repro.analysis.report import merge
    from repro.analysis.speclint import SpecLintError, lint_spec
    seen: set = set()
    bad = []
    for pt in points:
        if isinstance(pt.system, Composition):
            members = [(g.system, g.channels) for g in pt.system.groups]
        else:
            members = [(pt.system, pt.n_channels)]
        for sy, ch in members:
            if not sy.timing_overrides or (sy, ch) in seen:
                continue
            seen.add((sy, ch))
            rep = lint_spec(sy.standard, sy.org_preset, sy.timing_preset,
                            sy.overrides_dict, channels=ch)
            if not rep.ok():
                bad.append(rep)
    if bad:
        raise SpecLintError(merge(bad, target="sweep-pre-lint"))


def _compile_point_system(pt):
    """Compile a RunPoint's memory system: a plain `System` becomes the
    (1-group) CompiledSpec the historical cache key expects; a
    `Composition` becomes a MemorySystemSpec with one compiled spec per
    group."""
    if isinstance(pt.system, Composition):
        return compile_system([
            dict(standard=g.system.standard, org_preset=g.system.org_preset,
                 timing_preset=g.system.timing_preset,
                 timing_overrides=g.system.overrides_dict,
                 channels=g.channels, link_latency=g.link_latency)
            for g in pt.system.groups])
    return compile_spec(pt.system.standard, pt.system.org_preset,
                        pt.system.timing_preset, pt.system.overrides_dict,
                        channels=pt.n_channels)


def compile_group_key(pt) -> tuple:
    """Hashable key identifying the compiled program a point runs under.
    The channel count and the mapper order (inside the frontend freeze)
    both change the traced program, so they split compile groups."""
    return (pt.system, E._freeze(pt.controller), E._freeze(pt.frontend),
            pt.n_cycles, pt.n_channels)


def group_points(points) -> dict:
    """Group (index, point) pairs by compile group, preserving order."""
    groups: dict = {}
    for i, pt in enumerate(points):
        groups.setdefault(compile_group_key(pt), []).append((i, pt))
    return groups


def _front_params(pts, fcfg) -> F.FrontParams:
    """Stack the group's load points into vmappable `FrontParams`."""
    return F.stack_params([(pt.interval, pt.read_ratio) for pt in pts],
                          fcfg.probe_gap)


def _shard_batch(fp: F.FrontParams, devices):
    """Shard the batch axis across `devices`; pad by repeating the last
    point so the batch divides evenly.  Returns (fp, n_padding)."""
    ndev = len(devices)
    if ndev == 0:
        raise ValueError(
            "devices=[] — no devices to place the sweep batch on; pass "
            "devices=None to use jax.devices(), or a non-empty device "
            "list")
    n = fp.interval_fp.shape[0]
    if ndev == 1:
        # still honor an explicit single-device pin (e.g. devices=[gpu1])
        return jax.tree.map(lambda a: jax.device_put(a, devices[0]), fp), 0
    pad = (-n) % ndev
    if pad:
        fp = jax.tree.map(
            lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)]),
            fp)
    mesh = jax.sharding.Mesh(np.asarray(devices), ("b",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("b"))
    return jax.tree.map(lambda a: jax.device_put(a, sh), fp), pad


def execute(spec: SweepSpec, cache: E.RunCache | None = None,
            devices=None, max_in_flight: int = 2,
            profiler=None) -> R.SweepResult:
    """Run every point of `spec`, one compiled program per compile group,
    dispatching groups asynchronously and harvesting results as they
    complete (see the module docstring for the streaming pipeline).

    `cache` defaults to the engine's process-wide `RUN_CACHE`; pass a
    fresh `RunCache()` to isolate compilations (tests do).  `devices`
    defaults to `jax.devices()`.  `max_in_flight` bounds how many groups'
    device buffers may be live at once (>= 1); `profiler` is an optional
    `repro.telemetry.Profiler` to fold the dispatch/collect spans into
    (one is created per call otherwise, reported in ``meta["profile"]``).
    """
    from repro import telemetry as T    # lazy: keeps import order flexible
    cache = E.RUN_CACHE if cache is None else cache
    devices = jax.devices() if devices is None else devices
    if len(devices) == 0:
        raise ValueError("devices=[] — pass devices=None for jax.devices()"
                         " or a non-empty device list")
    prof = profiler if profiler is not None else T.Profiler(cache)
    points = spec.expand()
    if spec.lint_specs:
        lint_sweep_systems(points)      # fail fast with a LintReport
    groups = group_points(points)

    n = len(points)
    cols = {k: np.zeros((n,), np.float64)
            for k in ("throughput_gbps", "latency_ns", "peak_gbps")}
    ints = {k: np.zeros((n,), np.int64)
            for k in ("reads_done", "writes_done", "probe_cnt", "deferred",
                      "cycles", "scan_steps", "skipped_cycles")}
    cmd_counts: list = [None] * n
    cmd_names: list = [None] * n
    capture = spec.capture_traces
    traces: list | None = [None] * n if capture else None
    trace_dir = capture if isinstance(capture, str) else None
    trace_paths: dict = {}
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    telemetry: list | None = [None] * n if spec.telemetry else None
    telem_paths: dict = {}
    if spec.telemetry_dir:
        os.makedirs(spec.telemetry_dir, exist_ok=True)

    t0 = time.perf_counter()
    misses0, hits0, trace0 = cache.misses, cache.hits, E.TRACE_COUNT
    group_meta = []
    padded_total = 0
    inflight: deque = deque()

    def _harvest():
        """Synchronize the OLDEST in-flight group and fold its results."""
        g = inflight.popleft()
        tc = time.perf_counter()
        out = jax.block_until_ready(g["out"])
        members, idx = g["members"], g["idx"]
        msys, cspec = g["msys"], g["cspec"]
        ccfg, fcfg, pad = g["ccfg"], g["fcfg"], g["pad"]
        snaps = None
        if spec.telemetry:
            *out, snaps = out
            out = out[0] if len(out) == 1 else tuple(out)
        stats, dense = out if capture else (out, None)
        stats = jax.tree.map(np.asarray, stats)
        if pad:
            stats = jax.tree.map(lambda a: a[:-pad], stats)
        if snaps is not None:
            snaps = jax.tree.map(np.asarray, snaps)
            for j, (i, pt) in enumerate(members):
                telemetry[i] = T.build(
                    msys, jax.tree.map(lambda a: a[j], snaps),
                    window=spec.telemetry, n_cycles=pt.n_cycles)
                telemetry[i].meta["point"] = pt.label
                if spec.telemetry_dir:
                    telem_paths[i] = T.save(
                        telemetry[i], os.path.join(
                            spec.telemetry_dir, f"point_{i:04d}.npz"))
        if capture:
            from repro.trace.capture import capture as capture_trace
            from repro.trace.format import save as save_trace
            dense = jax.tree.map(np.asarray, dense)
            for j, (i, pt) in enumerate(members):
                tr = capture_trace(
                    cspec, dense, point=j, controller=ccfg, frontend=fcfg,
                    interval=pt.interval, read_ratio=pt.read_ratio,
                    seed=spec.seed, point_index=i, label=pt.label)
                traces[i] = tr
                if trace_dir:
                    trace_paths[i] = save_trace(
                        tr, os.path.join(trace_dir, f"point_{i:04d}.npz"))

        cols["throughput_gbps"][idx] = R.throughput_gbps_array(msys, stats)
        cols["latency_ns"][idx] = R.avg_probe_latency_ns_array(msys, stats)
        cols["peak_gbps"][idx] = E.peak_gbps(msys)
        for k in ints:
            ints[k][idx] = np.asarray(getattr(stats, k))
        for j, i in enumerate(idx):
            cmd_counts[i] = np.asarray(stats.cmd_counts[j])
            cmd_names[i] = list(msys.cmd_names)
        dt = time.perf_counter() - tc
        prof.add("collect", dt)
        g["meta"]["collect_s"] = round(dt, 3)
        g["meta"]["wall_s"] = round(g["meta"]["dispatch_s"] + dt, 3)

    for key, members in groups.items():
        td = time.perf_counter()
        idx = [i for i, _ in members]
        pts = [pt for _, pt in members]
        sy, ccfg, fcfg = pts[0].system, pts[0].controller, pts[0].frontend
        cspec = _compile_point_system(pts[0])
        msys = as_system(cspec)
        dp = tuple(D.dyn_params(g.cspec) for g in msys.groups)
        fp = _front_params(pts, fcfg)
        fp, pad = _shard_batch(fp, devices)
        padded_total += pad
        fn = cache.get(cspec, ccfg, fcfg, pts[0].n_cycles,
                       trace=bool(capture), batched=True,
                       telemetry=spec.telemetry, donate=True)
        # async dispatch: jax returns un-synchronized arrays; the device
        # churns through this group while the host dispatches the next
        # (and harvests the oldest).  A program's FIRST call still blocks
        # inside the cache's compile timer.
        out = fn(dp, fp, jnp.uint32(spec.seed))
        dt = time.perf_counter() - td
        prof.add("dispatch", dt)
        gm = {"system": sy.label, "n_points": len(pts),
              "n_channels": pts[0].n_channels,
              "n_spec_groups": msys.n_groups,
              "mapper": fcfg.mapper, "padded": pad,
              "dispatch_s": round(dt, 3)}
        group_meta.append(gm)
        inflight.append({"out": out, "members": members, "idx": idx,
                         "msys": msys, "cspec": cspec, "ccfg": ccfg,
                         "fcfg": fcfg, "pad": pad, "meta": gm})
        while len(inflight) > max(1, int(max_in_flight)):
            _harvest()
    while inflight:
        _harvest()

    meta = {
        "n_points": n,
        "n_groups": len(groups),
        "n_devices": len(devices),
        "compile_cache_misses": cache.misses - misses0,
        "compile_cache_hits": cache.hits - hits0,
        "traces": E.TRACE_COUNT - trace0,
        "wall_s": round(time.perf_counter() - t0, 3),
        "groups": group_meta,
        "seed": spec.seed,
        # batch-padding audit: device-count-aligned repeats of each
        # group's last point (simulated, then dropped from the results)
        "padded_points": padded_total,
        "max_in_flight": max(1, int(max_in_flight)),
        # dispatch vs collect wall attribution for the streamed pipeline,
        # plus what event-horizon fast-forward bought across the sweep
        "profile": {
            **prof.report(),
            "fast_forward": {
                "scan_steps": int(ints["scan_steps"].sum()),
                "skipped_cycles": int(ints["skipped_cycles"].sum()),
                "idle_fraction": round(
                    float(ints["skipped_cycles"].sum())
                    / max(float(ints["cycles"].sum()), 1.0), 4),
            },
        },
        # public RunCache accounting (RunCache.stats()) — cumulative over
        # the cache's lifetime, alongside the per-sweep deltas above
        "cache": cache.stats(),
    }
    if trace_paths:
        meta["trace_artifacts"] = [trace_paths.get(i) for i in range(n)]
    if telem_paths:
        meta["telemetry_artifacts"] = [telem_paths.get(i) for i in range(n)]
    return R.SweepResult(points=points, cmd_counts=cmd_counts,
                         cmd_names=cmd_names, meta=meta, traces=traces,
                         telemetry=telemetry, **cols, **ints)
