"""Batched sweep executor: group -> compile(cached) -> vmap -> stats.

The executor turns an expanded `SweepSpec` into as few compiled programs
as possible:

  1. group the `RunPoint`s by *compile group* — everything that changes
     the traced program: (standard, org, timing, overrides, controller
     config, frontend config, n_cycles).  Load knobs (interval,
     read ratio) are traced `FrontParams`, so the whole load grid of a
     group is one program;
  2. fetch the jitted batched run callable from the engine's process-wide
     `RUN_CACHE` — identical specs across sweeps (or repeated `execute`
     calls) re-trace exactly zero times;
  3. vmap over the group's load points, sharding the batch across devices
     when more than one is available;
  4. hand the stacked Stats to `repro.dse.results` for curve extraction.

With `SweepSpec(capture_traces=...)` each group runs its *trace-emitting*
program instead — still exactly one compiled program per group (the trace
variant replaces the stats-only variant rather than adding to it, so
`engine.TRACE_COUNT` advances identically to a no-capture sweep) — and the
batched trace arrays are compacted per point into
`repro.trace.CommandTrace` objects, optionally persisted as one `.npz`
artifact per point.  `SweepSpec(telemetry=W)` works the same way for the
windowed-metrics program: every point gains a
`repro.telemetry.Telemetry` time series on `SweepResult.telemetry`, and
`meta["cache"]` reports the public `RunCache.stats()` accounting.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device as D
from repro.core import engine as E
from repro.core import frontend as F
from repro.core.compile import as_system, compile_spec, compile_system
from repro.dse import results as R
from repro.dse.spec import Composition, SweepSpec


def _compile_point_system(pt):
    """Compile a RunPoint's memory system: a plain `System` becomes the
    (1-group) CompiledSpec the historical cache key expects; a
    `Composition` becomes a MemorySystemSpec with one compiled spec per
    group."""
    if isinstance(pt.system, Composition):
        return compile_system([
            dict(standard=g.system.standard, org_preset=g.system.org_preset,
                 timing_preset=g.system.timing_preset,
                 timing_overrides=g.system.overrides_dict,
                 channels=g.channels, link_latency=g.link_latency)
            for g in pt.system.groups])
    return compile_spec(pt.system.standard, pt.system.org_preset,
                        pt.system.timing_preset, pt.system.overrides_dict,
                        channels=pt.n_channels)


def compile_group_key(pt) -> tuple:
    """Hashable key identifying the compiled program a point runs under.
    The channel count and the mapper order (inside the frontend freeze)
    both change the traced program, so they split compile groups."""
    return (pt.system, E._freeze(pt.controller), E._freeze(pt.frontend),
            pt.n_cycles, pt.n_channels)


def group_points(points) -> dict:
    """Group (index, point) pairs by compile group, preserving order."""
    groups: dict = {}
    for i, pt in enumerate(points):
        groups.setdefault(compile_group_key(pt), []).append((i, pt))
    return groups


def _front_params(pts, fcfg) -> F.FrontParams:
    """Stack the group's load points into vmappable `FrontParams`."""
    return F.stack_params([(pt.interval, pt.read_ratio) for pt in pts],
                          fcfg.probe_gap)


def _shard_batch(fp: F.FrontParams, devices):
    """Shard the batch axis across `devices`; pad by repeating the last
    point so the batch divides evenly.  Returns (fp, n_padding)."""
    ndev = len(devices)
    n = fp.interval_fp.shape[0]
    if ndev == 1:
        # still honor an explicit single-device pin (e.g. devices=[gpu1])
        return jax.tree.map(lambda a: jax.device_put(a, devices[0]), fp), 0
    if ndev == 0:
        return fp, 0
    pad = (-n) % ndev
    if pad:
        fp = jax.tree.map(
            lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)]),
            fp)
    mesh = jax.sharding.Mesh(np.asarray(devices), ("b",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("b"))
    return jax.tree.map(lambda a: jax.device_put(a, sh), fp), pad


def execute(spec: SweepSpec, cache: E.RunCache | None = None,
            devices=None) -> R.SweepResult:
    """Run every point of `spec`, one compiled program per compile group.

    `cache` defaults to the engine's process-wide `RUN_CACHE`; pass a fresh
    `RunCache()` to isolate compilations (tests do).  `devices` defaults to
    `jax.devices()`.
    """
    cache = E.RUN_CACHE if cache is None else cache
    devices = jax.devices() if devices is None else devices
    points = spec.expand()
    groups = group_points(points)

    n = len(points)
    cols = {k: np.zeros((n,), np.float64)
            for k in ("throughput_gbps", "latency_ns", "peak_gbps")}
    ints = {k: np.zeros((n,), np.int64)
            for k in ("reads_done", "writes_done", "probe_cnt", "deferred",
                      "cycles")}
    cmd_counts: list = [None] * n
    cmd_names: list = [None] * n
    capture = spec.capture_traces
    traces: list | None = [None] * n if capture else None
    trace_dir = capture if isinstance(capture, str) else None
    trace_paths: dict = {}
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    telemetry: list | None = [None] * n if spec.telemetry else None
    telem_paths: dict = {}
    if spec.telemetry_dir:
        os.makedirs(spec.telemetry_dir, exist_ok=True)

    t0 = time.perf_counter()
    misses0, hits0, trace0 = cache.misses, cache.hits, E.TRACE_COUNT
    group_meta = []
    for key, members in groups.items():
        idx = [i for i, _ in members]
        pts = [pt for _, pt in members]
        sy, ccfg, fcfg = pts[0].system, pts[0].controller, pts[0].frontend
        cspec = _compile_point_system(pts[0])
        msys = as_system(cspec)
        dp = tuple(D.dyn_params(g.cspec) for g in msys.groups)
        fp = _front_params(pts, fcfg)
        fp, pad = _shard_batch(fp, devices)
        fn = cache.get(cspec, ccfg, fcfg, pts[0].n_cycles,
                       trace=bool(capture), batched=True,
                       telemetry=spec.telemetry)
        tg = time.perf_counter()
        out = fn(dp, fp, jnp.uint32(spec.seed))
        snaps = None
        if spec.telemetry:
            *out, snaps = out
            out = out[0] if len(out) == 1 else tuple(out)
        stats, dense = out if capture else (out, None)
        stats = jax.tree.map(np.asarray, stats)
        if pad:
            stats = jax.tree.map(lambda a: a[:-pad], stats)
        if snaps is not None:
            from repro import telemetry as T
            snaps = jax.tree.map(np.asarray, snaps)
            for j, (i, pt) in enumerate(members):
                telemetry[i] = T.build(
                    msys, jax.tree.map(lambda a: a[j], snaps),
                    window=spec.telemetry, n_cycles=pt.n_cycles)
                telemetry[i].meta["point"] = pt.label
                if spec.telemetry_dir:
                    telem_paths[i] = T.save(
                        telemetry[i], os.path.join(
                            spec.telemetry_dir, f"point_{i:04d}.npz"))
        if capture:
            from repro.trace.capture import capture as capture_trace
            from repro.trace.format import save as save_trace
            dense = jax.tree.map(np.asarray, dense)
            for j, (i, pt) in enumerate(members):
                tr = capture_trace(
                    cspec, dense, point=j, controller=ccfg, frontend=fcfg,
                    interval=pt.interval, read_ratio=pt.read_ratio,
                    seed=spec.seed, point_index=i, label=pt.label)
                traces[i] = tr
                if trace_dir:
                    trace_paths[i] = save_trace(
                        tr, os.path.join(trace_dir, f"point_{i:04d}.npz"))
        group_meta.append({"system": sy.label, "n_points": len(pts),
                           "n_channels": pts[0].n_channels,
                           "n_spec_groups": msys.n_groups,
                           "mapper": fcfg.mapper,
                           "wall_s": round(time.perf_counter() - tg, 3)})

        cols["throughput_gbps"][idx] = R.throughput_gbps_array(msys, stats)
        cols["latency_ns"][idx] = R.avg_probe_latency_ns_array(msys, stats)
        cols["peak_gbps"][idx] = E.peak_gbps(msys)
        for k in ints:
            ints[k][idx] = np.asarray(getattr(stats, k))
        for j, i in enumerate(idx):
            cmd_counts[i] = np.asarray(stats.cmd_counts[j])
            cmd_names[i] = list(msys.cmd_names)

    meta = {
        "n_points": n,
        "n_groups": len(groups),
        "n_devices": len(devices),
        "compile_cache_misses": cache.misses - misses0,
        "compile_cache_hits": cache.hits - hits0,
        "traces": E.TRACE_COUNT - trace0,
        "wall_s": round(time.perf_counter() - t0, 3),
        "groups": group_meta,
        "seed": spec.seed,
        # public RunCache accounting (RunCache.stats()) — cumulative over
        # the cache's lifetime, alongside the per-sweep deltas above
        "cache": cache.stats(),
    }
    if trace_paths:
        meta["trace_artifacts"] = [trace_paths.get(i) for i in range(n)]
    if telem_paths:
        meta["telemetry_artifacts"] = [telem_paths.get(i) for i in range(n)]
    return R.SweepResult(points=points, cmd_counts=cmd_counts,
                         cmd_names=cmd_names, meta=meta, traces=traces,
                         telemetry=telemetry, **cols, **ints)
