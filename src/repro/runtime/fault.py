"""Fault tolerance: checkpoint/restart driver, straggler monitoring,
elastic-mesh policy.

Design for 1000+ nodes (DESIGN.md §5):
  * the training step is pure and the data pipeline is a function of
    (seed, step), so recovery = restore latest checkpoint + fast-forward
    the step counter — no replay log needed;
  * node failure surfaces as an exception from the step (collective error /
    heartbeat timeout upstream); `run_with_restarts` restores and, when a
    `remesh` callback is provided, rebuilds the step for a smaller healthy
    mesh (elastic scaling) before resuming;
  * stragglers are detected from a step-latency EMA; the policy object only
    *decides* (log / skip-shard / remesh) — enforcement hooks live with the
    launcher, keeping this module hardware-free and unit-testable.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from repro.compat import ensure_jax_sharding_compat

# elastic remesh callbacks build meshes with ``axis_types=`` — make that
# API available on jax versions that predate it before any mesh exists
ensure_jax_sharding_compat()

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class FaultConfig:
    max_restarts: int = 3
    ckpt_interval: int = 50
    straggler_factor: float = 3.0     # step slower than factor x EMA
    straggler_patience: int = 2       # consecutive slow steps before action
    ema_alpha: float = 0.2


class StragglerMonitor:
    """Step-latency EMA; flags persistent stragglers."""

    def __init__(self, cfg: FaultConfig, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.ema: Optional[float] = None
        self.slow_streak = 0
        self.events: list = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = self.clock()

    def end_step(self, step: int) -> bool:
        """Returns True when straggler mitigation should trigger."""
        dt = self.clock() - self._t0
        if self.ema is None:
            self.ema = dt
            return False
        is_slow = dt > self.cfg.straggler_factor * self.ema
        if is_slow:
            self.slow_streak += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            log.warning("straggler suspected at step %d: %.3fs vs EMA %.3fs",
                        step, dt, self.ema)
        else:
            self.slow_streak = 0
            self.ema = (1 - self.cfg.ema_alpha) * self.ema \
                + self.cfg.ema_alpha * dt
        return self.slow_streak >= self.cfg.straggler_patience


def run_with_restarts(*, make_step, init_state, data_for_step, n_steps: int,
                      manager, cfg: FaultConfig = FaultConfig(),
                      remesh: Optional[Callable] = None,
                      monitor: Optional[StragglerMonitor] = None,
                      meta: Optional[dict] = None):
    """Run `n_steps`, surviving step exceptions via checkpoint/restart.

    make_step()            -> step function (state, batch) -> (state, metrics)
    data_for_step(step)    -> batch (deterministic!)
    remesh()               -> called after a failure; may rebuild meshes and
                              return a fresh make_step (elastic scaling)
    Returns (state, history dict).
    """
    state = init_state
    step_fn = make_step()
    start = 0
    restored, man = manager.restore_latest(like=state)
    if restored is not None:
        state, start = restored, man["step"]
        log.info("resumed from checkpoint at step %d", start)

    history = {"restarts": 0, "completed": [], "straggler_events": []}
    step = start
    restarts = 0
    while step < n_steps:
        try:
            if monitor:
                monitor.start_step()
            state, metrics = step_fn(state, data_for_step(step))
            if monitor and monitor.end_step(step):
                history["straggler_events"].append(step)
            step += 1
            history["completed"].append(step)
            manager.maybe_save(step, state, dict(meta or {}, step=step))
        except Exception as e:   # noqa: BLE001 — any step fault
            restarts += 1
            history["restarts"] = restarts
            log.error("step %d failed (%s); restart %d/%d", step, e,
                      restarts, cfg.max_restarts)
            if restarts > cfg.max_restarts:
                raise
            restored, man = manager.restore_latest(like=state)
            if restored is not None:
                state, step = restored, man["step"]
            else:
                state, step = init_state, 0
            if remesh is not None:
                step_fn = remesh() or step_fn
    return state, history
