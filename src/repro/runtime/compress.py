"""int8 gradient compression with error feedback.

Wraps the DP all-reduce boundary: under pjit the gradient pytree carries
the parameter shardings, so quantizing before the optimizer shrinks the
cross-pod ("pod" axis) all-reduce payload by 4x (bf16->int8 with a f32
scale per tensor).  Error feedback keeps the quantization noise unbiased
across steps (residual is re-added next step), preserving convergence —
the standard large-scale trick (1-bit Adam lineage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def make_int8_compressor():
    """Returns (transform, init_residual).  transform is stateful via an
    explicit residual pytree: (grads, residual) -> (grads', residual')."""

    def init_residual(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads, residual):
        def one(g, r):
            gf = g.astype(jnp.float32) + r
            q, s = quantize_int8(gf)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), gf - deq
        out = jax.tree.map(one, grads, residual)
        g2 = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        r2 = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return g2, r2

    return transform, init_residual
