from repro.runtime.fault import (FaultConfig, StragglerMonitor,
                                 run_with_restarts)
from repro.runtime.compress import make_int8_compressor

__all__ = ["FaultConfig", "StragglerMonitor", "run_with_restarts",
           "make_int8_compressor"]
