"""DeviceUnderTest: fine-grained probe API (paper §4, Listing 2).

An *independent*, scalar numpy implementation of the device semantics used
as the oracle for the vectorized JAX engine.  The API mirrors the paper:

    dram = ...  # any registered standard
    dut  = DeviceUnderTest(dram, org_preset=..., timing_preset=...)
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=12, Column=0)
    closed = dut.probe("RD", addr, clk=0)
    assert closed.preq == "ACT"
    assert closed.timing_OK is True
    assert closed.ready is False
    dut.issue("ACT", addr, clk=0)
    early = dut.probe("RD", addr, clk=dut.timings["nRCD"] - 1)
    assert early.timing_OK is False and early.row_hit is True
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spec as S
from repro.core.compile import CompiledSpec, compile_spec

NEG = -(1 << 28)
_LEVEL_KEYS = {"channel": "Channel", "rank": "Rank", "pseudochannel":
               "PseudoChannel", "bankgroup": "BankGroup", "bank": "Bank"}


@dataclasses.dataclass
class ProbeResult:
    preq: str           # prerequisite command needed before `cmd`
    timing_OK: bool     # `cmd` itself satisfies all timing constraints now
    ready: bool         # preq == cmd and timing_OK
    row_hit: bool
    row_open: bool
    earliest: int       # earliest cycle `cmd` is timing-legal


class DeviceUnderTest:
    def __init__(self, standard, org_preset: str, timing_preset: str,
                 timing_overrides: dict | None = None,
                 _cspec: CompiledSpec | None = None):
        if _cspec is not None:
            self.cspec = _cspec
        else:
            if not isinstance(standard, (str, type)):
                raise TypeError("pass a standard class or name")
            self.cspec: CompiledSpec = compile_spec(standard, org_preset,
                                                    timing_preset,
                                                    timing_overrides)
        cs = self.cspec
        self.timings = cs.timings
        # mirror of the engine's split timing state: dense most-recent
        # table + compact windowed ring (DUT-replay cross-checks must see
        # the exact same layout semantics)
        self.last_issue = np.full((cs.num_nodes, cs.n_cmds), NEG, np.int64)
        self.win_ring = np.full((max(cs.n_ring, 1), cs.ring_depth),
                                NEG, np.int64)
        self.row_state = np.full((cs.n_banks,), -1, np.int64)
        self.act1_row = np.zeros((cs.n_banks,), np.int64)
        self.act1_clk = np.full((cs.n_banks,), NEG, np.int64)
        self.clock_until = np.zeros((cs.n_refresh_units,), np.int64)
        self.history: list = []

    @classmethod
    def from_compiled(cls, cspec: CompiledSpec) -> "DeviceUnderTest":
        """Build the oracle directly from a compiled spec — e.g. one spec
        group of a heterogeneous ``MemorySystemSpec``, timing overrides
        and post-compile geometry edits included — so every channel of
        every group can be cross-checked against its OWN device model."""
        return cls(None, "", "", _cspec=cspec)

    # ---- addressing -------------------------------------------------------
    def addr_vec(self, **kw) -> dict:
        """addr_vec(Rank=0, BankGroup=0, Bank=0, Row=12, Column=0)"""
        addr = {}
        for lv in self.cspec.levels[1:]:
            addr[lv] = int(kw.pop(_LEVEL_KEYS[lv], kw.pop(lv, 0)))
        addr["row"] = int(kw.pop("Row", kw.pop("row", 0)))
        addr["col"] = int(kw.pop("Column", kw.pop("col", 0)))
        if kw:
            raise TypeError(f"unknown address fields {sorted(kw)} "
                            f"(levels: {self.cspec.levels[1:]})")
        return addr

    def _nodes(self, addr) -> list:
        cs = self.cspec
        nodes, flat = [0], 0
        for i, lv in enumerate(cs.levels[1:], start=1):
            flat = flat * int(cs.level_counts[i]) + addr[lv]
            nodes.append(int(cs.level_offsets[i]) + flat)
        return nodes

    def _bank(self, addr) -> int:
        cs = self.cspec
        flat = 0
        for i, lv in enumerate(cs.levels[1:], start=1):
            flat = flat * int(cs.level_counts[i]) + addr[lv]
        return flat

    # ---- semantics (scalar, loop-based — the oracle) -----------------------
    def earliest(self, cmd: str, addr) -> int:
        cs = self.cspec
        c = cs.cmd_id(cmd)
        nodes = self._nodes(addr)
        t = NEG
        for i in range(len(cs.ct_next)):
            if cs.ct_next[i] != c:
                continue
            node = nodes[cs.ct_level[i]]
            if cs.ct_win[i] > 1:
                ro = int(cs.ct_ring[i])
                if ro < 0:
                    continue    # window the preceding command never stamps
                e = ro + node - int(cs.level_offsets[cs.ct_level[i]])
                prev_t = self.win_ring[e, cs.ct_win[i] - 1]
            else:
                prev_t = self.last_issue[node, cs.ct_prev[i]]
            if prev_t > NEG:
                t = max(t, prev_t + int(cs.ct_lat[i]))
        return t

    def prereq(self, cmd: str, addr) -> str:
        """Prerequisite for the *request* carried by a column command, or
        for the command itself when it is not a column command."""
        cs = self.cspec
        kind = cs.cmd_kind[cs.cmd_id(cmd)]
        if kind != S.KIND_COL:
            return cmd
        bank = self._bank(addr)
        rs = self.row_state[bank]
        ru = addr[cs.levels[1]]
        if rs == -1:
            return "ACT1" if cs.split_activation else "ACT"
        if rs == -2:
            return "ACT2"
        if rs != addr["row"]:
            return "PRE"
        if cs.data_clock_sync and not (self._now_clock_on(ru)):
            if cs.id_RCKSTRT >= 0:
                return "RCKSTRT"
            return "CAS_WR" if cmd == "WR" else "CAS_RD"
        return cmd

    def _now_clock_on(self, ru) -> bool:
        return self._probe_clk < self.clock_until[ru]

    _probe_clk = 0

    def probe(self, cmd: str, addr, clk: int) -> ProbeResult:
        self._probe_clk = clk
        cs = self.cspec
        bank = self._bank(addr)
        rs = self.row_state[bank]
        earliest = self.earliest(cmd, addr)
        timing_OK = clk >= earliest
        preq = self.prereq(cmd, addr)
        return ProbeResult(preq=preq, timing_OK=bool(timing_OK),
                           ready=bool((preq == cmd) and timing_OK),
                           row_hit=bool(rs == addr["row"]),
                           row_open=bool(rs >= 0),
                           earliest=int(earliest))

    def issue(self, cmd: str, addr, clk: int, check: bool = False):
        cs = self.cspec
        c = cs.cmd_id(cmd)
        if check:
            r = self.probe(cmd, addr, clk)
            if not (r.timing_OK and r.preq == cmd):
                raise AssertionError(
                    f"illegal issue of {cmd} at clk={clk}: {r}")
        nodes = self._nodes(addr)
        scope = cs.cmd_scope[c]
        for lvl in range(scope + 1):
            self.last_issue[nodes[lvl], c] = clk
        for pcmd, plvl, eoff, _n_l in cs.ring_pairs:
            if pcmd != c:       # pair levels are <= the command's scope
                continue
            e = eoff + nodes[plvl] - int(cs.level_offsets[plvl])
            ring = self.win_ring[e]
            ring[1:] = ring[:-1]
            ring[0] = clk
        fx = int(cs.cmd_fx[c])
        bank = self._bank(addr)
        ru = addr[cs.levels[1]]
        if fx & S.FX_OPEN:
            self.row_state[bank] = addr["row"]
        if fx & S.FX_CLOSE:
            self.row_state[bank] = -1
        if fx & S.FX_CLOSE_ALL:
            bpr = cs.n_banks // cs.n_refresh_units
            self.row_state[ru * bpr:(ru + 1) * bpr] = -1
        if fx & S.FX_ACT1:
            self.row_state[bank] = -2
            self.act1_row[bank] = addr["row"]
            self.act1_clk[bank] = clk
        if fx & S.FX_CLOCK_ON:
            self.clock_until[ru] = clk + cs.clock_idle
        if fx & (S.FX_FINAL_RD | S.FX_FINAL_WR) and cs.data_clock_sync:
            self.clock_until[ru] = max(self.clock_until[ru],
                                       clk + cs.clock_idle)
        self.history.append((clk, cmd, dict(addr)))
