"""LPDDR6 — split activation + WCK, scaled from LPDDR5 (values extrapolated:
JESD209-6 is not fully public)."""
from repro.core.spec import Organization, register
from repro.core.standards.lpddr5 import LPDDR5


@register
class LPDDR6(LPDDR5):
    name = "LPDDR6"
    burst_beats = 24   # LPDDR6: BL24 on a x24 sub-channel pair
    org_presets = {
        "LPDDR6_16Gb_x16": Organization(16384, 16, {"rank": 1, "bankgroup": 4, "bank": 4}, rows=1 << 16, columns=1 << 10),
    }
    timing_presets = {
        "LPDDR6_8533": dict(  # extrapolated
            tCK_ps=937, nBL=4, nCL=20, nCWL=12, nRCD=18, nRP=18, nRAS=40,
            nRC=58, nWR=34, nRTP=10, nCCD_S=2, nCCD_L=4, nRRD_S=4, nRRD_L=4,
            nWTR_S=6, nWTR_L=10, nFAW=20, nRFC=222, nREFI=4163,
            nAAD=8, nAAD_MIN=2, nWCKEN=4, nWCKIDLE=10,
        ),
    }
