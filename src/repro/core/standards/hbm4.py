"""HBM4 — dual C/A, wider interface (values extrapolated from JESD270-4
public material)."""
from repro.core.spec import Organization, register
from repro.core.standards.hbm3 import HBM3


@register
class HBM4(HBM3):
    name = "HBM4"
    burst_beats = 8
    org_presets = {
        "HBM4_24Gb": Organization(24576, 128, {"pseudochannel": 4, "bankgroup": 4, "bank": 4}, rows=1 << 14, columns=1 << 6),
    }
    timing_presets = {
        "HBM4_8000": dict(  # 8 Gb/s/pin (extrapolated)
            tCK_ps=500, nBL=2, nCL=28, nCWL=8, nRCD=26, nRP=26, nRAS=62,
            nRC=88, nWR=30, nRTP=6, nCCD_S=2, nCCD_L=4, nRRD_S=4, nRRD_L=7,
            nWTR_S=9, nWTR_L=13, nFAW=20, nRFC=520, nREFI=7800,
        ),
    }
