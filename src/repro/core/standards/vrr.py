"""Victim-Row-Refresh variants — the paper's Listing 1, verbatim pattern.

Each variant is <20 lines: inherit, append the VRR command, append its
timing constraints, and derive the nVRR preset value from tCK.
"""
import math

from repro.core.spec import Command, TimingConstraint, KIND_ROW, register
from repro.core.standards.ddr4 import DDR4
from repro.core.standards.ddr5 import DDR5


def _with_vrr(base, name):
    class _VRR(base):
        pass
    _VRR.__name__ = _VRR.__qualname__ = name
    _VRR.name = name
    _VRR.command_meta = dict(base.command_meta, VRR=Command("VRR", "bank", KIND_ROW))
    _VRR.commands = base.commands + ["VRR"]
    _VRR.timing_params = base.timing_params + ["nVRR"]
    _VRR.timing_constraints = list(base.timing_constraints) + [
        TimingConstraint(level="bank", preceding=["VRR"], following=["ACT"], latency="nVRR"),
        TimingConstraint(level="bank", preceding=["ACT"], following=["VRR"], latency="nRC"),
        TimingConstraint(level="rank", preceding=["PRE", "PREab"], following=["VRR"], latency="nRP"),
    ]
    _VRR.org_presets = base.org_presets
    _VRR.timing_presets = {}
    for _name, _timings in base.timing_presets.items():
        _vrr = dict(_timings)
        _vrr["nVRR"] = math.ceil(280_000 / _timings["tCK_ps"])   # 280 ns
        _VRR.timing_presets[_name] = _vrr
    return register(_VRR)


DDR4_VRR = _with_vrr(DDR4, "DDR4_VRR")
DDR5_VRR = _with_vrr(DDR5, "DDR5_VRR")
