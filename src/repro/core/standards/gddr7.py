"""GDDR7 — dual C/A bus (parallel row/column issue) + RCK data-clock sync
(paper §2).  Values extrapolated from JESD239 public material."""
from repro.core.spec import DRAMSpec, Organization, TimingConstraint, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class GDDR7(DRAMSpec):
    name = "GDDR7"
    levels = ("channel", "rank", "bankgroup", "bank")
    burst_beats = 16
    dual_command_bus = True
    data_clock_sync = True
    clock_sync_commands = {"read": "RCKSTRT", "write": "RCKSTRT"}
    command_meta = base_commands(clock_sync="rck")
    commands = list(command_meta)
    timing_params = base_timing_params(extra=("nRCKEN", "nRCKIDLE"))
    timing_constraints = base_constraints() + [
        TimingConstraint("rank", ["RCKSTRT"], ["RD", "WR"], "nRCKEN"),
        TimingConstraint("rank", ["RCKSTRT"], ["RCKSTRT"], "nRCKEN"),
    ]
    org_presets = {
        "GDDR7_16Gb_x32": Organization(16384, 32, {"rank": 1, "bankgroup": 4, "bank": 4}, rows=1 << 14, columns=1 << 10),
    }
    timing_presets = {
        "GDDR7_32": dict(   # 32 Gb/s/pin, CK = 1.25 GHz (extrapolated)
            tCK_ps=800, nBL=2, nCL=30, nCWL=10, nRCD=30, nRP=30, nRAS=64,
            nRC=94, nWR=30, nRTP=5, nCCD_S=2, nCCD_L=3, nRRD_S=4, nRRD_L=6,
            nWTR_S=7, nWTR_L=10, nFAW=20, nRFC=350, nREFI=2375,
            nRCKEN=2, nRCKIDLE=8,
        ),
    }
