"""DDR3 — baseline standard, no bank groups."""
from repro.core.spec import DRAMSpec, Organization, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class DDR3(DRAMSpec):
    name = "DDR3"
    levels = ("channel", "rank", "bank")
    burst_beats = 8
    command_meta = base_commands()
    commands = list(command_meta)
    timing_params = base_timing_params(has_bankgroup=False)
    timing_constraints = base_constraints(has_bankgroup=False)
    org_presets = {
        "DDR3_4Gb_x8": Organization(4096, 8, {"rank": 2, "bank": 8}, rows=1 << 16, columns=1 << 10),
        "DDR3_8Gb_x8": Organization(8192, 8, {"rank": 2, "bank": 8}, rows=1 << 16, columns=1 << 11),
    }
    timing_presets = {
        "DDR3_1600K": dict(
            tCK_ps=1250, nBL=4, nCL=11, nCWL=8, nRCD=11, nRP=11, nRAS=28,
            nRC=39, nWR=12, nRTP=6, nCCD_S=4, nRRD_S=5, nWTR_S=6, nFAW=24,
            nRFC=208, nREFI=6240,
        ),
    }
