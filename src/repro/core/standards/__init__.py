"""DRAM standards modeled by the simulator (paper Fig. 1 set + VRR variants)."""
from repro.core.standards.ddr3 import DDR3
from repro.core.standards.ddr4 import DDR4
from repro.core.standards.ddr5 import DDR5
from repro.core.standards.lpddr5 import LPDDR5
from repro.core.standards.lpddr6 import LPDDR6
from repro.core.standards.gddr6 import GDDR6
from repro.core.standards.gddr7 import GDDR7
from repro.core.standards.hbm2 import HBM2
from repro.core.standards.hbm3 import HBM3
from repro.core.standards.hbm4 import HBM4
from repro.core.standards.vrr import DDR4_VRR, DDR5_VRR

ALL = [DDR3, DDR4, DDR5, LPDDR5, LPDDR6, GDDR6, GDDR7, HBM2, HBM3, HBM4,
       DDR4_VRR, DDR5_VRR]

__all__ = [s.__name__ for s in ALL] + ["ALL"]
