"""DDR4 — bank groups, nCCD_S/L split."""
from repro.core.spec import DRAMSpec, Organization, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class DDR4(DRAMSpec):
    name = "DDR4"
    levels = ("channel", "rank", "bankgroup", "bank")
    burst_beats = 8
    command_meta = base_commands()
    commands = list(command_meta)
    timing_params = base_timing_params()
    timing_constraints = base_constraints()
    org_presets = {
        "DDR4_8Gb_x8": Organization(8192, 8, {"rank": 1, "bankgroup": 4, "bank": 4}, rows=1 << 16, columns=1 << 10),
        "DDR4_8Gb_x8_2R": Organization(8192, 8, {"rank": 2, "bankgroup": 4, "bank": 4}, rows=1 << 16, columns=1 << 10),
    }
    timing_presets = {
        "DDR4_2400R": dict(
            tCK_ps=833, nBL=4, nCL=16, nCWL=12, nRCD=16, nRP=16, nRAS=32,
            nRC=48, nWR=18, nRTP=9, nCCD_S=4, nCCD_L=6, nRRD_S=4, nRRD_L=6,
            nWTR_S=3, nWTR_L=9, nFAW=26, nRFC=420, nREFI=9360,
        ),
        "DDR4_3200AA": dict(
            tCK_ps=625, nBL=4, nCL=22, nCWL=16, nRCD=22, nRP=22, nRAS=52,
            nRC=74, nWR=24, nRTP=12, nCCD_S=4, nCCD_L=8, nRRD_S=4, nRRD_L=8,
            nWTR_S=4, nWTR_L=12, nFAW=34, nRFC=560, nREFI=12480,
        ),
    }
