"""HBM2 — pseudo-channel organization, single C/A bus."""
from repro.core.spec import DRAMSpec, Organization, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class HBM2(DRAMSpec):
    name = "HBM2"
    levels = ("channel", "pseudochannel", "bankgroup", "bank")
    refresh_level = "pseudochannel"
    burst_beats = 4     # BL4 on a 128-bit (x64 per pseudo-channel) bus
    command_meta = base_commands(refresh_level="pseudochannel")
    commands = list(command_meta)
    timing_params = base_timing_params()
    timing_constraints = base_constraints(refresh_level="pseudochannel")
    org_presets = {
        "HBM2_8Gb": Organization(8192, 64, {"pseudochannel": 2, "bankgroup": 4, "bank": 4}, rows=1 << 14, columns=1 << 6),
    }
    timing_presets = {
        "HBM2_2Gbps": dict(
            tCK_ps=1000, nBL=2, nCL=14, nCWL=4, nRCD=14, nRP=14, nRAS=33,
            nRC=47, nWR=16, nRTP=4, nCCD_S=2, nCCD_L=3, nRRD_S=4, nRRD_L=6,
            nWTR_S=6, nWTR_L=8, nFAW=16, nRFC=260, nREFI=3900,
        ),
    }
