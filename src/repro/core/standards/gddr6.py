"""GDDR6 — high-frequency graphics DRAM, single C/A bus."""
from repro.core.spec import DRAMSpec, Organization, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class GDDR6(DRAMSpec):
    name = "GDDR6"
    levels = ("channel", "rank", "bankgroup", "bank")
    burst_beats = 16
    command_meta = base_commands()
    commands = list(command_meta)
    timing_params = base_timing_params()
    timing_constraints = base_constraints()
    org_presets = {
        "GDDR6_8Gb_x16": Organization(8192, 16, {"rank": 1, "bankgroup": 4, "bank": 4}, rows=1 << 14, columns=1 << 10),
    }
    timing_presets = {
        "GDDR6_16": dict(   # 16 Gb/s/pin, CK = 1 GHz
            tCK_ps=1000, nBL=2, nCL=24, nCWL=8, nRCD=24, nRP=24, nRAS=52,
            nRC=76, nWR=24, nRTP=4, nCCD_S=2, nCCD_L=3, nRRD_S=4, nRRD_L=6,
            nWTR_S=6, nWTR_L=8, nFAW=16, nRFC=280, nREFI=1900,
        ),
    }
