"""LPDDR5 — split ACT-1/ACT-2 activation + WCK data-clock sync (paper §2)."""
from repro.core.spec import DRAMSpec, Organization, TimingConstraint, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class LPDDR5(DRAMSpec):
    name = "LPDDR5"
    levels = ("channel", "rank", "bankgroup", "bank")
    burst_beats = 16
    split_activation = True
    data_clock_sync = True
    clock_sync_commands = {"read": "CAS_RD", "write": "CAS_WR"}
    command_meta = base_commands(split_act=True, clock_sync="wck")
    commands = list(command_meta)
    timing_params = base_timing_params(extra=(
        "nAAD", "nAAD_MIN", "nWCKEN", "nWCKIDLE"))
    timing_constraints = base_constraints(act="ACT2") + [
        # WCK sync commands must lead the column access by nWCKEN
        TimingConstraint("rank", ["CAS_RD"], ["RD"], "nWCKEN"),
        TimingConstraint("rank", ["CAS_WR"], ["WR"], "nWCKEN"),
        TimingConstraint("rank", ["CAS_RD", "CAS_WR"], ["CAS_RD", "CAS_WR"], "nWCKEN"),
    ]
    org_presets = {
        "LPDDR5_8Gb_x16": Organization(8192, 16, {"rank": 1, "bankgroup": 4, "bank": 4}, rows=1 << 15, columns=1 << 10),
        "LPDDR5_8Gb_x16_2R": Organization(8192, 16, {"rank": 2, "bankgroup": 4, "bank": 4}, rows=1 << 15, columns=1 << 10),
    }
    timing_presets = {
        "LPDDR5_6400": dict(
            tCK_ps=1250, nBL=4, nCL=15, nCWL=9, nRCD=15, nRP=15, nRAS=34,
            nRC=49, nWR=28, nRTP=8, nCCD_S=2, nCCD_L=4, nRRD_S=4, nRRD_L=4,
            nWTR_S=5, nWTR_L=8, nFAW=16, nRFC=166, nREFI=3128,
            nAAD=8, nAAD_MIN=2, nWCKEN=3, nWCKIDLE=8,
        ),
    }
