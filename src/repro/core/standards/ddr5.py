"""DDR5 — BL16, two-cycle commands folded into timings."""
from repro.core.spec import DRAMSpec, Organization, register
from repro.core.standards.common import base_commands, base_constraints, base_timing_params


@register
class DDR5(DRAMSpec):
    name = "DDR5"
    levels = ("channel", "rank", "bankgroup", "bank")
    burst_beats = 16
    command_meta = base_commands()
    commands = list(command_meta)
    timing_params = base_timing_params()
    timing_constraints = base_constraints()
    org_presets = {
        "DDR5_16Gb_x8": Organization(16384, 8, {"rank": 1, "bankgroup": 8, "bank": 4}, rows=1 << 16, columns=1 << 10),
        "DDR5_16Gb_x8_2R": Organization(16384, 8, {"rank": 2, "bankgroup": 8, "bank": 4}, rows=1 << 16, columns=1 << 10),
    }
    timing_presets = {
        "DDR5_4800B": dict(
            tCK_ps=416, nBL=8, nCL=40, nCWL=38, nRCD=40, nRP=40, nRAS=76,
            nRC=116, nWR=72, nRTP=18, nCCD_S=8, nCCD_L=12, nRRD_S=8,
            nRRD_L=12, nWTR_S=13, nWTR_L=24, nFAW=32, nRFC=984, nREFI=9360,
        ),
        "DDR5_6400AN": dict(
            tCK_ps=312, nBL=8, nCL=52, nCWL=50, nRCD=52, nRP=52, nRAS=102,
            nRC=154, nWR=96, nRTP=24, nCCD_S=8, nCCD_L=16, nRRD_S=8,
            nRRD_L=16, nWTR_S=18, nWTR_L=32, nFAW=40, nRFC=1312, nREFI=12480,
        ),
    }
