"""Shared command/constraint builders for DRAM standards.

Ramulator 2.1's LOC reduction comes from factoring the repetitive parts of a
standard (the classic JEDEC constraint set) out of each spec.  Each standard
file then only states its organization, presets, and *deviations* from the
common protocol skeleton — mirroring the paper's Python authoring layer.
"""
from __future__ import annotations

from repro.core.spec import (
    Command, TimingConstraint, KIND_ROW, KIND_COL, KIND_REF, KIND_SYNC,
    FX_OPEN, FX_CLOSE, FX_CLOSE_ALL, FX_ACT1, FX_CLOCK_ON, FX_FINAL_RD,
    FX_FINAL_WR,
)


def base_commands(refresh_level: str = "rank", split_act: bool = False,
                  clock_sync: str | None = None) -> dict:
    """The common command set.

    clock_sync: None | "wck" (LPDDR5/6 CAS_RD/CAS_WR) | "rck" (GDDR7 RCKSTRT)
    """
    cmds = {}
    if split_act:
        cmds["ACT1"] = Command("ACT1", "bank", KIND_ROW, FX_ACT1)
        cmds["ACT2"] = Command("ACT2", "bank", KIND_ROW, FX_OPEN)
    else:
        cmds["ACT"] = Command("ACT", "bank", KIND_ROW, FX_OPEN)
    cmds["PRE"] = Command("PRE", "bank", KIND_ROW, FX_CLOSE)
    cmds["PREab"] = Command("PREab", refresh_level, KIND_ROW, FX_CLOSE_ALL)
    cmds["RD"] = Command("RD", "bank", KIND_COL, FX_FINAL_RD)
    cmds["WR"] = Command("WR", "bank", KIND_COL, FX_FINAL_WR)
    cmds["REFab"] = Command("REFab", refresh_level, KIND_REF, FX_CLOSE_ALL)
    if clock_sync == "wck":
        cmds["CAS_RD"] = Command("CAS_RD", refresh_level, KIND_SYNC, FX_CLOCK_ON)
        cmds["CAS_WR"] = Command("CAS_WR", refresh_level, KIND_SYNC, FX_CLOCK_ON)
    elif clock_sync == "rck":
        cmds["RCKSTRT"] = Command("RCKSTRT", refresh_level, KIND_SYNC, FX_CLOCK_ON)
    return cmds


def base_constraints(*, act: str = "ACT", has_bankgroup: bool = True,
                     refresh_level: str = "rank") -> list:
    """The classic JEDEC timing-constraint skeleton.

    ``act`` names the row-opening command ("ACT", or "ACT2" for split
    activation where ACT1 carries the rank-level ACT-to-ACT spacing).
    Latency fields are parameter expressions resolved against the preset at
    spec-compile time (supports "+"/"-" of params and integer literals).
    """
    R = refresh_level
    opener = "ACT1" if act == "ACT2" else act   # command that *starts* an activation
    c = [
        # --- bank level ---
        TimingConstraint("bank", [act], ["RD", "WR"], "nRCD"),
        TimingConstraint("bank", [act], ["PRE"], "nRAS"),
        TimingConstraint("bank", ["PRE"], [opener], "nRP"),
        TimingConstraint("bank", [act], [opener], "nRC"),
        TimingConstraint("bank", ["RD"], ["PRE"], "nRTP"),
        TimingConstraint("bank", ["WR"], ["PRE"], "nCWL+nBL+nWR"),
        # --- refresh-unit (rank / pseudochannel) level ---
        TimingConstraint(R, [opener], [opener], "nRRD_S"),
        TimingConstraint(R, [opener], [opener], "nFAW", window=4),
        TimingConstraint(R, ["RD"], ["RD"], "nCCD_S"),
        TimingConstraint(R, ["WR"], ["WR"], "nCCD_S"),
        TimingConstraint(R, ["RD"], ["WR"], "nCL+nBL+2-nCWL", note="rd->wr turnaround"),
        TimingConstraint(R, ["WR"], ["RD"], "nCWL+nBL+nWTR_S"),
        TimingConstraint(R, ["RD"], ["PREab"], "nRTP"),
        TimingConstraint(R, ["WR"], ["PREab"], "nCWL+nBL+nWR"),
        TimingConstraint(R, [act], ["PREab"], "nRAS"),
        TimingConstraint(R, ["PREab", "PRE"], ["REFab"], "nRP"),
        TimingConstraint(R, ["REFab"], ["REFab"], "nRFC"),
        TimingConstraint(R, ["REFab"], [opener, "RD", "WR"], "nRFC"),
        TimingConstraint(R, ["PREab"], [opener], "nRP"),
        # --- channel level (shared data bus across refresh units) ---
        TimingConstraint("channel", ["RD"], ["RD"], "nBL"),
        TimingConstraint("channel", ["WR"], ["WR"], "nBL"),
        TimingConstraint("channel", ["RD"], ["WR"], "nBL"),
        TimingConstraint("channel", ["WR"], ["RD"], "nBL"),
    ]
    if has_bankgroup:
        c += [
            TimingConstraint("bankgroup", ["RD"], ["RD"], "nCCD_L"),
            TimingConstraint("bankgroup", ["WR"], ["WR"], "nCCD_L"),
            TimingConstraint("bankgroup", [opener], [opener], "nRRD_L"),
            TimingConstraint("bankgroup", ["WR"], ["RD"], "nCWL+nBL+nWTR_L"),
        ]
    if act == "ACT2":  # split activation: ACT1 -> ACT2 minimum spacing
        c += [TimingConstraint("bank", ["ACT1"], ["ACT2"], "nAAD_MIN")]
    return c


def base_timing_params(has_bankgroup: bool = True, extra: tuple = ()) -> list:
    p = ["nBL", "nCL", "nCWL", "nRCD", "nRP", "nRAS", "nRC", "nWR", "nRTP",
         "nCCD_S", "nRRD_S", "nWTR_S", "nFAW", "nRFC", "nREFI"]
    if has_bankgroup:
        p += ["nCCD_L", "nRRD_L", "nWTR_L"]
    return p + list(extra)
