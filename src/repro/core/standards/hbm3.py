"""HBM3 — dual C/A bus: parallel row/column command issue (paper §2)."""
from repro.core.spec import Organization, register
from repro.core.standards.hbm2 import HBM2


@register
class HBM3(HBM2):
    name = "HBM3"
    dual_command_bus = True
    burst_beats = 8     # BL8 on a x64 pseudo-channel
    org_presets = {
        "HBM3_16Gb": Organization(16384, 64, {"pseudochannel": 2, "bankgroup": 4, "bank": 4}, rows=1 << 14, columns=1 << 6),
    }
    timing_presets = {
        "HBM3_5200": dict(  # 5.2 Gb/s/pin
            tCK_ps=770, nBL=2, nCL=20, nCWL=6, nRCD=18, nRP=18, nRAS=42,
            nRC=60, nWR=20, nRTP=5, nCCD_S=2, nCCD_L=4, nRRD_S=4, nRRD_L=6,
            nWTR_S=7, nWTR_L=10, nFAW=16, nRFC=338, nREFI=5070,
        ),
    }
