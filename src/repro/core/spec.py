"""Python DRAM-standard authoring API (paper §3.2, Listing 1).

A DRAM standard is *plain Python data* attached to a class: the organization
hierarchy, the command set, command metadata, timing parameters, and timing
constraints.  Users extend a standard exactly as in the paper's Listing 1:

    class DDR5_VRR(DDR5):
        name = "DDR5_VRR"
        commands = DDR5.commands + ["VRR"]
        timing_params = DDR5.timing_params + ["nVRR"]
        timing_constraints = DDR5.timing_constraints + [
            TimingConstraint(level="Bank", preceding=["VRR"],
                             following=["ACT"], latency="nVRR"),
        ]

``core/compile.py`` is the code-generation step: it lowers these specs to
dense numpy tables consumed by the cycle-level JAX engine (the analogue of
Ramulator 2.1 generating C++ from the Python spec).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# ---------------------------------------------------------------------------
# Command metadata
# ---------------------------------------------------------------------------

# Command "kind" — determines which C/A bus a command occupies (paper §2:
# HBM3/4 and GDDR7 have separate row / column buses) and how the scheduler
# filters it.
KIND_ROW = 0      # ACT / PRE / PREab / ACT1 / ACT2 / VRR / RFM ...
KIND_COL = 1      # RD / WR
KIND_REF = 2      # REFab / REFpb
KIND_SYNC = 3     # CAS_RD / CAS_WR / RCKSTRT (data-clock sync, col bus)

# State effects (bitmask)
FX_NONE = 0
FX_OPEN = 1        # opens the addressed row              (ACT / ACT2)
FX_CLOSE = 2       # closes the addressed bank's row      (PRE)
FX_CLOSE_ALL = 4   # closes every row in the rank         (PREab / REFab)
FX_ACT1 = 8        # bank enters Activating state         (ACT-1)
FX_CLOCK_ON = 16   # starts the WCK/RCK data clock        (CAS_RD/CAS_WR/RCKSTRT)
FX_FINAL_RD = 32   # completes a read request             (RD)
FX_FINAL_WR = 64   # completes a write request            (WR)


@dataclasses.dataclass(frozen=True)
class Command:
    """Metadata for one DRAM command."""
    name: str
    scope: str          # hierarchy level the command addresses ("bank", "rank", ...)
    kind: int = KIND_ROW
    effects: int = FX_NONE


@dataclasses.dataclass(frozen=True)
class TimingConstraint:
    """``following`` may issue no earlier than ``latency`` cycles after the
    ``window``-th most recent ``preceding`` at the same ``level`` node.

    window=1 is the ordinary case; window=4 with preceding=[ACT] and
    latency=nFAW models the four-activate window.
    """
    level: str
    preceding: Sequence[str]
    following: Sequence[str]
    latency: str | int
    window: int = 1
    note: str = ""


@dataclasses.dataclass(frozen=True)
class Organization:
    """Counts per hierarchy level (below channel) plus geometry."""
    density_mb: int
    dq: int                      # data bus width in bits
    counts: dict                 # level name -> count, e.g. {"rank":1,"bankgroup":4,"bank":4}
    rows: int = 1 << 15
    columns: int = 1 << 10


class DRAMSpec:
    """Base class for DRAM standards.  All attributes are plain data."""

    name: str = "ABSTRACT"
    # Hierarchy below the controller; first level is always "channel".
    levels: Sequence[str] = ("channel", "rank", "bankgroup", "bank")
    commands: Sequence[str] = ()
    command_meta: dict = {}
    timing_params: Sequence[str] = ()
    timing_constraints: Sequence[TimingConstraint] = ()
    org_presets: dict = {}
    timing_presets: dict = {}     # name -> {param: cycles, "tCK_ps": ps}
    # request type -> final column command
    request_translation: dict = {"read": "RD", "write": "WR"}

    # --- protocol feature flags (paper §2) ---
    split_activation: bool = False     # LPDDR5/6 ACT-1 / ACT-2
    data_clock_sync: bool = False      # LPDDR5/6 WCK, GDDR7 RCK
    dual_command_bus: bool = False     # HBM3/4, GDDR7 parallel row+col issue
    # data-clock command names when data_clock_sync is set
    clock_sync_commands: dict = {}     # {"read": "CAS_RD", "write": "CAS_WR"}
    # burst length in command-clock cycles is timing param "nBL"

    @classmethod
    def describe(cls) -> dict:
        """Structured, human-readable summary of the standard (pure data)."""
        return {
            "name": cls.name,
            "levels": list(cls.levels),
            "commands": list(cls.commands),
            "timing_params": list(cls.timing_params),
            "n_constraints": len(cls.timing_constraints),
            "org_presets": sorted(cls.org_presets),
            "timing_presets": sorted(cls.timing_presets),
            "features": {
                "split_activation": cls.split_activation,
                "data_clock_sync": cls.data_clock_sync,
                "dual_command_bus": cls.dual_command_bus,
            },
        }


# ---------------------------------------------------------------------------
# Registry — every standard registers itself so proxies / CLIs can find it.
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(spec_cls):
    """Class decorator: register a DRAM standard by its ``name``."""
    _REGISTRY[spec_cls.name] = spec_cls
    return spec_cls


def get_standard(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown DRAM standard {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_standards() -> dict:
    return dict(_REGISTRY)
