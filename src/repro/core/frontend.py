"""Traffic-generator frontends (paper §4, improved version of [5]).

Three request sources drive the memory system:

  1. *streaming* requests at a configurable inter-arrival interval — the
     load (throughput) axis, with a configurable read ratio;
  2. *serialized random-access probe* requests — the latency axis: a probe
     is only issued after the previous probe's data returned;
  3. *trace replay* — a captured (or synthetic) linear-address stream,
     pre-decoded into per-channel request columns (:class:`ReplayStream`)
     and replayed at the streaming pace (``pattern="trace"``).

The frontend emits *linear physical addresses*: a sequential stream is a
linear request counter, decoded each cycle through the configured
``AddressMapper`` layout (``FrontendConfig.mapper``) into
(channel, sub-levels, row, col) inside the scan body — channel bits
included.  Requests route to the per-channel request queues with
per-channel backpressure (a full target queue leaves the arrival pending).

Everything is pure state-machines over int32 arrays so the whole
(frontend -> per-channel controllers -> devices) cycle is one `lax.scan`
body, and the load/read-ratio knobs are vmappable for design-space sweeps.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core.addrmap import (AddressMapper, decode_fields, make_layout,
                                make_system_layout)
from repro.core.compile import CompiledSpec


class FrontParams(NamedTuple):
    """vmappable load knobs (fixed-point by 256)."""
    interval_fp: jnp.ndarray    # inter-arrival interval in cycles * 256
    read_ratio_fp: jnp.ndarray  # P(read) * 256
    probe_gap: jnp.ndarray      # idle cycles between probes


class FrontState(NamedTuple):
    accum_fp: jnp.ndarray        # arrival accumulator (x256)
    rng: jnp.ndarray             # uint32 LCG state
    seq: jnp.ndarray             # linear request-counter / replay position
    probe_busy: jnp.ndarray      # bool — a probe is in flight
    probe_next: jnp.ndarray      # earliest clock for the next probe
    sent: jnp.ndarray            # streaming requests injected
    dropped_backpressure: jnp.ndarray
    served: jnp.ndarray          # non-probe requests served (dep tracking)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    interval: float = 4.0        # cycles between streaming arrivals
    read_ratio: float = 1.0
    probe_gap: int = 16
    probes: bool = True
    stream: bool = True
    #: streaming address pattern: ``sequential`` (linear counter decoded
    #: through ``mapper``), ``random``, or ``trace`` (replay a
    #: :class:`ReplayStream` supplied to the engine).
    pattern: str = "sequential"
    #: address-mapper order for the linear streams (see
    #: ``repro.core.addrmap.MAPPERS``).  The default rotates banks/channels
    #: fastest — the bank-interleaved, row-buffer-friendly streaming
    #: pattern of the paper's traffic generator.
    mapper: str = "RoCoBaRaCh"
    max_backlog_fp: int = 256 * 64   # accumulator cap: ≤64 queued arrivals

    def params(self) -> FrontParams:
        return FrontParams(
            interval_fp=jnp.int32(max(int(self.interval * 256), 1)),
            read_ratio_fp=jnp.int32(int(self.read_ratio * 256)),
            probe_gap=jnp.int32(self.probe_gap))


def stack_params(load_points, probe_gap: int) -> FrontParams:
    """Stack (interval, read_ratio) pairs into batched, vmappable
    `FrontParams` — the single home of the x256 fixed-point encoding used
    by `FrontendConfig.params`, `Simulator.run_batch`, and the DSE
    executor."""
    return FrontParams(
        interval_fp=jnp.asarray([max(int(i * 256), 1)
                                 for i, _ in load_points], jnp.int32),
        read_ratio_fp=jnp.asarray([int(r * 256) for _, r in load_points],
                                  jnp.int32),
        probe_gap=jnp.full((len(load_points),), probe_gap, jnp.int32))


class FrontDraft(NamedTuple):
    """One cycle's frontend-insert outcome, BEFORE the accept flags fold
    back into :class:`FrontState`.

    The insert/commit split exists for the channel-sharded engine path:
    every shard runs the same replicated frontend decode (``rng`` /
    ``accum`` / ``want`` are pure functions of the replicated state, so
    they are identical on every shard), but each shard inserts into its
    LOCAL slice of the channel axis only — ``okp``/``ok`` are therefore
    shard-local accept counts (0/1).  At most one shard owns the decoded
    channel, so a single cross-shard sum (one ``psum``) of the counts
    recovers the global accepts :func:`frontend_commit` needs.  On the
    unsharded path local == global and the wrappers below compose the
    two stages directly."""
    rng: jnp.ndarray     # uint32 LCG state after this cycle's draws
    accum: jnp.ndarray   # arrival accumulator after refill/clamp,
    #                      before the accepted-arrival decrement
    want: jnp.ndarray    # bool — a stream insert was attempted
    okp: jnp.ndarray     # int32 — locally accepted probes (0/1)
    ok: jnp.ndarray      # int32 — locally accepted stream requests (0/1)


def init_front(seed: int = 0x1234) -> FrontState:
    return FrontState(accum_fp=jnp.int32(0), rng=jnp.uint32(seed | 1),
                      seq=jnp.int32(0), probe_busy=jnp.asarray(False),
                      probe_next=jnp.int32(0), sent=jnp.int32(0),
                      dropped_backpressure=jnp.int32(0),
                      served=jnp.int32(0))


# --------------------------------------------------------------------------
# Trace-driven replay source
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class ReplayStream:
    """Pre-decoded replay request columns for the trace-driven frontend.

    Columns are host-side numpy int32 arrays of equal length N: target
    ``chan``, per-channel ``sub`` level indices ``(N, L-1)``, ``row``,
    ``col``, and ``is_write``.  For heterogeneous systems ``chan`` is the
    *system* channel id and ``sub`` is padded to the widest group's
    sub-level count.  ``arrive`` (optional) carries the captured arrival
    clock of each request: when present, replay honors the captured
    inter-arrival gaps instead of the streaming interval — the deltas
    (and, on wrap-around, the stream's span) pace the injection, so a
    capture→replay round trip preserves the traffic's time structure.
    ``dep`` (optional) carries same-address RAW/WAR dependencies: request
    ``k`` with ``dep[k] = j >= 0`` has producer ``j`` (always earlier in
    the stream) and is held at the frontend until every earlier stream
    request — its producer included — has been served (a conservative
    release that stays sound under FR-FCFS reordering), so replayed
    requests respect read-after-write / write-after-read ordering instead
    of arriving independently.  The engine closes over
    the columns as constants; ``fingerprint`` (a digest of the columns,
    ``arrive``/``dep`` included when present) keys the compile cache so
    two different streams never alias one compiled program.
    """
    chan: np.ndarray
    sub: np.ndarray
    row: np.ndarray
    col: np.ndarray
    is_write: np.ndarray
    arrive: np.ndarray | None = None
    fingerprint: str = ""
    dep: np.ndarray | None = None

    def __post_init__(self):
        if not self.fingerprint:
            h = hashlib.sha256()
            cols = (self.chan, self.sub, self.row, self.col, self.is_write)
            if self.arrive is not None:
                cols = cols + (self.arrive,)
            if self.dep is not None:
                cols = cols + (self.dep,)
            for a in cols:
                h.update(np.ascontiguousarray(a, np.int32).tobytes())
            object.__setattr__(self, "fingerprint", h.hexdigest()[:16])

    def __len__(self) -> int:
        return int(self.chan.shape[0])

    @classmethod
    def from_addresses(cls, spec, addrs, is_write=None,
                       order: str = "RoBaRaCoCh") -> "ReplayStream":
        """Decode a linear byte-address stream through ``order``.

        ``spec`` may be a :class:`repro.core.compile.CompiledSpec`
        (homogeneous system) or a
        :class:`repro.core.compile.MemorySystemSpec` — heterogeneous
        streams decode through the system-level channel digit
        (:class:`repro.core.addrmap.SystemAddressMapper`)."""
        from repro.core.addrmap import SystemAddressMapper
        from repro.core.compile import MemorySystemSpec
        if isinstance(spec, MemorySystemSpec):
            mapper = SystemAddressMapper(spec, order)
            chan, sub, row, col = mapper.to_chan_sub_row_col(
                np.asarray(addrs, np.int64))
        else:
            chan, sub, row, col = AddressMapper(
                spec, order).to_chan_sub_row_col(np.asarray(addrs, np.int64))
        n = len(chan)
        wr = np.zeros(n, np.int32) if is_write is None \
            else np.asarray(is_write, np.int32)
        i32 = lambda a: np.ascontiguousarray(a, np.int32)
        return cls(chan=i32(chan), sub=i32(sub), row=i32(row), col=i32(col),
                   is_write=i32(wr))


# --------------------------------------------------------------------------
# Address generation: linear streams decoded through the mapper layout
# --------------------------------------------------------------------------


def _lcg(rng):
    return rng * jnp.uint32(1664525) + jnp.uint32(1013904223)


def _replay_want(want, replay, fs: FrontState, seq, idx, clk, n):
    """Injection gate for the trace-driven stream source — the ONE home of
    the pacing + dependency-hold rules (shared by the single-spec and
    system frontends).

    * Arrive pacing: when the stream carries captured ``arrive`` clocks,
      request k is due at its captured arrival clock rebased to the
      stream start (wrapped laps repeat the gap pattern shifted by the
      stream's span) — this REPLACES the interval-accumulator gate.
    * Dependency hold (``dep`` column): a request with a RAW/WAR producer
      is additionally held until every earlier stream request has been
      served (``fs.served >= seq``, the absolute injection position).
      Injection is sequential, so this prefix-served condition implies
      the producer itself was served — a conservative release that stays
      sound under FR-FCFS reordering of the in-flight window.
    """
    if replay.arrive is not None:
        # ``arrive`` is host-side numpy, so the pacing scalars are static
        arr_np = np.asarray(replay.arrive)
        base = int(arr_np[0])
        span = int(arr_np[-1]) - base
        gap = max(span // max(int(n) - 1, 1), 1)
        arr = jnp.asarray(arr_np - base, jnp.int32)
        lap = seq // jnp.int32(n)
        want = clk >= arr[idx] + lap * jnp.int32(span + gap)
    if replay.dep is not None:
        prod = replay.dep[idx]
        want = want & ((prod < 0) | (fs.served >= seq))
    return want


def _pack_fields(cspec: CompiledSpec, fields: dict):
    chan = fields.get("channel", jnp.int32(0))
    sub = jnp.stack([fields.get(lv, jnp.int32(0))
                     for lv in cspec.levels[1:]])
    return chan, sub, fields["row"], fields["col"]


def _seq_addr(cspec: CompiledSpec, layout, seq):
    """Decode the linear request counter through the mapper layout —
    the exact same ``decode_fields`` the host-side ``AddressMapper.map``
    uses, just running traced inside the scan body."""
    return _pack_fields(cspec, decode_fields(layout, seq))


def _rand_addr(cspec: CompiledSpec, layout, rng):
    """Draw one random value per layout field (channel included)."""
    fields = {}
    r = rng
    for name, count in layout:
        r = _lcg(r)
        fields[name] = ((r >> jnp.uint32(8)).astype(jnp.int32)
                        % jnp.int32(count))
    chan, sub, row, col = _pack_fields(cspec, fields)
    return chan, sub, row, col, r


# --------------------------------------------------------------------------
# Per-channel routing
# --------------------------------------------------------------------------


def route_insert(queues: C.Queue, chan, is_write, is_probe, sub, row, col,
                 arrive, want, chan_base=0):
    """Insert one request into its target channel's queue.

    ``queues`` leaves carry a leading channel axis ``(C, Q)``; the insert
    is vmapped across channels with ``want`` gated on the channel match,
    so exactly one channel (the decoded one) can accept.  Returns
    ``(queues', ok)`` — ``ok`` False means the target channel's queue was
    full (per-channel backpressure).  ``chan_base`` is the global id of
    queue row 0: on the channel-sharded engine path each shard holds a
    contiguous slice of the channel axis, and the decoded ``chan`` is
    always a GLOBAL id — at most one shard (the owner's) can accept, so
    ``ok`` is then the shard-local accept that a cross-shard sum turns
    into the global one."""
    n_channels = queues.valid.shape[0]

    def one(q, c):
        return C.queue_insert(q, is_write, is_probe, sub, row, col, arrive,
                              want & (chan == c))

    ids = jnp.arange(n_channels, dtype=jnp.int32) + jnp.int32(chan_base)
    queues, oks = jax.vmap(one)(queues, ids)
    return queues, jnp.any(oks)


def paced_by_arrive(cfg: FrontendConfig, replay) -> bool:
    """True when replay pacing (captured ``arrive`` clocks) replaces the
    interval accumulator — a STATIC property of (config, stream)."""
    return (cfg.stream and cfg.pattern == "trace" and replay is not None
            and replay.arrive is not None)


def frontend_insert(cspec: CompiledSpec, cfg: FrontendConfig,
                    fp: FrontParams, fs: FrontState, queues: C.Queue, clk,
                    layout=None, replay=None, chan_base=0):
    """Decode + insert up to one probe and one streaming/replay request
    into ``queues`` this cycle, WITHOUT touching ``fs`` — the accept
    flags come back in a :class:`FrontDraft` for :func:`frontend_commit`.

    Probes insert first so a saturated streaming load cannot starve the
    latency measurement out of the queues entirely.  ``layout`` is the
    static mapper layout (defaults to ``cfg.mapper``'s); ``replay`` is the
    jnp-column :class:`ReplayStream` required by ``pattern="trace"``.
    ``chan_base`` is the global channel id of queue row 0 (non-zero only
    on the channel-sharded path).
    """
    if layout is None:
        layout = make_layout(cspec, cfg.mapper)
    rng = fs.rng
    accum = fs.accum_fp
    seq = fs.seq
    okp = jnp.int32(0)
    ok = jnp.int32(0)
    want = jnp.asarray(False)

    if cfg.probes:
        want_p = (~fs.probe_busy) & (clk >= fs.probe_next)
        chan, sub, row, col, rng = _rand_addr(cspec, layout, rng)
        queues, okp_b = route_insert(queues, chan, jnp.asarray(False),
                                     jnp.asarray(True), sub, row, col, clk,
                                     want_p, chan_base)
        okp = okp_b.astype(jnp.int32)

    if cfg.stream:
        if cfg.pattern == "trace" and replay is None:
            raise ValueError('pattern="trace" needs a ReplayStream '
                             "(Simulator(..., replay=...))")
        accum = jnp.minimum(accum + jnp.int32(256),
                            jnp.int32(cfg.max_backlog_fp))
        want = accum >= fp.interval_fp
        if cfg.pattern == "trace":
            n = replay.chan.shape[0]
            idx = seq % jnp.int32(n)
            chan, sub = replay.chan[idx], replay.sub[idx]
            row, col = replay.row[idx], replay.col[idx]
            is_write = replay.is_write[idx] != 0
            want = _replay_want(want, replay, fs, seq, idx, clk, n)
        else:
            if cfg.pattern == "sequential":
                chan, sub, row, col = _seq_addr(cspec, layout, seq)
            else:
                chan, sub, row, col, rng = _rand_addr(cspec, layout, rng)
            rng = _lcg(rng)
            is_write = ((rng >> jnp.uint32(9)).astype(jnp.int32) % 256
                        ) >= fp.read_ratio_fp
        queues, ok_b = route_insert(queues, chan, is_write,
                                    jnp.asarray(False), sub, row, col, clk,
                                    want, chan_base)
        ok = ok_b.astype(jnp.int32)

    return queues, FrontDraft(rng=rng, accum=accum, want=want, okp=okp,
                              ok=ok)


def frontend_commit(cfg: FrontendConfig, fp: FrontParams, fs: FrontState,
                    draft: FrontDraft, okp_total, ok_total,
                    paced: bool = False) -> FrontState:
    """Fold GLOBAL accept counts (int32, ``psum`` of the shards' draft
    counts — or the draft's own on the unsharded path) into the
    replicated :class:`FrontState`.  ``paced`` is
    :func:`paced_by_arrive`'s static verdict."""
    probe_busy = fs.probe_busy
    if cfg.probes:
        probe_busy = probe_busy | (okp_total > 0)
    accum = draft.accum
    seq, sent = fs.seq, fs.sent
    dropped = fs.dropped_backpressure
    if cfg.stream:
        okb = ok_total > 0
        if not paced:
            accum = jnp.where(okb, accum - fp.interval_fp, accum)
        seq = seq + okb.astype(jnp.int32)
        sent = sent + okb.astype(jnp.int32)
        dropped = dropped + (draft.want & ~okb).astype(jnp.int32)
    return FrontState(accum_fp=accum, rng=draft.rng, seq=seq,
                      probe_busy=probe_busy, probe_next=fs.probe_next,
                      sent=sent, dropped_backpressure=dropped,
                      served=fs.served)


def frontend_step(cspec: CompiledSpec, cfg: FrontendConfig, fp: FrontParams,
                  fs: FrontState, queues: C.Queue, clk, layout=None,
                  replay=None):
    """Single-device composition of :func:`frontend_insert` +
    :func:`frontend_commit` (local accepts ARE the global accepts)."""
    queues, draft = frontend_insert(cspec, cfg, fp, fs, queues, clk,
                                    layout, replay)
    return queues, frontend_commit(cfg, fp, fs, draft, draft.okp, draft.ok,
                                   paced_by_arrive(cfg, replay))


# --------------------------------------------------------------------------
# System-level frontend: one mapper routing across heterogeneous spec groups
# --------------------------------------------------------------------------


def _group_pack(cspec: CompiledSpec, fields: dict):
    """(sub, row, col) of one group's decoded fields (no channel digit)."""
    sub = jnp.stack([jnp.asarray(fields.get(lv, jnp.int32(0)), jnp.int32)
                     for lv in cspec.levels[1:]])
    return sub, fields["row"], fields["col"]


def _seq_addr_system(msys, sublayouts, seq):
    """Sequential pattern across a heterogeneous system: the linear
    request counter's system channel digit is least significant; the
    remainder decodes through every group's own layout (the router then
    picks the owning group's decode)."""
    chan = seq % jnp.int32(msys.n_channels)
    q = seq // jnp.int32(msys.n_channels)
    per_group = []
    for grp, lay in zip(msys.groups, sublayouts):
        per_group.append(_group_pack(grp.cspec, decode_fields(lay, q)))
    return chan, per_group


def _rand_addr_system(msys, sublayouts, rng):
    """Random pattern across a heterogeneous system.

    One LCG draw picks the system channel; then one draw per field *slot*
    (the widest group's field count) feeds every group's fields — the rng
    therefore advances by a static amount per request, independent of
    which group ends up owning the address."""
    r = _lcg(rng)
    chan = ((r >> jnp.uint32(8)).astype(jnp.int32)
            % jnp.int32(msys.n_channels))
    n_slots = max(len(lay) for lay in sublayouts)
    draws = []
    for _ in range(n_slots):
        r = _lcg(r)
        draws.append((r >> jnp.uint32(8)).astype(jnp.int32))
    per_group = []
    for grp, lay in zip(msys.groups, sublayouts):
        fields = {name: draws[i] % jnp.int32(count)
                  for i, (name, count) in enumerate(lay)}
        per_group.append(_group_pack(grp.cspec, fields))
    return chan, per_group, r


def _system_route(msys, queues: tuple, chan, is_write, is_probe, per_group,
                  clk, want, bases=None):
    """Insert one request into the owning group's owning channel.

    ``queues`` is the per-group tuple of channel-stacked queues; ``chan``
    is the system channel id.  Exactly one (group, local channel) can
    accept; a full target queue refuses (per-channel backpressure).
    ``bases`` (channel-sharded path) gives the system channel id of each
    group's queue row 0 — each shard holds a contiguous slice of every
    group's channels, so its queue tuples are narrower than the groups
    and sit at shard-dependent offsets; default is the unsharded
    cumulative group layout."""
    new_q, oks = [], []
    base_full = 0
    for g, (grp, q_g, (sub, row, col)) in enumerate(
            zip(msys.groups, queues, per_group)):
        local_n = q_g.valid.shape[0]
        base = jnp.int32(base_full) if bases is None else bases[g]
        in_g = (chan >= base) & (chan < base + jnp.int32(local_n))
        local = jnp.clip(chan - base, 0, local_n - 1)
        q_g, ok = route_insert(q_g, local, is_write, is_probe, sub, row,
                               col, clk, want & in_g)
        new_q.append(q_g)
        oks.append(ok)
        base_full += grp.channels
    return tuple(new_q), jnp.any(jnp.stack(oks))


def system_frontend_insert(msys, cfg: FrontendConfig, fp: FrontParams,
                           fs: FrontState, queues: tuple, clk, sys_layout,
                           replay=None, bases=None):
    """Multi-group twin of :func:`frontend_insert`.

    ``queues`` is a per-group tuple (each leaf channel-stacked ``(C_g,
    Q)``); ``sys_layout`` is :func:`repro.core.addrmap.make_system_layout`
    output; ``bases`` gives each group's queue-row-0 system channel id on
    the channel-sharded path (see :func:`_system_route`).  1-group
    systems delegate to :func:`frontend_insert` verbatim, so the
    homogeneous path's traced program is untouched.
    """
    if sys_layout[0] == "single":
        q0, draft = frontend_insert(
            msys.groups[0].cspec, cfg, fp, fs, queues[0], clk,
            sys_layout[1], replay,
            chan_base=0 if bases is None else bases[0])
        return (q0,), draft
    _, _n_channels, _bases, sublayouts = sys_layout
    rng = fs.rng
    accum = fs.accum_fp
    seq = fs.seq
    okp = jnp.int32(0)
    ok = jnp.int32(0)
    want = jnp.asarray(False)

    if cfg.probes:
        want_p = (~fs.probe_busy) & (clk >= fs.probe_next)
        chan, per_group, rng = _rand_addr_system(msys, sublayouts, rng)
        queues, okp_b = _system_route(msys, queues, chan,
                                      jnp.asarray(False),
                                      jnp.asarray(True), per_group, clk,
                                      want_p, bases)
        okp = okp_b.astype(jnp.int32)

    if cfg.stream:
        if cfg.pattern == "trace" and replay is None:
            raise ValueError('pattern="trace" needs a ReplayStream '
                             "(Simulator(..., replay=...))")
        accum = jnp.minimum(accum + jnp.int32(256),
                            jnp.int32(cfg.max_backlog_fp))
        want = accum >= fp.interval_fp
        if cfg.pattern == "trace":
            n = replay.chan.shape[0]
            idx = seq % jnp.int32(n)
            chan = replay.chan[idx]
            row, col = replay.row[idx], replay.col[idx]
            sub_all = replay.sub[idx]          # padded to the widest group
            per_group = []
            for grp in msys.groups:
                n_sub = len(grp.cspec.levels) - 1
                per_group.append((sub_all[:n_sub], row, col))
            is_write = replay.is_write[idx] != 0
            want = _replay_want(want, replay, fs, seq, idx, clk, n)
        else:
            if cfg.pattern == "sequential":
                chan, per_group = _seq_addr_system(msys, sublayouts, seq)
            else:
                chan, per_group, rng = _rand_addr_system(msys, sublayouts,
                                                         rng)
            rng = _lcg(rng)
            is_write = ((rng >> jnp.uint32(9)).astype(jnp.int32) % 256
                        ) >= fp.read_ratio_fp
        queues, ok_b = _system_route(msys, queues, chan, is_write,
                                     jnp.asarray(False), per_group, clk,
                                     want, bases)
        ok = ok_b.astype(jnp.int32)

    return queues, FrontDraft(rng=rng, accum=accum, want=want, okp=okp,
                              ok=ok)


def system_frontend_step(msys, cfg: FrontendConfig, fp: FrontParams,
                         fs: FrontState, queues: tuple, clk, sys_layout,
                         replay=None):
    """Multi-group twin of :func:`frontend_step` (insert + commit with
    local accepts standing in for the global ones)."""
    queues, draft = system_frontend_insert(msys, cfg, fp, fs, queues, clk,
                                           sys_layout, replay)
    return queues, frontend_commit(cfg, fp, fs, draft, draft.okp, draft.ok,
                                   paced_by_arrive(cfg, replay))


# --------------------------------------------------------------------------
# Event-horizon helpers (the engine's fast-forward path)
# --------------------------------------------------------------------------
#
# The fast-forward engine advances the whole simulation state across runs
# of provably idle cycles in one step (docs/architecture.md "Performance
# model").  The frontend's contributions: the earliest cycle at which it
# could next *attempt* an insert (`arrival_horizon`), and the closed forms
# of the only two pieces of frontend state that change on an idle cycle —
# the arrival accumulator's clamped refill and the LCG's fixed number of
# draws per cycle (`rng_draws_per_cycle` + `lcg_jump`).

#: Horizon sentinel — far beyond any reachable cycle count, small enough
#: that int32 comparisons never overflow.
HORIZON_MAX = jnp.int32(1 << 30)


def rng_draws_per_cycle(cfg: FrontendConfig, sys_layout) -> int:
    """STATIC number of LCG draws :func:`frontend_insert` /
    :func:`system_frontend_insert` performs per cycle.

    The draws are unconditional (they happen whether or not the decoded
    request is wanted or accepted), so an idle cycle advances the rng by
    exactly this count — which is what lets :func:`lcg_jump` replay a run
    of skipped cycles in closed form, bit-exactly."""
    if sys_layout[0] == "single":
        n_fields = len(sys_layout[1])
        probe_draws = n_fields
        stream_draws = {"sequential": 1, "random": n_fields + 1,
                        "trace": 0}[cfg.pattern]
    else:
        sublayouts = sys_layout[3]
        n_slots = max(len(lay) for lay in sublayouts)
        probe_draws = 1 + n_slots
        stream_draws = {"sequential": 1, "random": 1 + n_slots + 1,
                        "trace": 0}[cfg.pattern]
    draws = 0
    if cfg.probes:
        draws += probe_draws
    if cfg.stream:
        draws += stream_draws
    return draws


def lcg_affine(k: int) -> tuple:
    """Host-side ``(a, c)`` of :func:`_lcg` composed ``k`` times
    (mod 2**32): one cycle's worth of rng advance as a single affine
    map ``x -> a*x + c``."""
    a, c = 1, 0
    for _ in range(k):
        a, c = (1664525 * a) % (1 << 32), \
               (1664525 * c + 1013904223) % (1 << 32)
    return a, c


def lcg_jump(rng, d, a_cycle: int, c_cycle: int):
    """Advance ``rng`` by ``d`` cycles (traced, ``d >= 0``) of the
    per-cycle affine ``x -> a_cycle*x + c_cycle`` via binary
    exponentiation over the 32 bits of ``d`` — the closed form of ``d``
    consecutive idle-cycle rng advances (powers of one affine map
    commute, so the fold order is immaterial)."""
    ra, rc = jnp.uint32(1), jnp.uint32(0)
    pa, pc = jnp.uint32(a_cycle), jnp.uint32(c_cycle)
    du = d.astype(jnp.uint32)
    for i in range(32):
        take = ((du >> jnp.uint32(i)) & jnp.uint32(1)) != jnp.uint32(0)
        ra = jnp.where(take, pa * ra, ra)
        rc = jnp.where(take, pa * rc + pc, rc)
        pa, pc = pa * pa, pa * pc + pc
    return ra * rng + rc


def idle_advance(cfg: FrontendConfig, fs: FrontState, d, a_cycle: int,
                 c_cycle: int, k_draws: int) -> FrontState:
    """Apply ``d`` idle cycles' worth of frontend state change in one
    step.  On a cycle with no insert attempt and no completion, the ONLY
    frontend state that moves is the clamped accumulator refill and the
    rng's ``k_draws`` unconditional draws — both closed-formable:
    iterating ``a' = min(a + 256, cap)`` ``d`` times equals one clamped
    add of ``256*d`` (the clamp commutes with a constant positive
    addend), and the rng jump is :func:`lcg_jump`."""
    if cfg.stream:
        fs = fs._replace(accum_fp=jnp.minimum(
            fs.accum_fp + jnp.int32(256) * d,
            jnp.int32(cfg.max_backlog_fp)))
    if k_draws:
        fs = fs._replace(rng=lcg_jump(fs.rng, d, a_cycle, c_cycle))
    return fs


def arrival_horizon(cfg: FrontendConfig, fp: FrontParams, fs: FrontState,
                    cur, replay=None, paced: bool = False):
    """Earliest cycle ``>= cur`` at which the frontend could next attempt
    an insert, assuming no intervening completions.  CONSERVATIVE — never
    later than the true next attempt (undershooting merely executes an
    idle cycle, which is always correct):

    * probe: the serialized prober attempts at ``max(probe_next, cur)``
      once not busy; while busy it can only unblock via a command issue,
      which the controller horizon accounts for;
    * stream (interval-accumulator gate, replay dep-holds ignored):
      ``want`` first fires at the ``j``-th cycle from ``cur`` with
      ``min(accum + 256*(j+1), cap) >= interval`` — never, if the cap
      can't reach the interval;
    * paced replay (captured ``arrive`` clocks): request ``seq`` is due
      at its rebased arrival clock plus the wrap-lap offset — the exact
      :func:`_replay_want` gate."""
    h = HORIZON_MAX
    if cfg.probes:
        h = jnp.minimum(h, jnp.where(fs.probe_busy, HORIZON_MAX,
                                     jnp.maximum(fs.probe_next, cur)))
    if cfg.stream:
        if paced:
            arr_np = np.asarray(replay.arrive)
            n = int(replay.chan.shape[0])
            base = int(arr_np[0])
            span = int(arr_np[-1]) - base
            gap = max(span // max(n - 1, 1), 1)
            arr = jnp.asarray(arr_np - base, jnp.int32)
            idx = fs.seq % jnp.int32(n)
            lap = fs.seq // jnp.int32(n)
            hs = jnp.maximum(arr[idx] + lap * jnp.int32(span + gap), cur)
        else:
            need = fp.interval_fp - fs.accum_fp
            j = jnp.maximum(
                (need + jnp.int32(255)) // jnp.int32(256) - jnp.int32(1),
                jnp.int32(0))
            hs = jnp.where(fp.interval_fp > jnp.int32(cfg.max_backlog_fp),
                           HORIZON_MAX, cur + j)
        h = jnp.minimum(h, hs)
    return h


def absorb_locals(events: C.StepEvents) -> jnp.ndarray:
    """Reduce one group's completion events over its (local) channels to
    the ``(3,) int32`` vector ``[probes_done, requests_served,
    probe_completion]`` that :func:`frontend_finish` consumes.

    ``probe_completion`` is summed rather than maxed: the controller
    zeroes it on channels that did not serve a probe, and at most one
    probe is in flight system-wide, so at most one entry — across all
    channels, groups, AND shards — is non-zero and the sum equals the
    max.  Summing is what lets every cross-channel reduction of a cycle
    ride a single fused ``psum`` on the sharded path."""
    done = jnp.sum(events.served_probe.astype(jnp.int32))
    served = (jnp.sum((events.served_read & ~events.served_probe)
                      .astype(jnp.int32))
              + jnp.sum(events.served_write.astype(jnp.int32)))
    completion = jnp.sum(events.probe_completion)
    return jnp.stack([done, served, completion])


def frontend_finish(fs: FrontState, fp: FrontParams, done_total,
                    served_total, completion_total) -> FrontState:
    """Fold the GLOBAL absorb vector (summed over groups — and shards,
    on the sharded path) into the replicated :class:`FrontState`: closes
    the probe loop and advances the served-request counter the replay
    dependency hold reads."""
    done = done_total > 0
    return fs._replace(
        probe_busy=jnp.where(done, False, fs.probe_busy),
        probe_next=jnp.where(done, completion_total + fp.probe_gap,
                             fs.probe_next),
        served=fs.served + served_total)


def frontend_absorb(fs: FrontState, fp: FrontParams,
                    events: C.StepEvents) -> FrontState:
    """Consume completion events (closes the probe loop and advances the
    served-request counter the replay dependency hold reads).  Works on
    both single-channel (scalar) and channel-stacked ``(C,)`` events: at
    most one channel can complete the single in-flight probe.  For a
    multi-group system the engine folds this once per spec group."""
    v = absorb_locals(events)
    return frontend_finish(fs, fp, v[0], v[1], v[2])
