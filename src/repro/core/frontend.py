"""Traffic-generator frontends (paper §4, improved version of [5]).

Two request sources drive the latency-throughput evaluation:

  1. *streaming* requests at a configurable inter-arrival interval — the
     load (throughput) axis, with a configurable read ratio;
  2. *serialized random-access probe* requests — the latency axis: a probe
     is only issued after the previous probe's data returned.

Both are implemented as pure state-machines over int32 arrays so the whole
(frontend + controller + device) cycle is one `lax.scan` body, and the
load/read-ratio knobs are vmappable for design-space sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import controller as C
from repro.core.compile import CompiledSpec


class FrontParams(NamedTuple):
    """vmappable load knobs (fixed-point by 256)."""
    interval_fp: jnp.ndarray    # inter-arrival interval in cycles * 256
    read_ratio_fp: jnp.ndarray  # P(read) * 256
    probe_gap: jnp.ndarray      # idle cycles between probes


class FrontState(NamedTuple):
    accum_fp: jnp.ndarray        # arrival accumulator (x256)
    rng: jnp.ndarray             # uint32 LCG state
    seq: jnp.ndarray             # sequential-stream position counter
    probe_busy: jnp.ndarray      # bool — a probe is in flight
    probe_next: jnp.ndarray      # earliest clock for the next probe
    sent: jnp.ndarray            # streaming requests injected
    dropped_backpressure: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    interval: float = 4.0        # cycles between streaming arrivals
    read_ratio: float = 1.0
    probe_gap: int = 16
    probes: bool = True
    stream: bool = True
    pattern: str = "sequential"  # streaming address pattern: sequential|random
    max_backlog_fp: int = 256 * 64   # accumulator cap: ≤64 queued arrivals

    def params(self) -> FrontParams:
        return FrontParams(
            interval_fp=jnp.int32(max(int(self.interval * 256), 1)),
            read_ratio_fp=jnp.int32(int(self.read_ratio * 256)),
            probe_gap=jnp.int32(self.probe_gap))


def stack_params(load_points, probe_gap: int) -> FrontParams:
    """Stack (interval, read_ratio) pairs into batched, vmappable
    `FrontParams` — the single home of the x256 fixed-point encoding used
    by `FrontendConfig.params`, `Simulator.run_batch`, and the DSE
    executor."""
    return FrontParams(
        interval_fp=jnp.asarray([max(int(i * 256), 1)
                                 for i, _ in load_points], jnp.int32),
        read_ratio_fp=jnp.asarray([int(r * 256) for _, r in load_points],
                                  jnp.int32),
        probe_gap=jnp.full((len(load_points),), probe_gap, jnp.int32))


def init_front(seed: int = 0x1234) -> FrontState:
    return FrontState(accum_fp=jnp.int32(0), rng=jnp.uint32(seed | 1),
                      seq=jnp.int32(0), probe_busy=jnp.asarray(False),
                      probe_next=jnp.int32(0), sent=jnp.int32(0),
                      dropped_backpressure=jnp.int32(0))


def _lcg(rng):
    return rng * jnp.uint32(1664525) + jnp.uint32(1013904223)


def _rand_addr(cspec: CompiledSpec, rng):
    """Split one 32-bit random draw into (sub-levels, row, col)."""
    counts = cspec.level_counts
    subs = []
    r = rng
    for i in range(1, len(counts)):
        r = _lcg(r)
        subs.append((r >> jnp.uint32(8)).astype(jnp.int32)
                    % jnp.int32(int(counts[i])))
    r = _lcg(r)
    row = (r >> jnp.uint32(8)).astype(jnp.int32) % jnp.int32(cspec.rows)
    r = _lcg(r)
    col = (r >> jnp.uint32(8)).astype(jnp.int32) % jnp.int32(cspec.columns)
    return jnp.stack(subs), row, col, r


def _seq_addr(cspec: CompiledSpec, seq):
    """Bank-interleaved sequential walk: successive requests rotate across
    banks; within a bank, columns advance before the row does — the
    row-buffer-friendly streaming pattern of the paper's traffic generator."""
    counts = cspec.level_counts
    subs = []
    q = seq
    for i in range(len(counts) - 1, 0, -1):
        subs.append(q % jnp.int32(int(counts[i])))
        q = q // jnp.int32(int(counts[i]))
    subs = subs[::-1]          # back to (rank, ..., bank) order
    col = q % jnp.int32(cspec.columns)
    row = (q // jnp.int32(cspec.columns)) % jnp.int32(cspec.rows)
    return jnp.stack(subs), row, col


def frontend_step(cspec: CompiledSpec, cfg: FrontendConfig, fp: FrontParams,
                  fs: FrontState, queue: C.Queue, clk):
    """Inject up to one probe and one streaming request this cycle.

    Probes insert first so a saturated streaming load cannot starve the
    latency measurement out of the queue entirely.
    """
    rng = fs.rng
    accum = fs.accum_fp
    sent = fs.sent
    seq = fs.seq
    dropped = fs.dropped_backpressure

    if cfg.probes:
        want_p = (~fs.probe_busy) & (clk >= fs.probe_next)
        sub, row, col, rng = _rand_addr(cspec, rng)
        queue, okp = C.queue_insert(queue, jnp.asarray(False),
                                    jnp.asarray(True), sub, row, col, clk,
                                    want_p)
        probe_busy = fs.probe_busy | okp
    else:
        probe_busy = fs.probe_busy

    if cfg.stream:
        accum = jnp.minimum(accum + jnp.int32(256),
                            jnp.int32(cfg.max_backlog_fp))
        want = accum >= fp.interval_fp
        if cfg.pattern == "sequential":
            sub, row, col = _seq_addr(cspec, seq)
        else:
            sub, row, col, rng = _rand_addr(cspec, rng)
        rng = _lcg(rng)
        is_write = ((rng >> jnp.uint32(9)).astype(jnp.int32) % 256
                    ) >= fp.read_ratio_fp
        queue, ok = C.queue_insert(queue, is_write, jnp.asarray(False),
                                   sub, row, col, clk, want)
        accum = jnp.where(ok, accum - fp.interval_fp, accum)
        seq = seq + ok.astype(jnp.int32)
        sent = sent + ok.astype(jnp.int32)
        dropped = dropped + (want & ~ok).astype(jnp.int32)

    return queue, FrontState(accum_fp=accum, rng=rng, seq=seq,
                             probe_busy=probe_busy,
                             probe_next=fs.probe_next, sent=sent,
                             dropped_backpressure=dropped)


def frontend_absorb(fs: FrontState, fp: FrontParams,
                    events: C.StepEvents) -> FrontState:
    """Consume completion events (closes the probe loop)."""
    done = events.served_probe
    return fs._replace(
        probe_busy=jnp.where(done, False, fs.probe_busy),
        probe_next=jnp.where(done, events.probe_completion + fp.probe_gap,
                             fs.probe_next))
