"""Spec "code generation": lower a Python DRAM spec to dense numpy tables.

This is the analogue of Ramulator 2.1's generator that turns Python DRAM
specifications into low-level C++ — here the low-level target is the array
program consumed by the cycle-level JAX engine:

  * a constraint table  (prev_cmd, next_cmd, level, latency, window)
  * per-command metadata vectors (kind, scope level, effect bitmask)
  * hierarchy-node indexing (flattened channel/rank/bankgroup/bank tree)
  * resolved timing preset (latency *expressions* -> cycles)

Everything here is plain numpy; the engine wraps these in jnp arrays.
"""
from __future__ import annotations

import dataclasses
import os
import re

import numpy as np

from repro.core import spec as S

_TOKEN = re.compile(r"([+-]?)\s*([A-Za-z_][A-Za-z_0-9]*|\d+)")


def resolve_latency(expr, timings: dict, context: str = "") -> int:
    """Resolve a latency expression ("nCWL+nBL+nWR", "nBL+2", 7) to cycles.

    ``context`` (e.g. "DDR5 constraint ACT->RD@bank") is prepended to
    error messages so DSL-authored specs fail legibly."""
    if isinstance(expr, int):
        return expr
    where = f"{context}: " if context else ""
    total, matched = 0, 0
    for sign, tok in _TOKEN.findall(expr):
        matched += 1
        if tok.isdigit():
            val = int(tok)
        elif tok in timings:
            val = timings[tok]
        else:
            raise ValueError(
                f"{where}latency expression {expr!r} references unknown "
                f"timing parameter {tok!r} (known: {sorted(timings)})")
        total += -val if sign == "-" else val
    if matched == 0:
        raise ValueError(f"{where}bad latency expression {expr!r}")
    return total


@dataclasses.dataclass
class CompiledSpec:
    """Dense-table form of one (standard, org preset, timing preset).

    The node tables describe ONE channel; a multi-channel memory system
    replicates the whole controller+device state along a leading channel
    axis (``n_channels``) and runs it under ``jax.vmap`` inside the
    engine's cycle scan.  ``level_counts[0]`` therefore stays 1 — the
    per-channel hierarchy — while ``n_channels`` carries the system-level
    channel fan-out consumed by the address mapper and the engine.
    """
    name: str
    levels: list                    # level names, levels[0] == "channel"
    level_counts: np.ndarray        # per-level fan-out within one channel
    level_offsets: np.ndarray       # node-index base per level
    num_nodes: int
    n_banks: int
    n_refresh_units: int            # ranks / pseudochannels
    rows: int
    columns: int

    cmd_names: list
    n_cmds: int
    cmd_kind: np.ndarray            # KIND_* per command
    cmd_scope: np.ndarray           # level index per command
    cmd_fx: np.ndarray              # FX_* bitmask per command

    # constraint table
    ct_prev: np.ndarray
    ct_next: np.ndarray
    ct_level: np.ndarray
    ct_lat: np.ndarray
    ct_win: np.ndarray
    max_window: int

    # windowed-ring sub-table: deep issue history is kept ONLY for the few
    # (prev_cmd, level) pairs with a window>1 constraint (tFAW's ACT ring);
    # every other constraint reads the dense (num_nodes, n_cmds) last-issue
    # table.  See build_windowed_rings for the construction.
    ring_pairs: list            # [(cmd, level, entry_offset, n_nodes), ...]
    ring_cmd: np.ndarray        # (R,) per-entry prev-command id
    ring_level: np.ndarray      # (R,) per-entry hierarchy level
    ring_node: np.ndarray       # (R,) per-entry global node id
    ct_ring: np.ndarray         # (C,) per-constraint ring entry base, -1=dense
    n_ring: int                 # total ring entries R (0: no windowed pairs)
    ring_depth: int             # max window among allocated pairs (>= 1)

    timings: dict                   # resolved preset (cycles)
    tCK_ps: int
    read_latency: int               # RD issue -> data completion
    access_bytes: int
    peak_bytes_per_cycle: float

    # feature flags + special command ids (-1 when absent)
    split_activation: bool
    data_clock_sync: bool
    dual_command_bus: bool
    id_ACT: int; id_ACT1: int; id_ACT2: int
    id_PRE: int; id_PREab: int; id_RD: int; id_WR: int; id_REFab: int
    id_CAS_RD: int; id_CAS_WR: int; id_RCKSTRT: int
    nAAD: int                       # ACT2 deadline (0 if n/a)
    clock_idle: int                 # WCK/RCK idle expiry (0 if n/a)

    # provenance for proxies / checkpointing
    standard: str = ""
    org_preset: str = ""
    timing_preset: str = ""
    n_channels: int = 1             # memory-system channel fan-out

    #: telemetry latency-histogram bucket edges, planned at spec-compile
    #: time from the spec's own read latency (see plan_latency_buckets);
    #: request-latency telemetry buckets are therefore spec-relative —
    #: bucket 0 is "at the unloaded read latency", the last bucket is
    #: "pathologically queued".  Excluded from spec_fingerprint: the edges
    #: are derived, not an identity input.
    lat_bucket_edges: tuple = ()

    def cmd_id(self, name: str) -> int:
        return self.cmd_names.index(name)

    def addr_strides(self) -> np.ndarray:
        """Strides to flatten per-level indices into a flat bank id."""
        counts = self.level_counts[1:]          # below channel
        strides = np.ones(len(counts), dtype=np.int64)
        for i in range(len(counts) - 2, -1, -1):
            strides[i] = strides[i + 1] * counts[i + 1]
        return strides


def build_windowed_rings(ct_prev, ct_level, ct_win, cmd_scope,
                         level_counts, level_offsets) -> dict:
    """Plan the compact windowed-ring layout for a constraint table.

    Only (prev_cmd, level) pairs referenced by a ``window > 1`` constraint
    — and reachable, i.e. ``level <= cmd_scope[prev_cmd]`` so the command
    actually stamps that level — get a deep issue-history ring.  Each pair
    owns one contiguous block of entries, one entry per level-``level``
    node, so the engine can read a whole pair with a static slice.

    Returns the ``ring_*`` / ``ct_ring`` / ``n_ring`` / ``ring_depth``
    fields of :class:`CompiledSpec` as a dict.
    """
    node_counts = np.cumprod(np.asarray(level_counts, np.int64))
    pairs: dict = {}            # (cmd, level) -> [entry_offset, depth]
    n_ring = 0
    for i in range(len(ct_prev)):
        if int(ct_win[i]) <= 1:
            continue
        p, level = int(ct_prev[i]), int(ct_level[i])
        if level > int(cmd_scope[p]):
            continue            # the command never stamps this level
        key = (p, level)
        if key not in pairs:
            pairs[key] = [n_ring, int(ct_win[i])]
            n_ring += int(node_counts[level])
        else:
            pairs[key][1] = max(pairs[key][1], int(ct_win[i]))
    ring_depth = max((d for _, d in pairs.values()), default=1)

    ct_ring = np.full(len(ct_prev), -1, np.int32)
    for i in range(len(ct_prev)):
        key = (int(ct_prev[i]), int(ct_level[i]))
        if int(ct_win[i]) > 1 and key in pairs:
            ct_ring[i] = pairs[key][0]

    ring_cmd = np.zeros(n_ring, np.int32)
    ring_level = np.zeros(n_ring, np.int32)
    ring_node = np.zeros(n_ring, np.int32)
    ring_pairs = []
    for (p, level), (off, _depth) in sorted(pairs.items(),
                                            key=lambda kv: kv[1][0]):
        n_l = int(node_counts[level])
        ring_pairs.append((p, level, off, n_l))
        ring_cmd[off:off + n_l] = p
        ring_level[off:off + n_l] = level
        ring_node[off:off + n_l] = (int(level_offsets[level])
                                    + np.arange(n_l, dtype=np.int32))
    return dict(ring_pairs=ring_pairs, ring_cmd=ring_cmd,
                ring_level=ring_level, ring_node=ring_node, ct_ring=ct_ring,
                n_ring=int(n_ring), ring_depth=int(ring_depth))


#: Number of request-latency histogram buckets windowed telemetry records
#: (``repro.telemetry``): len(lat_bucket_edges) + 1.
N_LAT_BUCKETS = 16

#: Bucket-edge multipliers over the spec's unloaded read latency.  The low
#: buckets resolve queueing onset (1x..2x), the high ones starvation tails.
_LAT_EDGE_MULTIPLIERS = (1.0, 1.25, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0,
                         12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 96.0)


def plan_latency_buckets(read_latency: int) -> tuple:
    """Plan the ``N_LAT_BUCKETS``-bucket request-latency histogram edges
    for a spec with unloaded read latency ``read_latency`` cycles.

    Returns ``N_LAT_BUCKETS - 1`` strictly increasing integer edges;
    bucket ``i`` covers ``[edges[i-1], edges[i])`` (bucket 0 is
    ``< edges[0]``, the last bucket is unbounded).  Planned per spec at
    compile time so a DDR3 and an HBM4 histogram are comparable in units
    of their own unloaded latency."""
    edges, prev = [], 0
    for m in _LAT_EDGE_MULTIPLIERS:
        e = max(int(round(m * max(read_latency, 1))) + 1, prev + 1)
        edges.append(e)
        prev = e
    return tuple(edges)


# --------------------------------------------------------------------------
# Memory-system composition: ordered spec groups behind one address mapper
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecGroup:
    """One homogeneous slice of a memory system: ``channels`` identical
    channels of ``cspec``, optionally behind a CXL-style link that adds
    ``link_latency`` cycles in each direction (requests become visible to
    the group's controllers ``link_latency`` cycles after arrival, and
    read data needs another ``link_latency`` cycles to come back)."""
    cspec: CompiledSpec
    channels: int = 1
    link_latency: int = 0


class MemorySystemSpec:
    """An ordered list of :class:`SpecGroup`s composed behind one
    system-level address mapper.

    System channel ids are group-major: group 0 owns channels
    ``[0, groups[0].channels)``, group 1 the next block, and so on.  Each
    group keeps its *own* command namespace (its ``CompiledSpec``); the
    system additionally carries a merged ``cmd_names`` table (first-seen
    name order across groups) plus per-group local->global id maps so
    system-level artifacts (traces, aggregate ``cmd_counts``) can name
    commands uniformly while every group is still simulated — and audited
    — against its own constraint table.

    The homogeneous ``Simulator(..., channels=N)`` path is exactly the
    1-group, zero-link special case of this class.
    """

    def __init__(self, groups):
        groups = tuple(groups)
        if not groups:
            raise ValueError("a memory system needs at least one spec group")
        for g in groups:
            if not isinstance(g, SpecGroup):
                raise TypeError(f"expected SpecGroup, got {type(g).__name__}")
            if g.channels < 1:
                raise ValueError(f"group channels must be >= 1, got "
                                 f"{g.channels}")
            if g.link_latency < 0:
                raise ValueError("link_latency must be >= 0")
            if g.cspec.n_channels != g.channels:
                raise ValueError(
                    f"group cspec compiled for {g.cspec.n_channels} "
                    f"channel(s) but the group declares {g.channels} — "
                    "compile the group spec with channels=<group channels> "
                    "(compile_system does this for you)")
        self.groups = groups
        self.n_groups = len(groups)
        self.n_channels = sum(g.channels for g in groups)
        #: first system channel id of each group
        self.chan_base = np.concatenate(
            [[0], np.cumsum([g.channels for g in groups])[:-1]]).astype(
                np.int64)
        #: owning group of each system channel, shape (n_channels,)
        self.chan_group = np.repeat(np.arange(self.n_groups, dtype=np.int64),
                                    [g.channels for g in groups])
        # merged command namespace: first-seen name order across groups
        names: list = []
        maps = []
        for g in groups:
            local = []
            for n in g.cspec.cmd_names:
                if n not in names:
                    names.append(n)
                local.append(names.index(n))
            maps.append(np.asarray(local, np.int64))
        self.cmd_names = names
        self.n_cmds = len(names)
        #: per-group (n_cmds_g,) arrays mapping local command id -> merged id
        self.group_cmd_maps = tuple(maps)

    # -- conveniences ------------------------------------------------------
    @property
    def homogeneous(self) -> bool:
        """True when the system is the plain multi-channel special case."""
        return self.n_groups == 1 and self.groups[0].link_latency == 0

    @property
    def tCK_ps(self) -> int:
        """Reference clock of the system: the engine steps every group on
        one shared cycle index, interpreted on group 0's clock (cycle->ns
        conversions of *group-local* counters use that group's own tCK)."""
        return self.groups[0].cspec.tCK_ps

    def group_of_channel(self, chan: int) -> int:
        return int(self.chan_group[chan])

    @property
    def label(self) -> str:
        parts = []
        for g in self.groups:
            p = f"{g.cspec.standard or g.cspec.name}x{g.channels}"
            if g.link_latency:
                p += f"@{g.link_latency}"
            parts.append(p)
        return "+".join(parts)

    def __repr__(self):
        return f"MemorySystemSpec({self.label})"


def compile_system(groups) -> MemorySystemSpec:
    """Compile a heterogeneous memory system from group descriptors.

    Each descriptor is one of:

      * a mapping: ``dict(standard=..., org_preset=..., timing_preset=...,
        timing_overrides=None, channels=1, link_latency=0)``;
      * a tuple ``(standard, org_preset, timing_preset[, channels
        [, link_latency]])``;
      * an already-built :class:`SpecGroup` (used as-is);
      * a :class:`CompiledSpec` (its ``n_channels`` becomes the group's
        channel count, link latency 0).

    >>> msys = compile_system([
    ...     dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
    ...          timing_preset="DDR5_4800B", channels=2),
    ...     dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
    ...          timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ... ])
    """
    out = []
    for g in groups:
        if isinstance(g, SpecGroup):
            out.append(g)
            continue
        if isinstance(g, CompiledSpec):
            out.append(SpecGroup(g, g.n_channels, 0))
            continue
        if isinstance(g, dict):
            d = dict(g)
            std = d.pop("standard")
            org = d.pop("org_preset")
            tim = d.pop("timing_preset")
            ov = d.pop("timing_overrides", None)
            ch = int(d.pop("channels", 1))
            ll = int(d.pop("link_latency", 0))
            if d:
                raise TypeError(f"unknown group descriptor keys {sorted(d)}")
        else:
            std, org, tim, *rest = g
            ch = int(rest[0]) if rest else 1
            ll = int(rest[1]) if len(rest) > 1 else 0
            ov = None
        out.append(SpecGroup(compile_spec(std, org, tim, ov, channels=ch),
                             ch, ll))
    return MemorySystemSpec(out)


def as_system(spec) -> MemorySystemSpec:
    """Coerce a CompiledSpec / MemorySystemSpec / descriptor list into a
    :class:`MemorySystemSpec` (a bare spec becomes the 1-group system)."""
    if isinstance(spec, MemorySystemSpec):
        return spec
    if isinstance(spec, CompiledSpec):
        return MemorySystemSpec((SpecGroup(spec, spec.n_channels, 0),))
    if isinstance(spec, (list, tuple)):
        return compile_system(spec)
    raise TypeError(f"cannot build a memory system from "
                    f"{type(spec).__name__}")


def compile_spec(standard, org_preset: str, timing_preset: str,
                 timing_overrides: dict | None = None,
                 channels: int = 1, lint: str | None = None) -> CompiledSpec:
    """Lower a standard to its dense-table form.

    ``lint`` gates a compile-time run of the spec linter
    (``repro.analysis``) over the result: ``"error"`` raises on any
    error-severity finding, ``"warn"`` prints them, ``"off"`` (the
    default) skips the pass.  ``None`` reads the ``REPRO_SPEC_LINT``
    environment variable so CI can arm the gate globally.
    """
    if isinstance(standard, str):
        standard = S.get_standard(standard)
    if channels < 1:
        raise ValueError(f"channels must be >= 1, got {channels}")
    org: S.Organization = standard.org_presets[org_preset]
    timings = dict(standard.timing_presets[timing_preset])
    if timing_overrides:
        unknown = (set(timing_overrides) - set(timings)
                   - set(standard.timing_params) - {"tCK_ps"})
        if unknown:
            valid = sorted(set(timings) | set(standard.timing_params)
                           | {"tCK_ps"})
            raise ValueError(
                f"{standard.name}: unknown timing_overrides key(s) "
                f"{sorted(unknown)} — overrides must name an existing "
                f"timing parameter (valid: {valid})")
        timings.update(timing_overrides)

    levels = list(standard.levels)
    counts = [1] + [org.counts[lv] for lv in levels[1:]]
    # cumulative node counts per level: channel=1, rank=R, bankgroup=R*BG, ...
    sizes, acc = [], 1
    for c in counts:
        acc *= c
        sizes.append(acc)
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
    num_nodes = int(np.sum(sizes))
    n_banks = sizes[-1]
    n_refresh_units = sizes[1] if len(sizes) > 1 else 1

    cmd_names = list(standard.commands)
    n_cmds = len(cmd_names)
    meta = standard.command_meta
    kind = np.array([meta[c].kind for c in cmd_names], dtype=np.int32)
    scope = np.array([levels.index(meta[c].scope) for c in cmd_names], dtype=np.int32)
    fx = np.array([meta[c].effects for c in cmd_names], dtype=np.int32)

    prev, nxt, lvl, lat, win = [], [], [], [], []
    for tc in standard.timing_constraints:
        latency = resolve_latency(
            tc.latency, timings,
            context=f"{standard.name} constraint "
                    f"{','.join(tc.preceding)}->{','.join(tc.following)}"
                    f"@{tc.level}")
        for p in tc.preceding:
            for f in tc.following:
                prev.append(cmd_names.index(p))
                nxt.append(cmd_names.index(f))
                lvl.append(levels.index(tc.level))
                lat.append(latency)
                win.append(tc.window)
    ct_prev = np.array(prev, dtype=np.int32)
    ct_next = np.array(nxt, dtype=np.int32)
    ct_level = np.array(lvl, dtype=np.int32)
    ct_lat = np.array(lat, dtype=np.int32)
    ct_win = np.array(win, dtype=np.int32)
    max_window = int(ct_win.max()) if len(win) else 1
    rings = build_windowed_rings(ct_prev, ct_level, ct_win, scope,
                                 counts, offsets)

    def cid(name):
        return cmd_names.index(name) if name in cmd_names else -1

    access_bytes = org.dq * standard.burst_beats // 8
    nBL = timings["nBL"]
    read_latency = timings["nCL"] + nBL

    cspec = CompiledSpec(
        name=standard.name, levels=levels,
        level_counts=np.array(counts, dtype=np.int64),
        level_offsets=offsets, num_nodes=num_nodes, n_banks=n_banks,
        n_refresh_units=n_refresh_units, rows=org.rows, columns=org.columns,
        cmd_names=cmd_names, n_cmds=n_cmds, cmd_kind=kind, cmd_scope=scope,
        cmd_fx=fx, ct_prev=ct_prev, ct_next=ct_next, ct_level=ct_level,
        ct_lat=ct_lat, ct_win=ct_win, max_window=max_window, **rings,
        timings=timings, tCK_ps=timings["tCK_ps"], read_latency=read_latency,
        access_bytes=access_bytes,
        peak_bytes_per_cycle=access_bytes / nBL,
        split_activation=standard.split_activation,
        data_clock_sync=standard.data_clock_sync,
        dual_command_bus=standard.dual_command_bus,
        id_ACT=cid("ACT"), id_ACT1=cid("ACT1"), id_ACT2=cid("ACT2"),
        id_PRE=cid("PRE"), id_PREab=cid("PREab"), id_RD=cid("RD"),
        id_WR=cid("WR"), id_REFab=cid("REFab"), id_CAS_RD=cid("CAS_RD"),
        id_CAS_WR=cid("CAS_WR"), id_RCKSTRT=cid("RCKSTRT"),
        nAAD=timings.get("nAAD", 0),
        clock_idle=timings.get("nWCKIDLE", timings.get("nRCKIDLE", 0)),
        standard=standard.name, org_preset=org_preset,
        timing_preset=timing_preset, n_channels=int(channels),
        lat_bucket_edges=plan_latency_buckets(read_latency),
    )
    _lint_compiled(cspec, channels, lint)
    return cspec


def _lint_compiled(cspec: CompiledSpec, channels: int, lint: str | None):
    """Compile-time spec-lint gate (lazy import: compile must not pay
    for the analysis subsystem unless the gate is armed)."""
    mode = lint if lint is not None else os.environ.get(
        "REPRO_SPEC_LINT", "off")
    if mode in ("off", "", None):
        return
    if mode not in ("warn", "error"):
        raise ValueError(f"lint mode must be off|warn|error, got {mode!r}")
    from repro.analysis.speclint import lint_compiled
    report = lint_compiled(cspec, channels=max(1, channels))
    if report.ok() and not report.warnings:
        return
    if mode == "error" and not report.ok():
        raise ValueError("spec lint failed at compile time:\n"
                         + report.summary())
    print(report.summary())
