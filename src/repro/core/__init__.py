"""Ramulator-2.1-in-JAX: composable, vectorized DRAM memory-system simulator.

Public surface:
  * ``repro.core.standards`` — the modeled DRAM standards (extend per
    Listing 1 of the paper, see ``examples/extend_standard.py``)
  * ``Simulator`` — cycle-level engine (lax.scan) with vmap DSE batching
  * ``DeviceUnderTest`` — fine-grained probe API (paper Listing 2)
  * ``ControllerConfig`` / filtering predicates — paper §2
"""
from repro.core import standards  # noqa: F401  (populates the registry)
from repro.core.compile import (CompiledSpec, MemorySystemSpec, SpecGroup,
                                as_system, compile_spec, compile_system)
from repro.core.controller import ControllerConfig
from repro.core.dut import DeviceUnderTest
from repro.core.engine import (Simulator, avg_probe_latency_ns,
                               channel_breakdown, peak_gbps,
                               throughput_gbps)
from repro.core.frontend import FrontendConfig, ReplayStream
from repro.core.spec import (Command, DRAMSpec, Organization,
                             TimingConstraint, all_standards, get_standard)

__all__ = [
    "CompiledSpec", "compile_spec", "ControllerConfig", "DeviceUnderTest",
    "Simulator", "FrontendConfig", "Command", "DRAMSpec", "Organization",
    "TimingConstraint", "all_standards", "get_standard", "standards",
    "throughput_gbps", "peak_gbps", "avg_probe_latency_ns",
    "channel_breakdown", "ReplayStream",
    "MemorySystemSpec", "SpecGroup", "compile_system", "as_system",
]
