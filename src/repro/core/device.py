"""Cycle-level DRAM device model as a pure-JAX state machine.

All mutable device state is a pytree of dense int32 arrays; every operation
(prerequisite decode, timing-readiness check, command issue) is a pure
function `(tables, state, ...) -> ...` suitable for `jax.jit`, `jax.vmap`
(DSE batching) and `jax.lax.scan` (the cycle loop).

State encoding
--------------
row_state[bank]  : -1 closed, -2 activating (split ACT-1 issued), else open row
last_issue[node, cmd] : most-recent issue clock — the dense table every
                   window=1 constraint (i.e. almost all of them) reads
win_ring[e, w]   : issue-clock history (most recent first) ONLY for the few
                   (prev_cmd, level) pairs with a window>1 constraint
                   (tFAW's ACT ring); entry layout is planned at spec
                   compile time (``CompiledSpec.ring_*`` / ``ct_ring``)
clock_until[ru]  : WCK/RCK data clock active until this cycle (exclusive)
last_ref[ru]     : last REFab issue clock per refresh unit

Splitting the deep history out of the per-(node, cmd) state shrinks the
``lax.scan`` carry ~4x at DDR5/HBM3 window depths — the whole timing state
is what every cycle of every channel of every batched design point carries,
so its footprint is the engine's cache-pressure knob.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spec as S
from repro.core.compile import CompiledSpec

NEG = jnp.int32(-(1 << 28))     # "never issued"
ROW_CLOSED = -1
ROW_ACTIVATING = -2


class DynParams(NamedTuple):
    """Preset-dependent scalars/vectors — the *vmappable* axis for DSE."""
    ct_lat: jnp.ndarray          # (C,) resolved constraint latencies
    nREFI: jnp.ndarray
    nRFC: jnp.ndarray
    nAAD: jnp.ndarray            # ACT-2 deadline (0 = n/a)
    clock_idle: jnp.ndarray      # WCK/RCK idle window (0 = n/a)
    read_latency: jnp.ndarray    # RD issue -> data valid


def dyn_params(cspec: CompiledSpec) -> DynParams:
    t = cspec.timings
    return DynParams(
        ct_lat=jnp.asarray(cspec.ct_lat, jnp.int32),
        nREFI=jnp.int32(t["nREFI"]), nRFC=jnp.int32(t["nRFC"]),
        nAAD=jnp.int32(cspec.nAAD), clock_idle=jnp.int32(cspec.clock_idle),
        read_latency=jnp.int32(cspec.read_latency),
    )


class DeviceState(NamedTuple):
    last_issue: jnp.ndarray      # (num_nodes, n_cmds) int32 — window=1 table
    win_ring: jnp.ndarray        # (max(n_ring,1), ring_depth) int32
    row_state: jnp.ndarray       # (n_banks,) int32
    act1_row: jnp.ndarray        # (n_banks,) int32
    act1_clk: jnp.ndarray        # (n_banks,) int32
    clock_until: jnp.ndarray     # (n_refresh_units,) int32
    last_ref: jnp.ndarray        # (n_refresh_units,) int32


def init_state(cspec: CompiledSpec) -> DeviceState:
    return DeviceState(
        last_issue=jnp.full((cspec.num_nodes, cspec.n_cmds), NEG, jnp.int32),
        # a standard with no windowed constraints keeps a 1x1 dummy ring so
        # the pytree structure (and gather shapes) stay uniform
        win_ring=jnp.full((max(cspec.n_ring, 1), cspec.ring_depth),
                          NEG, jnp.int32),
        row_state=jnp.full((cspec.n_banks,), ROW_CLOSED, jnp.int32),
        act1_row=jnp.zeros((cspec.n_banks,), jnp.int32),
        act1_clk=jnp.full((cspec.n_banks,), NEG, jnp.int32),
        clock_until=jnp.zeros((cspec.n_refresh_units,), jnp.int32),
        last_ref=jnp.zeros((cspec.n_refresh_units,), jnp.int32),
    )


def carry_nbytes(cspec: CompiledSpec) -> int:
    """Per-channel scan-carry bytes of the timing state (the cache-pressure
    number the windowed-ring split optimizes)."""
    state = init_state(cspec)
    return sum(int(np.prod(a.shape)) * 4
               for a in (state.last_issue, state.win_ring))


def dense_ring_nbytes(cspec: CompiledSpec) -> int:
    """What the pre-split layout — a ``max_window``-deep ring for every
    (node, cmd) pair — would carry.  Kept as the benchmark baseline."""
    return cspec.num_nodes * cspec.n_cmds * cspec.max_window * 4


# --------------------------------------------------------------------------
# Addressing helpers (static org => plain python loops unroll at trace time)
# --------------------------------------------------------------------------

def node_per_level(cspec: CompiledSpec, addr_sub: jnp.ndarray) -> jnp.ndarray:
    """Node index at each hierarchy level for an address.

    addr_sub holds the per-level indices below channel, e.g. DDR4:
    (rank, bankgroup, bank).  Returns (L,) node ids; level 0 is channel 0.
    """
    counts = cspec.level_counts        # numpy, static
    offs = cspec.level_offsets
    nodes = [jnp.int32(0)]
    flat = jnp.int32(0)
    for i in range(1, len(counts)):
        flat = flat * jnp.int32(int(counts[i])) + addr_sub[i - 1]
        nodes.append(jnp.int32(int(offs[i])) + flat)
    return jnp.stack(nodes)


def flat_bank(cspec: CompiledSpec, addr_sub: jnp.ndarray) -> jnp.ndarray:
    counts = cspec.level_counts
    flat = jnp.int32(0)
    for i in range(1, len(counts)):
        flat = flat * jnp.int32(int(counts[i])) + addr_sub[i - 1]
    return flat


def refresh_unit(cspec: CompiledSpec, addr_sub: jnp.ndarray) -> jnp.ndarray:
    return addr_sub[0]


# --------------------------------------------------------------------------
# Timing-readiness check (XLA reference path; Pallas kernel in kernels/)
# --------------------------------------------------------------------------

def earliest_ready(cspec: CompiledSpec, dp: DynParams, state: DeviceState,
                   cmd: jnp.ndarray, addr_sub: jnp.ndarray) -> jnp.ndarray:
    """Earliest cycle at which `cmd` may issue at `addr` (timing only)."""
    nodes = node_per_level(cspec, addr_sub)          # (L,)
    ct_prev = jnp.asarray(cspec.ct_prev)             # (C,)
    ct_next = jnp.asarray(cspec.ct_next)
    ct_level = jnp.asarray(cspec.ct_level)
    node = nodes[ct_level]                           # (C,)
    t_prev = state.last_issue[node, ct_prev]
    if cspec.n_ring:
        # windowed rows read the pair's ring entry for this level node;
        # rows with ct_ring == -1 (window=1, or a window the command never
        # stamps) keep the dense-table value / NEG
        ct_ring = jnp.asarray(cspec.ct_ring)
        lvl_off = jnp.asarray(np.asarray(cspec.level_offsets,
                                         np.int32)[cspec.ct_level])
        ridx = jnp.clip(ct_ring + node - lvl_off, 0, cspec.n_ring - 1)
        t_ring = state.win_ring[ridx, jnp.asarray(cspec.ct_win) - 1]
        t_prev = jnp.where(ct_ring >= 0, t_ring, t_prev)
    # window>1 rows at a level the command never stamps have ct_ring == -1
    # AND a never-written dense slot, so they correctly stay NEG
    allowed = jnp.where((ct_next == cmd) & (t_prev > NEG),
                        t_prev + dp.ct_lat, NEG)
    return jnp.max(allowed, initial=NEG)


def earliest_ready_table(cspec: CompiledSpec, dp: DynParams,
                         state: DeviceState) -> jnp.ndarray:
    """Dense ``(n_cmds, n_banks)`` earliest-issue table for the whole
    device — the vectorized twin of :func:`earliest_ready`.

    The constraint table is static, so the whole computation unrolls at
    trace time into static slices: each constraint row reads its level's
    node timestamps with a static slice of ``last_issue`` and broadcasts
    them to banks with a static ``repeat`` — no gathers or scatters at
    all, which is what keeps the channel-vmapped selection pipeline
    vectorized (dynamic gathers serialize under nested vmap on CPU/TPU).
    The controller then resolves a queue slot's readiness with a single
    ``table[cmd, bank]`` lookup.
    """
    n_banks = cspec.n_banks
    sizes = np.asarray(cspec.level_counts, np.int64)
    node_counts = np.cumprod(sizes)                  # nodes per level
    offs = np.asarray(cspec.level_offsets, np.int64)
    acc = [None] * cspec.n_cmds                      # per-cmd running max
    for i in range(len(cspec.ct_prev)):
        p, f = int(cspec.ct_prev[i]), int(cspec.ct_next[i])
        level, w = int(cspec.ct_level[i]), int(cspec.ct_win[i]) - 1
        if level > int(cspec.cmd_scope[p]):
            continue        # preceding command never stamps this level
        n_l = int(node_counts[level])
        off = int(offs[level])
        if w == 0:
            # static slice of the dense table: the level's nodes for prev
            t_nodes = state.last_issue[off:off + n_l, p]         # (n_l,)
        else:
            # windowed constraint: the pair's contiguous ring block holds
            # exactly this level's nodes, so the read stays a static slice
            ro = int(cspec.ct_ring[i])
            assert ro >= 0, "reachable window>1 constraint without a ring"
            t_nodes = state.win_ring[ro:ro + n_l, w]             # (n_l,)
        t_banks = jnp.repeat(t_nodes, n_banks // n_l)            # (n_banks,)
        allowed = jnp.where(t_banks > NEG, t_banks + dp.ct_lat[i], NEG)
        acc[f] = allowed if acc[f] is None else jnp.maximum(acc[f], allowed)
    neg_row = jnp.full((n_banks,), NEG, jnp.int32)
    return jnp.stack([a if a is not None else neg_row for a in acc])


def timing_ok(cspec, dp, state, cmd, addr_sub, clk) -> jnp.ndarray:
    return clk >= earliest_ready(cspec, dp, state, cmd, addr_sub)


# --------------------------------------------------------------------------
# Prerequisite decode (paper §2: per-standard request -> next command)
# --------------------------------------------------------------------------

def prereq(cspec: CompiledSpec, dp: DynParams, state: DeviceState,
           is_write: jnp.ndarray, addr_sub: jnp.ndarray, row: jnp.ndarray,
           clk: jnp.ndarray):
    """Next command needed to advance a request.

    Returns (cmd, cmd_row): cmd_row is the row the command actually targets
    (ACT-2 completes the *pending* activation row, not the request's row).
    """
    bank = flat_bank(cspec, addr_sub)
    ru = refresh_unit(cspec, addr_sub)
    rs = state.row_state[bank]
    open_hit = rs == row
    closed = rs == ROW_CLOSED
    activating = rs == ROW_ACTIVATING

    final = jnp.where(is_write, jnp.int32(cspec.id_WR), jnp.int32(cspec.id_RD))
    col_cmd = final
    if cspec.data_clock_sync:
        clock_on = clk < state.clock_until[ru]
        sync = jnp.where(is_write,
                         jnp.int32(cspec.id_CAS_WR if cspec.id_CAS_WR >= 0 else cspec.id_RCKSTRT),
                         jnp.int32(cspec.id_CAS_RD if cspec.id_CAS_RD >= 0 else cspec.id_RCKSTRT))
        col_cmd = jnp.where(clock_on, final, sync)

    if cspec.split_activation:
        opener = jnp.int32(cspec.id_ACT1)
        cmd = jnp.where(closed, opener,
              jnp.where(activating, jnp.int32(cspec.id_ACT2),
              jnp.where(open_hit, col_cmd, jnp.int32(cspec.id_PRE))))
    else:
        opener = jnp.int32(cspec.id_ACT)
        cmd = jnp.where(closed, opener,
              jnp.where(open_hit, col_cmd, jnp.int32(cspec.id_PRE)))

    cmd_row = jnp.where(cmd == jnp.int32(cspec.id_ACT2),
                        state.act1_row[bank], row) if cspec.split_activation else row
    return cmd, cmd_row, open_hit


# --------------------------------------------------------------------------
# Command issue: timestamp rings + state effects
# --------------------------------------------------------------------------

def issue(cspec: CompiledSpec, dp: DynParams, state: DeviceState,
          cmd: jnp.ndarray, addr_sub: jnp.ndarray, row: jnp.ndarray,
          clk: jnp.ndarray, enable: jnp.ndarray) -> DeviceState:
    """Issue `cmd` at `addr` on cycle `clk` (no-op when ``enable`` is False).

    Every state mutation is a *dense one-hot masked update* (compare +
    select over the full array) instead of a scatter: scatters serialize
    under the engine's (batch x channel) vmap nesting on CPU/TPU backends,
    while these elementwise forms vectorize across all batch dimensions.
    The arrays are small (nodes x cmds, plus the tiny windowed ring), so
    the extra flops are noise next to the removed gather/scatter loops.
    """
    nodes = node_per_level(cspec, addr_sub)                    # (L,)
    scope = jnp.asarray(cspec.cmd_scope)[cmd]
    lvl_idx = jnp.arange(len(cspec.levels), dtype=jnp.int32)
    upd_mask = (lvl_idx <= scope) & enable                     # ancestors+self

    li = state.last_issue                                      # (N, cmds)
    node_ids = jnp.arange(cspec.num_nodes, dtype=jnp.int32)
    node_hit = jnp.any((node_ids[:, None] == nodes[None, :])
                       & upd_mask[None, :], axis=1)            # (N,)
    cmd_hit = jnp.arange(cspec.n_cmds, dtype=jnp.int32) == cmd  # (cmds,)
    li = jnp.where(node_hit[:, None] & cmd_hit[None, :], clk, li)

    ring = state.win_ring
    if cspec.n_ring:
        # shift-insert only the ring entries owned by (cmd, its level node);
        # a ring pair exists only for levels the command stamps, so the
        # scope mask is implied by ring_cmd == cmd
        r_cmd = jnp.asarray(cspec.ring_cmd)
        r_node = jnp.asarray(cspec.ring_node)
        r_level = jnp.asarray(cspec.ring_level)
        entry_hit = (r_cmd == cmd) & (nodes[r_level] == r_node) & enable
        shifted = jnp.concatenate(
            [jnp.full_like(ring[:, :1], clk), ring[:, :-1]], axis=1)
        ring = jnp.where(entry_hit[:, None], shifted, ring)

    fx = jnp.asarray(cspec.cmd_fx)[cmd]
    bank = flat_bank(cspec, addr_sub)
    ru = refresh_unit(cspec, addr_sub)
    bank_hit = jnp.arange(cspec.n_banks, dtype=jnp.int32) == bank
    ru_hit = jnp.arange(cspec.n_refresh_units, dtype=jnp.int32) == ru

    def has(bit):
        return ((fx & bit) != 0) & enable

    rs = state.row_state
    rs = jnp.where(has(S.FX_OPEN) & bank_hit, row, rs)
    rs = jnp.where(has(S.FX_CLOSE) & bank_hit, ROW_CLOSED, rs)
    # FX_CLOSE_ALL: close every bank in this refresh unit
    banks_per_ru = cspec.n_banks // cspec.n_refresh_units
    bank_ru = jnp.arange(cspec.n_banks, dtype=jnp.int32) // banks_per_ru
    rs = jnp.where(has(S.FX_CLOSE_ALL) & (bank_ru == ru), ROW_CLOSED, rs)
    rs = jnp.where(has(S.FX_ACT1) & bank_hit, ROW_ACTIVATING, rs)

    a1_hit = has(S.FX_ACT1) & bank_hit
    a1r = jnp.where(a1_hit, row, state.act1_row)
    a1c = jnp.where(a1_hit, clk, state.act1_clk)

    cu = state.clock_until
    cu = jnp.where(has(S.FX_CLOCK_ON) & ru_hit, clk + dp.clock_idle, cu)
    # data transfer keeps the data clock alive
    is_data = has(S.FX_FINAL_RD) | has(S.FX_FINAL_WR)
    if cspec.data_clock_sync:
        cu = jnp.where(is_data & ru_hit,
                       jnp.maximum(cu, clk + dp.clock_idle), cu)

    lr = state.last_ref
    lr = jnp.where((cmd == jnp.int32(cspec.id_REFab)) & enable & ru_hit,
                   clk, lr)

    return DeviceState(last_issue=li, win_ring=ring, row_state=rs,
                       act1_row=a1r, act1_clk=a1c, clock_until=cu,
                       last_ref=lr)
