"""Request schedulers: masked-priority selection over the request queue.

A scheduler is a pure function `(mask, row_hit, arrive) -> (slot, ok)` that
picks at most one queue slot among those allowed by `mask`.  The paper's
base workflow runs the *same* selection pipeline for every controller; the
controllers differ only in the predicate masks they inject (paper §2).
"""
from __future__ import annotations

import jax.numpy as jnp

I32_MAX = jnp.int32(2**31 - 1)


def _oldest(mask, arrive):
    key = jnp.where(mask, arrive, I32_MAX)
    return jnp.argmin(key), jnp.any(mask)


def frfcfs(mask, row_hit, arrive):
    """First-Ready FCFS: ready row hits first, then oldest ready."""
    hit_mask = mask & row_hit
    use_hits = jnp.any(hit_mask)
    m = jnp.where(use_hits, hit_mask, mask)
    return _oldest(m, arrive)


def fcfs(mask, row_hit, arrive):
    return _oldest(mask, arrive)


SCHEDULERS = {"FRFCFS": frfcfs, "FCFS": fcfs}
