"""Memory-controller base scheduling workflow + filtering predicates.

This is the paper's central software-architecture contribution (§2): one
*common* command-selection pipeline that every controller specializes by
injecting *filtering predicates* — composable functions producing boolean
masks over the request queue:

  * HBM3/4, GDDR7 dual C/A bus  -> run the pipeline twice per cycle with a
    column-command mask then a row-command mask;
  * LPDDR5/6 split activation   -> predicates that (a) let only requests
    that already issued ACT-1 proceed to ACT-2 and (b) prioritize a pending
    ACT-2 as its tAAD deadline approaches;
  * WCK/RCK data-clock sync     -> the prerequisite decoder injects
    CAS_RD/CAS_WR/RCKSTRT before column commands when the clock is off;
  * BlockHammer                 -> defer ACTs to blacklisted (hammered) rows;
  * PRAC                        -> alert-driven recovery (RFM) that ordinary
    requests must not interfere with.

All of it is vectorized: a predicate is `(PredCtx) -> bool[Q]`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import device as D
from repro.core import spec as S
from repro.core.compile import CompiledSpec

# --------------------------------------------------------------------------
# Request schedulers: masked-priority selection over the request queue
# --------------------------------------------------------------------------
#
# A scheduler is a pure function `(mask, row_hit, arrive) -> (slot, ok)` that
# picks at most one queue slot among those allowed by `mask`.  The paper's
# base workflow runs the *same* selection pipeline for every controller; the
# controllers differ only in the predicate masks they inject (paper §2).

I32_MAX = jnp.int32(2**31 - 1)


def _oldest(mask, arrive):
    key = jnp.where(mask, arrive, I32_MAX)
    return jnp.argmin(key), jnp.any(mask)


def frfcfs(mask, row_hit, arrive):
    """First-Ready FCFS: ready row hits first, then oldest ready."""
    hit_mask = mask & row_hit
    use_hits = jnp.any(hit_mask)
    m = jnp.where(use_hits, hit_mask, mask)
    return _oldest(m, arrive)


def fcfs(mask, row_hit, arrive):
    return _oldest(mask, arrive)


SCHEDULERS = {"FRFCFS": frfcfs, "FCFS": fcfs}

# --------------------------------------------------------------------------
# Queue / controller state
# --------------------------------------------------------------------------


class Queue(NamedTuple):
    valid: jnp.ndarray      # (Q,) bool
    is_write: jnp.ndarray   # (Q,) bool
    is_probe: jnp.ndarray   # (Q,) bool
    sub: jnp.ndarray        # (Q, L-1) per-level indices below channel
    row: jnp.ndarray        # (Q,)
    col: jnp.ndarray        # (Q,)
    arrive: jnp.ndarray     # (Q,)


def empty_queue(cspec: CompiledSpec, depth: int) -> Queue:
    nsub = len(cspec.levels) - 1
    z = lambda *sh: jnp.zeros(sh, jnp.int32)
    return Queue(valid=jnp.zeros((depth,), bool),
                 is_write=jnp.zeros((depth,), bool),
                 is_probe=jnp.zeros((depth,), bool),
                 sub=z(depth, nsub), row=z(depth), col=z(depth),
                 arrive=z(depth))


def queue_insert(q: Queue, is_write, is_probe, sub, row, col, arrive, want):
    """Insert one request into the first free slot (returns (q', ok)).

    Dense one-hot update (no scatter) — vectorizes under the engine's
    channel/batch vmap nesting."""
    free = ~q.valid
    ok = want & jnp.any(free)
    slot = jnp.argmax(free)          # first free slot
    hit = ok & (jnp.arange(q.valid.shape[0], dtype=jnp.int32) == slot)
    def put(a, v):
        return jnp.where(hit, v, a)
    return Queue(valid=q.valid | hit,
                 is_write=put(q.is_write, is_write),
                 is_probe=put(q.is_probe, is_probe),
                 sub=jnp.where(hit[:, None], sub[None, :], q.sub),
                 row=put(q.row, row), col=put(q.col, col),
                 arrive=put(q.arrive, arrive)), ok


class CtrlState(NamedTuple):
    dev: D.DeviceState
    queue: Queue
    hit_streak: jnp.ndarray   # (n_banks,) consecutive row-hit services
    bh_sketch: jnp.ndarray    # (2, SKETCH) BlockHammer count-min sketch
    prac_count: jnp.ndarray   # (n_banks,) ACT counter since last recovery


SKETCH = 1024


def init_ctrl_state(cspec: CompiledSpec, depth: int) -> CtrlState:
    return CtrlState(dev=D.init_state(cspec),
                     queue=empty_queue(cspec, depth),
                     hit_streak=jnp.zeros((cspec.n_banks,), jnp.int32),
                     bh_sketch=jnp.zeros((2, SKETCH), jnp.int32),
                     prac_count=jnp.zeros((cspec.n_banks,), jnp.int32))


class PredCtx(NamedTuple):
    """Everything a filtering predicate may look at."""
    dp: D.DynParams
    cs: CtrlState
    clk: jnp.ndarray
    cand_cmd: jnp.ndarray     # (Q,) candidate command per slot
    cand_row: jnp.ndarray     # (Q,)
    open_hit: jnp.ndarray     # (Q,) request's row is open
    bank: jnp.ndarray         # (Q,) flat bank ids
    ru: jnp.ndarray           # (Q,) refresh-unit ids
    ref_urgent: jnp.ndarray   # (n_refresh_units,) refresh must go first


Predicate = Callable[..., jnp.ndarray]   # (cspec, ctx) -> bool[Q]

# --------------------------------------------------------------------------
# Built-in filtering predicates (paper §2)
# --------------------------------------------------------------------------


def pred_refresh_urgency(cspec, ctx):
    """Block requests to a refresh unit whose refresh is overdue-urgent."""
    return ~ctx.ref_urgent[ctx.ru]


def pred_act2_exclusive(cspec, ctx):
    """LPDDR5/6: when a pending ACT-2 approaches its tAAD deadline, only
    ACT-2 candidates may issue (nothing may interrupt it)."""
    if not cspec.split_activation:
        return jnp.ones_like(ctx.cand_cmd, bool)
    pending = ctx.cs.dev.row_state[ctx.bank] == D.ROW_ACTIVATING
    deadline = ctx.cs.dev.act1_clk[ctx.bank] + ctx.dp.nAAD
    urgent = pending & (ctx.clk + 2 >= deadline)       # slack of one slot
    is_act2 = ctx.cand_cmd == jnp.int32(cspec.id_ACT2)
    return jnp.where(jnp.any(urgent), is_act2 & urgent, True)


def pred_act2_follows_act1(cspec, ctx):
    """LPDDR5/6: only a request whose bank is Activating may issue ACT-2
    (the prerequisite decoder guarantees it targets the pending row)."""
    if not cspec.split_activation:
        return jnp.ones_like(ctx.cand_cmd, bool)
    is_act2 = ctx.cand_cmd == jnp.int32(cspec.id_ACT2)
    activating = ctx.cs.dev.row_state[ctx.bank] == D.ROW_ACTIVATING
    return ~is_act2 | activating


def _bh_hashes(bank, row):
    k = (bank.astype(jnp.uint32) * jnp.uint32(1_000_003)
         + row.astype(jnp.uint32))
    h0 = ((k * jnp.uint32(2654435761)) >> jnp.uint32(5)) % jnp.uint32(SKETCH)
    h1 = (k * jnp.uint32(40503) + jnp.uint32(2057)) % jnp.uint32(SKETCH)
    return h0.astype(jnp.int32), h1.astype(jnp.int32)


def make_pred_blockhammer(threshold: int):
    """BlockHammer [65]: defer ACTs to rows whose estimated activation count
    exceeds the blacklist threshold."""
    def pred(cspec, ctx):
        opener = cspec.id_ACT1 if cspec.split_activation else cspec.id_ACT
        is_open_cmd = ctx.cand_cmd == jnp.int32(opener)
        h0, h1 = _bh_hashes(ctx.bank, ctx.cand_row)
        est = jnp.minimum(ctx.cs.bh_sketch[0, h0], ctx.cs.bh_sketch[1, h1])
        return ~(is_open_cmd & (est >= threshold))
    return pred


def make_pred_prac(threshold: int):
    """PRAC [66-68]: once a bank's activation counter crosses the alert
    threshold, ordinary requests to its refresh unit are blocked until the
    recovery (RFM, modeled as a priority REFab) completes."""
    def pred(cspec, ctx):
        banks_per_ru = cspec.n_banks // cspec.n_refresh_units
        per_bank_alert = ctx.cs.prac_count >= threshold
        ru_alert = jnp.max(per_bank_alert.reshape(cspec.n_refresh_units,
                                                  banks_per_ru), axis=1)
        return ~ru_alert[ctx.ru]
    return pred


PREDICATES = {
    "refresh_urgency": lambda cspec, ctx: pred_refresh_urgency(cspec, ctx),
    "act2_exclusive": pred_act2_exclusive,
    "act2_follows_act1": pred_act2_follows_act1,
}

# --------------------------------------------------------------------------
# Controller configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    scheduler: str = "FRFCFS"
    queue_depth: int = 32
    refresh_enabled: bool = True
    # urgency margin: refresh becomes *blocking* this many cycles past due
    refresh_urgent_margin: int = 4
    # stagger the initial refresh phase across channels (offset c*nREFI/C,
    # as real controllers do) so an all-channel REF never lands on one
    # cycle; False reproduces the historical in-phase behavior
    refresh_stagger: bool = True
    blockhammer_threshold: int = 0     # 0 = disabled
    prac_threshold: int = 0            # 0 = disabled
    extra_predicates: tuple = ()       # user predicates (cspec, ctx)->bool[Q]

    def predicates(self) -> tuple:
        preds = [pred_refresh_urgency, pred_act2_follows_act1,
                 pred_act2_exclusive]
        if self.blockhammer_threshold:
            preds.append(make_pred_blockhammer(self.blockhammer_threshold))
        if self.prac_threshold:
            preds.append(make_pred_prac(self.prac_threshold))
        return tuple(preds) + tuple(self.extra_predicates)


class StepEvents(NamedTuple):
    """What happened this cycle (static shape; -1 == nothing).

    ``bank`` is the flat bank id of queue-issued commands; refresh-engine
    commands (REFab/PREab) carry the *representative* bank of their refresh
    unit (``ru * banks_per_ru``) so trace auditing can attribute them to the
    right hierarchy node.  ``arrive`` is the served request's arrival clock
    (-1 for refresh-engine commands); ``hit_ready`` records whether a
    post-predicate row-hit candidate existed when this bus slot selected —
    the observable the FR-FCFS row-hit-first audit replays.
    """
    cmd: jnp.ndarray          # (2,) issued command per bus slot [col, row]
    bank: jnp.ndarray         # (2,)
    row: jnp.ndarray          # (2,)
    arrive: jnp.ndarray       # (2,) arrival clk of the served request, -1 n/a
    hit_ready: jnp.ndarray    # (2,) bool — a maskable row-hit was available
    served_read: jnp.ndarray      # bool — a read's final RD issued
    served_write: jnp.ndarray     # bool
    served_probe: jnp.ndarray     # bool — the read served was a probe
    probe_latency: jnp.ndarray    # i32 completion - arrival (valid if probe)
    probe_completion: jnp.ndarray  # i32 absolute completion clock
    deferred: jnp.ndarray         # i32 candidates masked by predicates


# --------------------------------------------------------------------------
# The base scheduling workflow (paper §2) — one pipeline, many controllers
# --------------------------------------------------------------------------


def _candidates(cspec, dp, cs, clk, bank):
    q = cs.queue
    pre = jax.vmap(partial(D.prereq, cspec, dp, cs.dev),
                   in_axes=(0, 0, 0, None))
    cand_cmd, cand_row, open_hit = pre(q.is_write, q.sub, q.row, clk)
    # dense (n_cmds, n_banks) earliest table + one (Q,) lookup — keeps the
    # channel-vmapped pipeline vectorized (no per-slot gather loops)
    table = D.earliest_ready_table(cspec, dp, cs.dev)
    timing_ready = clk >= table[cand_cmd, bank]
    return cand_cmd, cand_row, open_hit, timing_ready, table


def _refresh_plan(cspec, dp, cs, clk, cfg: ControllerConfig):
    """Per-refresh-unit refresh state: due / urgent / candidate command."""
    dev = cs.dev
    due_time = (clk - dev.last_ref) >= dp.nREFI
    # PRAC recovery requests ride the refresh engine (priority REFab)
    if cfg.prac_threshold:
        banks_per_ru = cspec.n_banks // cspec.n_refresh_units
        alert = jnp.max((cs.prac_count >= cfg.prac_threshold).reshape(
            cspec.n_refresh_units, banks_per_ru), axis=1)
        due = due_time | alert
    else:
        due = due_time
    urgent = (clk - dev.last_ref) >= (dp.nREFI + cfg.refresh_urgent_margin)
    if cfg.prac_threshold:
        urgent = urgent | (due & ~due_time)    # PRAC alerts are always urgent
    urgent = urgent & due
    if not cfg.refresh_enabled:
        due = jnp.zeros_like(due)
        urgent = jnp.zeros_like(urgent)
    banks_per_ru = cspec.n_banks // cspec.n_refresh_units
    any_open = jnp.any(
        dev.row_state.reshape(cspec.n_refresh_units, banks_per_ru)
        != D.ROW_CLOSED, axis=1)
    ref_cmd = jnp.where(any_open, jnp.int32(cspec.id_PREab),
                        jnp.int32(cspec.id_REFab))
    return due, urgent, ref_cmd


def _ru_addr(cspec, ru):
    """Address-vector stand-in for a refresh-unit-scoped command."""
    nsub = len(cspec.levels) - 1
    sub = jnp.zeros((nsub,), jnp.int32).at[0].set(ru)
    return sub


def _try_issue_refresh(cspec, dp, cs, clk, due, urgent, ref_cmd,
                       kind_mask_ok, table):
    """Issue the refresh-engine command of the most-overdue due unit.

    Refresh is *opportunistic* until urgent: a merely-due refresh yields to
    pending requests targeting the same unit; an urgent one preempts (the
    ``refresh_urgency`` predicate blocks those requests at the same time).
    ``table`` is the pass's dense earliest-issue table; the refresh unit's
    representative bank resolves its timing through the same lookup the
    queue candidates use.
    """
    score = jnp.where(due, clk - cs.dev.last_ref, -1)
    ru = jnp.argmax(score)
    cmd = ref_cmd[ru]
    sub = _ru_addr(cspec, ru)
    ok_kind = kind_mask_ok[cmd]
    banks_per_ru0 = cspec.n_banks // cspec.n_refresh_units
    ready = clk >= table[cmd, ru * jnp.int32(banks_per_ru0)]
    q = cs.queue
    pending_here = jnp.any(q.valid & (q.sub[:, 0] == ru))
    may_go = urgent[ru] | ~pending_here
    do = jnp.any(due) & ready & ok_kind & may_go
    dev = D.issue(cspec, dp, cs.dev, cmd, sub, jnp.int32(0), clk, do)
    # PRAC: recovery resets the unit's activation counters
    banks_per_ru = cspec.n_banks // cspec.n_refresh_units
    bank_ru = jnp.arange(cspec.n_banks, dtype=jnp.int32) // banks_per_ru
    is_ref = do & (cmd == jnp.int32(cspec.id_REFab))
    prac = jnp.where(is_ref & (bank_ru == ru), 0, cs.prac_count)
    ref_bank = (ru * jnp.int32(banks_per_ru)).astype(jnp.int32)
    return cs._replace(dev=dev, prac_count=prac), do, cmd, ref_bank


def _select_and_issue(cspec, dp, cs, clk, cfg, preds, kind_ok, sched_fn,
                      link_latency: int = 0):
    """One pass of the base pipeline restricted to commands with
    kind_ok[kind] == True (dual C/A runs this twice, paper §2).

    ``link_latency`` (static, cycles) models a CXL-style link in front of
    this channel: a request is not visible to the controller until
    ``arrive + link_latency``, and read data takes another
    ``link_latency`` cycles to cross back — probe completions therefore
    carry ``2 * link_latency`` of round-trip link time end to end."""
    q = cs.queue
    bank = jax.vmap(partial(D.flat_bank, cspec))(q.sub)
    cand_cmd, cand_row, open_hit, timing_ready, table = _candidates(
        cspec, dp, cs, clk, bank)
    ru = q.sub[:, 0]

    due, urgent, ref_cmd = _refresh_plan(cspec, dp, cs, clk, cfg)
    ctx = PredCtx(dp=dp, cs=cs, clk=clk, cand_cmd=cand_cmd,
                  cand_row=cand_row, open_hit=open_hit, bank=bank, ru=ru,
                  ref_urgent=urgent)

    kind_mask = jnp.asarray(cspec.cmd_kind)
    cand_kind_ok = kind_ok[kind_mask[cand_cmd]]

    mask = q.valid & timing_ready & cand_kind_ok
    if link_latency:
        # enqueue-boundary link latency: the request only becomes a
        # candidate once it has crossed the link (clk >= arrive + L);
        # zero-link groups skip the op entirely, keeping their traced
        # program — and command streams — bit-identical
        mask = mask & (clk >= q.arrive + jnp.int32(link_latency))
    pre_pred = mask
    for p in preds:
        mask = mask & p(cspec, ctx)
    deferred = jnp.sum(pre_pred & ~mask)

    # refresh engine first (its commands obey the same kind restriction)
    ref_kind_ok = kind_ok[kind_mask]
    cs, ref_issued, ref_cmd_done, ref_bank = _try_issue_refresh(
        cspec, dp, cs, clk, due, urgent, ref_cmd, ref_kind_ok, table)

    hit_ready = jnp.any(mask & open_hit) & ~ref_issued
    slot, ok = sched_fn(mask & ~ref_issued, open_hit, q.arrive)
    do = ok & ~ref_issued

    cmd = cand_cmd[slot]
    sub = q.sub[slot]
    rowv = cand_row[slot]
    dev = D.issue(cspec, dp, cs.dev, cmd, sub, rowv, clk, do)

    fx = jnp.asarray(cspec.cmd_fx)[cmd]
    fin_rd = do & ((fx & S.FX_FINAL_RD) != 0)
    fin_wr = do & ((fx & S.FX_FINAL_WR) != 0)
    served = fin_rd | fin_wr
    slot_hit = jnp.arange(q.valid.shape[0], dtype=jnp.int32) == slot
    valid = q.valid & ~(slot_hit & served)

    # row-hit streak bookkeeping (FRFCFS-Cap support)
    b = bank[slot]
    b_hit = jnp.arange(cspec.n_banks, dtype=jnp.int32) == b
    streak = cs.hit_streak
    streak = jnp.where(served & b_hit, streak + 1, streak)
    opener = cspec.id_ACT1 if cspec.split_activation else cspec.id_ACT
    streak = jnp.where(do & (cmd == jnp.int32(opener)) & b_hit,
                       0, streak)

    # BlockHammer sketch update on row-open
    sk = cs.bh_sketch
    if cfg.blockhammer_threshold:
        h0, h1 = _bh_hashes(b, rowv)
        is_open_cmd = do & (cmd == jnp.int32(opener))
        sk = jnp.where(is_open_cmd,
                       sk.at[0, h0].add(1).at[1, h1].add(1), sk)
        sk = jnp.where(clk % jnp.int32(dp.nREFI) == 0, sk // 2, sk)
    prac = cs.prac_count
    if cfg.prac_threshold:
        is_open_cmd = do & (cmd == jnp.int32(opener))
        prac = jnp.where(is_open_cmd & b_hit, prac + 1, prac)

    probe = fin_rd & q.is_probe[slot]
    completion = clk + dp.read_latency
    if link_latency:
        # completion-boundary link latency: the data crosses the link back
        completion = completion + jnp.int32(link_latency)
    ev = dict(
        cmd=jnp.where(do, cmd,
                      jnp.where(ref_issued, ref_cmd_done, jnp.int32(-1))),
        bank=jnp.where(do, b,
                       jnp.where(ref_issued, ref_bank, jnp.int32(-1))),
        row=jnp.where(do, rowv, jnp.int32(-1)),
        arrive=jnp.where(do, q.arrive[slot], jnp.int32(-1)),
        hit_ready=hit_ready,
        served_read=fin_rd, served_write=fin_wr, served_probe=probe,
        probe_latency=jnp.where(probe, completion - q.arrive[slot], 0),
        probe_completion=jnp.where(probe, completion, 0),
        deferred=deferred,
    )
    cs = cs._replace(dev=dev, queue=q._replace(valid=valid),
                     hit_streak=streak, bh_sketch=sk, prac_count=prac)
    return cs, ev


# --------------------------------------------------------------------------
# Event horizon (the engine's fast-forward path)
# --------------------------------------------------------------------------

#: see ``repro.core.frontend.HORIZON_MAX`` — shared sentinel value
HORIZON_MAX = jnp.int32(1 << 30)


def channel_horizon(cspec: CompiledSpec, dp: D.DynParams,
                    cfg: ControllerConfig, cs: CtrlState, clk,
                    link_latency: int = 0):
    """Earliest cycle ``>= clk`` at which THIS channel could issue any
    command — queue candidate or refresh engine — evaluated on the
    current (post-cycle) state.

    CONSERVATIVE by construction: predicate, bus-kind, and scheduler
    masks are ignored (they only *shrink* the issue set, so ignoring
    them can only move the horizon earlier), and an early horizon merely
    executes an idle cycle.  What it must never do is overshoot, and it
    can't: every issue requires ``pre_pred`` (valid & timing-ready [&
    link-visible]) or a due+ready refresh unit, and both bounds below
    are exact lower bounds on those events.  Between ``clk`` and the
    horizon the channel state is frozen (every controller/device update
    is gated on an issue), so the bound needs no re-evaluation until the
    next executed cycle.  Components:

    * queue: per valid slot, the dense last-issue/ring earliest-ready
      table at the slot's prerequisite command (the same
      ``table[cand_cmd, bank]`` lookup the selection pipeline performs);
      candidates cannot flip while idle except via WCK/RCK clock expiry
      — bounded separately below;
    * refresh: per unit, ``max(due clock, earliest-ready of its
      PREab/REFab candidate)``; a PRAC alert makes the unit due NOW;
    * clock expiry (``data_clock_sync`` standards): the first
      ``clock_until`` still in the future, where a column candidate
      flips between RD/WR and its CAS/RCKSTRT sync prerequisite;
    * BlockHammer sketch decay: the next ``nREFI`` multiple (the sketch
      halves on those cycles, so they must be executed, not skipped).
    """
    q = cs.queue
    bank = jax.vmap(partial(D.flat_bank, cspec))(q.sub)
    pre = jax.vmap(partial(D.prereq, cspec, dp, cs.dev),
                   in_axes=(0, 0, 0, None))
    cand_cmd, _, _ = pre(q.is_write, q.sub, q.row, clk)
    table = D.earliest_ready_table(cspec, dp, cs.dev)
    t_slot = table[cand_cmd, bank]
    if link_latency:
        t_slot = jnp.maximum(t_slot, q.arrive + jnp.int32(link_latency))
    h = jnp.min(jnp.where(q.valid, t_slot, HORIZON_MAX),
                initial=HORIZON_MAX)
    if cfg.refresh_enabled:
        banks_per_ru = cspec.n_banks // cspec.n_refresh_units
        due_t = cs.dev.last_ref + dp.nREFI
        if cfg.prac_threshold:
            alert = jnp.max(
                (cs.prac_count >= cfg.prac_threshold).reshape(
                    cspec.n_refresh_units, banks_per_ru), axis=1)
            due_t = jnp.where(alert, clk, due_t)
        any_open = jnp.any(
            cs.dev.row_state.reshape(cspec.n_refresh_units, banks_per_ru)
            != D.ROW_CLOSED, axis=1)
        ref_cmd = jnp.where(any_open, jnp.int32(cspec.id_PREab),
                            jnp.int32(cspec.id_REFab))
        rep = jnp.arange(cspec.n_refresh_units, dtype=jnp.int32) \
            * jnp.int32(banks_per_ru)
        h = jnp.minimum(h, jnp.min(jnp.maximum(due_t, table[ref_cmd, rep])))
    if cspec.data_clock_sync:
        cu = cs.dev.clock_until
        h = jnp.minimum(h, jnp.min(jnp.where(cu > clk, cu, HORIZON_MAX)))
    if cfg.blockhammer_threshold:
        h = jnp.minimum(h, ((clk + dp.nREFI - jnp.int32(1)) // dp.nREFI)
                        * dp.nREFI)
    return jnp.maximum(h, clk)


_IDLE_SLOT = dict(cmd=jnp.int32(-1), bank=jnp.int32(-1), row=jnp.int32(-1),
                  arrive=jnp.int32(-1), hit_ready=False)


def _pack_events(ev_col: dict, ev_row: dict | None = None) -> StepEvents:
    """Pack one or two selection-pass event dicts into ``StepEvents``.

    Per-bus-slot fields stack [col-bus, row-bus] (the row slot is idle for
    single-bus standards); per-cycle outcome fields OR/sum across passes —
    at most one pass can serve a given request, so the sums are exact.
    """
    if ev_row is None:
        ev_row = dict(_IDLE_SLOT,
                      **{k: jnp.zeros_like(ev_col[k])
                         for k in ("served_read", "served_write",
                                   "served_probe", "probe_latency",
                                   "probe_completion", "deferred")})
    slot = {k: jnp.stack([jnp.asarray(ev_col[k]), jnp.asarray(ev_row[k])])
            for k in ("cmd", "bank", "row", "arrive", "hit_ready")}
    return StepEvents(
        **slot,
        served_read=ev_col["served_read"] | ev_row["served_read"],
        served_write=ev_col["served_write"] | ev_row["served_write"],
        served_probe=ev_col["served_probe"] | ev_row["served_probe"],
        probe_latency=ev_col["probe_latency"] + ev_row["probe_latency"],
        probe_completion=(ev_col["probe_completion"]
                          + ev_row["probe_completion"]),
        deferred=ev_col["deferred"] + ev_row["deferred"],
    )


def controller_step(cspec: CompiledSpec, dp: D.DynParams, cfg: ControllerConfig,
                    cs: CtrlState, clk, link_latency: int = 0) -> tuple:
    """One controller cycle for ONE channel.  Dual-C/A standards run the
    selection pipeline twice — a column pass and a row pass (paper §2);
    others run it once.  The engine vmaps this function across each spec
    group's channels inside its cycle scan; CXL-attached groups pass their
    static ``link_latency``, applied at the enqueue boundary (request
    visibility) and the completion boundary (read-data return)."""
    preds = cfg.predicates()
    sched_fn = SCHEDULERS[cfg.scheduler]
    n_kinds = 4

    if cspec.dual_command_bus:
        col_ok = jnp.asarray(
            [k in (S.KIND_COL, S.KIND_SYNC) for k in range(n_kinds)])
        row_ok = jnp.asarray(
            [k in (S.KIND_ROW, S.KIND_REF) for k in range(n_kinds)])
        cs, ev_col = _select_and_issue(cspec, dp, cs, clk, cfg, preds,
                                       col_ok, sched_fn, link_latency)
        cs, ev_row = _select_and_issue(cspec, dp, cs, clk, cfg, preds,
                                       row_ok, sched_fn, link_latency)
        events = _pack_events(ev_col, ev_row)
    else:
        all_ok = jnp.ones((n_kinds,), bool)
        cs, ev = _select_and_issue(cspec, dp, cs, clk, cfg, preds, all_ok,
                                   sched_fn, link_latency)
        events = _pack_events(ev)
    return cs, events
