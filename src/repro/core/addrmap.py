"""Address mappers: linear physical address <-> DRAM address vector.

Channel-aware and JAX-traceable.  ``AddressMapper`` lowers a mapper *order*
string (Ramulator convention: Row / Bank(+group) / Rank / Column / Channel
fields listed MSB -> LSB) into a mixed-radix ``layout`` — a list of
``(field_name, count)`` pairs, least-significant first — over the compiled
spec's geometry.  The channel field width comes from ``cspec.n_channels``
(it is no longer pinned to one channel), so decoding a linear address
yields the target channel alongside the per-channel sub-level indices.

``map``/``encode`` use only arithmetic (``%``, ``//``, ``*``, shifts), so
they work identically on numpy int64 arrays (host-side tooling, replay
preparation) and on traced ``jnp`` arrays — the engine's streaming
frontend decodes its linear request counter through this very layout
inside the ``lax.scan`` body.  Mixed radix degrades to exact bit slicing
when every count is a power of two and stays correct when it is not
(e.g. benchmarks that shrink ``rows`` in place).

Request sources that consume a layout (see ``repro.core.frontend``):

  * streaming frontend — the sequential pattern decodes its linear
    request counter through the layout each cycle;
  * trace-driven frontend — replayed linear addresses are decoded
    host-side into per-channel request columns (``ReplayStream``);
  * probe frontend — random fields are drawn per layout entry.
"""
from __future__ import annotations

import numpy as np

from repro.core.compile import CompiledSpec


def _field_bits(n: int) -> int:
    return max(int(np.ceil(np.log2(max(n, 1)))), 0)


def split_fields(addr: np.ndarray, widths: list) -> list:
    """Split a linear address into bit fields, LSB-first widths list."""
    out = []
    a = np.asarray(addr, np.int64)
    for w in widths:
        out.append(a & ((1 << w) - 1))
        a = a >> w
    return out


def make_layout(cspec: CompiledSpec, order: str) -> list:
    """Lower a mapper order string to ``[(field, count), ...]`` LSB-first.

    Field names are ``"channel"``, the spec's sub-channel levels (rank /
    bankgroup / bank / pseudochannel...), ``"row"`` and ``"col"``.
    """
    sub_levels = cspec.levels[1:]
    bank_like = [lv for lv in sub_levels if lv in ("bankgroup", "bank")]
    rank_like = [lv for lv in sub_levels if lv not in ("bankgroup", "bank")]
    counts = {lv: int(cspec.level_counts[i + 1])
              for i, lv in enumerate(sub_levels)}
    field_defs = {
        "Ch": [("channel", int(cspec.n_channels))],
        "Ra": [(lv, counts[lv]) for lv in rank_like],
        "Ba": [(lv, counts[lv]) for lv in bank_like],
        "Ro": [("row", int(cspec.rows))],
        "Co": [("col", int(cspec.columns))],
    }
    toks = [order[i:i + 2] for i in range(0, len(order), 2)]
    if sorted(toks) != sorted(field_defs):
        raise ValueError(f"bad mapper order {order!r}: need each of "
                         f"{sorted(field_defs)} exactly once")
    lsb_first = []
    for tok in reversed(toks):          # order string is MSB -> LSB
        lsb_first.extend(field_defs[tok])
    return lsb_first


def decode_fields(layout, value):
    """Mixed-radix decode of a line index through ``layout`` (LSB-first).

    Pure ``%``/``//`` arithmetic, so it works identically on numpy
    arrays and on traced jnp values — the single decode implementation
    shared by :meth:`AddressMapper.map` (host side) and the engine's
    streaming frontend (inside the scan body).
    """
    out = {}
    q = value
    for name, count in layout:
        out[name] = q % count
        q = q // count
    return out


class AddressMapper:
    """Decode/encode linear addresses through a mapper layout.

    ``order`` reads MSB->LSB, e.g. ``"RoBaRaCoCh"`` is
    Row | Bank | Rank | Column | Channel (channel bits least significant:
    consecutive cache lines interleave across channels).
    """

    def __init__(self, cspec: CompiledSpec, order: str = "RoBaRaCoCh",
                 tx_bytes: int | None = None):
        self.cspec = cspec
        self.order = order
        self.tx_bits = _field_bits(tx_bytes or cspec.access_bytes)
        self.layout = make_layout(cspec, order)   # [(name, count)] LSB-first

    def map(self, addr):
        """addr (bytes) -> dict of address fields (vectorized, traceable)."""
        return decode_fields(self.layout, addr >> self.tx_bits)

    def encode(self, fields: dict):
        """Inverse of :meth:`map`: field dict -> linear byte address."""
        a = None
        for name, count in reversed(self.layout):   # MSB first
            f = fields[name]
            a = f if a is None else a * count + f
        return a << self.tx_bits

    def to_chan_sub_row_col(self, addr):
        """addr -> (channel, sub[levels-1], row, col) numpy arrays, the
        request-column form consumed by the engine/DUT and ``ReplayStream``."""
        f = self.map(np.asarray(addr, np.int64))
        sub = np.stack([f.get(lv, np.zeros_like(f["row"]))
                        for lv in self.cspec.levels[1:]], axis=-1)
        return f["channel"], sub, f["row"], f["col"]

    def to_sub_row_col(self, addr):
        """Single-channel legacy form: addr -> (sub, row, col)."""
        _, sub, row, col = self.to_chan_sub_row_col(addr)
        return sub, row, col


#: Supported mapper orders (MSB -> LSB).  ``RoBaRaCoCh`` interleaves
#: channels then columns fastest (row-buffer friendly, channel-parallel);
#: ``RoCoBaRaCh`` rotates banks fastest (bank-parallel streaming).
MAPPERS = ["RoBaRaCoCh", "RoRaBaCoCh", "RoCoBaRaCh"]
