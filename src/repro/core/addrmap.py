"""Address mappers: linear physical address <-> DRAM address vector.

Channel-aware and JAX-traceable.  ``AddressMapper`` lowers a mapper *order*
string (Ramulator convention: Row / Bank(+group) / Rank / Column / Channel
fields listed MSB -> LSB) into a mixed-radix ``layout`` — a list of
``(field_name, count)`` pairs, least-significant first — over the compiled
spec's geometry.  The channel field width comes from ``cspec.n_channels``
(it is no longer pinned to one channel), so decoding a linear address
yields the target channel alongside the per-channel sub-level indices.

``map``/``encode`` use only arithmetic (``%``, ``//``, ``*``, shifts), so
they work identically on numpy int64 arrays (host-side tooling, replay
preparation) and on traced ``jnp`` arrays — the engine's streaming
frontend decodes its linear request counter through this very layout
inside the ``lax.scan`` body.  Mixed radix degrades to exact bit slicing
when every count is a power of two and stays correct when it is not
(e.g. benchmarks that shrink ``rows`` in place).

Request sources that consume a layout (see ``repro.core.frontend``):

  * streaming frontend — the sequential pattern decodes its linear
    request counter through the layout each cycle;
  * trace-driven frontend — replayed linear addresses are decoded
    host-side into per-channel request columns (``ReplayStream``);
  * probe frontend — random fields are drawn per layout entry.
"""
from __future__ import annotations

import numpy as np

from repro.core.compile import CompiledSpec


def _field_bits(n: int) -> int:
    return max(int(np.ceil(np.log2(max(n, 1)))), 0)


def split_fields(addr: np.ndarray, widths: list) -> list:
    """Split a linear address into bit fields, LSB-first widths list."""
    out = []
    a = np.asarray(addr, np.int64)
    for w in widths:
        out.append(a & ((1 << w) - 1))
        a = a >> w
    return out


def make_layout(cspec: CompiledSpec, order: str) -> list:
    """Lower a mapper order string to ``[(field, count), ...]`` LSB-first.

    Field names are ``"channel"``, the spec's sub-channel levels (rank /
    bankgroup / bank / pseudochannel...), ``"row"`` and ``"col"``.
    """
    sub_levels = cspec.levels[1:]
    bank_like = [lv for lv in sub_levels if lv in ("bankgroup", "bank")]
    rank_like = [lv for lv in sub_levels if lv not in ("bankgroup", "bank")]
    counts = {lv: int(cspec.level_counts[i + 1])
              for i, lv in enumerate(sub_levels)}
    field_defs = {
        "Ch": [("channel", int(cspec.n_channels))],
        "Ra": [(lv, counts[lv]) for lv in rank_like],
        "Ba": [(lv, counts[lv]) for lv in bank_like],
        "Ro": [("row", int(cspec.rows))],
        "Co": [("col", int(cspec.columns))],
    }
    toks = [order[i:i + 2] for i in range(0, len(order), 2)]
    if sorted(toks) != sorted(field_defs):
        raise ValueError(f"bad mapper order {order!r}: need each of "
                         f"{sorted(field_defs)} exactly once")
    lsb_first = []
    for tok in reversed(toks):          # order string is MSB -> LSB
        lsb_first.extend(field_defs[tok])
    return lsb_first


def decode_fields(layout, value):
    """Mixed-radix decode of a line index through ``layout`` (LSB-first).

    Pure ``%``/``//`` arithmetic, so it works identically on numpy
    arrays and on traced jnp values — the single decode implementation
    shared by :meth:`AddressMapper.map` (host side) and the engine's
    streaming frontend (inside the scan body).
    """
    out = {}
    q = value
    for name, count in layout:
        out[name] = q % count
        q = q // count
    return out


def encode_fields(layout, fields):
    """Inverse of :func:`decode_fields`: fold a field dict back into the
    line index (MSB-first accumulate) — the single encode implementation
    shared by :meth:`AddressMapper.encode` and the system mapper."""
    a = None
    for name, count in reversed(layout):    # MSB first
        f = fields[name]
        a = f if a is None else a * count + f
    return 0 if a is None else a


class AddressMapper:
    """Decode/encode linear addresses through a mapper layout.

    ``order`` reads MSB->LSB, e.g. ``"RoBaRaCoCh"`` is
    Row | Bank | Rank | Column | Channel (channel bits least significant:
    consecutive cache lines interleave across channels).
    """

    def __init__(self, cspec: CompiledSpec, order: str = "RoBaRaCoCh",
                 tx_bytes: int | None = None):
        self.cspec = cspec
        self.order = order
        self.tx_bits = _field_bits(tx_bytes or cspec.access_bytes)
        self.layout = make_layout(cspec, order)   # [(name, count)] LSB-first

    def map(self, addr):
        """addr (bytes) -> dict of address fields (vectorized, traceable)."""
        return decode_fields(self.layout, addr >> self.tx_bits)

    def encode(self, fields: dict):
        """Inverse of :meth:`map`: field dict -> linear byte address."""
        return encode_fields(self.layout, fields) << self.tx_bits

    def to_chan_sub_row_col(self, addr):
        """addr -> (channel, sub[levels-1], row, col) numpy arrays, the
        request-column form consumed by the engine/DUT and ``ReplayStream``."""
        f = self.map(np.asarray(addr, np.int64))
        sub = np.stack([f.get(lv, np.zeros_like(f["row"]))
                        for lv in self.cspec.levels[1:]], axis=-1)
        return f["channel"], sub, f["row"], f["col"]

    def to_sub_row_col(self, addr):
        """Single-channel legacy form: addr -> (sub, row, col)."""
        _, sub, row, col = self.to_chan_sub_row_col(addr)
        return sub, row, col


#: Supported mapper orders (MSB -> LSB).  ``RoBaRaCoCh`` interleaves
#: channels then columns fastest (row-buffer friendly, channel-parallel);
#: ``RoCoBaRaCh`` rotates banks fastest (bank-parallel streaming).
MAPPERS = ["RoBaRaCoCh", "RoRaBaCoCh", "RoCoBaRaCh"]


# --------------------------------------------------------------------------
# System-level mapping: one channel digit routing across spec groups
# --------------------------------------------------------------------------
#
# A heterogeneous memory system (repro.core.compile.MemorySystemSpec) has
# groups with possibly different bank/row/column radices, so one global
# mixed-radix layout cannot exist.  Instead the mapper gains a *system-level
# channel digit*: the linear line index is first split as
#
#     chan_sys = q % n_channels ; q' = q // n_channels
#
# and the remainder q' is decoded through the *owning group's* own layout
# (its mapper order minus the channel field).  Every supported order keeps
# the channel field least significant, which is exactly what makes this
# split well-defined; for a 1-group system the split degenerates to the
# group's ordinary layout, bit for bit.


def make_system_layout(msys, order: str):
    """Lower a mapper order for a memory system.

    Returns ``("single", layout)`` for 1-group systems (the group's
    ordinary :func:`make_layout` layout, any order allowed) or
    ``("multi", n_channels, bases, sublayouts)`` where ``sublayouts[g]``
    is group ``g``'s LSB-first layout *without* the channel field and
    ``bases[g]`` its first system channel id.
    """
    if msys.n_groups == 1:
        return ("single", make_layout(msys.groups[0].cspec, order))
    toks = [order[i:i + 2] for i in range(0, len(order), 2)]
    if toks[-1] != "Ch":
        raise ValueError(
            f"mapper order {order!r} puts the channel field above the LSB "
            "— heterogeneous systems need channel-least-significant orders "
            f"(supported: {MAPPERS}) so the post-channel remainder can be "
            "decoded per spec group")
    subs = []
    for g in msys.groups:
        lay = [(n, c) for (n, c) in make_layout(g.cspec, order)
               if n != "channel"]
        subs.append(tuple(lay))
    return ("multi", int(msys.n_channels),
            tuple(int(b) for b in msys.chan_base), tuple(subs))


class SystemAddressMapper:
    """Decode/encode linear addresses across a heterogeneous memory system.

    Consecutive transaction-sized lines interleave across ALL system
    channels (the system channel digit is least significant); the
    remainder of the line index is decoded through the owning group's own
    mixed-radix layout.  ``tx_bytes`` defaults to the largest group
    ``access_bytes`` so one line granularity covers every group.
    """

    def __init__(self, msys, order: str = "RoBaRaCoCh",
                 tx_bytes: int | None = None):
        from repro.core.compile import as_system
        self.msys = as_system(msys)
        self.order = order
        self.tx_bits = _field_bits(
            tx_bytes or max(g.cspec.access_bytes for g in self.msys.groups))
        kind = make_system_layout(self.msys, order)
        if kind[0] == "single":
            self._single = AddressMapper(self.msys.groups[0].cspec, order,
                                         tx_bytes)
        else:
            self._single = None
            _, self.n_channels, self.bases, self.sublayouts = kind

    def to_chan_sub_row_col(self, addr):
        """addr (bytes) -> (chan, sub, row, col) numpy arrays.

        ``chan`` is the system channel id; ``sub`` is padded to the widest
        group's sub-level count (group ``g`` consumes its first
        ``len(levels_g) - 1`` entries, the rest are zero)."""
        if self._single is not None:
            return self._single.to_chan_sub_row_col(addr)
        a = np.asarray(addr, np.int64) >> self.tx_bits
        chan = a % self.n_channels
        q = a // self.n_channels
        groups = self.msys.groups
        gid = self.msys.chan_group[chan]
        width = max(len(g.cspec.levels) - 1 for g in groups)
        sub = np.zeros(a.shape + (width,), np.int64)
        row = np.zeros_like(a)
        col = np.zeros_like(a)
        for g, (grp, lay) in enumerate(zip(groups, self.sublayouts)):
            m = gid == g
            if not np.any(m):
                continue
            f = decode_fields(lay, q[m])
            for i, lv in enumerate(grp.cspec.levels[1:]):
                sub[m, i] = f.get(lv, 0)
            row[m] = f["row"]
            col[m] = f["col"]
        return chan, sub, row, col

    def encode(self, chan, sub, row, col):
        """Inverse of :meth:`to_chan_sub_row_col` -> linear byte address."""
        if self._single is not None:
            fields = {"channel": np.asarray(chan, np.int64),
                      "row": np.asarray(row, np.int64),
                      "col": np.asarray(col, np.int64)}
            sub = np.asarray(sub, np.int64)
            for i, lv in enumerate(self.msys.groups[0].cspec.levels[1:]):
                fields[lv] = sub[..., i]
            return self._single.encode(fields)
        chan = np.asarray(chan, np.int64)
        sub = np.asarray(sub, np.int64)
        row = np.asarray(row, np.int64)
        col = np.asarray(col, np.int64)
        gid = self.msys.chan_group[chan]
        q = np.zeros_like(chan)
        for g, (grp, lay) in enumerate(zip(self.msys.groups,
                                           self.sublayouts)):
            m = gid == g
            if not np.any(m):
                continue
            fields = {"row": row[m], "col": col[m]}
            for i, lv in enumerate(grp.cspec.levels[1:]):
                fields[lv] = sub[m, i]
            q[m] = encode_fields(lay, fields)
        return (q * self.n_channels + chan) << self.tx_bits
