"""Address mappers: physical address -> DRAM address vector.

Used by the trace-driven frontend and examples.  Mapper names follow
Ramulator convention: ordering of Row / Bank(+group) / Rank / Column /
Channel fields from MSB to LSB.
"""
from __future__ import annotations

import numpy as np

from repro.core.compile import CompiledSpec


def _field_bits(n: int) -> int:
    return max(int(np.ceil(np.log2(max(n, 1)))), 0)


def split_fields(addr: np.ndarray, widths: list) -> list:
    """Split a linear address into fields, LSB-first widths list."""
    out = []
    a = np.asarray(addr, np.int64)
    for w in widths:
        out.append(a & ((1 << w) - 1))
        a = a >> w
    return out


class AddressMapper:
    """order: field names LSB->MSB, e.g. RoBaRaCoCh reads MSB->LSB as
    Row | Bank | Rank | Column | Channel."""

    def __init__(self, cspec: CompiledSpec, order: str = "RoBaRaCoCh",
                 tx_bytes: int | None = None):
        self.cspec = cspec
        self.order = order
        self.tx_bits = _field_bits(tx_bytes or cspec.access_bytes)
        sub_levels = cspec.levels[1:]
        bank_like = [lv for lv in sub_levels if lv in ("bankgroup", "bank")]
        rank_like = [lv for lv in sub_levels if lv not in ("bankgroup", "bank")]
        counts = {lv: int(cspec.level_counts[i + 1])
                  for i, lv in enumerate(sub_levels)}
        field_defs = {
            "Ch": [("channel", 1)],
            "Ra": [(lv, counts[lv]) for lv in rank_like],
            "Ba": [(lv, counts[lv]) for lv in bank_like],
            "Ro": [("row", cspec.rows)],
            "Co": [("col", cspec.columns)],
        }
        # parse the order string into 2-char tokens, MSB -> LSB
        toks = [order[i:i + 2] for i in range(0, len(order), 2)]
        lsb_first = []
        for tok in reversed(toks):
            lsb_first.extend(field_defs[tok])
        self.layout = lsb_first   # [(name, count), ...] LSB-first

    def map(self, addr):
        """addr (bytes) -> dict of address fields (vectorized)."""
        a = np.asarray(addr, np.int64) >> self.tx_bits
        out = {}
        for name, count in self.layout:
            bits = _field_bits(count)
            out[name] = (a & ((1 << bits) - 1)).astype(np.int32)
            a = a >> bits
        return out

    def to_sub_row_col(self, addr):
        """addr -> (sub[levels-1], row, col) arrays for the engine/DUT."""
        f = self.map(addr)
        sub = np.stack([f.get(lv, np.zeros_like(f["row"]))
                        for lv in self.cspec.levels[1:]], axis=-1)
        return sub, f["row"], f["col"]


MAPPERS = ["RoBaRaCoCh", "RoRaBaCoCh", "RoCoBaRaCh"]
