"""Auto-generated Python proxies + YAML round-trip (paper §3.1).

Ramulator 2.1 auto-generates Python proxy classes for every C++ simulator
component; proxies are *lightweight structured objects* that mirror the
component hierarchy and hold configuration without binding to the live
engine.  A tool converts a proxy tree into an equivalent pure-text YAML
file so non-Python embedders can drive the simulator.

Here the "components" are the engine's config dataclasses and the standard
registry.  Proxies are generated *automatically* by introspecting the
component registry — adding a new component (or a field to one) requires no
manual proxy maintenance, matching the paper's build-time generation.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Any

from repro.core import spec as S
from repro.core.controller import ControllerConfig
from repro.core.frontend import FrontendConfig

# --------------------------------------------------------------------------
# Component registry: every configurable engine component registers here.
# --------------------------------------------------------------------------

COMPONENTS: dict = {
    "Controller": ControllerConfig,
    "Frontend": FrontendConfig,
}


def _proxy_for(name: str, cls) -> type:
    """Generate a proxy class mirroring a component's config fields."""
    fields = {f.name: f for f in dataclasses.fields(cls)}

    def __init__(self, **kw):
        for k in kw:
            if k not in fields:
                raise TypeError(f"{name}: unknown parameter {k!r}; "
                                f"valid: {sorted(fields)}")
        for f in fields.values():
            if f.name in kw:
                v = kw[f.name]
            elif f.default is not dataclasses.MISSING:
                v = f.default
            elif f.default_factory is not dataclasses.MISSING:  # type: ignore
                v = f.default_factory()                          # type: ignore
            else:
                raise TypeError(f"{name}: missing parameter {f.name!r}")
            setattr(self, f.name, v)

    def params(self):
        return {f: getattr(self, f) for f in fields}

    def build(self):
        return cls(**{f: getattr(self, f) for f in fields
                      if not str(f).startswith("_")})

    def __repr__(self):
        body = ", ".join(f"{k}={getattr(self, k)!r}" for k in fields)
        return f"{name}({body})"

    return type(name, (), {
        "__init__": __init__, "params": params, "build": build,
        "__repr__": __repr__, "_component_cls": cls,
        "_fields": tuple(fields),
    })


def generate_proxies(module_name: str = __name__) -> dict:
    """Generate proxies for every registered component (build-time step)."""
    mod = sys.modules[module_name]
    out = {}
    for name, cls in COMPONENTS.items():
        proxy = _proxy_for(name, cls)
        setattr(mod, name, proxy)
        out[name] = proxy
    return out


PROXIES = generate_proxies()


class System:
    """Top-level proxy composing the simulated system (paper Fig: frontend ->
    controller -> device).  ``build()`` returns a live ``Simulator``."""

    def __init__(self, standard: str, org_preset: str, timing_preset: str,
                 controller=None, frontend=None, n_cycles: int = 100_000,
                 timing_overrides: dict | None = None, channels: int = 1):
        S.get_standard(standard)   # validate early
        self.standard = standard
        self.org_preset = org_preset
        self.timing_preset = timing_preset
        self.controller = controller or PROXIES["Controller"]()
        self.frontend = frontend or PROXIES["Frontend"]()
        self.n_cycles = n_cycles
        self.timing_overrides = timing_overrides or {}
        self.channels = int(channels)

    def build(self):
        from repro.core.engine import Simulator
        return Simulator(self.standard, self.org_preset, self.timing_preset,
                         controller=self.controller.build(),
                         frontend=self.frontend.build(),
                         timing_overrides=self.timing_overrides or None,
                         channels=self.channels)

    # ---- YAML round-trip ---------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "standard": self.standard,
            "org_preset": self.org_preset,
            "timing_preset": self.timing_preset,
            "n_cycles": self.n_cycles,
            "channels": self.channels,
            "timing_overrides": dict(self.timing_overrides),
            "Controller": _plain(self.controller.params()),
            "Frontend": _plain(self.frontend.params()),
        }

    def to_yaml(self) -> str:
        return emit_yaml(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "System":
        ctrl = PROXIES["Controller"](**d.get("Controller", {}))
        front = PROXIES["Frontend"](**d.get("Frontend", {}))
        return cls(d["standard"], d["org_preset"], d["timing_preset"],
                   controller=ctrl, frontend=front,
                   n_cycles=int(d.get("n_cycles", 100_000)),
                   timing_overrides=d.get("timing_overrides") or {},
                   channels=int(d.get("channels", 1)))

    @classmethod
    def from_yaml(cls, text: str) -> "System":
        return cls.from_dict(parse_yaml(text))


def _plain(d: dict) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, tuple):
            v = list(v)
        if k == "extra_predicates":       # callables are not serializable
            v = []
        out[k] = v
    return out


# --------------------------------------------------------------------------
# Minimal YAML (emit always; parse via pyyaml when present, else built-in
# subset parser — keeps the pure-text path dependency-free, paper §3.1).
# --------------------------------------------------------------------------

def emit_yaml(d: dict, indent: int = 0) -> str:
    pad = "  " * indent
    lines = []
    for k, v in d.items():
        if isinstance(v, dict):
            lines.append(f"{pad}{k}:")
            lines.append(emit_yaml(v, indent + 1))
        elif isinstance(v, (list, tuple)):
            lines.append(f"{pad}{k}: [{', '.join(_scalar(x) for x in v)}]")
        else:
            lines.append(f"{pad}{k}: {_scalar(v)}")
    return "\n".join(x for x in lines if x)


def _scalar(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return repr(v)
    return str(v)


def parse_yaml(text: str) -> dict:
    try:
        import yaml
        return yaml.safe_load(text)
    except ImportError:
        pass
    root: dict = {}
    stack: list = [(-1, root)]
    for raw in text.splitlines():
        if not raw.strip() or raw.lstrip().startswith("#"):
            continue
        indent = len(raw) - len(raw.lstrip())
        key, _, val = raw.strip().partition(":")
        val = val.strip()
        while stack and indent <= stack[-1][0]:
            stack.pop()
        cur = stack[-1][1]
        if not val:
            child: dict = {}
            cur[key] = child
            stack.append((indent, child))
        elif val.startswith("["):
            items = [x.strip() for x in val.strip("[]").split(",") if x.strip()]
            cur[key] = [_coerce(x) for x in items]
        else:
            cur[key] = _coerce(val)
    return root


def _coerce(v: str):
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        pass
    return v
