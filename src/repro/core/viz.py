"""DRAM command-trace visualizer (paper §4.1, Fig. 2) — standalone HTML.

Two interactive views, as in the paper:
  (a) bus-utilization view — per-window C/A-bus and data-bus occupancy;
  (b) command-trace view  — per-bank command rectangles over time with
      hover tooltips (command, bank, row, cycle).

The live "attach to a running simulation" mode of the paper maps here to
feeding the trace arrays emitted by ``Simulator.run(..., trace=True)``
straight into ``render_html`` — same UX, no socket (DESIGN.md §2).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.compile import CompiledSpec

_PALETTE = ["#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f",
            "#edc948", "#b07aa1", "#ff9da7", "#9c755f", "#bab0ac",
            "#86bcb6", "#d37295"]


def trace_to_records(cspec: CompiledSpec, trace, start: int = 0,
                     limit: int | None = None) -> list:
    """Convert engine trace arrays (cmds[T,2], banks[T,2], rows[T,2]) into
    a list of {clk, cmd, bank, row, bus} records."""
    cmds, banks, rows = (np.asarray(t) for t in trace)
    recs = []
    T = cmds.shape[0] if limit is None else min(limit, cmds.shape[0])
    for t in range(start, T):
        for bus in range(cmds.shape[1]):
            c = int(cmds[t, bus])
            if c < 0:
                continue
            recs.append({"clk": t, "cmd": cspec.cmd_names[c],
                         "bank": int(banks[t, bus]), "row": int(rows[t, bus]),
                         "bus": bus})
    return recs


def render_html(cspec: CompiledSpec, trace, title: str = "",
                limit: int | None = 4096) -> str:
    recs = trace_to_records(cspec, trace, limit=limit)
    colors = {name: _PALETTE[i % len(_PALETTE)]
              for i, name in enumerate(cspec.cmd_names)}
    nbl = int(cspec.timings["nBL"])
    payload = json.dumps({
        "title": title or f"{cspec.name} command trace",
        "standard": cspec.name, "n_banks": int(cspec.n_banks),
        "nBL": nbl, "colors": colors, "records": recs,
        "cmd_kind": {n: int(k) for n, k in zip(cspec.cmd_names,
                                               cspec.cmd_kind)},
    })
    return _TEMPLATE.replace("__PAYLOAD__", payload)


def write_html(path: str, cspec: CompiledSpec, trace, title: str = "",
               limit: int | None = 4096) -> str:
    html = render_html(cspec, trace, title, limit)
    with open(path, "w") as f:
        f.write(html)
    return path


_TEMPLATE = """<!doctype html>
<html><head><meta charset="utf-8"><title>Ramulator-JAX trace</title>
<style>
 body{font-family:system-ui,sans-serif;margin:12px;background:#fafafa}
 h2{margin:4px 0} .views{display:flex;flex-direction:column;gap:12px}
 canvas{background:#fff;border:1px solid #ccc;width:100%}
 #tip{position:fixed;background:#222;color:#fff;padding:4px 8px;
      border-radius:4px;font-size:12px;pointer-events:none;display:none}
 .legend span{display:inline-block;margin-right:10px;font-size:12px}
 .legend i{display:inline-block;width:10px;height:10px;margin-right:3px}
 .bar{display:flex;gap:16px;align-items:center;font-size:13px}
</style></head><body>
<h2 id="title"></h2>
<div class="bar">
  <label>zoom <input id="zoom" type="range" min="1" max="40" value="6"></label>
  <label>offset <input id="off" type="range" min="0" max="100" value="0"></label>
  <span id="stats"></span>
</div>
<div class="views">
 <div><b>(a) bus utilization</b><canvas id="bus" height="140"></canvas></div>
 <div><b>(b) command trace</b><canvas id="cmds" height="420"></canvas></div>
</div>
<div class="legend" id="legend"></div>
<div id="tip"></div>
<script>
const D = __PAYLOAD__;
document.getElementById('title').textContent = D.title;
const recs = D.records;
const maxClk = recs.length ? recs[recs.length-1].clk + 1 : 1;
const legend = document.getElementById('legend');
for (const [name,col] of Object.entries(D.colors)) {
  const s=document.createElement('span');
  s.innerHTML='<i style="background:'+col+'"></i>'+name; legend.appendChild(s);
}
const busC = document.getElementById('bus'), cmdC = document.getElementById('cmds');
const tip = document.getElementById('tip');
function layout(){
  busC.width = busC.clientWidth; cmdC.width = cmdC.clientWidth; draw();
}
let pxPerClk = 6, off = 0;
document.getElementById('zoom').oninput = e=>{pxPerClk=+e.target.value;draw();};
document.getElementById('off').oninput = e=>{off=+e.target.value/100*maxClk;draw();};
function draw(){
  const W = cmdC.width, lanes = D.n_banks + 1;
  const laneH = Math.max(6, Math.floor((cmdC.height-20)/lanes));
  let g = cmdC.getContext('2d'); g.clearRect(0,0,W,cmdC.height);
  g.font='10px sans-serif'; g.fillStyle='#888';
  for (let b=0;b<D.n_banks;b++) g.fillText('bank '+b, 2, 12+b*laneH+laneH/2);
  let busUse = {}, dataUse = {};
  for (const r of recs){
    const x = (r.clk-off)*pxPerClk + 60;
    if (x < -20 || x > W) {
      // still accumulate utilization over visible window only
    }
    const wbin = Math.floor(r.clk/32);
    busUse[wbin] = (busUse[wbin]||0)+1;
    const isCol = D.cmd_kind[r.cmd]===1;
    if (isCol) dataUse[wbin]=(dataUse[wbin]||0)+D.nBL;
    if (x < -20 || x > W) continue;
    const lane = r.bank >= 0 ? r.bank : D.n_banks;
    g.fillStyle = D.colors[r.cmd]||'#000';
    g.fillRect(x, 8+lane*laneH, Math.max(2,pxPerClk*0.9), laneH-2);
  }
  // bus utilization view: 32-cycle bins
  const bg = busC.getContext('2d'); bg.clearRect(0,0,busC.width,busC.height);
  const bins = Math.ceil(maxClk/32);
  const bw = Math.max(1, (busC.width-70)/bins);
  bg.fillStyle='#888'; bg.font='10px sans-serif';
  bg.fillText('C/A bus', 2, 30); bg.fillText('data bus', 2, 100);
  for (let i=0;i<bins;i++){
    const u=(busUse[i]||0)/64, d=Math.min(1,(dataUse[i]||0)/32);
    bg.fillStyle='#4e79a7'; bg.fillRect(60+i*bw, 50-40*u, Math.max(1,bw-0.5), 40*u);
    bg.fillStyle='#e15759'; bg.fillRect(60+i*bw, 120-40*d, Math.max(1,bw-0.5), 40*d);
  }
  document.getElementById('stats').textContent =
    recs.length+' commands, '+maxClk+' cycles';
}
cmdC.onmousemove = e=>{
  const rect = cmdC.getBoundingClientRect();
  const clk = Math.round((e.clientX-rect.left-60)/pxPerClk + off);
  const hits = recs.filter(r=>Math.abs(r.clk-clk)<=1);
  if (hits.length){
    tip.style.display='block'; tip.style.left=(e.clientX+12)+'px';
    tip.style.top=(e.clientY+12)+'px';
    tip.textContent = hits.map(r=>r.cmd+'@clk'+r.clk+' bank'+r.bank+' row'+r.row).join(' | ');
  } else tip.style.display='none';
};
cmdC.onmouseleave = ()=>{tip.style.display='none';};
window.onresize = layout; layout();
</script></body></html>
"""
