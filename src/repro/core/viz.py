"""Back-compat shim over the `repro.trace` subsystem (paper §4.1).

The visualizer now lives in `src/repro/trace/viz.py`, operating on compact
columnar `repro.trace.CommandTrace` captures with level-of-detail rendering
and derived bus-utilization denominators.  This module keeps the original
`(cspec, dense_trace_arrays)` entry points working: each call compacts the
engine's dense trace arrays via `repro.trace.capture` and delegates.
"""
from __future__ import annotations

from repro.core.compile import CompiledSpec
from repro.trace import format as _format
from repro.trace import viz as _viz
from repro.trace.capture import capture as _capture_fn

_PALETTE = _viz.PALETTE        # legacy alias


def _to_command_trace(cspec: CompiledSpec, trace):
    return _capture_fn(cspec, trace)


def trace_to_records(cspec: CompiledSpec, trace, start: int = 0,
                     limit: int | None = None) -> list:
    """Convert engine trace arrays into `{clk, cmd, bank, row, bus}`
    records (commands with ``start <= clk < limit``)."""
    ct = _to_command_trace(cspec, trace)
    recs = []
    for r in _format.iter_records(ct, start=start, stop=limit):
        r.pop("arrive", None)
        recs.append(r)
    return recs


def render_html(cspec: CompiledSpec, trace, title: str = "",
                limit: int | None = None) -> str:
    """Render the two-view HTML from dense engine trace arrays.  ``limit``
    is accepted for backwards compatibility and ignored — the new renderer
    is level-of-detail and handles full-length traces."""
    del limit
    return _viz.render_html(_to_command_trace(cspec, trace), cspec,
                            title=title)


def write_html(path: str, cspec: CompiledSpec, trace, title: str = "",
               limit: int | None = None) -> str:
    html = render_html(cspec, trace, title, limit)
    with open(path, "w") as f:
        f.write(html)
    return path
