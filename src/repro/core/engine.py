"""Cycle-level simulation engine: `lax.scan` over cycles, `vmap` over configs.

The engine composes (frontend -> controller -> device) into one pure cycle
function and runs it under `jax.lax.scan`.  Because every load knob and
every timing latency is a traced array (`FrontParams`, `DynParams`), a
*batched* engine falls out of `jax.vmap` — hundreds of design-space points
(timing presets x scheduler loads x read ratios) simulate in one compiled
program.  This is the TPU-native analogue of Ramulator's DSE workflows
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core import device as D
from repro.core import frontend as F
from repro.core.compile import CompiledSpec, compile_spec


class Stats(NamedTuple):
    cycles: jnp.ndarray
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles the data bus carried data
    cmd_counts: jnp.ndarray         # (n_cmds,)
    deferred: jnp.ndarray           # predicate-masked candidate count


def _zero_stats(cspec: CompiledSpec) -> Stats:
    z = jnp.int32(0)
    return Stats(z, z, z, z, z, z, jnp.zeros((cspec.n_cmds,), jnp.int32), z)


class SimState(NamedTuple):
    cs: C.CtrlState
    fs: F.FrontState
    stats: Stats
    clk: jnp.ndarray


@dataclasses.dataclass
class Simulator:
    """User-facing simulator handle for one (standard, org, timing) triple.

    >>> sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    >>> stats = sim.run(100_000, interval=4.0, read_ratio=1.0)
    """
    standard: str
    org_preset: str
    timing_preset: str
    controller: C.ControllerConfig = dataclasses.field(
        default_factory=C.ControllerConfig)
    frontend: F.FrontendConfig = dataclasses.field(
        default_factory=F.FrontendConfig)
    timing_overrides: dict | None = None

    def __post_init__(self):
        self.cspec = compile_spec(self.standard, self.org_preset,
                                  self.timing_preset, self.timing_overrides)

    # -- single-config run ------------------------------------------------
    def run(self, n_cycles: int, interval: float | None = None,
            read_ratio: float | None = None, trace: bool = False,
            seed: int = 0x1234):
        fcfg = self.frontend
        if interval is not None or read_ratio is not None:
            fcfg = dataclasses.replace(
                fcfg,
                interval=interval if interval is not None else fcfg.interval,
                read_ratio=(read_ratio if read_ratio is not None
                            else fcfg.read_ratio))
        dp = D.dyn_params(self.cspec)
        fp = fcfg.params()
        run_fn = make_run(self.cspec, self.controller, fcfg, n_cycles, trace)
        out = jax.jit(run_fn)(dp, fp, jnp.uint32(seed))
        return jax.tree.map(np.asarray, out)

    # -- batched DSE run ---------------------------------------------------
    def run_batch(self, n_cycles: int, intervals, read_ratios,
                  seed: int = 0x1234):
        """Simulate the outer product of load points in one vmapped program."""
        dp = D.dyn_params(self.cspec)
        pts = [(i, r) for i in intervals for r in read_ratios]
        fp = F.FrontParams(
            interval_fp=jnp.asarray([max(int(i * 256), 1) for i, _ in pts],
                                    jnp.int32),
            read_ratio_fp=jnp.asarray([int(r * 256) for _, r in pts],
                                      jnp.int32),
            probe_gap=jnp.full((len(pts),), self.frontend.probe_gap,
                               jnp.int32))
        run_fn = make_run(self.cspec, self.controller, self.frontend,
                          n_cycles, trace=False)
        batched = jax.jit(jax.vmap(run_fn, in_axes=(None, 0, None)))
        out = batched(dp, fp, jnp.uint32(seed))
        return pts, jax.tree.map(np.asarray, out)


def make_run(cspec: CompiledSpec, ccfg: C.ControllerConfig,
             fcfg: F.FrontendConfig, n_cycles: int, trace: bool):
    """Build the pure run function (dp, fp, seed) -> Stats [, trace]."""

    def cycle(sim: SimState, _, dp, fp):
        queue, fs = F.frontend_step(cspec, fcfg, fp, sim.fs, sim.cs.queue,
                                    sim.clk)
        cs = sim.cs._replace(queue=queue)
        cs, ev = C.controller_step(cspec, dp, ccfg, cs, sim.clk)
        fs = F.frontend_absorb(fs, fp, ev)

        st = sim.stats
        nBL = jnp.int32(cspec.timings["nBL"])
        issued = ev.cmd >= 0
        counts = st.cmd_counts
        for i in range(2):
            counts = jnp.where(issued[i], counts.at[ev.cmd[i]].add(1), counts)
        st = Stats(
            cycles=st.cycles + 1,
            reads_done=st.reads_done + ev.served_read.astype(jnp.int32),
            writes_done=st.writes_done + ev.served_write.astype(jnp.int32),
            probe_lat_sum=st.probe_lat_sum + ev.probe_latency,
            probe_cnt=st.probe_cnt + ev.served_probe.astype(jnp.int32),
            data_bus_busy=st.data_bus_busy + nBL * (
                ev.served_read.astype(jnp.int32)
                + ev.served_write.astype(jnp.int32)),
            cmd_counts=counts,
            deferred=st.deferred + ev.deferred,
        )
        out = SimState(cs=cs, fs=fs, stats=st, clk=sim.clk + 1)
        ys = (ev.cmd, ev.bank, ev.row) if trace else None
        return out, ys

    def run(dp, fp, seed):
        init = SimState(cs=C.init_ctrl_state(cspec, ccfg.queue_depth),
                        fs=F.init_front(),
                        stats=_zero_stats(cspec), clk=jnp.int32(0))
        init = init._replace(fs=init.fs._replace(rng=seed | jnp.uint32(1)))
        final, ys = jax.lax.scan(partial(cycle, dp=dp, fp=fp), init, None,
                                 length=n_cycles)
        if trace:
            return final.stats, ys
        return final.stats

    return run


# --------------------------------------------------------------------------
# Derived metrics
# --------------------------------------------------------------------------

def throughput_gbps(cspec: CompiledSpec, stats) -> float:
    bytes_moved = float(stats.reads_done + stats.writes_done) * cspec.access_bytes
    seconds = float(stats.cycles) * cspec.tCK_ps * 1e-12
    return bytes_moved / seconds / 1e9 if seconds else 0.0


def peak_gbps(cspec: CompiledSpec) -> float:
    return cspec.peak_bytes_per_cycle / (cspec.tCK_ps * 1e-12) / 1e9


def avg_probe_latency_ns(cspec: CompiledSpec, stats) -> float:
    if int(stats.probe_cnt) == 0:
        return float("nan")
    cycles = float(stats.probe_lat_sum) / float(stats.probe_cnt)
    return cycles * cspec.tCK_ps * 1e-3
