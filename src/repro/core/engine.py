"""Cycle-level memory-system engine: `lax.scan` over cycles, `vmap` over
channels *and* configs.

The engine composes (frontend -> address mapper -> per-channel controllers
-> devices) into one pure cycle function and runs it under `jax.lax.scan`.
Controller and device state carry a leading channel axis; `controller_step`
runs across the system's C channels via an inner `jax.vmap`, so a 1-channel
and an 8-channel system are the *same* compiled program shape family — one
trace, one XLA compile, regardless of channel count.  Because every load
knob and every timing latency is a traced array (`FrontParams`,
`DynParams`), a *batched* engine falls out of an outer `jax.vmap` —
hundreds of design-space points (timing presets x scheduler loads x read
ratios x channel counts x mapper orders) simulate in few compiled
programs.  This is the TPU-native analogue of Ramulator's DSE workflows
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core import device as D
from repro.core import frontend as F
from repro.core.compile import CompiledSpec, compile_spec


class ChannelStats(NamedTuple):
    """Per-channel breakdowns; every leaf has a leading ``(C,)`` axis
    (``(B, C)`` for batched runs)."""
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles the channel's data bus was busy
    cmd_counts: jnp.ndarray         # (C, n_cmds)
    deferred: jnp.ndarray


class Stats(NamedTuple):
    """Aggregate run statistics plus the per-channel breakdown.

    The scalar fields sum across channels (identical to the historical
    single-channel semantics); ``per_channel`` holds the same counters
    split by channel.
    """
    cycles: jnp.ndarray
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles any data bus carried data
    cmd_counts: jnp.ndarray         # (n_cmds,)
    deferred: jnp.ndarray           # predicate-masked candidate count
    per_channel: ChannelStats


def _zero_channel_stats(cspec: CompiledSpec) -> ChannelStats:
    nch = cspec.n_channels
    z = lambda *sh: jnp.zeros(sh, jnp.int32)
    return ChannelStats(z(nch), z(nch), z(nch), z(nch), z(nch),
                        z(nch, cspec.n_cmds), z(nch))


class SimState(NamedTuple):
    cs: C.CtrlState              # every leaf has a leading channel axis
    fs: F.FrontState
    ch: ChannelStats
    clk: jnp.ndarray


class TraceArrays(NamedTuple):
    """Dense per-cycle trace emitted by ``run(..., trace=True)``.

    Single-channel systems emit ``[T, 2]`` fields ([cycles, bus slots];
    slot 0 is the column C/A bus, slot 1 the row bus — single-bus
    standards only use slot 0).  Multi-channel systems emit ``[T, C, 2]``
    with the channel axis in the middle.  ``cmd`` is -1 on idle slots.
    ``repro.trace.capture`` compacts these dense arrays into a columnar
    :class:`repro.trace.CommandTrace` (with a ``chan`` column).
    """
    cmd: jnp.ndarray         # issued command id, -1 == idle
    bank: jnp.ndarray        # flat bank id (refresh: representative bank)
    row: jnp.ndarray         # target row, -1 when n/a
    arrive: jnp.ndarray      # served request's arrival clk, -1 for refresh
    hit_ready: jnp.ndarray   # bool — a post-predicate row hit was available


# --------------------------------------------------------------------------
# Compile cache
# --------------------------------------------------------------------------
#
# `make_run` returns a fresh closure every call, so a bare `jax.jit(run_fn)`
# can never share traces between two `Simulator` instances of the same
# (standard, org, timing) triple — every instance would pay the full trace +
# XLA-compile cost again.  `RunCache` memoizes the *jitted callable* keyed on
# everything that changes the traced program: the compiled-spec identity
# (including timing overrides and post-hoc `rows`/`columns` edits), the
# controller and frontend configs, the cycle count, and the trace/batched
# flags.  Load knobs (interval / read ratio / seed) are traced arguments and
# therefore never part of the key.

#: Incremented once per actual trace of a run closure; tests use it to
#: assert that identical sweep specs are compiled exactly once.
TRACE_COUNT = 0


def _freeze(obj):
    """Recursively convert configs/dicts into hashable cache-key tuples.

    Callables (user filtering predicates in ``extra_predicates``) freeze
    to their qualified name plus frozen closure constants — two equal
    configs built from *separate but identical* factory calls therefore
    share one cache entry, instead of silently never hitting because the
    lambdas hash by identity.
    """
    if obj is None or isinstance(obj, (int, float, str, bool, bytes)):
        return obj
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, _freeze(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(x) for x in obj)
    if callable(obj):
        # Key on everything that can bind a value into the function:
        # closure cells, default args (the `def pred(..., t=t)` binding
        # idiom), and bytecode+consts (distinguishes different lambdas
        # sharing the '<lambda>' qualname).  Factory-rebuilt equal copies
        # still collide into one cache entry.  Known limitation: a
        # predicate reading a *module-level global* that mutates between
        # runs is not re-keyed — bind state via closures/defaults instead.
        cells = getattr(obj, "__closure__", None) or ()
        closure = tuple(_freeze(c.cell_contents) for c in cells)
        defaults = (_freeze(getattr(obj, "__defaults__", None)),
                    _freeze(getattr(obj, "__kwdefaults__", None)))
        code = getattr(obj, "__code__", None)
        code_key = ((code.co_code, _freeze(code.co_consts))
                    if code is not None else id(obj))
        return ("callable", getattr(obj, "__module__", ""),
                getattr(obj, "__qualname__", repr(obj)), code_key, closure,
                defaults)
    try:
        hash(obj)
        return obj
    except TypeError:
        return repr(obj)


def spec_fingerprint(cspec: CompiledSpec):
    """Hashable identity of a compiled spec *as the engine traces it*.

    Keyed on provenance (standard/org/timing preset names) plus the resolved
    timing table, the geometry fields benchmarks are allowed to mutate
    in place (`rows`, `columns`), and the memory-system channel count — so
    an edited spec never aliases a cached program built from the pristine
    one, and an N-channel system never aliases a 1-channel program.  The
    channel count is appended only when >1: every pre-multi-channel trace
    artifact was captured single-channel, and this keeps their stored
    fingerprints verifiable.
    """
    base = (cspec.standard, cspec.org_preset, cspec.timing_preset,
            _freeze(cspec.timings), cspec.rows, cspec.columns)
    return base if cspec.n_channels == 1 else base + (cspec.n_channels,)


def run_key(cspec: CompiledSpec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool,
            batched: bool, replay: F.ReplayStream | None = None):
    # interval/read_ratio reach the traced program only through FrontParams
    # (a traced argument) in both scalar and batched mode; the fcfg copies
    # are dead at trace time, so drop them from the key — sweeping the load
    # knobs through `Simulator.run` never recompiles.  The mapper order
    # stays in the key (it changes the traced decode), as does the replay
    # stream's content fingerprint.
    fkey = tuple(kv for kv in _freeze(fcfg)
                 if not (isinstance(kv, tuple)
                         and kv[0] in ("interval", "read_ratio")))
    return (spec_fingerprint(cspec), _freeze(ccfg), fkey,
            int(n_cycles), bool(trace), bool(batched),
            None if replay is None else replay.fingerprint)


class RunCache:
    """Memoizes jitted engine run callables.

    ``get`` returns a jitted ``(dp, fp, seed) -> Stats`` callable (vmapped
    over ``fp`` when ``batched=True``).  ``hits``/``misses`` count lookups;
    re-tracing is observable via the module-level ``TRACE_COUNT``.
    """

    def __init__(self):
        self._runs: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._runs)

    def clear(self):
        self._runs.clear()
        self.hits = self.misses = 0

    def get(self, cspec: CompiledSpec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool = False,
            batched: bool = False, replay: F.ReplayStream | None = None):
        key = run_key(cspec, ccfg, fcfg, n_cycles, trace, batched, replay)
        fn = self._runs.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        # Close over a snapshot, not the caller's object: jit may re-trace
        # this closure much later (new batch shape), and by then the caller
        # may have mutated its cspec in place — the snapshot keeps every
        # retrace consistent with the fingerprint taken above.
        cspec = dataclasses.replace(cspec)
        fn = make_run(cspec, ccfg, fcfg, n_cycles, trace, replay)
        if batched:
            fn = jax.vmap(fn, in_axes=(None, 0, None))
        fn = jax.jit(fn)
        self._runs[key] = fn
        return fn


#: Process-wide default cache used by `Simulator` and `repro.dse`.
RUN_CACHE = RunCache()


@dataclasses.dataclass
class Simulator:
    """User-facing memory-system handle for one (standard, org, timing)
    triple, with a configurable channel count and address-mapper order.

    >>> sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    >>> stats = sim.run(100_000, interval=4.0, read_ratio=1.0)
    >>> quad = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=4)
    >>> stats = quad.run(50_000)      # stats.per_channel: (4,) breakdowns
    """
    standard: str
    org_preset: str
    timing_preset: str
    controller: C.ControllerConfig = dataclasses.field(
        default_factory=C.ControllerConfig)
    frontend: F.FrontendConfig = dataclasses.field(
        default_factory=F.FrontendConfig)
    timing_overrides: dict | None = None
    #: memory-system channel fan-out (vmapped controllers inside the scan)
    channels: int = 1
    #: convenience override for ``frontend.mapper`` (None keeps it)
    mapper: str | None = None
    #: replay source for ``FrontendConfig(pattern="trace")``
    replay: F.ReplayStream | None = None

    def __post_init__(self):
        self.cspec = compile_spec(self.standard, self.org_preset,
                                  self.timing_preset, self.timing_overrides,
                                  channels=self.channels)
        if self.mapper is not None:
            self.frontend = dataclasses.replace(self.frontend,
                                                mapper=self.mapper)

    # -- single-config run ------------------------------------------------
    def run(self, n_cycles: int, interval: float | None = None,
            read_ratio: float | None = None, trace: bool = False,
            seed: int = 0x1234):
        fcfg = self.frontend
        if interval is not None or read_ratio is not None:
            fcfg = dataclasses.replace(
                fcfg,
                interval=interval if interval is not None else fcfg.interval,
                read_ratio=(read_ratio if read_ratio is not None
                            else fcfg.read_ratio))
        dp = D.dyn_params(self.cspec)
        fp = fcfg.params()
        run_fn = RUN_CACHE.get(self.cspec, self.controller, fcfg, n_cycles,
                               trace=trace, replay=self.replay)
        out = run_fn(dp, fp, jnp.uint32(seed))
        return jax.tree.map(np.asarray, out)

    # -- batched DSE run ---------------------------------------------------
    def run_batch(self, n_cycles: int, intervals, read_ratios,
                  seed: int = 0x1234):
        """Simulate the outer product of load points in one vmapped program."""
        dp = D.dyn_params(self.cspec)
        pts = [(i, r) for i in intervals for r in read_ratios]
        fp = F.stack_params(pts, self.frontend.probe_gap)
        batched = RUN_CACHE.get(self.cspec, self.controller, self.frontend,
                                n_cycles, batched=True, replay=self.replay)
        out = batched(dp, fp, jnp.uint32(seed))
        return pts, jax.tree.map(np.asarray, out)


def make_run(cspec: CompiledSpec, ccfg: C.ControllerConfig,
             fcfg: F.FrontendConfig, n_cycles: int, trace: bool,
             replay: F.ReplayStream | None = None):
    """Build the pure run function (dp, fp, seed) -> Stats [, trace].

    One compiled program per (spec, configs, n_cycles, trace, replay)
    regardless of channel count: the frontend routes decoded requests to
    per-channel queues and ``controller_step`` runs across all channels
    via an inner ``jax.vmap`` inside the single ``lax.scan`` body.
    """
    nch = cspec.n_channels
    layout = F.make_layout(cspec, fcfg.mapper)
    if fcfg.stream and fcfg.pattern == "trace" and replay is None:
        raise ValueError('FrontendConfig(pattern="trace") needs a '
                         "ReplayStream (Simulator(..., replay=...))")
    if replay is not None:
        if len(replay) == 0:
            raise ValueError("replay stream is empty — nothing to replay")
        if replay.arrive is not None \
                and np.any(np.diff(np.asarray(replay.arrive)) < 0):
            raise ValueError(
                "replay arrive column must be non-decreasing (injection "
                "is index-ordered) — sort the stream into arrival order "
                "as trace.to_replay does")
        top = int(np.max(replay.chan))
        if top >= nch or int(np.min(replay.chan)) < 0:
            raise ValueError(
                f"replay stream targets channel {top} but the memory "
                f"system has {nch} channel(s) — re-encode the stream "
                "through this system's mapper (ReplayStream."
                "from_addresses) instead of reusing captured channels")
    rp = None if replay is None else F.ReplayStream(
        chan=jnp.asarray(replay.chan), sub=jnp.asarray(replay.sub),
        row=jnp.asarray(replay.row), col=jnp.asarray(replay.col),
        is_write=jnp.asarray(replay.is_write),
        # arrive stays host-side numpy: the frontend derives static pacing
        # scalars (base / span / wrap gap) from it at trace time
        arrive=replay.arrive,
        fingerprint=replay.fingerprint)

    def cycle(sim: SimState, _, dp, fp):
        queues, fs = F.frontend_step(cspec, fcfg, fp, sim.fs, sim.cs.queue,
                                     sim.clk, layout, rp)
        cs = sim.cs._replace(queue=queues)
        cs, ev = jax.vmap(
            lambda s: C.controller_step(cspec, dp, ccfg, s, sim.clk))(cs)
        fs = F.frontend_absorb(fs, fp, ev)

        ch = sim.ch
        nBL = jnp.int32(cspec.timings["nBL"])
        rd = ev.served_read.astype(jnp.int32)          # (C,)
        wr = ev.served_write.astype(jnp.int32)
        counts = ch.cmd_counts                          # (C, n_cmds)
        cmd_ids = jnp.arange(cspec.n_cmds, dtype=jnp.int32)
        for i in range(2):
            # dense one-hot add (idle slots are -1: no match, no count)
            counts = counts + (cmd_ids[None, :]
                               == ev.cmd[:, i:i + 1]).astype(jnp.int32)
        ch = ChannelStats(
            reads_done=ch.reads_done + rd,
            writes_done=ch.writes_done + wr,
            probe_lat_sum=ch.probe_lat_sum + ev.probe_latency,
            probe_cnt=ch.probe_cnt + ev.served_probe.astype(jnp.int32),
            data_bus_busy=ch.data_bus_busy + nBL * (rd + wr),
            cmd_counts=counts,
            deferred=ch.deferred + ev.deferred,
        )
        out = SimState(cs=cs, fs=fs, ch=ch, clk=sim.clk + 1)
        if trace:
            # single-channel systems keep the historical [2] slot shape
            sq = (lambda a: a[0]) if nch == 1 else (lambda a: a)
            ys = TraceArrays(sq(ev.cmd), sq(ev.bank), sq(ev.row),
                             sq(ev.arrive), sq(ev.hit_ready))
        else:
            ys = None
        return out, ys

    def run(dp, fp, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1            # runs once per jax trace, not per call
        cs1 = C.init_ctrl_state(cspec, ccfg.queue_depth)
        css = jax.tree.map(lambda a: jnp.broadcast_to(a, (nch,) + a.shape),
                           cs1)
        if ccfg.refresh_stagger and nch > 1:
            # phase-shift each channel's refresh epoch by c*nREFI/C (real
            # controllers stagger REF so the channels' refresh windows —
            # and their bandwidth dips — never align); channel 0 keeps the
            # historical phase, so single-channel runs are bit-identical
            nrefi = int(cspec.timings["nREFI"])
            offs = jnp.asarray([-(c * nrefi // nch) for c in range(nch)],
                               jnp.int32)
            css = css._replace(dev=css.dev._replace(
                last_ref=css.dev.last_ref + offs[:, None]))
        init = SimState(cs=css, fs=F.init_front(),
                        ch=_zero_channel_stats(cspec), clk=jnp.int32(0))
        init = init._replace(fs=init.fs._replace(rng=seed | jnp.uint32(1)))
        final, ys = jax.lax.scan(partial(cycle, dp=dp, fp=fp), init, None,
                                 length=n_cycles)
        ch = final.ch
        stats = Stats(
            cycles=final.clk,
            reads_done=jnp.sum(ch.reads_done),
            writes_done=jnp.sum(ch.writes_done),
            probe_lat_sum=jnp.sum(ch.probe_lat_sum),
            probe_cnt=jnp.sum(ch.probe_cnt),
            data_bus_busy=jnp.sum(ch.data_bus_busy),
            cmd_counts=jnp.sum(ch.cmd_counts, axis=0),
            deferred=jnp.sum(ch.deferred),
            per_channel=ch,
        )
        if trace:
            return stats, ys
        return stats

    return run


# --------------------------------------------------------------------------
# Derived metrics
# --------------------------------------------------------------------------
#
# These helpers take the Stats of ONE run: the `float()` casts require
# 0-d (scalar) stat fields and raise on the stacked (B,)-shaped Stats that
# `run_batch` / `repro.dse` produce.  For batched stats either index one
# point out first (`jax.tree.map(lambda a: a[i], stats)`) or use the
# vectorized equivalents in `repro.dse.results`.

def throughput_gbps(cspec: CompiledSpec, stats) -> float:
    """Achieved data throughput in GB/s (1e9 bytes per second).

    bytes moved = (reads + writes) * access_bytes; wall time =
    cycles * tCK_ps.  Scalar stats only — see the batched-stats caveat above.
    """
    bytes_moved = float(stats.reads_done + stats.writes_done) * cspec.access_bytes
    seconds = float(stats.cycles) * cspec.tCK_ps * 1e-12
    return bytes_moved / seconds / 1e9 if seconds else 0.0


def peak_gbps(cspec: CompiledSpec) -> float:
    """Theoretical peak throughput of the memory *system* in GB/s:
    access_bytes / nBL per cycle sustained on every cycle of every
    channel's data bus (scales with ``n_channels``)."""
    per_chan = cspec.peak_bytes_per_cycle / (cspec.tCK_ps * 1e-12) / 1e9
    return cspec.n_channels * per_chan


def channel_breakdown(cspec: CompiledSpec, stats) -> dict:
    """Per-channel summary of one scalar run's ``stats.per_channel``:
    ``{channel: {reads_done, writes_done, throughput_gbps, bus_util}}``."""
    ch = stats.per_channel
    seconds = float(stats.cycles) * cspec.tCK_ps * 1e-12
    out = {}
    for c in range(cspec.n_channels):
        moved = (int(ch.reads_done[c]) + int(ch.writes_done[c])) \
            * cspec.access_bytes
        out[c] = {
            "reads_done": int(ch.reads_done[c]),
            "writes_done": int(ch.writes_done[c]),
            "throughput_gbps": moved / seconds / 1e9 if seconds else 0.0,
            "bus_util": (float(ch.data_bus_busy[c]) / float(stats.cycles)
                         if int(stats.cycles) else 0.0),
        }
    return out


def avg_probe_latency_ns(cspec: CompiledSpec, stats) -> float:
    """Mean random-probe read latency in nanoseconds (arrival to data
    completion), NaN when no probe finished.  Scalar stats only — see the
    batched-stats caveat above."""
    if int(stats.probe_cnt) == 0:
        return float("nan")
    cycles = float(stats.probe_lat_sum) / float(stats.probe_cnt)
    return cycles * cspec.tCK_ps * 1e-3
