"""Cycle-level simulation engine: `lax.scan` over cycles, `vmap` over configs.

The engine composes (frontend -> controller -> device) into one pure cycle
function and runs it under `jax.lax.scan`.  Because every load knob and
every timing latency is a traced array (`FrontParams`, `DynParams`), a
*batched* engine falls out of `jax.vmap` — hundreds of design-space points
(timing presets x scheduler loads x read ratios) simulate in one compiled
program.  This is the TPU-native analogue of Ramulator's DSE workflows
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core import device as D
from repro.core import frontend as F
from repro.core.compile import CompiledSpec, compile_spec


class Stats(NamedTuple):
    cycles: jnp.ndarray
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles the data bus carried data
    cmd_counts: jnp.ndarray         # (n_cmds,)
    deferred: jnp.ndarray           # predicate-masked candidate count


def _zero_stats(cspec: CompiledSpec) -> Stats:
    z = jnp.int32(0)
    return Stats(z, z, z, z, z, z, jnp.zeros((cspec.n_cmds,), jnp.int32), z)


class SimState(NamedTuple):
    cs: C.CtrlState
    fs: F.FrontState
    stats: Stats
    clk: jnp.ndarray


class TraceArrays(NamedTuple):
    """Dense per-cycle trace emitted by ``run(..., trace=True)``.

    Every field is ``[T, 2]`` ([cycles, bus slots]; slot 0 is the column
    C/A bus, slot 1 the row bus — single-bus standards only use slot 0).
    ``cmd`` is -1 on idle slots.  ``repro.trace.capture`` compacts these
    dense arrays into a columnar :class:`repro.trace.CommandTrace`.
    """
    cmd: jnp.ndarray         # issued command id, -1 == idle
    bank: jnp.ndarray        # flat bank id (refresh: representative bank)
    row: jnp.ndarray         # target row, -1 when n/a
    arrive: jnp.ndarray      # served request's arrival clk, -1 for refresh
    hit_ready: jnp.ndarray   # bool — a post-predicate row hit was available


# --------------------------------------------------------------------------
# Compile cache
# --------------------------------------------------------------------------
#
# `make_run` returns a fresh closure every call, so a bare `jax.jit(run_fn)`
# can never share traces between two `Simulator` instances of the same
# (standard, org, timing) triple — every instance would pay the full trace +
# XLA-compile cost again.  `RunCache` memoizes the *jitted callable* keyed on
# everything that changes the traced program: the compiled-spec identity
# (including timing overrides and post-hoc `rows`/`columns` edits), the
# controller and frontend configs, the cycle count, and the trace/batched
# flags.  Load knobs (interval / read ratio / seed) are traced arguments and
# therefore never part of the key.

#: Incremented once per actual trace of a run closure; tests use it to
#: assert that identical sweep specs are compiled exactly once.
TRACE_COUNT = 0


def _freeze(obj):
    """Recursively convert configs/dicts into hashable cache-key tuples."""
    if obj is None or isinstance(obj, (int, float, str, bool, bytes)):
        return obj
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, _freeze(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(x) for x in obj)
    return obj                      # callables etc. hash by identity


def spec_fingerprint(cspec: CompiledSpec):
    """Hashable identity of a compiled spec *as the engine traces it*.

    Keyed on provenance (standard/org/timing preset names) plus the resolved
    timing table and the geometry fields benchmarks are allowed to mutate
    in place (`rows`, `columns`) — so an edited spec never aliases a cached
    program built from the pristine one.
    """
    return (cspec.standard, cspec.org_preset, cspec.timing_preset,
            _freeze(cspec.timings), cspec.rows, cspec.columns)


def run_key(cspec: CompiledSpec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool,
            batched: bool):
    # interval/read_ratio reach the traced program only through FrontParams
    # (a traced argument) in both scalar and batched mode; the fcfg copies
    # are dead at trace time, so drop them from the key — sweeping the load
    # knobs through `Simulator.run` never recompiles.
    fkey = tuple(kv for kv in _freeze(fcfg)
                 if not (isinstance(kv, tuple)
                         and kv[0] in ("interval", "read_ratio")))
    return (spec_fingerprint(cspec), _freeze(ccfg), fkey,
            int(n_cycles), bool(trace), bool(batched))


class RunCache:
    """Memoizes jitted engine run callables.

    ``get`` returns a jitted ``(dp, fp, seed) -> Stats`` callable (vmapped
    over ``fp`` when ``batched=True``).  ``hits``/``misses`` count lookups;
    re-tracing is observable via the module-level ``TRACE_COUNT``.
    """

    def __init__(self):
        self._runs: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._runs)

    def clear(self):
        self._runs.clear()
        self.hits = self.misses = 0

    def get(self, cspec: CompiledSpec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool = False,
            batched: bool = False):
        key = run_key(cspec, ccfg, fcfg, n_cycles, trace, batched)
        fn = self._runs.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        # Close over a snapshot, not the caller's object: jit may re-trace
        # this closure much later (new batch shape), and by then the caller
        # may have mutated its cspec in place — the snapshot keeps every
        # retrace consistent with the fingerprint taken above.
        cspec = dataclasses.replace(cspec)
        fn = make_run(cspec, ccfg, fcfg, n_cycles, trace)
        if batched:
            fn = jax.vmap(fn, in_axes=(None, 0, None))
        fn = jax.jit(fn)
        self._runs[key] = fn
        return fn


#: Process-wide default cache used by `Simulator` and `repro.dse`.
RUN_CACHE = RunCache()


@dataclasses.dataclass
class Simulator:
    """User-facing simulator handle for one (standard, org, timing) triple.

    >>> sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    >>> stats = sim.run(100_000, interval=4.0, read_ratio=1.0)
    """
    standard: str
    org_preset: str
    timing_preset: str
    controller: C.ControllerConfig = dataclasses.field(
        default_factory=C.ControllerConfig)
    frontend: F.FrontendConfig = dataclasses.field(
        default_factory=F.FrontendConfig)
    timing_overrides: dict | None = None

    def __post_init__(self):
        self.cspec = compile_spec(self.standard, self.org_preset,
                                  self.timing_preset, self.timing_overrides)

    # -- single-config run ------------------------------------------------
    def run(self, n_cycles: int, interval: float | None = None,
            read_ratio: float | None = None, trace: bool = False,
            seed: int = 0x1234):
        fcfg = self.frontend
        if interval is not None or read_ratio is not None:
            fcfg = dataclasses.replace(
                fcfg,
                interval=interval if interval is not None else fcfg.interval,
                read_ratio=(read_ratio if read_ratio is not None
                            else fcfg.read_ratio))
        dp = D.dyn_params(self.cspec)
        fp = fcfg.params()
        run_fn = RUN_CACHE.get(self.cspec, self.controller, fcfg, n_cycles,
                               trace=trace)
        out = run_fn(dp, fp, jnp.uint32(seed))
        return jax.tree.map(np.asarray, out)

    # -- batched DSE run ---------------------------------------------------
    def run_batch(self, n_cycles: int, intervals, read_ratios,
                  seed: int = 0x1234):
        """Simulate the outer product of load points in one vmapped program."""
        dp = D.dyn_params(self.cspec)
        pts = [(i, r) for i in intervals for r in read_ratios]
        fp = F.stack_params(pts, self.frontend.probe_gap)
        batched = RUN_CACHE.get(self.cspec, self.controller, self.frontend,
                                n_cycles, batched=True)
        out = batched(dp, fp, jnp.uint32(seed))
        return pts, jax.tree.map(np.asarray, out)


def make_run(cspec: CompiledSpec, ccfg: C.ControllerConfig,
             fcfg: F.FrontendConfig, n_cycles: int, trace: bool):
    """Build the pure run function (dp, fp, seed) -> Stats [, trace]."""

    def cycle(sim: SimState, _, dp, fp):
        queue, fs = F.frontend_step(cspec, fcfg, fp, sim.fs, sim.cs.queue,
                                    sim.clk)
        cs = sim.cs._replace(queue=queue)
        cs, ev = C.controller_step(cspec, dp, ccfg, cs, sim.clk)
        fs = F.frontend_absorb(fs, fp, ev)

        st = sim.stats
        nBL = jnp.int32(cspec.timings["nBL"])
        issued = ev.cmd >= 0
        counts = st.cmd_counts
        for i in range(2):
            counts = jnp.where(issued[i], counts.at[ev.cmd[i]].add(1), counts)
        st = Stats(
            cycles=st.cycles + 1,
            reads_done=st.reads_done + ev.served_read.astype(jnp.int32),
            writes_done=st.writes_done + ev.served_write.astype(jnp.int32),
            probe_lat_sum=st.probe_lat_sum + ev.probe_latency,
            probe_cnt=st.probe_cnt + ev.served_probe.astype(jnp.int32),
            data_bus_busy=st.data_bus_busy + nBL * (
                ev.served_read.astype(jnp.int32)
                + ev.served_write.astype(jnp.int32)),
            cmd_counts=counts,
            deferred=st.deferred + ev.deferred,
        )
        out = SimState(cs=cs, fs=fs, stats=st, clk=sim.clk + 1)
        ys = TraceArrays(ev.cmd, ev.bank, ev.row, ev.arrive,
                         ev.hit_ready) if trace else None
        return out, ys

    def run(dp, fp, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1            # runs once per jax trace, not per call
        init = SimState(cs=C.init_ctrl_state(cspec, ccfg.queue_depth),
                        fs=F.init_front(),
                        stats=_zero_stats(cspec), clk=jnp.int32(0))
        init = init._replace(fs=init.fs._replace(rng=seed | jnp.uint32(1)))
        final, ys = jax.lax.scan(partial(cycle, dp=dp, fp=fp), init, None,
                                 length=n_cycles)
        if trace:
            return final.stats, ys
        return final.stats

    return run


# --------------------------------------------------------------------------
# Derived metrics
# --------------------------------------------------------------------------
#
# These helpers take the Stats of ONE run: the `float()` casts require
# 0-d (scalar) stat fields and raise on the stacked (B,)-shaped Stats that
# `run_batch` / `repro.dse` produce.  For batched stats either index one
# point out first (`jax.tree.map(lambda a: a[i], stats)`) or use the
# vectorized equivalents in `repro.dse.results`.

def throughput_gbps(cspec: CompiledSpec, stats) -> float:
    """Achieved data throughput in GB/s (1e9 bytes per second).

    bytes moved = (reads + writes) * access_bytes; wall time =
    cycles * tCK_ps.  Scalar stats only — see the batched-stats caveat above.
    """
    bytes_moved = float(stats.reads_done + stats.writes_done) * cspec.access_bytes
    seconds = float(stats.cycles) * cspec.tCK_ps * 1e-12
    return bytes_moved / seconds / 1e9 if seconds else 0.0


def peak_gbps(cspec: CompiledSpec) -> float:
    """Theoretical peak throughput in GB/s: access_bytes / nBL per cycle
    sustained on every cycle of the data bus."""
    return cspec.peak_bytes_per_cycle / (cspec.tCK_ps * 1e-12) / 1e9


def avg_probe_latency_ns(cspec: CompiledSpec, stats) -> float:
    """Mean random-probe read latency in nanoseconds (arrival to data
    completion), NaN when no probe finished.  Scalar stats only — see the
    batched-stats caveat above."""
    if int(stats.probe_cnt) == 0:
        return float("nan")
    cycles = float(stats.probe_lat_sum) / float(stats.probe_cnt)
    return cycles * cspec.tCK_ps * 1e-3
