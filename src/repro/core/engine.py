"""Cycle-level memory-system engine: `lax.scan` over cycles, `vmap` over
channels *and* configs.

The engine composes (frontend -> address mapper -> per-channel controllers
-> devices) into one pure cycle function and runs it under `jax.lax.scan`.
Controller and device state carry a leading channel axis; `controller_step`
runs across the system's C channels via an inner `jax.vmap`, so a 1-channel
and an 8-channel system are the *same* compiled program shape family — one
trace, one XLA compile, regardless of channel count.  Because every load
knob and every timing latency is a traced array (`FrontParams`,
`DynParams`), a *batched* engine falls out of an outer `jax.vmap` —
hundreds of design-space points (timing presets x scheduler loads x read
ratios x channel counts x mapper orders) simulate in few compiled
programs.  This is the TPU-native analogue of Ramulator's DSE workflows
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core import device as D
from repro.core import frontend as F
from repro.core.compile import (CompiledSpec, MemorySystemSpec, SpecGroup,
                                as_system, compile_spec, compile_system)


class ChannelStats(NamedTuple):
    """Per-channel breakdowns; every leaf has a leading ``(C,)`` axis
    (``(B, C)`` for batched runs)."""
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles the channel's data bus was busy
    cmd_counts: jnp.ndarray         # (C, n_cmds)
    deferred: jnp.ndarray


class Stats(NamedTuple):
    """Aggregate run statistics plus the per-channel breakdown.

    The scalar fields sum across channels — and, for a heterogeneous
    system, across all spec groups (identical to the historical
    single-channel semantics when there is one group of one channel).
    ``per_channel`` holds the same counters split by *system* channel
    (group-major order); its ``cmd_counts`` are expressed in the system's
    merged command namespace (``MemorySystemSpec.cmd_names``), which for a
    homogeneous system IS the spec's own namespace.  ``per_group`` holds
    each spec group's native-namespace :class:`ChannelStats` — the
    group-correct view heterogeneous metrics (``throughput_gbps``,
    ``channel_breakdown``) are derived from.
    """
    cycles: jnp.ndarray
    reads_done: jnp.ndarray
    writes_done: jnp.ndarray
    probe_lat_sum: jnp.ndarray
    probe_cnt: jnp.ndarray
    data_bus_busy: jnp.ndarray      # cycles any data bus carried data
    cmd_counts: jnp.ndarray         # (n_cmds,) merged namespace
    deferred: jnp.ndarray           # predicate-masked candidate count
    per_channel: ChannelStats
    per_group: tuple                # per-group native ChannelStats
    #: scan-body executions this run — with fast-forward, the number of
    #: cycles actually stepped; ``cycles`` otherwise
    scan_steps: jnp.ndarray = 0
    #: cycles the fast-forward horizon skipped (``cycles - scan_steps``);
    #: 0 on the classic per-cycle path
    skipped_cycles: jnp.ndarray = 0

    # -- human-readable views ---------------------------------------------
    def to_dict(self) -> dict:
        """Plain-Python counter dict of one scalar run (ints throughout;
        per-channel counters as lists).  Raises on batched (B,)-shaped
        stats — index one point out first."""
        d = {k: int(getattr(self, k))
             for k in ("cycles", "reads_done", "writes_done",
                       "probe_lat_sum", "probe_cnt", "data_bus_busy",
                       "deferred", "scan_steps", "skipped_cycles")}
        d["cmd_counts"] = [int(c) for c in np.asarray(self.cmd_counts)]
        ch = self.per_channel
        d["per_channel"] = {
            k: [int(v) for v in np.asarray(getattr(ch, k))]
            for k in ("reads_done", "writes_done", "probe_cnt",
                      "data_bus_busy", "deferred")}
        return d

    def summary(self, spec=None) -> str:
        """Human-readable run summary; pass the run's spec/system for the
        group-aware view with physical units (GB/s, ns, %).  Replaces the
        ad-hoc prints of the examples and the trace CLI."""
        return format_stats(self, spec)


def _zero_channel_stats(cspec: CompiledSpec, telemetry: bool = False,
                        n_channels: int | None = None) -> ChannelStats:
    """Zeroed per-channel counters; with ``telemetry``, ``cmd_counts``
    is widened by the ``1 + n_edges`` telemetry gauge columns of
    :func:`_accum_channel_stats`.  ``n_channels`` overrides the spec's
    channel count (the channel-sharded path carries one device's slice)."""
    nch = cspec.n_channels if n_channels is None else n_channels
    width = cspec.n_cmds + (1 + len(cspec.lat_bucket_edges)
                            if telemetry else 0)
    z = lambda *sh: jnp.zeros(sh, jnp.int32)
    return ChannelStats(z(nch), z(nch), z(nch), z(nch), z(nch),
                        z(nch, width), z(nch))


class GroupWindowSnap(NamedTuple):
    """One window-boundary telemetry snapshot of ONE spec group: the
    cumulative :class:`ChannelStats` the scan already carries (gauge
    columns split off), plus the packed cumulative telemetry gauges
    (see :func:`_accum_channel_stats`).  Emitted as scan ``ys`` once
    per window — O(n_windows) output, never O(n_cycles)."""
    ch: ChannelStats
    tm: jnp.ndarray         # (C, 1 + n_edges) packed gauges


def _snap_telemetry(cspec: CompiledSpec, gs: GroupState,
                    clk) -> "GroupWindowSnap":
    """The window-boundary view of one group's counters: the carried
    :class:`ChannelStats` with its telemetry extension columns (see
    :func:`_accum_channel_stats`) split back out into the packed gauge
    array, plus the residual queue residency of requests still queued
    at ``clk`` (computed once per window, never per cycle).  The gauge
    array's column 0 is then the exact cycle-sum of queue occupancy
    over ``[0, clk)``."""
    nc = cspec.n_cmds
    q = gs.cs.queue
    resid = jnp.sum(jnp.where(q.valid, clk - q.arrive, 0), axis=1)
    return GroupWindowSnap(
        ch=gs.ch._replace(cmd_counts=gs.ch.cmd_counts[:, :nc]),
        tm=gs.ch.cmd_counts[:, nc:].at[:, 0].add(resid))


class GroupState(NamedTuple):
    """Scan-carried state of ONE spec group: controller+device state and
    running stats, every leaf with a leading group-channel axis.  When a
    telemetry window is requested the ``ch.cmd_counts`` leaf is widened
    by the gauge columns (no extra carry leaf; the telemetry-off traced
    program is unchanged)."""
    cs: C.CtrlState
    ch: ChannelStats


class SimState(NamedTuple):
    """Group-indexed scan carry: ``gs`` is a static-length tuple with one
    :class:`GroupState` per spec group (the homogeneous path is the
    1-tuple special case)."""
    gs: tuple
    fs: F.FrontState
    clk: jnp.ndarray


class TraceArrays(NamedTuple):
    """Dense per-cycle trace emitted by ``run(..., trace=True)``.

    Single-channel systems emit ``[T, 2]`` fields ([cycles, bus slots];
    slot 0 is the column C/A bus, slot 1 the row bus — single-bus
    standards only use slot 0).  Multi-channel systems emit ``[T, C, 2]``
    with the *system* channel axis in the middle (heterogeneous systems
    concatenate their groups' channels in group-major order; ``cmd`` ids
    are then GROUP-LOCAL — ``repro.trace.capture`` resolves them into the
    system's merged command namespace using the channel→group map).
    ``cmd`` is -1 on idle slots.  ``repro.trace.capture`` compacts these
    dense arrays into a columnar :class:`repro.trace.CommandTrace` (with
    ``chan`` and ``group`` columns).
    """
    cmd: jnp.ndarray         # issued command id, -1 == idle
    bank: jnp.ndarray        # flat bank id (refresh: representative bank)
    row: jnp.ndarray         # target row, -1 when n/a
    arrive: jnp.ndarray      # served request's arrival clk, -1 for refresh
    hit_ready: jnp.ndarray   # bool — a post-predicate row hit was available


# --------------------------------------------------------------------------
# Compile cache
# --------------------------------------------------------------------------
#
# `make_run` returns a fresh closure every call, so a bare `jax.jit(run_fn)`
# can never share traces between two `Simulator` instances of the same
# (standard, org, timing) triple — every instance would pay the full trace +
# XLA-compile cost again.  `RunCache` memoizes the *jitted callable* keyed on
# everything that changes the traced program: the compiled-spec identity
# (including timing overrides and post-hoc `rows`/`columns` edits), the
# controller and frontend configs, the cycle count, and the trace/batched
# flags.  Load knobs (interval / read ratio / seed) are traced arguments and
# therefore never part of the key.

#: Incremented once per actual trace of a run closure; tests use it to
#: assert that identical sweep specs are compiled exactly once.
TRACE_COUNT = 0


def _freeze(obj):
    """Recursively convert configs/dicts into hashable cache-key tuples.

    Callables (user filtering predicates in ``extra_predicates``) freeze
    to their qualified name plus frozen closure constants — two equal
    configs built from *separate but identical* factory calls therefore
    share one cache entry, instead of silently never hitting because the
    lambdas hash by identity.
    """
    if obj is None or isinstance(obj, (int, float, str, bool, bytes)):
        return obj
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, _freeze(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(x) for x in obj)
    if callable(obj):
        # Key on everything that can bind a value into the function:
        # closure cells, default args (the `def pred(..., t=t)` binding
        # idiom), and bytecode+consts (distinguishes different lambdas
        # sharing the '<lambda>' qualname).  Factory-rebuilt equal copies
        # still collide into one cache entry.  Known limitation: a
        # predicate reading a *module-level global* that mutates between
        # runs is not re-keyed — bind state via closures/defaults instead.
        cells = getattr(obj, "__closure__", None) or ()
        closure = tuple(_freeze(c.cell_contents) for c in cells)
        defaults = (_freeze(getattr(obj, "__defaults__", None)),
                    _freeze(getattr(obj, "__kwdefaults__", None)))
        code = getattr(obj, "__code__", None)
        code_key = ((code.co_code, _freeze(code.co_consts))
                    if code is not None else id(obj))
        return ("callable", getattr(obj, "__module__", ""),
                getattr(obj, "__qualname__", repr(obj)), code_key, closure,
                defaults)
    try:
        hash(obj)
        return obj
    except TypeError:
        return repr(obj)


def spec_fingerprint(cspec: CompiledSpec):
    """Hashable identity of a compiled spec *as the engine traces it*.

    Keyed on provenance (standard/org/timing preset names) plus the resolved
    timing table, the geometry fields benchmarks are allowed to mutate
    in place (`rows`, `columns`), and the memory-system channel count — so
    an edited spec never aliases a cached program built from the pristine
    one, and an N-channel system never aliases a 1-channel program.  The
    channel count is appended only when >1: every pre-multi-channel trace
    artifact was captured single-channel, and this keeps their stored
    fingerprints verifiable.
    """
    base = (cspec.standard, cspec.org_preset, cspec.timing_preset,
            _freeze(cspec.timings), cspec.rows, cspec.columns)
    return base if cspec.n_channels == 1 else base + (cspec.n_channels,)


def system_fingerprint(spec):
    """Hashable identity of a memory system *as the engine traces it*.

    A bare :class:`CompiledSpec` — and the 1-group, zero-link system it is
    equivalent to — keeps the historical :func:`spec_fingerprint` value,
    so every stored trace artifact and cached program stays verifiable
    (and ``Simulator(system=[one group])`` aliases the very same compiled
    program as ``Simulator(..., channels=N)``).  A genuine composition
    keys on the ordered tuple of (group fingerprint, channels,
    link_latency)."""
    if isinstance(spec, CompiledSpec):
        return spec_fingerprint(spec)
    msys = as_system(spec)
    if msys.homogeneous:
        return spec_fingerprint(msys.groups[0].cspec)
    return tuple((spec_fingerprint(g.cspec), g.channels, g.link_latency)
                 for g in msys.groups)


#: mesh axis name of the channel-sharded engine path
CHANNEL_AXIS = "channels"


def auto_channel_shard(spec, n_devices: int | None = None) -> int | None:
    """Largest channel-mesh size ``d > 1`` the visible device count
    supports and that divides EVERY spec group's channel count — the
    fan-out ``make_run(..., shard=d)`` places one contiguous channel
    slice per device.  None when no such ``d`` exists (single device,
    single channel, or indivisible counts): callers then stay on the
    vmapped single-device path."""
    msys = as_system(spec)
    ndev = jax.device_count() if n_devices is None else int(n_devices)
    counts = [g.channels for g in msys.groups]
    for d in range(min(ndev, min(counts)), 1, -1):
        if all(c % d == 0 for c in counts):
            return d
    return None


def _shard_desc(shard):
    """Hashable mesh identity of a channel-sharded program: axis name,
    mesh size, and the participating devices' (platform, id) pairs — a
    cache warmed under one device topology never aliases another's
    programs."""
    if not shard or int(shard) <= 1:
        return None
    return (CHANNEL_AXIS, int(shard),
            tuple((d.platform, d.id) for d in jax.devices()[:int(shard)]))


def run_key(spec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool,
            batched: bool, replay: F.ReplayStream | None = None,
            telemetry: int = 0, shard: int | None = None,
            donate: bool = False, fast_forward: bool = True):
    # interval/read_ratio reach the traced program only through FrontParams
    # (a traced argument) in both scalar and batched mode; the fcfg copies
    # are dead at trace time, so drop them from the key — sweeping the load
    # knobs through `Simulator.run` never recompiles.  The mapper order
    # stays in the key (it changes the traced decode), as does the replay
    # stream's content fingerprint and the telemetry window (windowed runs
    # restructure the scan, so every window size is its own program).
    # The device count + channel-mesh descriptor + donation flag key the
    # topology: a program compiled for one mesh (or with donated inputs)
    # is never silently reused for another.
    fkey = tuple(kv for kv in _freeze(fcfg)
                 if not (isinstance(kv, tuple)
                         and kv[0] in ("interval", "read_ratio")))
    # fast_forward restructures the scan into event-horizon macro-steps
    # (a different traced program), so it keys the cache too
    return (system_fingerprint(spec), _freeze(ccfg), fkey,
            int(n_cycles), bool(trace), bool(batched),
            None if replay is None else replay.fingerprint,
            int(telemetry), int(jax.device_count()), _shard_desc(shard),
            bool(donate), bool(fast_forward))


class _TimedRun:
    """Callable wrapper around one cached jitted run: its FIRST call —
    trace + XLA compile + the run itself, synchronized — is timed into the
    owning cache's ``first_call_s``.  Warm calls pass straight through.
    This is the observable the run profiler reports as compile cost (the
    pure-execute share is separately measurable from a warm re-run)."""

    __slots__ = ("fn", "_cache", "_timed")

    def __init__(self, fn, cache: "RunCache"):
        self.fn = fn
        self._cache = cache
        self._timed = False

    def __call__(self, *args):
        if self._timed:
            return self.fn(*args)
        t0 = time.perf_counter()
        out = jax.block_until_ready(self.fn(*args))
        self._cache.first_call_s += time.perf_counter() - t0
        self._timed = True
        return out


class RunCache:
    """Memoizes jitted engine run callables.

    ``get`` returns a jitted ``(dp, fp, seed) -> Stats`` callable (vmapped
    over ``fp`` when ``batched=True``).  ``hits``/``misses`` count lookups;
    re-tracing is observable via the module-level ``TRACE_COUNT``, and
    ``stats()`` publishes the full accounting (entries, hit/miss counts,
    cumulative first-call wall time) for the run profiler and the DSE
    sweep reports.
    """

    def __init__(self):
        self._runs: dict = {}
        self.hits = 0
        self.misses = 0
        #: cumulative wall seconds of every cached program's FIRST call
        #: (trace + XLA compile + one synchronized run)
        self.first_call_s = 0.0
        #: distinct program topologies compiled ("vmap" single-device,
        #: "channels:<d>" for channel-sharded meshes)
        self._topologies: set = set()

    def __len__(self):
        return len(self._runs)

    def clear(self):
        self._runs.clear()
        self.hits = self.misses = 0
        self.first_call_s = 0.0
        self._topologies.clear()

    def stats(self) -> dict:
        """Public cache accounting: ``entries`` (live programs), ``hits``
        / ``misses`` (lookup counts since construction/clear),
        ``first_call_s`` (cumulative wall time of each program's first
        call — the trace + compile cost plus one run), plus the device
        topology view: ``devices`` (visible device count) and
        ``shard_topologies`` (distinct program topologies compiled —
        ``"vmap"`` for single-device programs, ``"channels:<d>"`` for
        channel-sharded meshes)."""
        return {"entries": len(self._runs), "hits": self.hits,
                "misses": self.misses,
                "first_call_s": round(self.first_call_s, 3),
                "devices": int(jax.device_count()),
                "shard_topologies": tuple(sorted(self._topologies))}

    def get(self, spec, ccfg: C.ControllerConfig,
            fcfg: F.FrontendConfig, n_cycles: int, trace: bool = False,
            batched: bool = False, replay: F.ReplayStream | None = None,
            telemetry: int = 0, shard: int | None = None,
            donate: bool = False, fast_forward: bool = True):
        """``spec`` may be a :class:`CompiledSpec` (homogeneous system) or
        a :class:`MemorySystemSpec` (heterogeneous composition).
        ``telemetry`` is the windowed-telemetry window in cycles (0 =
        off); windowed programs emit cumulative snapshots every window.
        ``shard`` runs the scan channel-sharded over a ``shard``-device
        mesh (see :func:`make_run`); ``donate`` donates the ``fp``
        argument's buffers to the computation (``donate_argnums``) — safe
        whenever the caller rebuilds FrontParams per call, as the DSE
        executor does."""
        if shard and batched:
            raise ValueError(
                "channel sharding (shard=) composes with scalar runs only "
                "— batched DSE points shard across devices in repro.dse "
                "instead")
        key = run_key(spec, ccfg, fcfg, n_cycles, trace, batched, replay,
                      telemetry, shard, donate, fast_forward)
        fn = self._runs.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        # Close over a snapshot, not the caller's object: jit may re-trace
        # this closure much later (new batch shape), and by then the caller
        # may have mutated its cspec(s) in place — the snapshot keeps every
        # retrace consistent with the fingerprint taken above.
        if isinstance(spec, CompiledSpec):
            spec = dataclasses.replace(spec)
        else:
            spec = MemorySystemSpec(tuple(
                SpecGroup(dataclasses.replace(g.cspec), g.channels,
                          g.link_latency) for g in as_system(spec).groups))
        fn = make_run(spec, ccfg, fcfg, n_cycles, trace, replay,
                      telemetry_window=telemetry, shard=shard,
                      fast_forward=fast_forward)
        if batched:
            fn = jax.vmap(fn, in_axes=(None, 0, None))
        fn = _TimedRun(
            jax.jit(fn, donate_argnums=(1,) if donate else ()), self)
        self._topologies.add(f"{CHANNEL_AXIS}:{int(shard)}" if shard
                             else "vmap")
        self._runs[key] = fn
        return fn


#: Process-wide default cache used by `Simulator` and `repro.dse`.
RUN_CACHE = RunCache()


@dataclasses.dataclass
class Simulator:
    """User-facing memory-system handle: one (standard, org, timing)
    triple with a channel count and mapper order, OR an explicit
    heterogeneous composition via ``system=``.

    >>> sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    >>> stats = sim.run(100_000, interval=4.0, read_ratio=1.0)
    >>> quad = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=4)
    >>> stats = quad.run(50_000)      # stats.per_channel: (4,) breakdowns
    >>> cxl = Simulator(system=[
    ...     dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
    ...          timing_preset="DDR5_4800B", channels=2),
    ...     dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
    ...          timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ... ])
    >>> stats = cxl.run(50_000)       # 4 system channels, 2 spec groups

    Every system — homogeneous or not — compiles exactly once: the whole
    composition is one ``lax.scan`` program keyed in the process-wide
    :class:`RunCache` on the system tuple.
    """
    standard: str | None = None
    org_preset: str | None = None
    timing_preset: str | None = None
    controller: C.ControllerConfig = dataclasses.field(
        default_factory=C.ControllerConfig)
    frontend: F.FrontendConfig = dataclasses.field(
        default_factory=F.FrontendConfig)
    timing_overrides: dict | None = None
    #: memory-system channel fan-out (vmapped controllers inside the scan)
    channels: int = 1
    #: convenience override for ``frontend.mapper`` (None keeps it)
    mapper: str | None = None
    #: replay source for ``FrontendConfig(pattern="trace")``
    replay: F.ReplayStream | None = None
    #: heterogeneous composition: a :class:`MemorySystemSpec` or a list of
    #: group descriptors (see :func:`repro.core.compile.compile_system`);
    #: mutually exclusive with the (standard, org, timing) triple
    system: object = None
    #: channel-axis device sharding for scalar runs: ``None`` = auto
    #: (shard across the largest channel mesh the visible devices
    #: support; single-device boxes stay on the vmapped path), ``False``
    #: = never, ``True`` = require (raise when no mesh fits), int ``d``
    #: = exact mesh size.  Sharded and vmapped runs are bit-exact twins
    #: (pinned by the golden command-stream hashes).
    channel_shard: object = None
    #: event-horizon fast-forward: skip provably idle cycle runs in one
    #: variable-stride step (docs/architecture.md "Performance model").
    #: Bit-exact by construction — stats, command streams, and telemetry
    #: are identical with it on or off (pinned by the golden hashes) —
    #: so it defaults on; False forces the classic per-cycle scan.
    fast_forward: bool = True

    def __post_init__(self):
        if self.system is not None:
            if self.standard is not None:
                raise ValueError("pass either a (standard, org_preset, "
                                 "timing_preset) triple or system=..., "
                                 "not both")
            if self.channels != 1 or self.timing_overrides is not None:
                raise ValueError(
                    "channels=/timing_overrides= apply to the (standard, "
                    "org, timing) path only — a system=... composition "
                    "carries its own per-group channel counts and timing "
                    "overrides (see compile_system)")
            self.msys = as_system(self.system)
            # the 1-group zero-link composition IS the classic path: hand
            # the cache the bare CompiledSpec so both spellings alias one
            # compiled program (and one fingerprint)
            self.cspec = self.msys.groups[0].cspec \
                if self.msys.n_groups == 1 else None
        else:
            if self.standard is None:
                raise ValueError("Simulator needs a (standard, org_preset, "
                                 "timing_preset) triple or system=...")
            self.cspec = compile_spec(self.standard, self.org_preset,
                                      self.timing_preset,
                                      self.timing_overrides,
                                      channels=self.channels)
            self.msys = as_system(self.cspec)
        if self.mapper is not None:
            self.frontend = dataclasses.replace(self.frontend,
                                                mapper=self.mapper)

    @property
    def _cache_spec(self):
        """What the run cache is keyed/traced on: the bare CompiledSpec
        for homogeneous systems (historical key), the MemorySystemSpec
        otherwise."""
        return self.cspec if self.msys.homogeneous else self.msys

    def _dyn_params(self):
        return tuple(D.dyn_params(g.cspec) for g in self.msys.groups)

    def _resolved_shard(self) -> int | None:
        """The channel-mesh size scalar runs use, per ``channel_shard``."""
        cs = self.channel_shard
        if cs is None or cs is True:
            d = auto_channel_shard(self.msys)
            if d is None and cs is True:
                raise ValueError(
                    "channel_shard=True but no usable channel mesh: "
                    f"{jax.device_count()} device(s) for per-group "
                    f"channel counts "
                    f"{[g.channels for g in self.msys.groups]} (pin host "
                    "devices with XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N)")
            return d
        if not cs or int(cs) <= 1:
            return None
        return int(cs)

    # -- single-config run ------------------------------------------------
    def run(self, n_cycles: int, interval: float | None = None,
            read_ratio: float | None = None, trace: bool = False,
            seed: int = 0x1234, telemetry: int = 0,
            fast_forward: bool | None = None):
        """Run ``n_cycles``.  Returns ``stats`` — plus the raw trace
        arrays when ``trace=True``, plus a :class:`repro.telemetry.
        Telemetry` time series when ``telemetry=W > 0`` (windowed
        counters, one sample every W cycles; see docs/observability.md).
        Both extras: ``(stats, ys, telem)``."""
        fcfg = self.frontend
        if interval is not None or read_ratio is not None:
            fcfg = dataclasses.replace(
                fcfg,
                interval=interval if interval is not None else fcfg.interval,
                read_ratio=(read_ratio if read_ratio is not None
                            else fcfg.read_ratio))
        fp = fcfg.params()
        ff = self.fast_forward if fast_forward is None else fast_forward
        run_fn = RUN_CACHE.get(self._cache_spec, self.controller, fcfg,
                               n_cycles, trace=trace, replay=self.replay,
                               telemetry=telemetry,
                               shard=self._resolved_shard(),
                               fast_forward=ff)
        out = run_fn(self._dyn_params(), fp, jnp.uint32(seed))
        out = jax.tree.map(np.asarray, out)
        if telemetry:
            from repro import telemetry as T   # lazy: keeps core dep-free
            *rest, snaps = out
            telem = T.build(self.msys, snaps, window=telemetry,
                            n_cycles=n_cycles)
            return tuple(rest) + (telem,) if len(rest) > 1 \
                else (rest[0], telem)
        return out

    # -- batched DSE run ---------------------------------------------------
    def run_batch(self, n_cycles: int, intervals, read_ratios,
                  seed: int = 0x1234):
        """Simulate the outer product of load points in one vmapped program."""
        pts = [(i, r) for i in intervals for r in read_ratios]
        fp = F.stack_params(pts, self.frontend.probe_gap)
        batched = RUN_CACHE.get(self._cache_spec, self.controller,
                                self.frontend, n_cycles, batched=True,
                                replay=self.replay,
                                fast_forward=self.fast_forward)
        out = batched(self._dyn_params(), fp, jnp.uint32(seed))
        return pts, jax.tree.map(np.asarray, out)


def _accum_channel_stats(cspec: CompiledSpec, ch: ChannelStats,
                         ev: C.StepEvents, clk=None,
                         telemetry: bool = False) -> ChannelStats:
    """Fold one cycle's channel-stacked events into the running stats of
    ONE spec group (counts in the group's native command namespace).

    With ``telemetry``, the SAME per-cycle ``cmd_counts`` add also folds
    the windowed-telemetry gauges into ``1 + n_edges`` extension columns
    (split back off by :func:`_snap_telemetry` before stats ever leave
    the engine) — no extra scan carry, no extra per-cycle kernel:

    - column ``n_cmds``: the queue-residency integral of SERVED
      requests (``clk - arrive`` at each service event; requests
      release their queue slot on the column bus — FINAL_RD/FINAL_WR
      are column commands — so the served arrival clock is
      ``ev.arrive[:, 0]``).  The cycle-sum of queue occupancy over
      ``[0, t)`` is this plus the residual ``t - arrive`` of requests
      still queued at ``t``, added once per window boundary — so no
      per-cycle occupancy reduction is ever needed;
    - column ``n_cmds + 1 + k``: served probes with latency <= edge
      ``k``, a CUMULATIVE histogram (the host diffs along the bucket
      axis and closes the open top bucket with ``probe_cnt``).

    Separate accumulators for the same gauges — a packed carry-add, a
    per-cycle (C, 2) ys emission with per-window folds, searchsorted +
    one-hot — all measured noticeably more engine overhead than riding
    the adds that the stats fold performs anyway."""
    nBL = jnp.int32(cspec.timings["nBL"])
    rd = ev.served_read.astype(jnp.int32)          # (C,)
    wr = ev.served_write.astype(jnp.int32)
    counts = ch.cmd_counts                          # (C, n_cmds [+ 1 + E])
    cmd_ids = jnp.arange(cspec.n_cmds, dtype=jnp.int32)
    if telemetry:
        served = ev.served_read | ev.served_write                  # (C,)
        res = jnp.where(served, clk - ev.arrive[:, 0], 0)          # (C,)
        edges = jnp.asarray(cspec.lat_bucket_edges, jnp.int32)
        lat = jnp.where(ev.served_probe, ev.probe_latency,
                        jnp.int32(1 << 30))
        cum = (lat[:, None] <= edges[None, :]).astype(jnp.int32)   # (C, E)
        oh = ((cmd_ids[None, :] == ev.cmd[:, 0:1]).astype(jnp.int32)
              + (cmd_ids[None, :] == ev.cmd[:, 1:2]).astype(jnp.int32))
        counts = counts + jnp.concatenate([oh, res[:, None], cum], axis=1)
    else:
        for i in range(2):
            # dense one-hot add (idle slots are -1: no match, no count)
            counts = counts + (cmd_ids[None, :]
                               == ev.cmd[:, i:i + 1]).astype(jnp.int32)
    return ChannelStats(
        reads_done=ch.reads_done + rd,
        writes_done=ch.writes_done + wr,
        probe_lat_sum=ch.probe_lat_sum + ev.probe_latency,
        probe_cnt=ch.probe_cnt + ev.served_probe.astype(jnp.int32),
        data_bus_busy=ch.data_bus_busy + nBL * (rd + wr),
        cmd_counts=counts,
        deferred=ch.deferred + ev.deferred,
    )


def _aggregate_stats(msys: MemorySystemSpec, chs: list, clk,
                     scan_steps=None) -> Stats:
    """Fold the per-group running stats into the uniform :class:`Stats`.

    The 1-group path is bit-identical to the historical aggregation; for
    a composition the per-channel view concatenates the groups' channels
    (group-major) and lifts each group's command counts into the merged
    namespace via its local→global id map."""
    if msys.n_groups == 1:
        ch = chs[0]
        per_channel = ch
        cmd_counts = jnp.sum(ch.cmd_counts, axis=0)
    else:
        n_global = msys.n_cmds
        lifted = []
        for g, ch in enumerate(chs):
            gmap = jnp.asarray(msys.group_cmd_maps[g], jnp.int32)
            c_g = ch.cmd_counts.shape[0]
            lift = jnp.zeros((c_g, n_global), jnp.int32)
            lifted.append(lift.at[:, gmap].set(ch.cmd_counts))
        cat = lambda f: jnp.concatenate([getattr(ch, f) for ch in chs])
        per_channel = ChannelStats(
            reads_done=cat("reads_done"), writes_done=cat("writes_done"),
            probe_lat_sum=cat("probe_lat_sum"), probe_cnt=cat("probe_cnt"),
            data_bus_busy=cat("data_bus_busy"),
            cmd_counts=jnp.concatenate(lifted, axis=0),
            deferred=cat("deferred"))
        cmd_counts = jnp.sum(per_channel.cmd_counts, axis=0)
    return Stats(
        cycles=clk,
        reads_done=jnp.sum(per_channel.reads_done),
        writes_done=jnp.sum(per_channel.writes_done),
        probe_lat_sum=jnp.sum(per_channel.probe_lat_sum),
        probe_cnt=jnp.sum(per_channel.probe_cnt),
        data_bus_busy=jnp.sum(per_channel.data_bus_busy),
        cmd_counts=cmd_counts,
        deferred=jnp.sum(per_channel.deferred),
        per_channel=per_channel,
        per_group=tuple(chs),
        # classic per-cycle path: every cycle is one scan step
        scan_steps=clk if scan_steps is None else scan_steps,
        skipped_cycles=(jnp.zeros_like(clk) if scan_steps is None
                        else clk - scan_steps),
    )


def make_run(spec, ccfg: C.ControllerConfig,
             fcfg: F.FrontendConfig, n_cycles: int, trace: bool,
             replay: F.ReplayStream | None = None,
             telemetry_window: int = 0, shard: int | None = None,
             fast_forward: bool = True):
    """Build the pure run function (dps, fp, seed) -> Stats [, trace]
    [, telemetry snapshots].

    ``shard = d > 1`` runs the SAME cycle function channel-sharded over a
    ``d``-device mesh (one contiguous slice of every group's channel axis
    per device, ``d`` dividing every group's channel count): the whole
    scan sits inside one ``jax.shard_map``, the frontend decode runs
    replicated on every shard, each shard inserts into / steps its local
    channels only, and the sole cross-shard traffic is one fused 5-wide
    int32 ``psum`` per cycle (insert accepts + completion events).  The
    sharded and vmapped programs are bit-exact twins — same stats, same
    command streams, same telemetry.

    ``spec`` is a :class:`CompiledSpec` or a :class:`MemorySystemSpec`;
    ``dps`` is the per-group tuple of :class:`repro.core.device.DynParams`
    (a bare ``DynParams`` is accepted for the 1-group case).  One compiled
    program per (system, configs, n_cycles, trace, replay, telemetry)
    regardless of group or channel count: the frontend routes decoded
    requests to per-(group, channel) queues, ``controller_step`` runs
    across each group's channels via an inner ``jax.vmap``, and the groups
    advance as parallel branches of the single ``lax.scan`` body, their
    states living in the group-indexed :class:`SimState` carry.
    CXL-attached groups (``link_latency > 0``) see requests
    ``link_latency`` cycles after arrival and return read data
    ``link_latency`` cycles late.

    ``fast_forward`` (default on) replaces the fixed-stride cycle scan
    with event-horizon macro-stepping: a ``lax.while_loop`` executes one
    full cycle, then computes a safe skip distance — the minimum of the
    frontend's next arrival/probe attempt, every channel's next
    timing-ready/refresh/clock-expiry event, the BlockHammer decay
    boundary, and the current segment end — and advances the state
    across the provably idle run in closed form (clamped accumulator
    refill + LCG jump; all other state is frozen on idle cycles).  The
    result is O(events) instead of O(cycles) on idle-heavy workloads and
    bit-exact by construction: stats, command streams, and telemetry
    snapshots are identical with it on or off (pinned by the golden-hash
    suite).  With ``trace=True`` the dense per-cycle ys become an
    idle-initialized ``(T, C, 2)`` buffer written at the TRUE cycle
    index of each executed cycle, so skipped cycles hold exactly the
    idle values the per-cycle scan would have emitted.

    ``telemetry_window = W > 0`` restructures the cycle scan into windows
    of W cycles (an outer scan over full windows around an inner W-cycle
    scan of the SAME cycle function, plus a ragged final segment for the
    ``n_cycles % W`` remainder) and emits one cumulative
    :class:`GroupWindowSnap` tuple per window boundary — O(n_windows)
    output, so long runs pay neither per-cycle trace memory nor
    end-of-run-only blindness.  The per-cycle math is identical to the
    flat scan, so stats — and command streams under ``trace=True`` — are
    bit-equal with telemetry on or off."""
    msys = as_system(spec)
    groups = msys.groups
    n_groups = msys.n_groups
    n_chan_total = msys.n_channels
    sys_layout = F.make_system_layout(msys, fcfg.mapper)
    if fcfg.stream and fcfg.pattern == "trace" and replay is None:
        raise ValueError('FrontendConfig(pattern="trace") needs a '
                         "ReplayStream (Simulator(..., replay=...))")
    if replay is not None:
        if len(replay) == 0:
            raise ValueError("replay stream is empty — nothing to replay")
        if replay.arrive is not None \
                and np.any(np.diff(np.asarray(replay.arrive)) < 0):
            raise ValueError(
                "replay arrive column must be non-decreasing (injection "
                "is index-ordered) — sort the stream into arrival order "
                "as trace.to_replay does")
        top = int(np.max(replay.chan))
        if top >= n_chan_total or int(np.min(replay.chan)) < 0:
            raise ValueError(
                f"replay stream targets channel {top} but the memory "
                f"system has {n_chan_total} channel(s) — re-encode the "
                "stream through this system's mapper (ReplayStream."
                "from_addresses) instead of reusing captured channels")
        max_sub = max(len(g.cspec.levels) - 1 for g in groups)
        if replay.sub.shape[1] != max_sub:
            raise ValueError(
                f"replay sub columns are {replay.sub.shape[1]} wide but "
                f"this system needs {max_sub} sub-level indices — rebuild "
                "the stream against this system (ReplayStream."
                "from_addresses / trace.to_replay)")
    rp = None if replay is None else F.ReplayStream(
        chan=jnp.asarray(replay.chan), sub=jnp.asarray(replay.sub),
        row=jnp.asarray(replay.row), col=jnp.asarray(replay.col),
        is_write=jnp.asarray(replay.is_write),
        # arrive stays host-side numpy: the frontend derives static pacing
        # scalars (base / span / wrap gap) from it at trace time
        arrive=replay.arrive,
        fingerprint=replay.fingerprint,
        dep=None if replay.dep is None else jnp.asarray(replay.dep))

    static_bases = []
    _b = 0
    for grp in groups:
        static_bases.append(_b)
        _b += grp.channels

    def cycle(sim: SimState, _, dps, fp, axis_name=None, bases=None):
        # insert → step → ONE fused reduction → commit/finish.  On the
        # sharded path ``axis_name``/``bases`` are set: the frontend
        # decode runs replicated, inserts hit the local channel slice
        # only, and the 5-wide int32 vector below is the cycle's entire
        # cross-shard traffic (a single psum).  The fast-forward path
        # widens it to 6 with the cycle's issued command count and
        # returns it, so the macro-stepper can gate its horizon
        # computation on a busy/idle verdict that is uniform across
        # shards by construction (it rides the psum).
        queues, draft = F.system_frontend_insert(
            msys, fcfg, fp, sim.fs, tuple(g.cs.queue for g in sim.gs),
            sim.clk, sys_layout, rp, bases)
        new_gs, evs = [], []
        for gi, (grp, dp) in enumerate(zip(groups, dps)):
            cs = sim.gs[gi].cs._replace(queue=queues[gi])
            cs, ev = jax.vmap(
                lambda s: C.controller_step(grp.cspec, dp, ccfg, s, sim.clk,
                                            grp.link_latency))(cs)
            # with telemetry, the gauge columns ride this same stats fold
            # (the telemetry-off traced program is unchanged)
            ch = _accum_channel_stats(grp.cspec, sim.gs[gi].ch, ev,
                                      sim.clk, bool(telemetry_window))
            new_gs.append(GroupState(cs=cs, ch=ch))
            evs.append(ev)
        absorb = F.absorb_locals(evs[0])
        for ev in evs[1:]:
            absorb = absorb + F.absorb_locals(ev)
        # [probe-accept, stream-accept, probes-done, served, completion]
        loc = jnp.concatenate([jnp.stack([draft.okp, draft.ok]), absorb])
        if fast_forward:
            issued = sum(jnp.sum((ev.cmd >= 0).astype(jnp.int32))
                         for ev in evs)
            loc = jnp.concatenate([loc, issued[None]])
        if axis_name is not None:
            loc = jax.lax.psum(loc, axis_name)
        fs = F.frontend_commit(fcfg, fp, sim.fs, draft, loc[0], loc[1],
                               F.paced_by_arrive(fcfg, rp))
        fs = F.frontend_finish(fs, fp, loc[2], loc[3], loc[4])
        out = SimState(gs=tuple(new_gs), fs=fs, clk=sim.clk + 1)
        # trace ys stay a per-group tuple ((C_g, 2) leaves) until the
        # post-scan finalize — on the sharded path the gather happens on
        # the group tuples, so the concat order is shard-independent
        ys = tuple(TraceArrays(e.cmd, e.bank, e.row, e.arrive,
                               e.hit_ready) for e in evs) if trace else None
        if fast_forward:
            return out, ys, loc
        return out, ys

    def _finalize_trace(ys_groups):
        """Per-group ``(T, C_g, 2)`` trace fields → the public
        :class:`TraceArrays` layout: single-channel systems keep the
        historical ``(T, 2)`` slot shape; multi-channel systems
        concatenate the groups' channel axes group-major."""
        if n_chan_total == 1:
            return jax.tree.map(lambda a: a[:, 0], ys_groups[0])
        if n_groups == 1:
            return ys_groups[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                            *ys_groups)

    def _init_state(seed, shard_index=None):
        gs = []
        for grp in groups:
            cspec, nch = grp.cspec, grp.channels
            loc = nch // shard if shard else nch
            cs1 = C.init_ctrl_state(cspec, ccfg.queue_depth)
            css = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (loc,) + a.shape), cs1)
            if ccfg.refresh_stagger and nch > 1:
                # phase-shift each channel's refresh epoch by c*nREFI/C
                # (real controllers stagger REF so the channels' refresh
                # windows — and their bandwidth dips — never align);
                # channel 0 keeps the historical phase, so single-channel
                # groups are bit-identical.  Staggering is group-local:
                # each group phases its own nREFI.  On the sharded path
                # the offsets come from the GLOBAL channel ids of this
                # shard's slice, so every channel keeps the phase it has
                # on the vmapped path.
                nrefi = int(cspec.timings["nREFI"])
                if shard_index is None:
                    offs = jnp.asarray(
                        [-(c * nrefi // nch) for c in range(nch)],
                        jnp.int32)
                else:
                    gidx = (shard_index * jnp.int32(loc)
                            + jnp.arange(loc, dtype=jnp.int32))
                    offs = -((gidx * jnp.int32(nrefi)) // jnp.int32(nch))
                css = css._replace(dev=css.dev._replace(
                    last_ref=css.dev.last_ref + offs[:, None]))
            gs.append(GroupState(
                cs=css,
                ch=_zero_channel_stats(cspec, bool(telemetry_window),
                                       n_channels=loc)))
        init = SimState(gs=tuple(gs), fs=F.init_front(), clk=jnp.int32(0))
        return init._replace(
            fs=init.fs._replace(rng=seed | jnp.uint32(1)))

    def _scan_cycles(init, body):
        """Drive ``body`` over ``n_cycles`` honoring the telemetry
        windowing; returns ``(final SimState, per-group trace ys | None,
        per-group window snaps | None)``.  Shared verbatim by the
        vmapped and sharded paths (the body closure is the only
        difference), so the windowed restructure cannot diverge between
        them."""
        if not telemetry_window:
            final, ys = jax.lax.scan(body, init, None, length=n_cycles)
            return final, ys, None

        # Windowed telemetry: same cycle function, scanned in W-cycle
        # segments.  Each boundary emits the CUMULATIVE counters (the
        # host diffs consecutive snapshots), so the final snapshot equals
        # the end-of-run aggregates bit-exactly by construction.
        def snapshot(sim):
            return tuple(_snap_telemetry(grp.cspec, g, sim.clk)
                         for grp, g in zip(groups, sim.gs))

        W = telemetry_window
        n_full, rem = divmod(n_cycles, W)
        sim = init
        snap_parts, ys_parts = [], []

        def window(sim, _):
            sim, ys = jax.lax.scan(body, sim, None, length=W)
            return sim, (snapshot(sim), ys)

        if n_full:
            sim, (snaps, ys) = jax.lax.scan(window, sim, None,
                                            length=n_full)
            snap_parts.append(snaps)
            if trace:
                # [n_full, W, ...] -> [n_full*W, ...]: cycle-major order
                # is unchanged, so command streams hash identically
                ys_parts.append(jax.tree.map(
                    lambda a: a.reshape((n_full * W,) + a.shape[2:]), ys))
        if rem:
            sim, ys = jax.lax.scan(body, sim, None, length=rem)
            snap_parts.append(jax.tree.map(lambda a: a[None],
                                           snapshot(sim)))
            if trace:
                ys_parts.append(ys)
        if not snap_parts:          # n_cycles == 0: one (all-zero) window
            snap_parts.append(jax.tree.map(lambda a: a[None],
                                           snapshot(sim)))
        cat = (lambda *xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs))
        snaps = jax.tree.map(lambda *xs: cat(*xs), *snap_parts)
        ys = jax.tree.map(lambda *xs: cat(*xs), *ys_parts) if trace \
            else None
        return sim, ys, snaps

    # -- event-horizon fast-forward machinery --------------------------
    # Static per-cycle rng advance: the frontend draws a FIXED number of
    # LCG values per cycle (independent of accepts), so a run of skipped
    # cycles is one affine jump.  Computed host-side at build time.
    _k_draws = F.rng_draws_per_cycle(fcfg, sys_layout)
    _a_cyc, _c_cyc = F.lcg_affine(_k_draws)
    _paced = F.paced_by_arrive(fcfg, rp)

    def _horizon(sim, dps, fp):
        """min over all event sources of the next cycle >= sim.clk at
        which anything could happen: frontend arrival/probe attempts
        plus every channel's controller horizon.  Conservative — an
        early horizon just executes an idle cycle (see
        ``C.channel_horizon``)."""
        h = F.arrival_horizon(fcfg, fp, sim.fs, sim.clk, rp, _paced)
        for gi, (grp, dp) in enumerate(zip(groups, dps)):
            hc = jax.vmap(
                lambda s: C.channel_horizon(grp.cspec, dp, ccfg, s,
                                            sim.clk, grp.link_latency)
            )(sim.gs[gi].cs)
            h = jnp.minimum(h, jnp.min(hc))
        return h

    def _idle_jump(sim, target):
        """Advance the state across the idle run [sim.clk, target) in
        one step: only the frontend accumulator and rng move on idle
        cycles (closed forms); everything else is provably frozen."""
        fs = F.idle_advance(fcfg, sim.fs, target - sim.clk,
                            _a_cyc, _c_cyc, _k_draws)
        return sim._replace(fs=fs, clk=target)

    def _init_trace_bufs(local_counts):
        """Idle-initialized dense per-cycle trace buffers, one per spec
        group: the fast-forward path writes each EXECUTED cycle's events
        at its true cycle index, and skipped cycles keep these fill
        values — exactly what the per-cycle scan emits on an idle cycle
        (no candidate is ready, so cmd/bank/row/arrive are -1 and no
        post-predicate row hit exists), making the dense trace — and its
        golden sha256 — bit-identical to the fixed-stride path's."""
        bufs = []
        for nch in local_counts:
            i32 = lambda: jnp.full((n_cycles, nch, 2), -1, jnp.int32)
            bufs.append(TraceArrays(
                cmd=i32(), bank=i32(), row=i32(), arrive=i32(),
                hit_ready=jnp.zeros((n_cycles, nch, 2), bool)))
        return tuple(bufs)

    def _ff_cycles(init, body, dps, fp, local_counts, axis_name=None):
        """Fast-forward twin of ``_scan_cycles``: ONE ``lax.while_loop``
        over the whole run, each iteration executing ONE real cycle and
        then jumping to ``min(horizon, next window boundary)``.  Returns
        ``(final SimState, per-group trace buffers | None, window snaps
        | None, scan-step count)``.  The horizon computation is gated on
        the cycle's busy verdict (any accept or issue => next cycle runs
        anyway), which rides the fused reduction — on the sharded path
        the verdict is therefore uniform across shards and the
        cross-device ``pmin`` of the per-shard horizons sits OUTSIDE the
        gate, so every shard takes the same trip count.

        Windowed telemetry rides the SAME loop: jump targets are capped
        at the next ``W``-boundary, so the clock lands on every boundary
        exactly once (it advances by >= 1 per iteration and never jumps
        across a cap), and that iteration writes one snapshot row into a
        dense ``(n_full, ...)`` buffer carried through the loop.  An
        earlier revision nested the while loop inside a ``lax.scan``
        over windows instead; XLA:CPU would not keep the loop carry
        in-place across the scan->while boundary and the resulting
        per-iteration state copies cost ~20% wall clock regardless of
        window count."""
        bufs0 = _init_trace_bufs(local_counts) if trace else None
        W = telemetry_window
        n_full = n_cycles // W if W else 0

        def snapshot(sim):
            return tuple(_snap_telemetry(grp.cspec, g, sim.clk)
                         for grp, g in zip(groups, sim.gs))

        snaps0 = jax.tree.map(
            lambda s: jnp.zeros((n_full,) + s.shape, s.dtype),
            jax.eval_shape(snapshot, init)) if W else None

        def cond(c):
            return c[0].clk < jnp.int32(n_cycles)

        def step(c):
            sim, steps, bufs, snaps = c
            t0 = sim.clk
            out, ys, loc = body(sim)
            if trace:
                z = jnp.int32(0)
                bufs = tuple(
                    jax.tree.map(
                        lambda b, y: jax.lax.dynamic_update_slice(
                            b, y[None].astype(b.dtype), (t0, z, z)),
                        bufs[g], ys[g])
                    for g in range(n_groups))
            busy = (loc[0] + loc[1] + loc[5]) > 0
            h = jax.lax.cond(busy, lambda _: out.clk,
                             lambda _: _horizon(out, dps, fp), None)
            if axis_name is not None:
                h = jax.lax.pmin(h, axis_name)
            cap = jnp.int32(n_cycles)
            if W:
                cap = jnp.minimum(cap, (t0 // W + 1) * W)
            target = jnp.minimum(jnp.maximum(h, out.clk), cap)
            nxt = _idle_jump(out, target)
            if W and n_full:        # n_cycles < W: tail snapshot only
                snaps = jax.lax.cond(
                    target % W == 0,
                    lambda s: jax.tree.map(
                        lambda b, v: jax.lax.dynamic_update_index_in_dim(
                            b, v.astype(b.dtype), target // W - 1, 0),
                        s, snapshot(nxt)),
                    lambda s: s, snaps)
            return nxt, steps + jnp.int32(1), bufs, snaps

        sim, steps, bufs, snaps = jax.lax.while_loop(
            cond, step, (init, jnp.int32(0), bufs0, snaps0))
        if not W:
            return sim, bufs, None, steps
        snap_parts = [snaps] if n_full else []
        if n_cycles % W or not n_full:   # ragged tail / n_cycles < W
            snap_parts.append(jax.tree.map(lambda a: a[None],
                                           snapshot(sim)))
        cat = (lambda *xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs))
        snaps = jax.tree.map(lambda *xs: cat(*xs), *snap_parts)
        return sim, bufs, snaps, steps

    def _final_chs(final_gs):
        """The groups' end-of-run ChannelStats, telemetry gauge columns
        stripped before the uniform aggregation."""
        if not telemetry_window:
            return [g.ch for g in final_gs]
        return [g.ch._replace(cmd_counts=g.ch.cmd_counts[:, :grp.cspec
                              .n_cmds])
                for grp, g in zip(groups, final_gs)]

    def _check_dps(dps):
        if isinstance(dps, D.DynParams):
            dps = (dps,)            # 1-group back-compat
        if len(dps) != n_groups:
            raise ValueError(f"expected {n_groups} DynParams (one per spec "
                             f"group), got {len(dps)}")
        return dps

    def run(dps, fp, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1            # runs once per jax trace, not per call
        dps = _check_dps(dps)
        if fast_forward:
            body = lambda sim: cycle(sim, None, dps=dps, fp=fp)
            final, ys, snaps, steps = _ff_cycles(
                _init_state(seed), body, dps, fp,
                tuple(g.channels for g in groups))
        else:
            body = partial(cycle, dps=dps, fp=fp)
            final, ys, snaps = _scan_cycles(_init_state(seed), body)
            steps = None
        stats = _aggregate_stats(msys, _final_chs(final.gs), final.clk,
                                 steps)
        out = (stats,)
        if trace:
            out += (_finalize_trace(ys),)
        if telemetry_window:
            out += (snaps,)
        return out if len(out) > 1 else stats

    if not shard:
        return run

    # -- channel-sharded variant --------------------------------------
    # The ENTIRE scan sits inside one shard_map, so the per-cycle psum
    # compiles into the same single program as the scan (no per-cycle
    # host round trips).  Each device owns a contiguous slice of every
    # group's channel axis; out_specs gather the per-channel outputs
    # back onto the global channel axis, and the replicated aggregation
    # below is shared verbatim with the vmapped path.
    from repro.compat import ensure_jax_shard_map_compat
    ensure_jax_shard_map_compat()
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    shard = int(shard)
    bad = [g.channels for g in groups if g.channels % shard]
    if shard < 2 or bad:
        raise ValueError(
            f"channel shard {shard} must be >= 2 and divide every "
            f"group's channel count {[g.channels for g in groups]}")
    devs = jax.devices()
    if len(devs) < shard:
        raise ValueError(
            f"channel shard {shard} needs {shard} devices, have "
            f"{len(devs)} — pin host devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shard}")
    mesh = Mesh(np.asarray(devs[:shard]), (CHANNEL_AXIS,))

    def run_sharded(dps, fp, seed):
        global TRACE_COUNT
        TRACE_COUNT += 1
        dps = _check_dps(dps)

        def shard_body(dps, fp, seed):
            si = jax.lax.axis_index(CHANNEL_AXIS)
            bases = tuple(
                jnp.int32(b) + si * jnp.int32(grp.channels // shard)
                for b, grp in zip(static_bases, groups))
            if fast_forward:
                body = lambda sim: cycle(sim, None, dps=dps, fp=fp,
                                         axis_name=CHANNEL_AXIS,
                                         bases=bases)
                final, ys, snaps, steps = _ff_cycles(
                    _init_state(seed, si), body, dps, fp,
                    tuple(g.channels // shard for g in groups),
                    axis_name=CHANNEL_AXIS)
            else:
                body = partial(cycle, dps=dps, fp=fp,
                               axis_name=CHANNEL_AXIS, bases=bases)
                final, ys, snaps = _scan_cycles(_init_state(seed, si),
                                                body)
                steps = jnp.int32(n_cycles)
            # steps is uniform across shards (the busy verdict rides the
            # psum and the horizon is pmin-reduced) — emit a (1,) slice
            # per shard and read any one back after the gather
            return tuple(_final_chs(final.gs)), ys, snaps, steps[None]

        chs, ys, snaps, steps = jax.shard_map(
            shard_body, mesh=mesh, in_specs=(P(), P(), P()),
            out_specs=(P(CHANNEL_AXIS), P(None, CHANNEL_AXIS),
                       P(None, CHANNEL_AXIS),
                       P(CHANNEL_AXIS)))(dps, fp, seed)
        stats = _aggregate_stats(msys, list(chs), jnp.int32(n_cycles),
                                 steps[0] if fast_forward else None)
        out = (stats,)
        if trace:
            out += (_finalize_trace(ys),)
        if telemetry_window:
            out += (snaps,)
        return out if len(out) > 1 else stats

    return run_sharded


# --------------------------------------------------------------------------
# Derived metrics
# --------------------------------------------------------------------------
#
# These helpers take the Stats of ONE run: the `float()` casts require
# 0-d (scalar) stat fields and raise on the stacked (B,)-shaped Stats that
# `run_batch` / `repro.dse` produce.  For batched stats either index one
# point out first (`jax.tree.map(lambda a: a[i], stats)`) or use the
# vectorized equivalents in `repro.dse.results`.
#
# Every helper accepts either a CompiledSpec (homogeneous system) or a
# MemorySystemSpec.  For heterogeneous systems the math is GROUP-CORRECT:
# each group's bytes/cycle-time come from its own spec — never one spec's
# bandwidth multiplied by the total channel count — and a spec/stats
# mismatch raises instead of silently aggregating wrong numbers.


def _check_system_stats(msys: MemorySystemSpec, stats):
    got = len(getattr(stats, "per_group", ()) or ())
    if got != msys.n_groups:
        raise ValueError(
            f"stats carry {got} spec group(s) but the system has "
            f"{msys.n_groups} — these stats were produced by a different "
            "memory system (pass the matching spec/system)")


def throughput_gbps(spec, stats) -> float:
    """Achieved data throughput in GB/s (1e9 bytes per second).

    Homogeneous: bytes moved = (reads + writes) * access_bytes; wall time
    = cycles * tCK_ps.  Heterogeneous: each group's bytes and clock come
    from its own spec (``sum_g bytes_g / (cycles * tCK_g)``).  Scalar
    stats only — see the batched-stats caveat above.
    """
    msys = as_system(spec)
    _check_system_stats(msys, stats)
    total = 0.0
    for grp, ch in zip(msys.groups, stats.per_group):
        moved = float(np.sum(np.asarray(ch.reads_done))
                      + np.sum(np.asarray(ch.writes_done))) \
            * grp.cspec.access_bytes
        seconds = float(stats.cycles) * grp.cspec.tCK_ps * 1e-12
        total += moved / seconds / 1e9 if seconds else 0.0
    return total


def peak_gbps(spec) -> float:
    """Theoretical peak throughput of the memory *system* in GB/s:
    each group sustains access_bytes / nBL per cycle on every cycle of
    every one of its channels' data buses, on its own clock — summed
    across groups (the homogeneous case degenerates to the historical
    ``n_channels * per_channel_peak``)."""
    msys = as_system(spec)
    total = 0.0
    for grp in msys.groups:
        per_chan = grp.cspec.peak_bytes_per_cycle \
            / (grp.cspec.tCK_ps * 1e-12) / 1e9
        total += grp.channels * per_chan
    return total


def channel_breakdown(spec, stats) -> dict:
    """Per-system-channel summary of one scalar run's ``stats``:
    ``{channel: {group, standard, reads_done, writes_done,
    throughput_gbps, bus_util}}`` — each channel's conversion uses its own
    group's access_bytes and tCK."""
    msys = as_system(spec)
    _check_system_stats(msys, stats)
    out = {}
    c_sys = 0
    for g, (grp, ch) in enumerate(zip(msys.groups, stats.per_group)):
        seconds = float(stats.cycles) * grp.cspec.tCK_ps * 1e-12
        for c in range(grp.channels):
            moved = (int(ch.reads_done[c]) + int(ch.writes_done[c])) \
                * grp.cspec.access_bytes
            out[c_sys] = {
                "group": g,
                "standard": grp.cspec.standard or grp.cspec.name,
                "reads_done": int(ch.reads_done[c]),
                "writes_done": int(ch.writes_done[c]),
                "throughput_gbps": moved / seconds / 1e9 if seconds else 0.0,
                "bus_util": (float(ch.data_bus_busy[c]) / float(stats.cycles)
                             if int(stats.cycles) else 0.0),
            }
            c_sys += 1
    return out


def avg_probe_latency_ns(spec, stats) -> float:
    """Mean random-probe read latency in nanoseconds (arrival to data
    completion — CXL-attached groups include the round-trip link time),
    NaN when no probe finished.  Probe latencies are counted on the
    system's shared cycle index and converted with the reference clock
    (group 0's tCK).  Scalar stats only — see the batched-stats caveat
    above."""
    if int(stats.probe_cnt) == 0:
        return float("nan")
    cycles = float(stats.probe_lat_sum) / float(stats.probe_cnt)
    return cycles * as_system(spec).tCK_ps * 1e-3


def format_stats(stats, spec=None) -> str:
    """Human-readable summary of one scalar run's ``stats``.

    Without a spec: raw counters only.  With the run's spec/system:
    group-aware physical units — per-group GB/s vs peak, bus utilization,
    row-hit rate (1 - ACT/(RD+WR)), mean probe latency in ns — and a
    per-channel table labeled by each channel's owning standard.  This is
    the formatter behind :meth:`Stats.summary`, shared by the examples
    and the trace/telemetry CLIs."""
    cyc = int(stats.cycles)
    lines = [f"cycles            {cyc:>14,}",
             f"reads done        {int(stats.reads_done):>14,}",
             f"writes done       {int(stats.writes_done):>14,}",
             f"deferred          {int(stats.deferred):>14,}"]
    skipped = int(stats.skipped_cycles)
    if cyc:
        # what fast-forward bought on this workload: the fraction of
        # cycles the engine never had to execute
        lines.append(f"idle fast-forward {skipped / cyc:>14.1%}  "
                     f"({int(stats.scan_steps):,} scan steps)")
    if spec is None:
        if cyc:
            lines.append(f"bus busy          "
                         f"{int(stats.data_bus_busy) / cyc:>14.1%}")
        return "\n".join(lines)
    msys = as_system(spec)
    _check_system_stats(msys, stats)
    ach = throughput_gbps(msys, stats)
    lines += [f"throughput (GB/s) {ach:>14.2f}  "
              f"(peak {peak_gbps(msys):.2f})",
              f"probe latency(ns) {avg_probe_latency_ns(msys, stats):>14.1f}"]
    hit = row_hit_rate(msys, stats)
    if hit == hit:                          # NaN-safe
        lines.append(f"row-hit rate      {hit:>14.1%}")
    bd = channel_breakdown(msys, stats)
    if len(bd) > 1 or msys.n_groups > 1:
        lines.append("channel  standard     reads      writes   "
                     "GB/s   bus-util")
        for c, d in bd.items():
            lines.append(
                f"{c:>7}  {d['standard']:<9}{d['reads_done']:>10,}"
                f"{d['writes_done']:>12,}{d['throughput_gbps']:>7.2f}"
                f"{d['bus_util']:>10.1%}")
    return "\n".join(lines)


def row_hit_rate(spec, stats) -> float:
    """Fraction of data commands (RD+WR) served without opening a new
    row: ``1 - ACT / (RD + WR)``, summed over every group's native
    command counts.  NaN when no data command issued.  Scalar stats only
    — see the batched-stats caveat above."""
    msys = as_system(spec)
    _check_system_stats(msys, stats)
    act = data = 0
    for grp, ch in zip(msys.groups, stats.per_group):
        counts = np.asarray(ch.cmd_counts).sum(axis=0)
        names = grp.cspec.cmd_names
        act += sum(int(counts[i]) for i, n in enumerate(names)
                   if n.startswith("ACT"))
        data += sum(int(counts[i]) for i, n in enumerate(names)
                    if n in ("RD", "WR", "RDA", "WRA"))
    return 1.0 - act / data if data else float("nan")
