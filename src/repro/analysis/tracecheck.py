"""AST-based JAX trace-safety linter over ``src/repro``.

The jitted engine hot paths live or die by staying traceable: a Python
``if`` on a traced value raises ``TracerBoolConversionError`` only on
the code path that reaches it, a stray ``np.*`` on a traced array
silently falls back to host round-trips, and a list captured into a
``RunCache``-keyed predicate breaks compile-cache keying.  This pass
finds those *statically*, before a run trips over them.

Rules
-----
* ``TS101`` — Python ``if``/``while`` on a traced value inside a traced
  context (scan body, jitted function, or anything they call).
  Hashability tests the tracer allows — ``x is None``, ``isinstance``,
  ``len(...)`` (shape-only) — are exempt.
* ``TS102`` — host coercion of a traced value (``.item()``, ``int()``,
  ``float()``, ``bool()``) inside a traced context.
* ``TS103`` — ``np.*`` call on a traced value inside a traced context
  (silent device->host fallback).
* ``TS104`` — non-hashable closure capture (list/dict/set) in a
  callable passed to a cache-keyed sink (``extra_predicates``): the
  engine's ``RunCache`` freezes callables by closure contents, and
  mutable captures either fail to hash or alias stale state.
* ``TS105`` — ``jax.numpy`` import in a module outside the allowlisted
  hot-path set: keeps accidental device code out of host-side layers
  (artifacts, CLIs, docs tooling) as the codebase grows.

Traced contexts are discovered, not annotated: direct functional
operands of ``lax.scan`` / ``cond`` / ``while_loop`` / ``fori_loop`` /
``switch`` and of ``jit`` / ``vmap`` / ``pmap`` / ``shard_map``
(decorator or call form), one level of higher-order propagation (a
function whose *parameter* is scanned marks its callers' arguments,
resolving ``partial``), then transitive closure over same-project
callees via import-alias resolution.  Within a context, traced values
propagate forward from ``jnp.``/``lax.``/``jax.`` producers (and, for
direct scan bodies, from the function's own parameters) through
assignments.

Suppression: append ``# lint: ignore[ts101]`` (comma-separate several
ids) to the offending line, or put ``# lint: skip-file`` near the top
of a file.  Suppressions are per-rule by design — a bare ``ignore``
does not parse.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.report import ERROR, WARN, Finding, LintReport

#: modules (dotted, relative to the lint root package) allowed to import
#: jax.numpy — the compiled hot paths and their direct model/kernel
#: dependencies.  Everything else is host-side by policy (TS105).
JNP_ALLOWLIST = frozenset({
    "repro.compat",
    "repro.core.controller", "repro.core.device", "repro.core.engine",
    "repro.core.frontend",
    "repro.data.pipeline",
    "repro.dse.executor",
    "repro.kernels.flash_attention", "repro.kernels.ops",
    "repro.kernels.ref", "repro.kernels.timing_check",
    "repro.launch.serve", "repro.launch.specs",
    "repro.models.blocks", "repro.models.layers", "repro.models.model",
    "repro.optim.adamw",
    "repro.runtime.compress",
    "repro.serve.step",
    "repro.train.step",
    "repro.verify.explore",
})

#: jax transforms whose functional operand becomes a traced context.
#: value: True when the operand's *parameters* are traced values
#: (loop/branch bodies); False when only jnp-derived locals are (jit &co
#: trace whatever arrays flow in, which we can't see statically).
_TRACERS = {
    "scan": True, "cond": True, "while_loop": True, "fori_loop": True,
    "switch": True, "checkpoint": False, "remat": False,
    "jit": False, "vmap": False, "pmap": False, "shard_map": False,
}

#: sinks whose callable arguments are frozen into cache keys (TS104)
_CACHE_KEYED_KWARGS = frozenset({"extra_predicates"})

_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore\[([a-zA-Z0-9_,\s-]+)\]")
_SKIP_RE = re.compile(r"#\s*lint:\s*skip-file")


# ---------------------------------------------------------------------------
# per-module model
# ---------------------------------------------------------------------------

class Module:
    """One parsed source file: AST + import aliases + function index."""

    def __init__(self, path: str, name: str, tree: ast.Module,
                 source: str):
        self.path = path
        self.name = name                    # dotted module name
        self.tree = tree
        self.lines = source.splitlines()
        self.aliases: dict = {}             # local alias -> dotted module
        self.from_imports: dict = {}        # local name -> (module, attr)
        self.functions: dict = {}           # qualname -> FunctionDef
        self._index()

    def _index(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    self.from_imports[a.asname or a.name] = (node.module,
                                                             a.name)
                    # `from jax import numpy as jnp` is a module alias too
                    self.aliases.setdefault(a.asname or a.name, full)

        def visit(node, prefix=""):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    self.functions[q] = child
                    visit(child, prefix=f"{q}.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, prefix=f"{prefix}{child.name}.")
                else:
                    visit(child, prefix=prefix)
        visit(self.tree)

    def imports_jnp(self) -> bool:
        for alias, target in self.aliases.items():
            if target in ("jax.numpy", "jax.experimental.pallas"):
                return True
        return any(m == "jax" and a == "numpy"
                   for m, a in self.from_imports.values())

    def jaxish_roots(self) -> set:
        """Local names that are jax-module aliases (jnp, lax, jax, ...)."""
        roots = set()
        for alias, target in self.aliases.items():
            if target == "jax" or target.startswith("jax."):
                roots.add(alias)
        for alias, (mod, attr) in self.from_imports.items():
            if mod == "jax" or mod.startswith("jax."):
                roots.add(alias)
        return roots

    def numpy_roots(self) -> set:
        roots = set()
        for alias, target in self.aliases.items():
            if target == "numpy":
                roots.add(alias)
        return roots

    def suppressed(self, line: int) -> set:
        """Rule ids suppressed on a 1-indexed source line."""
        if 1 <= line <= len(self.lines):
            m = _IGNORE_RE.search(self.lines[line - 1])
            if m:
                return {t.strip().lower() for t in m.group(1).split(",")}
        return set()

    def skip_file(self) -> bool:
        return any(_SKIP_RE.search(ln) for ln in self.lines[:5])


def _module_name(path: str, root: str) -> str:
    rel = os.path.relpath(path, root)
    parts = rel[:-3].split(os.sep)          # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def load_modules(paths, root: str | None = None) -> dict:
    """Parse every ``.py`` under ``paths`` into {dotted name: Module}.

    ``root`` is the directory whose children are top-level packages
    (defaults to the common parent of ``paths`` that makes the first
    path's package importable — for this repo, ``src/``)."""
    files = []
    dir_roots = []
    for p in paths:
        if os.path.isdir(p):
            # the scanned directory IS a package (possibly a namespace
            # package without __init__.py): its parent is the root
            dir_roots.append(os.path.dirname(os.path.abspath(p)))
            for dirpath, _dirs, names in os.walk(p):
                files += [os.path.join(dirpath, n) for n in names
                          if n.endswith(".py")]
        elif p.endswith(".py"):
            files.append(p)
    files = sorted(set(files))
    if root is None:
        root = dir_roots[0] if dir_roots else _guess_root(files)
    out = {}
    for path in files:
        with open(path) as fh:
            src = fh.read()
        tree = ast.parse(src, filename=path)
        name = _module_name(path, root)
        out[name] = Module(path, name, tree, src)
    return out


def _guess_root(files) -> str:
    """Find the ancestor directory that makes files importable packages
    (walk up while __init__.py is present)."""
    if not files:
        return "."
    d = os.path.dirname(os.path.abspath(files[0]))
    while os.path.exists(os.path.join(d, "__init__.py")):
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
    return d


# ---------------------------------------------------------------------------
# traced-context discovery
# ---------------------------------------------------------------------------

def _func_operand(node):
    """Resolve a call argument to the *name* of the function it denotes:
    plain name, ``mod.attr``, or ``partial(f, ...)`` -> f."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = node.value
        if isinstance(base, ast.Name):
            return f"{base.id}.{node.attr}"
        return None
    if isinstance(node, ast.Call):
        fn = node.func
        fname = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if fname == "partial" and node.args:
            return _func_operand(node.args[0])
    return None


def _own_nodes(fn):
    """Walk a function's own body without descending into nested defs
    (nested functions are their own scopes/contexts)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def _scoped_calls(mod: Module):
    """Yield (scope function or None, Call node) with innermost scopes."""
    for fn in mod.functions.values():
        for node in _own_nodes(fn):
            if isinstance(node, ast.Call):
                yield fn, node
    stack = list(ast.iter_child_nodes(mod.tree))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call):
            yield None, node
        stack.extend(ast.iter_child_nodes(node))


def _tracer_name(call: ast.Call):
    """If ``call`` invokes a jax transform from ``_TRACERS``, return its
    short name, else None."""
    fn = call.func
    name = None
    if isinstance(fn, ast.Attribute):
        name = fn.attr
    elif isinstance(fn, ast.Name):
        name = fn.id
    return name if name in _TRACERS else None


class ContextIndex:
    """Project-wide set of traced-context functions.

    Keys are ``(module name, function qualname)``; the value records
    whether the function's own parameters count as traced (scan/cond
    bodies) or only jnp-derived locals do (jit/vmap operands and
    transitive callees).
    """

    def __init__(self, modules: dict):
        self.modules = modules
        self.contexts: dict = {}            # (mod, qual) -> params_traced
        self._discover_direct()
        self._discover_higher_order()
        self._close_over_callees()

    # -- resolution helpers -------------------------------------------------
    def _resolve_operand(self, mod: Module, node, scope=None, depth=0):
        """Resolve a call-argument AST node to (module, qualname),
        chasing local aliases like ``body = partial(cycle, ...)`` inside
        the enclosing ``scope`` function."""
        name = _func_operand(node)
        key = self._resolve(mod, name) if name else None
        if key is not None or depth > 4:
            return key
        if isinstance(node, ast.Name) and scope is not None:
            for n in _own_nodes(scope):
                if isinstance(n, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == node.id
                        for t in n.targets):
                    return self._resolve_operand(mod, n.value, scope,
                                                 depth + 1)
        return None

    def _resolve(self, mod: Module, name: str):
        """Resolve a (possibly dotted) local name to (module, qualname)."""
        if name is None:
            return None
        if "." in name:
            base, attr = name.split(".", 1)
            target = mod.aliases.get(base)
            if target in self.modules and attr in self.modules[target] \
                    .functions:
                return (target, attr)
            return None
        if name in mod.functions:
            return (mod.name, name)
        # nested qualnames: prefer the innermost match
        for q in mod.functions:
            if q.endswith(f".{name}"):
                return (mod.name, q)
        if name in mod.from_imports:
            m, attr = mod.from_imports[name]
            if m in self.modules and attr in self.modules[m].functions:
                return (m, attr)
        return None

    def _mark(self, key, params_traced: bool):
        if key is None:
            return
        if key not in self.contexts or (params_traced
                                        and not self.contexts[key]):
            self.contexts[key] = params_traced

    # -- passes -------------------------------------------------------------
    def _discover_direct(self):
        for mod in self.modules.values():
            for scope, node in _scoped_calls(mod):
                t = _tracer_name(node)
                if t is None:
                    continue
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    key = self._resolve_operand(mod, arg, scope)
                    if key:
                        self._mark(key, _TRACERS[t])
            for node in ast.walk(mod.tree):
                # decorator form: @jax.jit / @partial(jax.jit, ...)
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        d = dec.func if isinstance(dec, ast.Call) else dec
                        inner = None
                        if isinstance(dec, ast.Call) \
                                and _func_operand(dec.func) == "partial" \
                                and dec.args:
                            d = dec.args[0]
                        t = None
                        if isinstance(d, ast.Attribute):
                            t = d.attr if d.attr in _TRACERS else None
                        elif isinstance(d, ast.Name):
                            t = d.id if d.id in _TRACERS else None
                        if t:
                            key = self._resolve(mod, node.name)
                            self._mark(key, _TRACERS[t])
                        del inner

    def _discover_higher_order(self):
        """One level: a function that scans one of its own parameters is
        a sink — function-valued arguments at its call sites become
        traced contexts (with traced params)."""
        sinks: dict = {}                    # (mod, qual) -> {param index}
        for mod in self.modules.values():
            for qual, fn in mod.functions.items():
                params = [a.arg for a in fn.args.args]
                scanned = set()
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    if _tracer_name(node) is None:
                        continue
                    for arg in list(node.args) + [kw.value
                                                  for kw in node.keywords]:
                        nm = _func_operand(arg)
                        if nm in params:
                            scanned.add(params.index(nm))
                if scanned:
                    sinks[(mod.name, qual)] = scanned
        for mod in self.modules.values():
            for scope, node in _scoped_calls(mod):
                key = self._resolve_operand(mod, node.func, scope)
                if key not in sinks:
                    continue
                for idx in sinks[key]:
                    if idx < len(node.args):
                        fk = self._resolve_operand(mod, node.args[idx],
                                                   scope)
                        self._mark(fk, True)

    def _close_over_callees(self):
        """Transitive closure: everything a traced context calls (same
        project) is traced too — jit/scan trace through plain calls."""
        work = list(self.contexts)
        seen = set(work)
        while work:
            mname, qual = work.pop()
            mod = self.modules.get(mname)
            fn = mod.functions.get(qual) if mod else None
            if fn is None:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                key = self._resolve_operand(mod, node.func, fn)
                if key and key not in seen:
                    seen.add(key)
                    self.contexts[key] = False   # params not traced
                    work.append(key)


# ---------------------------------------------------------------------------
# in-context dataflow + rule checks
# ---------------------------------------------------------------------------

def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _FunctionLint(ast.NodeVisitor):
    """Forward traced-value propagation + rule checks for one function."""

    def __init__(self, mod: Module, fn, params_traced: bool,
                 jax_roots: set, np_roots: set):
        self.mod = mod
        self.fn = fn
        self.jax_roots = jax_roots
        self.np_roots = np_roots
        self.traced: set = set()
        if params_traced:
            self.traced |= {a.arg for a in fn.args.args
                            if a.arg not in ("self", "cls")}
        self.findings: list = []

    # -- traced-expression predicate ---------------------------------------
    def is_traced(self, node) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in self.traced:
                return True
            if isinstance(n, ast.Call):
                root = _root_name(n.func)
                if root in self.jax_roots:
                    return True
        return False

    def _exempt_test(self, test) -> bool:
        """Tracer-tolerated tests: identity vs None, isinstance, len()
        (shape is static under trace), attribute flags (self.x)."""
        if isinstance(test, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in test.ops):
            return True
        for n in ast.walk(test):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                    and n.func.id in ("isinstance", "len", "hasattr",
                                      "getattr", "callable"):
                return True
        return False

    def emit(self, rule, node, msg, severity=ERROR):
        line = getattr(node, "lineno", 0)
        if rule.lower() in self.mod.suppressed(line):
            return
        self.findings.append(Finding(
            rule=rule, severity=severity, message=msg,
            target=self.mod.name, path=self.mod.path, line=line))

    # -- statement flow -----------------------------------------------------
    def _assign_targets(self, target):
        if isinstance(target, ast.Name):
            yield target.id
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                yield from self._assign_targets(el)
        elif isinstance(target, ast.Starred):
            yield from self._assign_targets(target.value)

    def visit_Assign(self, node):
        self.generic_visit(node)
        if self.is_traced(node.value):
            for t in node.targets:
                self.traced.update(self._assign_targets(t))

    def visit_AugAssign(self, node):
        self.generic_visit(node)
        if self.is_traced(node.value) and isinstance(node.target, ast.Name):
            self.traced.add(node.target.id)

    def visit_AnnAssign(self, node):
        self.generic_visit(node)
        if node.value is not None and self.is_traced(node.value) \
                and isinstance(node.target, ast.Name):
            self.traced.add(node.target.id)

    def visit_For(self, node):
        # iterating a traced array is itself suspect, but the common
        # legitimate pattern is `for i in range(static)`; only propagate
        if self.is_traced(node.iter):
            self.traced.update(self._assign_targets(node.target))
        self.generic_visit(node)

    # -- rules --------------------------------------------------------------
    def visit_If(self, node):
        if self.is_traced(node.test) and not self._exempt_test(node.test):
            self.emit("TS101", node,
                      "Python `if` on a traced value inside a traced "
                      "context — use jnp.where / lax.cond (or hoist the "
                      "decision out of the jitted region)")
        self.generic_visit(node)

    def visit_While(self, node):
        if self.is_traced(node.test) and not self._exempt_test(node.test):
            self.emit("TS101", node,
                      "Python `while` on a traced value inside a traced "
                      "context — use lax.while_loop")
        self.generic_visit(node)

    def visit_Call(self, node):
        fn = node.func
        # TS102: int()/float()/bool() on a traced expression
        if isinstance(fn, ast.Name) and fn.id in ("int", "float", "bool") \
                and node.args and self.is_traced(node.args[0]):
            self.emit("TS102", node,
                      f"`{fn.id}()` coerces a traced value to host — "
                      "fails under jit; keep it as a jnp array or "
                      "compute it outside the traced region")
        # TS102: .item()
        if isinstance(fn, ast.Attribute) and fn.attr == "item" \
                and self.is_traced(fn.value):
            self.emit("TS102", node,
                      "`.item()` on a traced value — host sync; fails "
                      "under jit")
        # TS103: np.* on traced args
        root = _root_name(fn)
        if root in self.np_roots and (
                any(self.is_traced(a) for a in node.args)
                or any(self.is_traced(kw.value) for kw in node.keywords)):
            self.emit("TS103", node,
                      "`np.*` call on a traced value — silently leaves "
                      "the device (or fails under jit); use jnp")
        self.generic_visit(node)

    # do not descend into nested defs: they are linted as their own
    # contexts if reachable
    def visit_FunctionDef(self, node):
        if node is self.fn:
            self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def run(self) -> list:
        self.visit_FunctionDef(self.fn)
        return self.findings


def _root_name(node):
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


# ---------------------------------------------------------------------------
# TS104: non-hashable captures in cache-keyed callables
# ---------------------------------------------------------------------------

_MUTABLE_NODES = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                  ast.SetComp)


def _check_cache_keyed(mod: Module) -> list:
    findings = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg not in _CACHE_KEYED_KWARGS:
                continue
            for f in _callables_in(kw.value, mod):
                findings += _mutable_captures(mod, f, kw.arg)
    return findings


def _callables_in(node, mod: Module):
    """Lambdas / resolvable function defs inside a sink argument."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Lambda):
            out.append(n)
        elif isinstance(n, ast.Name) and n.id in mod.functions:
            out.append(mod.functions[n.id])
    return out


def _mutable_captures(mod: Module, fn, sink: str) -> list:
    """Flag free variables of ``fn`` bound to list/dict/set literals in
    an enclosing scope, and mutable default arguments."""
    findings = []
    args = fn.args
    params = {a.arg for a in list(args.args) + list(args.kwonlyargs)}
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    local = set(params)
    for n in ast.walk(fn):
        if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            tgt = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in tgt:
                for nm in ast.walk(t):
                    if isinstance(nm, ast.Name):
                        local.add(nm.id)
    free = set()
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id not in local:
                free.add(n.id)
    # mutable defaults are captured into the callable's identity too
    for d in list(args.defaults) + [d for d in args.kw_defaults if d]:
        if isinstance(d, _MUTABLE_NODES):
            findings.append(Finding(
                rule="TS104", severity=ERROR, target=mod.name,
                path=mod.path, line=d.lineno,
                message=f"mutable default argument in a callable passed "
                        f"to cache-keyed sink `{sink}` — unhashable / "
                        "aliases state across cached runs"))
    # free names assigned mutable literals anywhere in the module
    mutable_names = set()
    for n in ast.walk(mod.tree):
        if isinstance(n, ast.Assign) and isinstance(n.value,
                                                    _MUTABLE_NODES):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    mutable_names.add(t.id)
    for nm in sorted(free & mutable_names):
        line = getattr(fn, "lineno", 0)
        if "ts104" in mod.suppressed(line):
            continue
        findings.append(Finding(
            rule="TS104", severity=ERROR, target=mod.name, path=mod.path,
            line=line,
            message=f"callable passed to cache-keyed sink `{sink}` "
                    f"captures `{nm}`, which is bound to a mutable "
                    "list/dict/set — RunCache freezes closures by value "
                    "and mutables are unhashable"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_paths(paths, root: str | None = None,
               allowlist=JNP_ALLOWLIST) -> LintReport:
    """Run the trace-safety pass over files/directories."""
    modules = load_modules(paths, root=root)
    report = LintReport(target="trace-safety", meta={
        "modules": len(modules),
        "paths": [str(p) for p in paths]})
    index = ContextIndex(modules)
    report.meta["traced_contexts"] = sorted(
        f"{m}:{q}" for (m, q) in index.contexts)

    for mod in modules.values():
        if mod.skip_file():
            continue
        # TS105: jnp import policy
        if mod.imports_jnp() and mod.name not in allowlist \
                and not any(mod.name.startswith(a + ".")
                            for a in allowlist):
            if "ts105" not in mod.suppressed(1):
                report.add(Finding(
                    rule="TS105", severity=WARN, target=mod.name,
                    path=mod.path, line=1,
                    message="module imports jax.numpy but is not in the "
                            "hot-path allowlist (repro.analysis."
                            "tracecheck.JNP_ALLOWLIST) — host-side "
                            "layers should stay numpy-only"))
        report.extend(_check_cache_keyed(mod))

    jax_roots = {}
    for (mname, qual), params_traced in sorted(index.contexts.items()):
        mod = modules.get(mname)
        fn = mod.functions.get(qual) if mod else None
        if fn is None or mod.skip_file():
            continue
        if mname not in jax_roots:
            jax_roots[mname] = (mod.jaxish_roots(), mod.numpy_roots())
        jx, npx = jax_roots[mname]
        lint = _FunctionLint(mod, fn, params_traced, jx, npx)
        report.extend(lint.run())
    return report
