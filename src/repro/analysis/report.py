"""Structured lint findings + report artifacts for ``repro.analysis``.

Both analysis passes (the DRAM-spec linter and the JAX trace-safety
linter) emit the same currency: a :class:`Finding` per defect and a
:class:`LintReport` per lint target.  Reports serialize to JSON (full
fidelity) and ``.npz`` (columnar, for CI artifact diffing next to trace
and telemetry artifacts), and two reports diff structurally — the
cross-standard / before-after-override comparison the CLI exposes.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

ERROR = "error"
WARN = "warn"
INFO = "info"

#: severity rank for sorting / gating (highest first)
_SEV_RANK = {ERROR: 0, WARN: 1, INFO: 2}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint defect.

    ``rows`` are offending constraint-table row indices (spec lint);
    ``path``/``line`` locate source findings (trace-safety lint).  ``key``
    is the stable identity used by report diffing — it deliberately
    excludes the message text so rewording a rule does not churn diffs.
    """
    rule: str                      # registry id, e.g. "trc-decomposition"
    severity: str                  # error | warn | info
    message: str
    target: str = ""               # standard / module the finding is about
    rows: tuple = ()               # offending constraint-table rows
    path: str = ""                 # source file (trace-safety lint)
    line: int = 0                  # 1-indexed source line (0 = n/a)
    data: tuple = ()               # sorted (key, value) detail pairs

    def __post_init__(self):
        if self.severity not in _SEV_RANK:
            raise ValueError(f"unknown severity {self.severity!r}")
        object.__setattr__(self, "rows", tuple(int(r) for r in self.rows))
        d = self.data
        d = d.items() if isinstance(d, dict) else (d or ())
        object.__setattr__(self, "data",
                           tuple(sorted((str(k), v) for k, v in d)))

    @property
    def key(self) -> tuple:
        return (self.rule, self.target, self.rows, self.path, self.line)

    @property
    def location(self) -> str:
        if self.path:
            return f"{self.path}:{self.line}" if self.line else self.path
        return self.target

    def render(self) -> str:
        loc = self.location
        head = f"{self.severity.upper():5s} [{self.rule}]"
        return f"{head} {loc}: {self.message}" if loc \
            else f"{head} {self.message}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rows"] = list(self.rows)
        d["data"] = {k: v for k, v in self.data}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(rule=d["rule"], severity=d["severity"],
                   message=d["message"], target=d.get("target", ""),
                   rows=tuple(d.get("rows", ())), path=d.get("path", ""),
                   line=int(d.get("line", 0)),
                   data=tuple(sorted(d.get("data", {}).items())))


@dataclasses.dataclass
class LintReport:
    """Ordered findings for one lint target (a spec, a system, a tree)."""
    target: str
    findings: list = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def add(self, finding: Finding):
        self.findings.append(finding)

    def extend(self, findings):
        self.findings.extend(findings)

    def sorted(self) -> list:
        return sorted(self.findings,
                      key=lambda f: (_SEV_RANK[f.severity], f.rule,
                                     f.location, f.rows))

    def by_severity(self, severity: str) -> list:
        return [f for f in self.findings if f.severity == severity]

    @property
    def errors(self) -> list:
        return self.by_severity(ERROR)

    @property
    def warnings(self) -> list:
        return self.by_severity(WARN)

    @property
    def infos(self) -> list:
        return self.by_severity(INFO)

    def ok(self, strict: bool = False) -> bool:
        """Gate predicate: no errors (``strict`` also forbids warnings)."""
        if self.errors:
            return False
        return not (strict and self.warnings)

    def counts(self) -> dict:
        return {s: len(self.by_severity(s)) for s in (ERROR, WARN, INFO)}

    def rules_fired(self) -> dict:
        out: dict = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def summary(self, show_info: bool = False) -> str:
        c = self.counts()
        lines = [f"{self.target}: {c[ERROR]} error(s), {c[WARN]} "
                 f"warning(s), {c[INFO]} info"]
        for f in self.sorted():
            if f.severity == INFO and not show_info:
                continue
            lines.append("  " + f.render())
        return "\n".join(lines)

    # -- artifacts ---------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "format": "repro.analysis/v1", "target": self.target,
            "meta": self.meta, "counts": self.counts(),
            "findings": [f.to_dict() for f in self.sorted()],
        }, indent=2, default=str)

    @classmethod
    def from_json(cls, text: str) -> "LintReport":
        d = json.loads(text)
        if d.get("format") != "repro.analysis/v1":
            raise ValueError(f"not a repro.analysis report: "
                             f"format={d.get('format')!r}")
        return cls(target=d["target"], meta=d.get("meta", {}),
                   findings=[Finding.from_dict(f) for f in d["findings"]])

    def save_json(self, path: str) -> str:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")
        return path

    @classmethod
    def load_json(cls, path: str) -> "LintReport":
        with open(path) as fh:
            return cls.from_json(fh.read())

    def save_npz(self, path: str) -> str:
        """Columnar artifact: one row per finding + JSON sidecar blob."""
        fs = self.sorted()
        np.savez_compressed(
            path,
            rule=np.asarray([f.rule for f in fs], dtype=object),
            severity=np.asarray([f.severity for f in fs], dtype=object),
            target=np.asarray([f.target for f in fs], dtype=object),
            path=np.asarray([f.path for f in fs], dtype=object),
            line=np.asarray([f.line for f in fs], np.int64),
            rows=np.asarray([json.dumps(list(f.rows)) for f in fs],
                            dtype=object),
            json=np.asarray(self.to_json()))
        return path

    @classmethod
    def load_npz(cls, path: str) -> "LintReport":
        with np.load(path, allow_pickle=True) as z:
            return cls.from_json(str(z["json"]))


def merge(reports, target: str = "all") -> LintReport:
    """Fold per-target reports into one (finding targets stay qualified)."""
    out = LintReport(target=target)
    for r in reports:
        out.extend(r.findings)
        if r.meta:
            out.meta[r.target] = r.meta
    return out


def diff(a: LintReport, b: LintReport) -> dict:
    """Structural report diff keyed on :attr:`Finding.key`.

    Returns ``{"added": [...], "removed": [...], "common": int}`` where
    added/removed are findings present only in ``b`` / only in ``a`` —
    the cross-standard (or pristine-vs-overridden) comparison mode.
    """
    ka = {f.key: f for f in a.findings}
    kb = {f.key: f for f in b.findings}
    return {
        "added": [kb[k] for k in sorted(kb.keys() - ka.keys())],
        "removed": [ka[k] for k in sorted(ka.keys() - kb.keys())],
        "common": len(ka.keys() & kb.keys()),
    }


def render_diff(a: LintReport, b: LintReport) -> str:
    d = diff(a, b)
    lines = [f"lint diff {a.target} -> {b.target}: "
             f"+{len(d['added'])} -{len(d['removed'])} "
             f"(={d['common']} unchanged)"]
    lines += ["  + " + f.render() for f in d["added"]]
    lines += ["  - " + f.render() for f in d["removed"]]
    return "\n".join(lines)
