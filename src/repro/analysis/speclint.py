"""Spec-linter drivers: run the rule registry over standards and systems.

Three entry points, layered:

* :func:`lint_spec` — the full pass for one (standard, org preset, timing
  preset, overrides, channels): standard-scope rules first (unknown
  tokens, dangling references, unknown override keys), then — only when
  those produce no errors, so a broken spec fails legibly instead of
  crashing the compiler — lowers via ``compile_spec`` and runs the
  table-scope rules (inequalities, dominance, coverage holes, refresh
  headroom, ring validation).
* :func:`lint_compiled` — table-scope rules only, for an
  already-compiled :class:`CompiledSpec` (e.g. a mutated table from the
  verification harness, or a spec loaded from a checkpoint).
* :func:`lint_system` — every group of a heterogeneous
  :class:`MemorySystemSpec`, merged into one report.

``lint_all`` sweeps every registered standard with its first-authored
presets — the CI smoke gate.
"""
from __future__ import annotations

from repro.core import spec as S
from repro.core.compile import MemorySystemSpec, as_system, compile_spec
from repro.analysis.report import ERROR, Finding, LintReport, merge
from repro.analysis.rules import RuleCtx, run_rules


class SpecLintError(ValueError):
    """Raised when a lint gate fails; carries the structured report."""

    def __init__(self, report: LintReport):
        self.report = report
        super().__init__("spec lint failed:\n" + report.summary())


def default_presets(standard) -> tuple:
    """First-authored (org, timing) preset pair of a standard — dict
    insertion order is authoring order, so this is the spec's primary
    configuration."""
    if isinstance(standard, str):
        standard = S.get_standard(standard)
    try:
        org = next(iter(standard.org_presets))
        tim = next(iter(standard.timing_presets))
    except StopIteration:
        raise ValueError(f"standard {standard.name} declares no presets")
    return org, tim


def lint_spec(standard, org_preset: str | None = None,
              timing_preset: str | None = None,
              timing_overrides: dict | None = None,
              channels: int = 1) -> LintReport:
    """Full two-stage lint of one spec configuration."""
    if isinstance(standard, str):
        standard = S.get_standard(standard)
    if org_preset is None or timing_preset is None:
        d_org, d_tim = default_presets(standard)
        org_preset = org_preset or d_org
        timing_preset = timing_preset or d_tim
    target = f"{standard.name}[{org_preset}/{timing_preset}]"
    report = LintReport(target=target, meta={
        "standard": standard.name, "org_preset": org_preset,
        "timing_preset": timing_preset, "channels": int(channels),
        "timing_overrides": dict(timing_overrides or {})})

    base = dict(standard.timing_presets[timing_preset])
    timings = dict(base)
    if timing_overrides:
        timings.update(timing_overrides)
    ctx = RuleCtx(std=standard, timings=timings, base_timings=base,
                  overrides=timing_overrides, channels=channels,
                  target=target)
    report.extend(run_rules(ctx, "standard"))
    if not report.ok():
        # the spec cannot (or should not) be lowered — stop legibly
        report.meta["compiled"] = False
        return report

    try:
        cspec = compile_spec(standard, org_preset, timing_preset,
                             timing_overrides, channels=max(1, channels))
    except Exception as e:                      # pragma: no cover - guard
        report.add(Finding(rule="compile-error", severity=ERROR,
                           message=f"compile_spec failed: {e}",
                           target=target))
        report.meta["compiled"] = False
        return report
    report.meta["compiled"] = True
    report.extend(_table_findings(cspec, channels=channels, target=target,
                                  std=standard))
    return report


def _table_findings(cspec, channels: int, target: str, std=None) -> list:
    ctx = RuleCtx(std=std, cspec=cspec, timings=cspec.timings,
                  channels=channels, target=target)
    return run_rules(ctx, "table")


def lint_compiled(cspec, channels: int | None = None,
                  target: str | None = None) -> LintReport:
    """Table-scope lint of an already-compiled spec (post-compile gate)."""
    channels = cspec.n_channels if channels is None else channels
    target = target or (f"{cspec.standard or cspec.name}"
                        f"[{cspec.org_preset}/{cspec.timing_preset}]")
    report = LintReport(target=target, meta={
        "standard": cspec.standard or cspec.name,
        "channels": int(channels), "compiled": True})
    report.extend(_table_findings(cspec, channels=channels, target=target))
    return report


def lint_system(msys) -> LintReport:
    """Lint every group of a (possibly heterogeneous) memory system."""
    msys = as_system(msys)
    assert isinstance(msys, MemorySystemSpec)
    parts = []
    for gi, g in enumerate(msys.groups):
        cs = g.cspec
        target = f"{cs.standard or cs.name}[group{gi} x{g.channels}]"
        parts.append(lint_compiled(cs, channels=g.channels, target=target))
    out = merge(parts, target=msys.label)
    out.meta["groups"] = [p.target for p in parts]
    return out


def lint_all(channels: int = 1) -> dict:
    """Lint every registered standard (first-authored presets).

    Returns ``{standard name: LintReport}`` — the CI smoke sweep."""
    out = {}
    for name in sorted(S.all_standards()):
        out[name] = lint_spec(name, channels=channels)
    return out
