"""Declarative rule registry + built-in DRAM-spec lint rules.

Rules come in two scopes:

* ``standard`` — semantic checks on the *authored* spec (a
  :class:`repro.core.spec.DRAMSpec` subclass plus a chosen org/timing
  preset and optional overrides), run **before** compilation so a broken
  DSL-authored spec fails legibly instead of crashing ``compile_spec``:
  unknown timing tokens in latency expressions, dangling command / level
  references, unknown override keys, unused timing parameters.
* ``table`` — checks on the lowered :class:`CompiledSpec` constraint
  tables: derived-timing inequalities (with their JEDEC rationale),
  constraint dominance/shadowing (dead table rows), coverage holes
  (unconstrained same-bank hazard pairs), refresh schedulability, and
  windowed-ring capacity validation against ``build_windowed_rings``.

Every rule carries its rationale; ``families`` restricts a rule to
standards whose name matches one of the given prefixes (``None`` = every
standard).  Register new rules with the :func:`rule` decorator — the
linter drivers in ``repro.analysis.speclint`` iterate the registry, so a
user-authored rule module only has to import and decorate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spec as S
from repro.core.compile import (_TOKEN, build_windowed_rings,
                                resolve_latency)
from repro.analysis.report import ERROR, WARN, INFO, Finding

#: timing parameters consumed by the engine/controller directly rather
#: than through constraint-table latency expressions — never "unused"
ENGINE_PARAMS = frozenset({"tCK_ps", "nREFI", "nAAD", "nWCKIDLE",
                           "nRCKIDLE"})

#: refresh duty cycle (nRFC / nREFI) above which scheduling headroom is
#: considered suspicious (GDDR sits near 0.15; JEDEC postpone rules
#: assume plenty of slack)
REFRESH_DUTY_WARN = 0.25


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    scope: str                     # "standard" | "table"
    severity: str                  # default severity of its findings
    rationale: str
    families: tuple | None        # standard-name prefixes (None = all)
    fn: object


RULES: dict = {}


def rule(rule_id: str, *, scope: str, severity: str = ERROR,
         rationale: str = "", families=None):
    """Register a lint rule.  The decorated function receives a
    :class:`RuleCtx` and yields findings via ``ctx.finding(...)``."""
    if scope not in ("standard", "table"):
        raise ValueError(f"rule scope must be standard|table, got {scope!r}")

    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, scope, severity, rationale,
                              None if families is None else tuple(families),
                              fn)
        return fn
    return deco


def applicable(r: Rule, standard_name: str) -> bool:
    if r.families is None:
        return True
    return any(standard_name == f or standard_name.startswith(f)
               for f in r.families)


class RuleCtx:
    """Everything a rule may inspect, plus the finding factory.

    ``std`` is the DRAMSpec class (standard-scope rules; may be ``None``
    when linting a bare CompiledSpec), ``cspec`` the compiled tables
    (table-scope rules), ``timings`` the resolved preset incl. overrides.
    """

    def __init__(self, *, std=None, cspec=None, timings=None,
                 base_timings=None, overrides=None, channels: int = 1,
                 target: str = ""):
        self.std = std
        self.cspec = cspec
        self.timings = dict(timings or {})
        #: preset timings *before* overrides merged (override validation)
        self.base_timings = dict(base_timings
                                 if base_timings is not None else self.timings)
        self.overrides = dict(overrides or {})
        self.channels = int(channels)
        self.target = target or (cspec.name if cspec is not None
                                 else getattr(std, "name", "?"))
        self._rule: Rule | None = None

    def finding(self, message: str, *, severity: str | None = None,
                rows=(), data=()) -> Finding:
        r = self._rule
        return Finding(rule=r.id, severity=severity or r.severity,
                       message=message, target=self.target, rows=rows,
                       data=data)

    def row_name(self, i: int) -> str:
        cs = self.cspec
        p = cs.cmd_names[int(cs.ct_prev[i])]
        f = cs.cmd_names[int(cs.ct_next[i])]
        lv = cs.levels[int(cs.ct_level[i])]
        name = f"{p}->{f}@{lv} lat={int(cs.ct_lat[i])}"
        if int(cs.ct_win[i]) > 1:
            name += f" win={int(cs.ct_win[i])}"
        return name


def run_rules(ctx: RuleCtx, scope: str) -> list:
    """Run every applicable registered rule of ``scope``; returns
    findings (rules see the shared ctx; a rule raising is a bug, not a
    finding — let it propagate)."""
    out = []
    for r in RULES.values():
        if r.scope != scope or not applicable(r, ctx.target.split("[")[0]):
            continue
        ctx._rule = r
        out.extend(r.fn(ctx))
        ctx._rule = None
    return out


# ==========================================================================
# standard-scope rules (pre-compile semantic analysis)
# ==========================================================================

def _expr_tokens(expr) -> list:
    if isinstance(expr, int):
        return []
    return [tok for _sign, tok in _TOKEN.findall(expr) if not tok.isdigit()]


@rule("unknown-token", scope="standard", severity=ERROR,
      rationale="A latency expression referencing a timing parameter the "
                "preset does not define can never be resolved; compiling "
                "would fail. Catching it here names the constraint.")
def check_unknown_tokens(ctx):
    for k, tc in enumerate(ctx.std.timing_constraints):
        for tok in _expr_tokens(tc.latency):
            if tok not in ctx.timings:
                yield ctx.finding(
                    f"constraint #{k} {list(tc.preceding)}->"
                    f"{list(tc.following)}@{tc.level}: latency expression "
                    f"{tc.latency!r} references unknown timing parameter "
                    f"{tok!r} (known: {sorted(ctx.timings)})",
                    data={"constraint": k, "token": tok})


@rule("unused-param", scope="standard", severity=WARN,
      rationale="A preset parameter no constraint expression (and no "
                "engine consumer) reads is usually a typo'd name — the "
                "intended constraint silently keeps its old latency.")
def check_unused_params(ctx):
    used: set = set()
    for tc in ctx.std.timing_constraints:
        used.update(_expr_tokens(tc.latency))
    # read_latency (nCL + nBL) is an engine-level consumer
    used.update({"nCL", "nBL", "nCWL"})
    declared = set(ctx.timings) | set(ctx.std.timing_params)
    for name in sorted(declared - used - ENGINE_PARAMS):
        yield ctx.finding(
            f"timing parameter {name!r} is never referenced by any "
            "constraint latency expression or engine consumer",
            data={"param": name})


@rule("bad-reference", scope="standard", severity=ERROR,
      rationale="Constraints naming commands or hierarchy levels the "
                "standard does not declare lower into out-of-range table "
                "indices — the engine would check the wrong rows.")
def check_references(ctx):
    std = ctx.std
    cmds = set(std.commands)
    levels = set(std.levels)
    for name in std.commands:
        if name not in std.command_meta:
            yield ctx.finding(f"command {name!r} has no command_meta entry",
                              data={"command": name})
    for k, tc in enumerate(std.timing_constraints):
        if tc.level not in levels:
            yield ctx.finding(
                f"constraint #{k}: unknown level {tc.level!r} "
                f"(levels: {list(std.levels)})", data={"constraint": k})
        for name in list(tc.preceding) + list(tc.following):
            if name not in cmds:
                yield ctx.finding(
                    f"constraint #{k}: unknown command {name!r}",
                    data={"constraint": k, "command": name})
        if tc.window < 1:
            yield ctx.finding(
                f"constraint #{k}: window must be >= 1, got {tc.window}",
                data={"constraint": k})


@rule("unknown-override", scope="standard", severity=ERROR,
      rationale="timing_overrides keys outside the preset/param namespace "
                "silently add dead entries instead of changing the "
                "intended timing — the classic tRRD vs nRRD_S typo.")
def check_override_keys(ctx):
    valid = (set(ctx.base_timings) | set(ctx.std.timing_params)
             | {"tCK_ps"})
    for key in sorted(set(ctx.overrides) - valid):
        yield ctx.finding(
            f"timing override {key!r} matches no timing parameter of "
            f"{ctx.std.name} (valid: {sorted(valid)})",
            data={"override": key})


# ==========================================================================
# table-scope rules (compiled constraint-table analysis)
# ==========================================================================

#: derived-timing inequalities: (rule id, lhs expr, rhs expr, families,
#: JEDEC rationale).  Expressions resolve through the same
#: ``resolve_latency`` grammar the spec compiler uses; a rule is skipped
#: when the preset does not define every referenced parameter.
INEQUALITIES = (
    ("trc-decomposition", "nRC", "nRAS+nRP", None,
     "JEDEC: the row cycle tRC is the activate phase (tRAS) plus the "
     "precharge phase (tRP); tRC < tRAS + tRP lets back-to-back ACTs "
     "violate precharge time on the same bank."),
    ("faw-four-activates", "nFAW", "nRRD_S+nRRD_S+nRRD_S+nRRD_S", None,
     "JEDEC: the four-activate window spans at least four consecutive "
     "ACT-to-ACT (tRRD) intervals; tFAW < 4*tRRD makes the window "
     "constraint vacuous and overstates activation throughput."),
    ("ras-covers-rcd", "nRAS", "nRCD", None,
     "JEDEC: a row must stay active at least until its first column "
     "access can issue (tRCD); tRAS < tRCD closes rows before use."),
    ("ccd-long-short", "nCCD_L", "nCCD_S", None,
     "JEDEC: same-bank-group column spacing (tCCD_L) cannot be tighter "
     "than the cross-group spacing (tCCD_S)."),
    ("rrd-long-short", "nRRD_L", "nRRD_S", None,
     "JEDEC: same-bank-group ACT spacing (tRRD_L) cannot be tighter "
     "than the cross-group spacing (tRRD_S)."),
    ("wtr-long-short", "nWTR_L", "nWTR_S", None,
     "JEDEC: same-bank-group write-to-read turnaround (tWTR_L) cannot "
     "be tighter than the cross-group turnaround (tWTR_S)."),
    ("vrr-covers-row-cycle", "nVRR", "nRC", ("DDR4_VRR", "DDR5_VRR"),
     "A victim-row refresh internally activates and restores the row; "
     "nVRR < nRC would let the next ACT interrupt the restore."),
)


def _make_inequality_rule(rid, lhs, rhs, families, rationale):
    @rule(rid, scope="table", severity=ERROR, rationale=rationale,
          families=families)
    def check(ctx, _lhs=lhs, _rhs=rhs, _rat=rationale):
        t = ctx.timings
        toks = _expr_tokens(_lhs) + _expr_tokens(_rhs)
        if any(tok not in t for tok in toks):
            return                     # parameter family not modeled here
        lv, rv = resolve_latency(_lhs, t), resolve_latency(_rhs, t)
        if lv < rv:
            yield ctx.finding(
                f"derived-timing inequality violated: {_lhs} = {lv} < "
                f"{_rhs} = {rv}. {_rat}",
                data={"lhs": _lhs, "lhs_value": lv,
                      "rhs": _rhs, "rhs_value": rv})
    return check


for _ineq in INEQUALITIES:
    _make_inequality_rule(*_ineq)


def _reachable(cs, i: int) -> bool:
    return int(cs.ct_level[i]) <= int(cs.cmd_scope[int(cs.ct_prev[i])])


@rule("unreachable-row", scope="table", severity=WARN,
      rationale="A constraint at a hierarchy level deeper than its "
                "preceding command's scope can never bind: the command "
                "never stamps that level's issue timestamps.")
def check_unreachable(ctx):
    cs = ctx.cspec
    for i in range(len(cs.ct_prev)):
        if not _reachable(cs, i):
            yield ctx.finding(
                f"dead table row {ctx.row_name(i)}: "
                f"{cs.cmd_names[int(cs.ct_prev[i])]} has scope "
                f"{cs.levels[int(cs.cmd_scope[int(cs.ct_prev[i])])]} and "
                f"never stamps level {cs.levels[int(cs.ct_level[i])]}",
                rows=(i,))
        elif int(cs.ct_level[i]) > int(cs.cmd_scope[int(cs.ct_next[i])]):
            yield ctx.finding(
                f"suspicious row {ctx.row_name(i)}: constraint level is "
                f"deeper than the following command's scope "
                f"({cs.levels[int(cs.cmd_scope[int(cs.ct_next[i])])]}) — "
                "it binds on an arbitrary descendant node", rows=(i,))


@rule("dominated-row", scope="table", severity=ERROR,
      rationale="A (prev,next,level) row whose latency can never bind — "
                "a tighter constraint at an equal-or-wider scope always "
                "covers it — is a dead table row: either a duplicate "
                "(same scope: spec bug) or a preset where the symbolic "
                "constraint degenerates (cross-scope: informational).")
def check_dominated(ctx):
    cs = ctx.cspec
    n = len(cs.ct_prev)
    for i in range(n):
        if not _reachable(cs, i):
            continue                   # reported by unreachable-row
        li, wi, ti = int(cs.ct_level[i]), int(cs.ct_win[i]), int(cs.ct_lat[i])
        for j in range(n):
            if j == i or not _reachable(cs, j):
                continue
            if int(cs.ct_prev[j]) != int(cs.ct_prev[i]) \
                    or int(cs.ct_next[j]) != int(cs.ct_next[i]):
                continue
            lj, wj, tj = (int(cs.ct_level[j]), int(cs.ct_win[j]),
                          int(cs.ct_lat[j]))
            # j dominates i: equal-or-wider scope, equal-or-more-recent
            # window anchor, equal-or-larger latency — strictly tighter
            # somewhere, or an exact duplicate (then flag the later row)
            if lj > li or wj > wi or tj < ti:
                continue
            strict = (lj < li) or (wj < wi) or (tj > ti)
            if not strict and j >= i:
                continue
            same_scope = lj == li
            how = ("duplicate/shadowed by" if same_scope
                   else "covered by wider-scope row")
            yield ctx.finding(
                f"row {ctx.row_name(i)} can never bind: {how} "
                f"{ctx.row_name(j)}",
                severity=ERROR if same_scope else INFO,
                rows=(i, j), data={"dominated": i, "dominator": j})
            break                      # one dominator per row is enough


@rule("coverage-hole", scope="table", severity=ERROR,
      rationale="State-machine-adjacent same-bank command pairs (the "
                "enable graph: ACT enables RD/WR, PRE enables ACT, REF "
                "blocks everything, the data bus serializes column "
                "commands) must carry an ordering constraint at some "
                "covering level — otherwise the pair can issue in the "
                "same cycle: a zero-latency issue hazard no simulation "
                "would flag.")
def check_coverage(ctx):
    cs = ctx.cspec
    fx = np.asarray(cs.cmd_fx)
    kind = np.asarray(cs.cmd_kind)
    ids = range(cs.n_cmds)
    opens = [i for i in ids if fx[i] & S.FX_OPEN]
    close_row = [i for i in ids
                 if (fx[i] & (S.FX_CLOSE | S.FX_CLOSE_ALL))
                 and kind[i] == S.KIND_ROW]
    refs = [i for i in ids if kind[i] == S.KIND_REF]
    rds = [i for i in ids if fx[i] & S.FX_FINAL_RD]
    wrs = [i for i in ids if fx[i] & S.FX_FINAL_WR]
    starter = cs.id_ACT1 if cs.id_ACT1 >= 0 else cs.id_ACT
    starters = [starter] if starter >= 0 else []

    required: list = []                # (prev, next, why)
    for o in opens:
        for f in rds + wrs:
            required.append((o, f, "activate-to-column (tRCD)"))
        for c in close_row:
            required.append((o, c, "activate-to-precharge (tRAS)"))
        for st in starters:
            required.append((o, st, "row cycle (tRC)"))
    for c in close_row:
        for st in starters:
            required.append((c, st, "precharge-to-activate (tRP)"))
        for r in refs:
            required.append((c, r, "precharge-to-refresh (tRP)"))
    for r in refs:
        for st in starters:
            required.append((r, st, "refresh recovery (tRFC)"))
        required.append((r, r, "refresh-to-refresh (tRFC)"))
        for f in rds + wrs:
            required.append((r, f, "refresh recovery (tRFC)"))
    for a in rds + wrs:
        for b in rds + wrs:
            required.append((a, b, "data-bus serialization (nBL/tCCD)"))
    for a in rds:
        for c in close_row:
            required.append((a, c, "read-to-precharge (tRTP)"))
    for a in wrs:
        for c in close_row:
            required.append((a, c, "write recovery (tWR)"))
    for st in starters:
        required.append((st, st, "activate-to-activate (tRRD)"))

    covered = set()
    for i in range(len(cs.ct_prev)):
        if _reachable(cs, i) and int(cs.ct_lat[i]) >= 1:
            covered.add((int(cs.ct_prev[i]), int(cs.ct_next[i])))
    seen = set()
    for p, f, why in required:
        if (p, f) in covered or (p, f) in seen:
            continue
        seen.add((p, f))
        yield ctx.finding(
            f"coverage hole: no ordering constraint for same-bank pair "
            f"{cs.cmd_names[p]}->{cs.cmd_names[f]} at any level — "
            f"expected {why}; the pair can issue zero cycles apart",
            data={"prev": cs.cmd_names[p], "next": cs.cmd_names[f],
                  "expected": why})


@rule("refresh-headroom", scope="table", severity=ERROR,
      rationale="Refresh is schedulable only when the recovery time "
                "(tRFC) fits inside the refresh interval (tREFI) with "
                "headroom for pending work; per-channel stagger shifts "
                "each channel's epoch by tREFI/C, so overlapping "
                "recovery windows defeat the stagger's purpose.")
def check_refresh(ctx):
    t = ctx.timings
    if "nRFC" not in t or "nREFI" not in t:
        return
    nrfc, nrefi = int(t["nRFC"]), int(t["nREFI"])
    if nrefi <= 0 or nrfc <= 0:
        yield ctx.finding(f"non-positive refresh timing: nRFC={nrfc}, "
                          f"nREFI={nrefi}")
        return
    if nrfc >= nrefi:
        yield ctx.finding(
            f"refresh unschedulable: nRFC={nrfc} >= nREFI={nrefi} — the "
            "device spends its whole interval (or more) in recovery and "
            "the controller can never drain demand traffic")
        return
    duty = nrfc / nrefi
    if duty > REFRESH_DUTY_WARN:
        yield ctx.finding(
            f"refresh duty cycle {duty:.1%} (nRFC={nrfc} / nREFI={nrefi}) "
            f"exceeds {REFRESH_DUTY_WARN:.0%} — little headroom for "
            "demand traffic between refreshes", severity=WARN,
            data={"duty": round(duty, 4)})
    if ctx.channels > 1:
        spacing = nrefi // ctx.channels
        if spacing < nrfc:
            yield ctx.finding(
                f"per-channel refresh stagger overlap: stagger spacing "
                f"nREFI/C = {spacing} < nRFC = {nrfc} with C = "
                f"{ctx.channels} channels — staggered refresh recovery "
                "windows overlap, so system bandwidth still dips",
                severity=WARN,
                data={"channels": ctx.channels, "spacing": spacing})


@rule("ring-capacity", scope="table", severity=ERROR,
      rationale="The engine reads window>1 constraints from compact "
                "per-(command, level) rings planned at compile time; a "
                "ring layout inconsistent with the constraint table "
                "silently reads the wrong issue history.")
def check_rings(ctx):
    cs = ctx.cspec
    want = build_windowed_rings(
        np.asarray(cs.ct_prev), np.asarray(cs.ct_level),
        np.asarray(cs.ct_win), np.asarray(cs.cmd_scope),
        np.asarray(cs.level_counts), np.asarray(cs.level_offsets))
    pairs = dict(
        ring_pairs=[tuple(p) for p in cs.ring_pairs],
        n_ring=int(cs.n_ring), ring_depth=int(cs.ring_depth))
    want_pairs = dict(
        ring_pairs=[tuple(p) for p in want["ring_pairs"]],
        n_ring=int(want["n_ring"]), ring_depth=int(want["ring_depth"]))
    for field in ("ring_pairs", "n_ring", "ring_depth"):
        if pairs[field] != want_pairs[field]:
            yield ctx.finding(
                f"windowed-ring layout mismatch: {field} is "
                f"{pairs[field]!r} but the constraint table needs "
                f"{want_pairs[field]!r} — rebuild via "
                "build_windowed_rings", data={"field": field})
            return
    for field in ("ring_cmd", "ring_level", "ring_node", "ct_ring"):
        have = np.asarray(getattr(cs, field))
        if have.shape != want[field].shape \
                or not np.array_equal(have, want[field]):
            yield ctx.finding(
                f"windowed-ring table mismatch: {field} disagrees with "
                "build_windowed_rings for this constraint table",
                data={"field": field})
            return
    # capacity: the allocated depth must cover the deepest reachable window
    deep = [int(cs.ct_win[i]) for i in range(len(cs.ct_prev))
            if int(cs.ct_win[i]) > 1 and _reachable(cs, i)]
    if deep and int(cs.ring_depth) < max(deep):
        yield ctx.finding(
            f"ring depth {int(cs.ring_depth)} cannot hold the deepest "
            f"window ({max(deep)}) — windowed constraints would read "
            "evicted history", data={"depth": int(cs.ring_depth),
                                     "max_window": max(deep)})
