"""``repro.analysis`` — static analysis for specs and source.

Two passes over two very different artifacts, one reporting currency:

* **Spec lint** (:mod:`repro.analysis.speclint` /
  :mod:`repro.analysis.rules`): semantic analysis of DRAM standards and
  compiled constraint tables — derived-timing inequalities with their
  JEDEC rationale, dominance/shadowing (dead rows), coverage holes
  (zero-latency issue hazards), refresh schedulability, windowed-ring
  validation, unknown/unused timing tokens.
* **Trace-safety lint** (:mod:`repro.analysis.tracecheck`): an AST pass
  over ``src/repro`` that flags JAX anti-patterns before they reach the
  jitted hot paths — Python control flow on traced values in scan
  bodies, host coercions under jit, ``np.*`` on traced arrays,
  non-hashable closure captures in cache-keyed callables, ``jnp`` use
  outside the allowlisted hot-path modules.

Both emit :class:`~repro.analysis.report.LintReport` artifacts
(JSON/npz) and share the CLI: ``python -m repro.analysis``.
"""
from repro.analysis.report import (ERROR, INFO, WARN, Finding, LintReport,
                                   diff, merge, render_diff)
from repro.analysis.rules import RULES, Rule, RuleCtx, rule
from repro.analysis.speclint import (SpecLintError, default_presets,
                                     lint_all, lint_compiled, lint_spec,
                                     lint_system)
from repro.analysis.tracecheck import JNP_ALLOWLIST, lint_paths

__all__ = [
    "ERROR", "WARN", "INFO", "Finding", "LintReport", "diff", "merge",
    "render_diff", "RULES", "Rule", "RuleCtx", "rule", "default_presets",
    "SpecLintError", "lint_all", "lint_compiled", "lint_spec",
    "lint_system", "JNP_ALLOWLIST", "lint_paths",
]
