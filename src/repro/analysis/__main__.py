"""Static-analysis CLI: ``python -m repro.analysis`` — lint specs and
source, save artifacts, diff reports.

    # lint every registered standard + the hetero composition (CI gate)
    PYTHONPATH=src python -m repro.analysis spec --all

    # one configuration, with overrides and artifacts
    PYTHONPATH=src python -m repro.analysis spec --standard DDR5 \\
        --channels 4 --override nRCD=50 --out lint.json --npz lint.npz

    # heterogeneous composition (same --group syntax as repro.telemetry)
    PYTHONPATH=src python -m repro.analysis spec --group DDR5:2 \\
        --group DDR4:2:80

    # JAX trace-safety lint over the source tree
    PYTHONPATH=src python -m repro.analysis trace src/repro --out ts.json

    # structural diff: saved reports or standards linted on the fly
    PYTHONPATH=src python -m repro.analysis diff DDR4 DDR5
    PYTHONPATH=src python -m repro.analysis diff before.json after.json

Exit status: 0 when no error-severity findings (``--strict`` also
counts warnings), 1 otherwise.
"""
from __future__ import annotations

import argparse
import os
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static DRAM-spec linter + JAX trace-safety linter.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("spec", help="lint DRAM standards / systems")
    sp.add_argument("--all", action="store_true",
                    help="lint every registered standard plus the "
                         "reference hetero composition")
    sp.add_argument("--standard", default=None)
    sp.add_argument("--org", default=None)
    sp.add_argument("--timing", default=None)
    sp.add_argument("--channels", default=1, type=int)
    sp.add_argument("--group", default=None, action="append",
                    metavar="STD[:CHANNELS[:LINK]]",
                    help="heterogeneous spec group (repeatable); "
                         "overrides --standard")
    sp.add_argument("--override", default=None, action="append",
                    metavar="PARAM=CYCLES",
                    help="timing override (repeatable), e.g. nRCD=50")
    sp.add_argument("--out", default=None, metavar="JSON")
    sp.add_argument("--npz", default=None, metavar="NPZ")
    sp.add_argument("--strict", action="store_true",
                    help="warnings also fail the lint")
    sp.add_argument("--show-info", action="store_true")

    tp = sub.add_parser("trace", help="JAX trace-safety lint over source")
    tp.add_argument("paths", nargs="*", default=None,
                    help="files/directories (default: the installed "
                         "repro package tree)")
    tp.add_argument("--out", default=None, metavar="JSON")
    tp.add_argument("--npz", default=None, metavar="NPZ")
    tp.add_argument("--strict", action="store_true",
                    help="warnings (e.g. TS105 allowlist) also fail")
    tp.add_argument("--show-contexts", action="store_true",
                    help="print every discovered traced context")

    dp = sub.add_parser("diff", help="structural diff of two lint runs")
    dp.add_argument("a", help="report path (.json/.npz) or standard name")
    dp.add_argument("b", help="report path (.json/.npz) or standard name")
    dp.add_argument("--show-info", action="store_true")
    return ap


def _parse_overrides(items) -> dict | None:
    if not items:
        return None
    out = {}
    for item in items:
        if "=" not in item:
            raise SystemExit(f"--override expects PARAM=CYCLES, got "
                             f"{item!r}")
        k, v = item.split("=", 1)
        out[k.strip()] = int(v)
    return out


#: reference heterogeneous composition linted by ``spec --all`` — the
#: same native-DDR5 + CXL-attached-DDR4 system the CI hetero smoke runs
HETERO_GROUPS = ("DDR5:2", "DDR4:2:80")


def _parse_group(text: str) -> dict:
    from repro.dse.spec import DEFAULT_SYSTEMS
    parts = text.split(":")
    std = parts[0]
    if std not in DEFAULT_SYSTEMS:
        raise SystemExit(f"no default org/timing for {std!r}; known: "
                         f"{sorted(DEFAULT_SYSTEMS)}")
    org, tim = DEFAULT_SYSTEMS[std]
    return dict(standard=std, org_preset=org, timing_preset=tim,
                channels=int(parts[1]) if len(parts) > 1 else 1,
                link_latency=int(parts[2]) if len(parts) > 2 else 0)


def _save(report, out, npz):
    for path, save in ((out, report.save_json), (npz, report.save_npz)):
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            save(path)
            print(f"report written to {path}")


def cmd_spec(args) -> int:
    import repro.core.standards  # noqa: F401  (register all standards)
    from repro.analysis import lint_all, lint_spec, lint_system, merge
    from repro.core.compile import compile_system

    if args.all:
        reports = list(lint_all(channels=args.channels).values())
        msys = compile_system([_parse_group(g) for g in HETERO_GROUPS])
        reports.append(lint_system(msys))
        report = merge(reports, target="all-standards+hetero")
    elif args.group:
        from repro.core.compile import compile_system as _cs
        msys = _cs([_parse_group(g) for g in args.group])
        report = lint_system(msys)
    else:
        std = args.standard or "DDR4"
        report = lint_spec(std, args.org, args.timing,
                           _parse_overrides(args.override),
                           channels=args.channels)
    print(report.summary(show_info=args.show_info))
    _save(report, args.out, args.npz)
    ok = report.ok(strict=args.strict)
    print("spec lint:", "clean" if ok else "FAILED")
    return 0 if ok else 1


def cmd_trace(args) -> int:
    from repro.analysis.tracecheck import lint_paths
    paths = args.paths
    if not paths:
        import repro
        # repro is a namespace package: __file__ is None, use __path__
        paths = [os.path.abspath(list(repro.__path__)[0])]
    report = lint_paths(paths)
    if args.show_contexts:
        for c in report.meta["traced_contexts"]:
            print("context:", c)
    print(report.summary(show_info=True))
    print(f"({report.meta['modules']} modules, "
          f"{len(report.meta['traced_contexts'])} traced contexts)")
    _save(report, args.out, args.npz)
    ok = report.ok(strict=args.strict)
    print("trace-safety lint:", "clean" if ok else "FAILED")
    return 0 if ok else 1


def _load_or_lint(ref: str):
    from repro.analysis import LintReport, lint_spec
    if ref.endswith(".json"):
        return LintReport.load_json(ref)
    if ref.endswith(".npz"):
        return LintReport.load_npz(ref)
    import repro.core.standards  # noqa: F401
    return lint_spec(ref)


def cmd_diff(args) -> int:
    from repro.analysis import render_diff
    a, b = _load_or_lint(args.a), _load_or_lint(args.b)
    print(render_diff(a, b))
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return {"spec": cmd_spec, "trace": cmd_trace,
            "diff": cmd_diff}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
