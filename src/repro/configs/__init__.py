"""Assigned architecture configs (public-literature, see each module)."""
from repro.configs.base import (ModelConfig, MoEConfig, ShapeConfig, SHAPES,
                                all_archs, get_arch, register_arch,
                                valid_cells)
from repro.configs.recurrentgemma_2b import RECURRENTGEMMA_2B
from repro.configs.qwen3_4b import QWEN3_4B
from repro.configs.llama3_2_1b import LLAMA32_1B
from repro.configs.qwen3_14b import QWEN3_14B
from repro.configs.glm4_9b import GLM4_9B
from repro.configs.phi3_5_moe import PHI35_MOE
from repro.configs.llama4_maverick import LLAMA4_MAVERICK
from repro.configs.qwen2_vl_72b import QWEN2_VL_72B
from repro.configs.xlstm_350m import XLSTM_350M
from repro.configs.musicgen_medium import MUSICGEN_MEDIUM

__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES", "all_archs",
           "get_arch", "register_arch", "valid_cells"]
