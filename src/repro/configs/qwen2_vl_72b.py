"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Backbone only: the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings and M-RoPE (t, h, w) position triples."""
from repro.configs.base import ModelConfig, register_arch

QWEN2_VL_72B = register_arch(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29_568,
    vocab=152_064, head_dim=128, rope="mrope", rope_theta=1_000_000.0,
    frontend="vision_stub",
))
