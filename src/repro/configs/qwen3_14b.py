"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936."""
from repro.configs.base import ModelConfig, register_arch

QWEN3_14B = register_arch(ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17_408,
    vocab=151_936, head_dim=128, qk_norm=True, rope="rope",
    rope_theta=1_000_000.0,
    notes="40 heads % 16 != 0: head TP falls back to qkv-dim sharding "
          "(sharding/rules.py).",
))
