"""Model/run configuration schema + architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # GShard-style grouped dispatch (data x model sharded expert compute);
    # False = flat global dispatch (the recorded §Perf baseline)
    grouped: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # 0 => block supplies its own projections
    vocab: int
    head_dim: int = 128
    block_pattern: tuple = ("attn",)     # cycled across layers
    attn_window: Optional[int] = None    # local attention window (tokens)
    qk_norm: bool = False
    rope: str = "rope"                   # rope | mrope | none
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    frontend: Optional[str] = None       # vision_stub | audio_stub
    n_codebooks: int = 1                 # audio (EnCodec streams)
    norm_eps: float = 1e-6
    sub_quadratic: bool = False          # may run long_500k decode
    tie_embeddings: bool = False
    d_ff_dense: int = 0                  # dense-layer ffn when != d_ff (llama4)
    kv_quant: bool = False               # int8 KV cache (decode memory lever)
    notes: str = ""

    # ----- derived -----
    @property
    def d_qkv(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim

    def pattern_layers(self) -> list:
        """Per-layer block kinds, block_pattern cycled over n_layers."""
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    def n_remainder(self) -> int:
        return self.n_layers % len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        from repro.models.model import count_params
        return count_params(self, active_only=True)

    def reduced(self, n_layers=None) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pat = len(self.block_pattern)
        nl = n_layers if n_layers is not None else max(pat, 2 * pat)
        kv = min(self.n_kv_heads, 2)
        heads = max(2, (self.n_heads * 2) // self.n_heads)  # 2 q heads
        heads = max(heads, kv)
        moe = None
        if self.moe:
            # capacity_factor 4.0: tiny smoke shapes must stay drop-free so
            # decode-vs-forward parity is exact
            moe = dataclasses.replace(self.moe, num_experts=4,
                                      top_k=min(self.moe.top_k, 2),
                                      d_ff_expert=64, capacity_factor=4.0)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=nl, d_model=64,
            n_heads=heads, n_kv_heads=kv, d_ff=128 if self.d_ff else 0,
            vocab=256, head_dim=32, moe=moe,
            attn_window=min(self.attn_window, 64) if self.attn_window else None)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_ARCHS: dict = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 — ensure registry is populated
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    return _ARCHS[name]


def all_archs() -> dict:
    import repro.configs  # noqa: F401
    return dict(_ARCHS)


def valid_cells() -> list:
    """All (arch, shape) dry-run cells, honoring the long-context rule."""
    cells = []
    for name, cfg in sorted(all_archs().items()):
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                continue   # skipped per DESIGN.md §Arch-applicability
            cells.append((name, sname))
    return cells
