"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
48L d_model=5120 40H (GQA kv=8) d_ff=8192, MoE 128e top-1 + shared expert."""
from repro.configs.base import ModelConfig, MoEConfig, register_arch

LLAMA4_MAVERICK = register_arch(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202_048, head_dim=128, rope="rope", rope_theta=500_000.0,
    block_pattern=("attn", "moe"),   # llama4: MoE every other layer
    d_ff_dense=16_384,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  shared_expert=True),
    notes="shared expert + top-1 routed expert, MoE interleaved 1:2 "
          "(interleave_moe_layer_step=2) per llama4; 'early fusion' concerns "
          "the multimodal frontend, which is out of backbone scope.",
))
