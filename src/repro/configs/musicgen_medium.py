"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 per codebook, 4 codebooks (delay pattern).
Backbone only: the EnCodec frontend is a STUB — input_specs() provides
the 4 parallel codebook token streams."""
from repro.configs.base import ModelConfig, register_arch

MUSICGEN_MEDIUM = register_arch(ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab=2048, head_dim=64, rope="none",
    frontend="audio_stub", n_codebooks=4,
    notes="sum of 4 codebook embeddings in, 4 parallel lm heads out "
          "(delay-pattern scheduling happens in the data pipeline).",
))
