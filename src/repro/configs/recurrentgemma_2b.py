"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427; hf].  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; two recurrent blocks per local-attention block, 2k window."""
from repro.configs.base import ModelConfig, register_arch

RECURRENTGEMMA_2B = register_arch(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    attn_window=2048, rope="rope",
    sub_quadratic=True, tie_embeddings=True,
    notes="RG-LRU recurrence via associative scan; local attn window 2048; "
          "26 = 8x(R,R,A) + (R,R) remainder.",
))
