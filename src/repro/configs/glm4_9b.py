"""glm4-9b [dense] — RoPE, GQA [hf:THUDM/glm-4-9b; hf].
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""
from repro.configs.base import ModelConfig, register_arch

GLM4_9B = register_arch(ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13_696,
    vocab=151_552, head_dim=128, rope="rope",
))
