"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
24L d_model=1024 4H d_ff=0 vocab=50304; alternating mLSTM/sLSTM blocks
(d_ff=0: each block carries its own projections)."""
from repro.configs.base import ModelConfig, register_arch

XLSTM_350M = register_arch(ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50_304, head_dim=256, rope="none",
    block_pattern=("mlstm", "slstm"),
    sub_quadratic=True,
    notes="matrix-memory mLSTM + scalar-memory sLSTM, 1:1 alternation; "
          "O(1) state per token => long_500k eligible.",
))
