"""Windowed-telemetry consistency: counters captured one window at a
time inside the scan, summed over ALL windows, must equal the end-of-run
``Stats`` aggregates bit-exactly — across every registered standard,
multi-channel systems, and heterogeneous compositions, including the
ragged final window (``n_cycles % window != 0``).

``Telemetry.check`` is the property under test; the explicit assertions
below also pin the derived-metric invariants (occupancy bounds, latency
histogram accounting) and the artifact round-trip.
"""
import os

import numpy as np
import pytest

from repro import telemetry as T
from repro.core import ControllerConfig, Simulator, compile_system
from repro.dse.spec import DEFAULT_SYSTEMS

pytestmark = pytest.mark.device_timings

RAGGED = 1500        # 1500 % 256 != 0: exercises the ragged final window
W = 256


def _assert_consistent(sim, stats, telem, n_cycles, window):
    # the property: sum-over-windows == aggregates, every counter
    telem.check(stats)
    assert telem.n_cycles == n_cycles and telem.window == window
    n_full, rem = divmod(n_cycles, window)
    assert telem.n_windows == n_full + (1 if rem or not n_full else 0)
    assert int(telem.widths.sum()) == n_cycles
    for gi, gt in enumerate(telem.groups):
        grp = sim.msys.groups[gi]
        # occupancy: bounded by the request-queue depth, never negative
        occ = gt.occupancy(telem.widths)
        assert (gt.occ_sum >= 0).all()
        assert (occ <= sim.controller.queue_depth + 1e-9).all(), \
            f"group {gi} occupancy exceeds queue depth"
        # latency histogram: non-negative, accounts for every served
        # probe window by window, bucket axis = edges + open top bucket
        assert gt.lat_hist.shape[2] == len(grp.cspec.lat_bucket_edges) + 1
        assert (gt.lat_hist >= 0).all()
        np.testing.assert_array_equal(gt.lat_hist.sum(axis=2), gt.probe_cnt)
        # windowed counters are deltas of monotone counts: non-negative
        for name in ("reads", "writes", "probe_cnt", "deferred",
                     "cmd_counts"):
            assert (getattr(gt, name) >= 0).all(), f"group {gi} {name}"


@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_windows_sum_to_stats_every_standard(standard):
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim)
    stats, telem = sim.run(RAGGED, interval=2.0, read_ratio=0.7,
                           telemetry=W)
    _assert_consistent(sim, stats, telem, RAGGED, W)
    # the same run without telemetry yields the same aggregates: the
    # windowed restructuring is observationally pure
    plain = sim.run(RAGGED, interval=2.0, read_ratio=0.7)
    np.testing.assert_array_equal(np.asarray(stats.reads_done),
                                  np.asarray(plain.reads_done))
    np.testing.assert_array_equal(np.asarray(stats.cmd_counts),
                                  np.asarray(plain.cmd_counts))


def test_multi_channel_windows_sum_to_stats():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    stats, telem = sim.run(3000, interval=1.0, read_ratio=0.7, telemetry=W)
    _assert_consistent(sim, stats, telem, 3000, W)
    (gt,) = telem.groups
    assert gt.reads.shape == (telem.n_windows, 4)
    # some window saw traffic on every channel
    assert (gt.reads.sum(axis=0) > 0).all()


def test_hetero_ddr5_cxl_ddr4_windows_sum_to_stats():
    hsys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    sim = Simulator(system=hsys)
    stats, telem = sim.run(2900, interval=1.0, read_ratio=0.7, telemetry=200)
    _assert_consistent(sim, stats, telem, 2900, 200)
    assert len(telem.groups) == 2
    assert telem.groups[0].standard == "DDR5"
    assert telem.groups[1].link_latency == 80
    # each group's windowed command counts live in its native namespace
    assert telem.groups[0].cmd_counts.shape[2] == \
        len(hsys.groups[0].cspec.cmd_names)
    assert telem.groups[1].cmd_counts.shape[2] == \
        len(hsys.groups[1].cspec.cmd_names)


def test_exact_multiple_and_tiny_runs():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    # n_cycles an exact multiple of the window: no ragged tail
    stats, telem = sim.run(1024, interval=2.0, telemetry=256)
    assert telem.n_windows == 4
    _assert_consistent(sim, stats, telem, 1024, 256)
    # n_cycles below one window: a single ragged window IS the run
    stats, telem = sim.run(100, interval=2.0, telemetry=256)
    assert telem.n_windows == 1 and int(telem.widths[0]) == 100
    _assert_consistent(sim, stats, telem, 100, 256)


def test_stats_identical_with_and_without_telemetry():
    """Scheduler decisions must be unaffected: trace streams with
    telemetry on are pinned by tests/trace/test_golden_equality.py; here
    the scalar aggregates are compared field by field."""
    sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=2)
    plain = sim.run(2000, interval=1.0, read_ratio=0.7)
    stats, _ = sim.run(2000, interval=1.0, read_ratio=0.7, telemetry=128)
    for f in ("reads_done", "writes_done", "probe_lat_sum", "probe_cnt",
              "data_bus_busy", "deferred", "cmd_counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(stats, f)), np.asarray(getattr(plain, f)),
            err_msg=f)


def test_check_rejects_tampered_series():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    stats, telem = sim.run(RAGGED, interval=2.0, telemetry=W)
    telem.groups[0].reads[0, 0] += 1
    with pytest.raises(ValueError, match="reads"):
        telem.check(stats)


def test_artifact_roundtrip(tmp_path):
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
    stats, telem = sim.run(RAGGED, interval=2.0, telemetry=W)
    path = T.save(telem, os.path.join(tmp_path, "t.npz"))
    back = T.load(path)
    assert back.window == telem.window
    assert back.n_cycles == telem.n_cycles
    np.testing.assert_array_equal(back.t_end, telem.t_end)
    for gt, gb in zip(telem.groups, back.groups):
        assert gb.standard == gt.standard
        assert gb.cmd_names == gt.cmd_names
        assert gb.lat_edges == gt.lat_edges
        for name in ("reads", "writes", "occ_sum", "cmd_counts",
                     "lat_hist"):
            np.testing.assert_array_equal(getattr(gb, name),
                                          getattr(gt, name), err_msg=name)
    back.check(stats)           # the reloaded series still verifies

    n = T.write_jsonl(telem, os.path.join(tmp_path, "t.jsonl"))
    assert n == telem.n_windows
    import json
    lines = [json.loads(l) for l in
             open(os.path.join(tmp_path, "t.jsonl"))]
    assert len(lines) == n
    assert lines[-1]["t_end"] == RAGGED
    assert sum(r["groups"][0]["reads"][0] for r in lines) == \
        int(np.asarray(stats.per_group[0].reads_done)[0])


def test_summary_mentions_each_group():
    hsys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=1),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=1, link_latency=40),
    ])
    sim = Simulator(system=hsys)
    _, telem = sim.run(1200, interval=2.0, telemetry=300)
    s = telem.summary()
    assert "DDR5" in s and "DDR4" in s and "link=40" in s
    assert f"{telem.n_windows} windows" in s


def test_sweep_attaches_per_point_telemetry(tmp_path):
    from repro.dse import SweepSpec, execute
    spec = SweepSpec(systems=("DDR4",), intervals=(4.0, 1.0),
                     read_ratios=(0.7,), n_cycles=1000, telemetry=128,
                     telemetry_dir=str(tmp_path))
    res = execute(spec)
    assert res.telemetry is not None
    assert len(res.telemetry) == len(res.points) == 2
    for i, tel in enumerate(res.telemetry):
        assert tel.window == 128 and tel.n_cycles == 1000
        # sweep results are columnar (no per-point Stats object): the
        # windowed series must sum to the columnar aggregates
        tot = sum(int(gt.reads.sum() + gt.writes.sum())
                  for gt in tel.groups)
        assert tot == int(res.reads_done[i] + res.writes_done[i])
        assert tel.meta["point"] == res.points[i].label
    arts = res.meta["telemetry_artifacts"]
    assert len(arts) == 2
    back = T.load(arts[0])
    np.testing.assert_array_equal(back.groups[0].reads,
                                  res.telemetry[0].groups[0].reads)


def test_refresh_windows_nonzero_on_long_runs():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(refresh_enabled=True))
    _, telem = sim.run(20_000, interval=4.0, telemetry=1024)
    (gt,) = telem.groups
    ref = gt.refreshes()
    assert ref.sum() > 0          # tREFI windows elapsed -> refreshes seen
    # refresh activity is windowed, not lumped into one sample
    assert (ref > 0).sum() > 1
