"""Host-side run profiler: RunCache public accounting, span recording,
and the one-shot cold/warm characterization."""
import numpy as np

from repro import telemetry as T
from repro.core import Simulator
from repro.core import engine as E


def test_runcache_stats_public_api():
    s = E.RUN_CACHE.stats()
    assert set(s) == {"entries", "hits", "misses", "first_call_s",
                      "devices", "shard_topologies"}
    assert s["entries"] >= 0 and s["first_call_s"] >= 0.0
    assert s["devices"] >= 1
    assert all(t == "vmap" or t.startswith("channels:")
               for t in s["shard_topologies"])
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    sim.run(500)
    s2 = E.RUN_CACHE.stats()
    # the run either compiled a new program (miss) or reused one (hit)
    assert s2["hits"] + s2["misses"] > s["hits"] + s["misses"]


def test_profiler_spans_and_cache_delta():
    prof = T.Profiler()
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    with prof.span("first"):
        sim.run(600, interval=3.0)
    with prof.span("warm"):
        sim.run(600, interval=3.0)
    with prof.span("warm"):
        sim.run(600, interval=3.0)
    r = prof.report()
    assert r["spans"]["first"]["calls"] == 1
    assert r["spans"]["warm"]["calls"] == 2
    assert r["wall_s"] >= r["spans"]["first"]["s"]
    # cache view is a delta: exactly one compile, then hits
    assert r["cache"]["misses"] == 1
    assert r["cache"]["hits"] == 2
    assert "programs" in prof.summary()


def test_profile_run_cold_warm():
    sim = Simulator("DDR5", "DDR5_16Gb_x8", "DDR5_4800B")
    p = T.profile_run(sim, 800, repeats=2, interval=2.0)
    assert set(p) >= {"first_call_s", "warm_s", "compile_s",
                      "cycles_per_sec", "cache"}
    assert p["first_call_s"] >= p["warm_s"] > 0
    assert p["compile_s"] >= 0
    assert p["cycles_per_sec"] > 0
    # forwarding run_kw: telemetry-on profiling also works and the
    # windowed run produces the same aggregate throughput
    p_tel = T.profile_run(sim, 800, repeats=1, interval=2.0, telemetry=128)
    assert p_tel["cycles_per_sec"] > 0


def test_sweep_reports_cache_accounting():
    from repro.dse import SweepSpec, execute
    res = execute(SweepSpec(systems=("DDR4",), intervals=(4.0,),
                            read_ratios=(1.0,), n_cycles=500))
    c = res.meta["cache"]
    assert set(c) >= {"entries", "hits", "misses", "first_call_s"}
