"""``python -m repro.telemetry`` CLI: simulate -> check -> artifacts ->
timeline HTML, the --load path, heterogeneous groups, and --profile (the
telemetry-smoke CI contract)."""
import pytest

from repro.telemetry.__main__ import main


def test_cli_end_to_end(tmp_path, capsys):
    out = tmp_path / "t.npz"
    jsonl = tmp_path / "t.jsonl"
    html = tmp_path / "t.html"
    rc = main(["--standard", "DDR4", "--cycles", "3000", "--window", "256",
               "--check", "--out", str(out), "--jsonl", str(jsonl),
               "--html", str(html)])
    assert rc == 0
    assert out.exists() and jsonl.exists() and html.exists()
    text = capsys.readouterr().out
    assert "check: sum-over-windows == Stats aggregates" in text
    assert "ragged tail yes" in text          # 3000 % 256 != 0
    assert "windows" in text
    page = html.read_text()
    assert "bandwidth" in page and "occupancy" in page

    # --load: re-summarize + re-render the saved artifact
    html2 = tmp_path / "again.html"
    rc = main(["--load", str(out), "--html", str(html2)])
    assert rc == 0 and html2.exists()
    assert "loaded" in capsys.readouterr().out


def test_cli_hetero_groups(capsys):
    rc = main(["--group", "DDR5:2", "--group", "DDR4:2:80",
               "--cycles", "2000", "--window", "200", "--check"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "DDR5" in text and "DDR4" in text and "link=80" in text
    assert "check: sum-over-windows == Stats aggregates" in text


def test_cli_check_fails_nonzero(monkeypatch, capsys):
    # tamper with the built series through the build hook: --check must
    # propagate the mismatch as a nonzero exit status
    import repro.telemetry as T
    orig = T.build

    def tampered(*a, **k):
        telem = orig(*a, **k)
        telem.groups[0].reads[0, 0] += 1
        return telem
    monkeypatch.setattr(T, "build", tampered)
    # the engine looks build up through the package at call time
    import repro.core.engine  # noqa: F401  (import for the record)
    rc = main(["--standard", "DDR4", "--cycles", "1000", "--window", "256",
               "--check"])
    assert rc == 1
    assert "reads" in capsys.readouterr().out


def test_cli_profile(capsys):
    rc = main(["--standard", "DDR4", "--cycles", "1000", "--window", "256",
               "--profile"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "profile: first call" in text and "cycles/s" in text


def test_cli_rejects_bad_window():
    with pytest.raises(SystemExit):
        main(["--standard", "DDR4", "--cycles", "1000", "--window", "0"])


def test_cli_rejects_unknown_standard():
    with pytest.raises(SystemExit):
        main(["--standard", "NOPE", "--cycles", "100"])
