"""Launch-layer tests: mini dry-run in a subprocess (own device count),
spec choosers, and collective-stats parser."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def test_collective_parser():
    from repro.launch.dryrun import collective_stats
    txt = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %p), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%add
  %t = (f32[128]{0}, f32[64]{0}) all-to-all(f32[128]{0} %a, f32[64]{0} %b)
  %rs = bf16[2,4]{1,0} reduce-scatter(bf16[16,4]{1,0} %y), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z)
  %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %l, f32[2,2]{1,0} %r)
"""
    st = collective_stats(txt)
    assert set(st["by_kind"]) == {"all-gather", "all-reduce", "all-to-all",
                                  "reduce-scatter", "collective-permute"}
    assert st["by_kind"]["all-gather"]["bytes"] == 16 * 512 * 2
    assert st["by_kind"]["all-reduce"]["bytes"] == 256 * 4
    assert st["by_kind"]["all-to-all"]["bytes"] == (128 + 64) * 4
    assert st["by_kind"]["reduce-scatter"]["bytes"] == 8 * 2
    assert st["by_kind"]["collective-permute"]["bytes"] == 32
    assert st["total_bytes"] == sum(e["bytes"]
                                    for e in st["by_kind"].values())


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """Real lower+compile of a reduced arch on a faked 8-device (2,4) mesh
    in a subprocess (the session process has its device count locked)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import jax
from repro.configs import get_arch
from repro.launch.specs import cell_spec, step_fn_for
from repro.launch.dryrun import collective_stats

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = dataclasses.replace(
    get_arch("llama3.2-1b").reduced(), vocab=2048, name="mini")
import repro.configs.base as cb
cb.register_arch(cfg)
import repro.configs as C
C.SHAPES = dict(C.SHAPES)
from repro.configs.base import ShapeConfig, SHAPES
SHAPES["mini_train"] = ShapeConfig("mini_train", 64, 8, "train")
SHAPES["mini_decode"] = ShapeConfig("mini_decode", 64, 8, "decode")
out = {}
for shape in ("mini_train", "mini_decode"):
    cs = cell_spec(cfg, shape, mesh)
    step = step_fn_for(cfg, shape)
    with mesh:
        lowered = jax.jit(step, in_shardings=cs.args_shardings,
                          donate_argnums=cs.donate).lower(*cs.args_avals)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    st = collective_stats(compiled.as_text())
    out[shape] = {"flops": ca.get("flops", 0),
                  "temp": ma.temp_size_in_bytes,
                  "coll": st["total_bytes"]}
print(json.dumps(out))
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["mini_train"]["flops"] > 0
    assert out["mini_train"]["coll"] > 0, "no collectives on a 2x4 mesh?"
    assert out["mini_decode"]["temp"] > 0


def test_cell_specs_cover_all_cells():
    """Every valid cell must produce coherent avals + shardings."""
    import jax
    from repro.configs import valid_cells
    from repro.launch.specs import cell_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    seen = set()
    for arch, shape in valid_cells():
        cs = cell_spec(arch, shape, mesh)
        flat_a = jax.tree.leaves(cs.args_avals)
        flat_s = jax.tree.leaves(
            cs.args_shardings,
            is_leaf=lambda x: hasattr(x, "spec"))
        assert len(flat_a) == len(flat_s), (arch, shape)
        seen.add((arch, shape))
    assert len(seen) == 32
