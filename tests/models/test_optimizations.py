"""Beyond-baseline optimization tests: grouped MoE dispatch, int8 KV cache,
FSDP sharding rules, causal/window block skipping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Batch, decode_step, forward, init_params
from repro.models import blocks as B
from repro.models.model import last_logits


def _ample(cfg):
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("name", ["phi3.5-moe-42b-a6.6b",
                                  "llama4-maverick-400b-a17b"])
def test_grouped_moe_equals_flat_dispatch_f32(name):
    """GShard one-hot dispatch must exactly equal flat dispatch in f32 when
    neither drops tokens (ample capacity)."""
    cfg = _ample(get_arch(name).reduced())
    d, E = cfg.d_model, cfg.moe.num_experts
    ff = cfg.moe.d_ff_expert
    rng = np.random.default_rng(0)
    p = {"router": jnp.asarray(rng.standard_normal((d, E)), jnp.float32) * .5,
         "w_gate": jnp.asarray(rng.standard_normal((E, d, ff)), jnp.float32) * .2,
         "w_up": jnp.asarray(rng.standard_normal((E, d, ff)), jnp.float32) * .2,
         "w_down": jnp.asarray(rng.standard_normal((E, ff, d)), jnp.float32) * .2}
    if cfg.moe.shared_expert:
        p["shared"] = {
            "w_gate": jnp.asarray(rng.standard_normal((d, ff)), jnp.float32) * .2,
            "w_up": jnp.asarray(rng.standard_normal((d, ff)), jnp.float32) * .2,
            "w_down": jnp.asarray(rng.standard_normal((ff, d)), jnp.float32) * .2}
    x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32)
    og, probs_g = B.moe_ffn_grouped(cfg, p, x)
    of, probs_f = B.moe_ffn(cfg, p, x.reshape(32, d))
    np.testing.assert_allclose(np.asarray(og).reshape(32, d),
                               np.asarray(of), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(probs_g), np.asarray(probs_f),
                               atol=1e-6)


def test_grouped_moe_full_model_close():
    """Whole-model parity (bf16): dispatch-order rounding only."""
    cfg_g = _ample(get_arch("phi3.5-moe-42b-a6.6b").reduced())
    cfg_f = dataclasses.replace(cfg_g, moe=dataclasses.replace(
        cfg_g.moe, grouped=False))
    params = init_params(cfg_g, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg_g.vocab, (2, 16)), jnp.int32)
    pos = jnp.arange(16, dtype=jnp.int32)[None].repeat(2, 0)
    xg, auxg = forward(cfg_g, params, Batch(tokens=toks, positions=pos))
    xf, auxf = forward(cfg_f, params, Batch(tokens=toks, positions=pos))
    np.testing.assert_allclose(np.asarray(xg, np.float32),
                               np.asarray(xf, np.float32), atol=0.2)
    np.testing.assert_allclose(float(auxg), float(auxf), rtol=1e-3)


def test_grouped_moe_capacity_is_per_group():
    """A group that routes everything to one expert drops independently of
    other groups (per-group capacity, unlike flat global dispatch)."""
    cfg = get_arch("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, top_k=1, capacity_factor=0.5, num_experts=4))
    d = cfg.d_model
    E = 4
    rng = np.random.default_rng(1)
    p = {
        "router": jnp.zeros((d, E), jnp.float32).at[:, 0].set(1.0),
        "w_gate": jnp.asarray(rng.standard_normal((E, d, 64)), jnp.float32) * .1,
        "w_up": jnp.asarray(rng.standard_normal((E, d, 64)), jnp.float32) * .1,
        "w_down": jnp.asarray(rng.standard_normal((E, 64, d)), jnp.float32) * .1,
    }
    x = jnp.asarray(rng.standard_normal((4, 16, d)), jnp.float32)
    out, _ = B.moe_ffn_grouped(cfg, p, x)
    dropped_per_group = np.asarray(
        jnp.sum(jnp.all(out == 0.0, axis=-1), axis=1))
    # every group drops the same count (same capacity, same routing skew)
    assert (dropped_per_group > 0).all()
    assert len(set(dropped_per_group.tolist())) == 1


def test_int8_kv_cache_dense_decode_parity():
    cfg = dataclasses.replace(get_arch("llama3.2-1b").reduced(),
                              kv_quant=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B_, T = 2, 17
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B_, T + 1)), jnp.int32)
    pos = jnp.arange(T + 1, dtype=jnp.int32)[None].repeat(B_, 0)
    x, _ = forward(cfg, params, Batch(tokens=toks, positions=pos))
    want = last_logits(cfg, params, x)
    x2, _, states = forward(cfg, params,
                            Batch(tokens=toks[:, :T], positions=pos[:, :T]),
                            return_states=True, cache_len=T + 2)
    got, cache = decode_step(
        cfg, params, states,
        Batch(tokens=toks[:, T:T + 1], positions=pos[:, T:T + 1],
              cache_index=jnp.int32(T), cache_len=jnp.int32(T + 1)))
    # int8 quantization noise only — logits stay close, caches are int8
    assert float(jnp.max(jnp.abs(got - want))) < 0.6
    k_leaf = jax.tree.leaves(cache)[0]
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(cache))


def test_int8_kv_cache_memory_halves():
    cfg_q = dataclasses.replace(get_arch("llama3.2-1b").reduced(),
                                kv_quant=True)
    cfg_b = get_arch("llama3.2-1b").reduced()
    from repro.models import init_cache

    def nbytes(cfg):
        cache = jax.eval_shape(lambda: init_cache(cfg, 2, 512))
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.tree.leaves(cache))
    ratio = nbytes(cfg_q) / nbytes(cfg_b)
    assert ratio < 0.6, ratio    # int8 + f32 scales ~ 0.52x of bf16


def test_fsdp_rules_shard_weights_over_data():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import FSDP_RULES, choose_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    # clean divisibility: ffn dim takes both axes -> 256-way on one dim
    s = choose_spec((4096, 16384), ("embed_tp", "ffn"), FakeMesh(),
                    FSDP_RULES)
    assert s == P(None, ("model", "data")), s
    # 13696 % 256 != 0: ffn keeps model, the embed dim soaks up data —
    # still 256-way total
    s1 = choose_spec((4096, 13696), ("embed_tp", "ffn"), FakeMesh(),
                     FSDP_RULES)
    assert s1 == P("data", "model"), s1
    # expert tensor: experts->model, ffn falls back to data
    s2 = choose_spec((16, 4096, 6400), ("experts", None, "ffn"), FakeMesh(),
                     FSDP_RULES)
    assert s2 == P("model", None, "data"), s2


def test_window_skip_matches_full_mask():
    """Block-skipped local attention == dense-masked reference at every
    (window, chunk) geometry."""
    from repro.models.layers import flash_attention_xla
    rng = np.random.default_rng(3)
    B_, H, D, T = 1, 2, 8, 96
    q = jnp.asarray(rng.standard_normal((B_, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B_, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B_, T, H, D)), jnp.float32)
    for window in (8, 16, 40):
        got = flash_attention_xla(q, k, v, causal=True, window=window,
                                  bq=32, bk=16)
        qf, kf, vf = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        lg = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / np.sqrt(D)
        i = np.arange(T)[:, None]
        j = np.arange(T)[None, :]
        mask = (j <= i) & ((i - j) < window)
        lg = jnp.where(jnp.asarray(mask), lg, -1e30)
        want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(lg, -1),
                          vf).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
