"""Component tests: MoE dispatch, RG-LRU scan vs step, sharding rules,
RoPE/M-RoPE, chunked attention vs reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import blocks as B
from repro.models.layers import flash_attention_xla
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# Chunked attention == reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,window", [(64, None), (100, None), (64, 16),
                                      (200, 32)])
def test_flash_xla_vs_ref(T, window):
    rng = np.random.default_rng(0)
    B_, H, Hkv, D = 2, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B_, T, H, D)), jnp.float32) * 0.4
    k = jnp.asarray(rng.standard_normal((B_, T, Hkv, D)), jnp.float32) * 0.4
    v = jnp.asarray(rng.standard_normal((B_, T, Hkv, D)), jnp.float32) * 0.4
    got = flash_attention_xla(q, k, v, causal=True, window=window, bq=32,
                              bk=32)
    # reference: repeat kv + dense mask
    kf = jnp.repeat(k, H // Hkv, 2).transpose(0, 2, 1, 3)
    vf = jnp.repeat(v, H // Hkv, 2).transpose(0, 2, 1, 3)
    qf = q.transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / np.sqrt(D)
    i = np.arange(T)[:, None]
    j = np.arange(T)[None, :]
    mask = j <= i
    if window is not None:
        mask &= (i - j) < window
    logits = jnp.where(jnp.asarray(mask), logits, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), vf)
    want = want.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(4, 90), bq=st.sampled_from([16, 32, 64]),
       bk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 99))
def test_flash_xla_block_invariance(t, bq, bk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    a = flash_attention_xla(q, k, v, bq=bq, bk=bk)
    b = flash_attention_xla(q, k, v, bq=t, bk=t)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(E=4, k=2, cap=4.0):
    return ModelConfig(name="t", family="moe", n_layers=2, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                       head_dim=8, block_pattern=("moe",),
                       moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=32,
                                     capacity_factor=cap))


def test_moe_matches_dense_computation():
    """With ample capacity, sort-based dispatch == direct per-token loop."""
    cfg = _moe_cfg()
    rng = np.random.default_rng(1)
    N, d = 24, cfg.d_model
    p = {
        "router": jnp.asarray(rng.standard_normal((d, 4)), jnp.float32) * .5,
        "w_gate": jnp.asarray(rng.standard_normal((4, d, 32)), jnp.float32) * .2,
        "w_up": jnp.asarray(rng.standard_normal((4, d, 32)), jnp.float32) * .2,
        "w_down": jnp.asarray(rng.standard_normal((4, 32, d)), jnp.float32) * .2,
    }
    x = jnp.asarray(rng.standard_normal((N, d)), jnp.float32)
    got, probs = B.moe_ffn(cfg, p, x)

    # reference: explicit loop
    pr = jax.nn.softmax(x @ p["router"], -1)
    want = np.zeros((N, d), np.float32)
    for n in range(N):
        top = np.argsort(-np.asarray(pr[n]))[:2]
        g = np.asarray(pr[n])[top]
        g = g / g.sum()
        for e, w in zip(top, g):
            h = jax.nn.silu(x[n] @ p["w_gate"][e]) * (x[n] @ p["w_up"][e])
            want[n] += w * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(E=4, k=1, cap=0.3)
    rng = np.random.default_rng(2)
    N, d = 64, cfg.d_model
    p = {
        "router": jnp.zeros((d, 4), jnp.float32)   # uniform -> argmax expert 0
        .at[:, 0].set(1.0),
        "w_gate": jnp.ones((4, d, 32), jnp.float32) * 0.1,
        "w_up": jnp.ones((4, d, 32), jnp.float32) * 0.1,
        "w_down": jnp.ones((4, 32, d), jnp.float32) * 0.1,
    }
    x = jnp.asarray(rng.standard_normal((N, d)), jnp.float32)
    out, _ = B.moe_ffn(cfg, p, x)
    # all tokens route to expert 0 with capacity ~ 0.3*N/4 -> most dropped
    n_zero = int(jnp.sum(jnp.all(out == 0.0, axis=-1)))
    assert n_zero > N // 2


def test_moe_aux_loss_balanced_vs_skewed():
    cfg = _moe_cfg()
    E = 4
    bal = jnp.full((32, E), 1.0 / E)
    skew = jnp.zeros((32, E)).at[:, 0].set(1.0)
    assert float(B.moe_aux_loss(skew, cfg)) > float(B.moe_aux_loss(bal, cfg))


# ---------------------------------------------------------------------------
# RG-LRU: associative scan == sequential reference; step == scan
# ---------------------------------------------------------------------------

def test_rglru_assoc_scan_vs_sequential():
    rng = np.random.default_rng(3)
    Bs, T, D = 2, 20, 8
    a = jnp.asarray(rng.uniform(0.2, 0.99, (Bs, T, D)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((Bs, T, D)), jnp.float32)

    def combine(l, r):
        a1, u1 = l
        a2, u2 = r
        return a1 * a2, u1 * a2 + u2
    _, hs = jax.lax.associative_scan(combine, (a, u), axis=1)

    h = np.zeros((Bs, D), np.float32)
    for t in range(T):
        h = np.asarray(a[:, t]) * h + np.asarray(u[:, t])
        np.testing.assert_allclose(np.asarray(hs[:, t]), h, atol=1e-5)


def test_sharding_rules_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding import choose_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    # mesh of size 1: everything "divides"; check axis assignment priority
    s = choose_spec((64, 128), ("embed_tp", "ffn"), mesh)
    assert s == P(None, "model"), s          # ffn outranks embed_tp
    s2 = choose_spec((64, 128), ("embed_tp", None), mesh)
    assert s2 == P("model", None), s2        # fallback used when free

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
    # 40 heads % 16 != 0 -> replicated; ffn takes model
    s3 = choose_spec((40, 128), ("heads", "ffn"), FakeMesh())
    assert s3 == P(None, "model"), s3
    # batch takes (pod, data) when divisible by 32
    s4 = choose_spec((256, 4096), ("batch", None), FakeMesh())
    assert s4 == P(("pod", "data"), None), s4
    # batch 8: divisible by pod(2) only -> pod prefix
    s5 = choose_spec((8, 4), ("batch", None), FakeMesh())
    assert s5 == P("pod", None), s5


def test_mrope_differs_from_rope_and_matches_on_text():
    from repro.models.layers import apply_mrope, apply_rope
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 6, 2, 16)), jnp.float32)
    pos = jnp.arange(6, dtype=jnp.int32)[None]
    p3_text = jnp.stack([pos, pos, pos], -1)     # text: t == h == w
    a = apply_rope(x, pos)
    b = apply_mrope(x, p3_text)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    p3_img = jnp.stack([pos, pos * 0, pos * 2], -1)
    c = apply_mrope(x, p3_img)
    assert not np.allclose(np.asarray(a), np.asarray(c))
