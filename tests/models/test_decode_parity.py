"""Decode-vs-forward parity: prefill(T) + decode(token T) must reproduce a
full forward over T+1 tokens, for every block family (the cache/state
machinery correctness proof)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Batch, decode_step, forward, init_params
from repro.models.model import last_logits

FAMILIES = ["llama3.2-1b", "qwen3-4b", "glm4-9b", "recurrentgemma-2b",
            "xlstm-350m", "phi3.5-moe-42b-a6.6b",
            "llama4-maverick-400b-a17b", "qwen2-vl-72b", "musicgen-medium",
            "qwen3-14b"]


def _inputs(cfg, B, T, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio_stub":
        toks = jnp.asarray(rng.integers(0, cfg.vocab,
                                        (B, T, cfg.n_codebooks)), jnp.int32)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    pos = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    if cfg.rope == "mrope":
        pos = jnp.stack([pos, pos // 7, pos % 7], axis=-1)
    vis = None
    if cfg.frontend == "vision_stub":
        vis = jnp.asarray(rng.standard_normal((B, T // 8, cfg.d_model)),
                          jnp.bfloat16) * 0.05
    return toks, pos, vis


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_forward(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, T = 2, 16
    toks, pos, vis = _inputs(cfg, B, T + 1)

    x, _ = forward(cfg, params, Batch(tokens=toks, positions=pos,
                                      vis_embeds=vis))
    want = last_logits(cfg, params, x)

    S = T + 4
    vis_p = vis[:, :T // 8] if vis is not None else None
    x2, _, states = forward(cfg, params,
                            Batch(tokens=toks[:, :T], positions=pos[:, :T],
                                  vis_embeds=vis_p),
                            return_states=True, cache_len=S)
    got, _cache = decode_step(
        cfg, params, states,
        Batch(tokens=toks[:, T:T + 1], positions=pos[:, T:T + 1],
              cache_index=jnp.int32(T), cache_len=jnp.int32(T + 1)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.2,
                               rtol=0.05)


def test_multi_token_greedy_decode_consistency():
    """Greedy decode 6 tokens == argmax of successive full forwards."""
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, T, N = 1, 12, 6
    toks, pos, _ = _inputs(cfg, B, T)
    S = T + N + 1
    x, _, cache = forward(cfg, params, Batch(tokens=toks, positions=pos),
                          return_states=True, cache_len=S)
    cur = jnp.argmax(last_logits(cfg, params, x)[:, -1], -1).astype(jnp.int32)
    seq = toks
    decoded = [int(cur[0])]
    for i in range(N - 1):
        p = T + i
        lg, cache = decode_step(
            cfg, params, cache,
            Batch(tokens=cur[:, None],
                  positions=jnp.full((B, 1), p, jnp.int32),
                  cache_index=jnp.int32(p), cache_len=jnp.int32(p + 1)))
        cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
        decoded.append(int(cur[0]))

    # reference: grow the sequence and re-run full forwards
    seq_ref = toks
    ref = []
    for i in range(N):
        posr = jnp.arange(seq_ref.shape[1], dtype=jnp.int32)[None]
        x, _ = forward(cfg, params, Batch(tokens=seq_ref, positions=posr))
        nxt = int(jnp.argmax(last_logits(cfg, params, x)[0, -1]))
        ref.append(nxt)
        seq_ref = jnp.concatenate(
            [seq_ref, jnp.full((1, 1), nxt, jnp.int32)], axis=1)
    assert decoded == ref, (decoded, ref)


def test_local_attention_ring_buffer():
    """Decode far past the window: ring buffer must keep only the last
    `window` tokens and still match a full forward."""
    cfg = get_arch("recurrentgemma-2b").reduced()
    assert cfg.attn_window == 64 or cfg.attn_window is not None
    params = init_params(cfg, jax.random.PRNGKey(4))
    B = 1
    T = cfg.attn_window + 9       # prompt longer than the window
    toks, pos, _ = _inputs(cfg, B, T + 1, seed=5)
    x, _ = forward(cfg, params, Batch(tokens=toks, positions=pos))
    want = last_logits(cfg, params, x)
    x2, _, cache = forward(cfg, params,
                           Batch(tokens=toks[:, :T], positions=pos[:, :T]),
                           return_states=True, cache_len=T + 4)
    got, _ = decode_step(
        cfg, params, cache,
        Batch(tokens=toks[:, T:T + 1], positions=pos[:, T:T + 1],
              cache_index=jnp.int32(T), cache_len=jnp.int32(T + 1)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.2,
                               rtol=0.05)
