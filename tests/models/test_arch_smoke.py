"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, get_arch, valid_cells
from repro.data import DataConfig, make_batch
from repro.models import Batch, forward, init_params, logits_and_loss
from repro.models.model import last_logits
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_init

ARCHS = sorted(all_archs())


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_no_nans(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(seq_len=32, global_batch=2)
    batch = make_batch(cfg, dc, step=0)
    x, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert x.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))
    lg = last_logits(cfg, params, x)
    if cfg.frontend == "audio_stub":
        assert lg.shape == (2, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_runs_and_is_finite(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = train_init(cfg, params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    dc = DataConfig(seq_len=32, global_batch=2)
    state, m = step(state, make_batch(cfg, dc, step=0))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


def test_loss_decreases_multi_step():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=100)
    state = train_init(cfg, params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    dc = DataConfig(seq_len=32, global_batch=4)
    losses = []
    for i in range(10):
        state, m = step(state, make_batch(cfg, dc, step=i))
        losses.append(float(m["loss"]))
    assert min(losses[-3:]) < losses[0], losses


def test_microbatching_matches_full_batch():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = DataConfig(seq_len=16, global_batch=4)
    batch = make_batch(cfg, dc, step=0)
    s1 = train_init(cfg, params, opt_cfg)
    s2 = train_init(cfg, params, opt_cfg)
    full = jax.jit(make_train_step(cfg, opt_cfg, microbatch=1))
    micro = jax.jit(make_train_step(cfg, opt_cfg, microbatch=2))
    s1, m1 = full(s1, batch)
    s2, m2 = micro(s2, batch)
    # same gradient direction/magnitude up to accumulation-order noise
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    a = np.asarray(jax.tree.leaves(s1.params)[0], np.float32)
    b = np.asarray(jax.tree.leaves(s2.params)[0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-3)


def test_valid_cells_count():
    cells = valid_cells()
    # 10 archs x 3 shapes + 2 sub-quadratic archs on long_500k
    assert len(cells) == 32, len(cells)
    assert ("recurrentgemma-2b", "long_500k") in cells
    assert ("xlstm-350m", "long_500k") in cells
    assert ("qwen3-4b", "long_500k") not in cells


def test_exact_assigned_configs():
    """The registered configs carry exactly the assigned hyperparameters."""
    spec = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_arch(name)
        assert cfg.n_layers == L and cfg.d_model == d, name
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, name
        assert (cfg.d_ff == ff) and cfg.vocab == v, name
