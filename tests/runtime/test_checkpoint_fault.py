"""Checkpoint/restart, elastic resharding, straggler monitor, gradient
compression tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              reshard_state, save_checkpoint)
from repro.configs import get_arch
from repro.data import DataConfig, make_batch
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.runtime import (FaultConfig, StragglerMonitor,
                           make_int8_compressor, run_with_restarts)
from repro.train import make_train_step, train_init


@pytest.fixture
def small():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    return cfg, params, opt_cfg


def test_checkpoint_roundtrip(tmp_path, small):
    cfg, params, opt_cfg = small
    state = train_init(cfg, params, opt_cfg)
    p = save_checkpoint(str(tmp_path / "ck"), 7, state, {"arch": cfg.name})
    restored, manifest = load_checkpoint(p, like=state)
    assert manifest["step"] == 7 and manifest["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_overwrite(tmp_path, small):
    cfg, params, opt_cfg = small
    state = train_init(cfg, params, opt_cfg)
    p = str(tmp_path / "ck")
    save_checkpoint(p, 1, state)
    save_checkpoint(p, 2, state)      # overwrite must not corrupt
    _, manifest = load_checkpoint(p)
    assert manifest["step"] == 2


def test_manager_rolling_gc(tmp_path, small):
    cfg, params, opt_cfg = small
    state = train_init(cfg, params, opt_cfg)
    man = CheckpointManager(str(tmp_path), interval=2, keep=2)
    for s in range(1, 9):
        man.maybe_save(s, state)
    assert man.all_steps() == [6, 8]


def test_run_with_restarts_recovers(tmp_path, small):
    """A step that crashes twice must resume from checkpoint and finish."""
    cfg, params, opt_cfg = small
    state0 = train_init(cfg, params, opt_cfg)
    dc = DataConfig(seq_len=16, global_batch=2)
    raw = jax.jit(make_train_step(cfg, opt_cfg))
    crashes = {"left": 2}

    def make_step():
        def step(state, batch):
            state, m = raw(state, batch)
            if int(state.opt.step) == 5 and crashes["left"] > 0:
                crashes["left"] -= 1
                raise RuntimeError("injected node failure")
            return state, m
        return step

    man = CheckpointManager(str(tmp_path), interval=2, keep=3)
    state, hist = run_with_restarts(
        make_step=make_step, init_state=state0,
        data_for_step=lambda s: make_batch(cfg, dc, s),
        n_steps=8, manager=man, cfg=FaultConfig(max_restarts=5,
                                                ckpt_interval=2))
    assert hist["restarts"] == 2
    assert int(state.opt.step) >= 8


def test_restart_determinism(tmp_path, small):
    """Crash-and-resume must land on the same final params as a clean run
    (pure step + deterministic data => exact recovery)."""
    cfg, params, opt_cfg = small
    dc = DataConfig(seq_len=16, global_batch=2)
    raw = jax.jit(make_train_step(cfg, opt_cfg))

    # clean run
    clean = train_init(cfg, params, opt_cfg)
    for s in range(6):
        clean, _ = raw(clean, make_batch(cfg, dc, s))

    # checkpoint at 4 (interval=4): crash at 5, resume from 4, replay 4..5
    crashed = {"done": False}

    def make_step():
        def step(state, batch):
            state, m = raw(state, batch)
            if int(state.opt.step) == 5 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("boom")
            return state, m
        return step

    man = CheckpointManager(str(tmp_path), interval=4, keep=2)
    state, _ = run_with_restarts(
        make_step=make_step, init_state=train_init(cfg, params, opt_cfg),
        data_for_step=lambda s: make_batch(cfg, dc, s),
        n_steps=6, manager=man)
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_monitor_flags_persistent_slowdowns():
    clock = {"t": 0.0}
    times = iter([1.0, 1.0, 1.0, 5.0, 5.0, 1.0])   # EMA ~1.0, two 5s steps

    def fake_clock():
        return clock["t"]

    mon = StragglerMonitor(FaultConfig(straggler_factor=3.0,
                                       straggler_patience=2),
                           clock=fake_clock)
    fired = []
    for i, dt in enumerate(times):
        mon.start_step()
        clock["t"] += dt
        fired.append(mon.end_step(i))
    assert fired[3] is False and fired[4] is True   # fires on 2nd slow step
    assert len(mon.events) == 2


def test_elastic_reshard_roundtrip(small):
    """Restore the same logical state under a different mesh shape."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg, params, opt_cfg = small
    mesh_a = jax.make_mesh((1, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mesh_b = jax.make_mesh((1,), ("data",),
                           axis_types=(jax.sharding.AxisType.Auto,))
    sh_a = jax.tree.map(lambda _: NamedSharding(mesh_a, P()), params)
    sh_b = jax.tree.map(lambda _: NamedSharding(mesh_b, P()), params)
    pa = reshard_state(params, sh_a)
    pb = reshard_state(pa, sh_b)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_int8_compression_error_feedback():
    transform, init_res = make_int8_compressor()
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64, 64)), jnp.float32)}
    res = init_res(g)
    total = jax.tree.map(jnp.zeros_like, g)
    # accumulated compressed grads converge to accumulated true grads
    for _ in range(20):
        cg, res = transform(g, res)
        total = jax.tree.map(jnp.add, total, cg)
    want = g["w"] * 20
    err = float(jnp.max(jnp.abs(total["w"] - want))) / float(
        jnp.max(jnp.abs(want)))
    assert err < 0.05, err
    # single-shot quantization error is bounded by the int8 step size
    cg, _ = transform(g, init_res(g))
    step = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(cg["w"] - g["w"]))) <= step * 1.01
