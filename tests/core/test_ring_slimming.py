"""Windowed-ring slimming: the split (dense last-issue table + compact
windowed ring) must be observationally identical to the old layout — a
``max_window``-deep ring for EVERY (node, cmd) pair — while carrying a
fraction of the scan state.

The reference implementation here maintains that full dense ring in plain
numpy and derives the earliest-ready table from it exactly as the
pre-split engine did; hypothesis drives random constraint tables (random
prev/next/level/latency/window rows recompiled through
``build_windowed_rings``) and random issue sequences through both."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorator stand-ins so the
        return lambda f: f              # module still collects (the tests

    def given(**kw):                    # themselves are skipped below)
        return lambda f: f

    class st:                           # noqa: N801 - mirrors the real name
        @staticmethod
        def integers(*a, **kw):
            return None
needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

from repro.core import compile_spec
from repro.core import device as D
from repro.core.compile import build_windowed_rings

NEG = int(D.NEG)


# ---------------------------------------------------------------------------
# Reference: the pre-split dense-ring layout in numpy
# ---------------------------------------------------------------------------

class DenseRingRef:
    """(num_nodes, n_cmds, max_window) ring for every pair — the layout the
    split replaced."""

    def __init__(self, cspec):
        self.cspec = cspec
        W = max(int(np.max(cspec.ct_win)) if len(cspec.ct_win) else 1, 1)
        self.W = W
        self.ring = np.full((cspec.num_nodes, cspec.n_cmds, W), NEG,
                            np.int64)

    def issue(self, cmd: int, sub, clk: int):
        cs = self.cspec
        nodes, flat = [0], 0
        for i in range(1, len(cs.level_counts)):
            flat = flat * int(cs.level_counts[i]) + int(sub[i - 1])
            nodes.append(int(cs.level_offsets[i]) + flat)
        for lvl in range(int(cs.cmd_scope[cmd]) + 1):
            r = self.ring[nodes[lvl], cmd]
            r[1:] = r[:-1]
            r[0] = clk

    def earliest_table(self):
        cs = self.cspec
        node_counts = np.cumprod(np.asarray(cs.level_counts, np.int64))
        table = np.full((cs.n_cmds, cs.n_banks), NEG, np.int64)
        for i in range(len(cs.ct_prev)):
            p, f = int(cs.ct_prev[i]), int(cs.ct_next[i])
            level, w = int(cs.ct_level[i]), int(cs.ct_win[i]) - 1
            if level > int(cs.cmd_scope[p]):
                continue
            n_l = int(node_counts[level])
            off = int(cs.level_offsets[level])
            t_nodes = self.ring[off:off + n_l, p, w]
            t_banks = np.repeat(t_nodes, cs.n_banks // n_l)
            allowed = np.where(t_banks > NEG, t_banks + int(cs.ct_lat[i]),
                               NEG)
            table[f] = np.maximum(table[f], allowed)
        return table


def random_constraint_spec(base, rng, n_rows: int):
    """Replace the base spec's constraint table with random rows (windows
    1..4 over random levels/commands) and re-plan the windowed rings."""
    L, C = len(base.levels), base.n_cmds
    prev = rng.integers(0, C, n_rows).astype(np.int32)
    nxt = rng.integers(0, C, n_rows).astype(np.int32)
    level = rng.integers(0, L, n_rows).astype(np.int32)
    lat = rng.integers(1, 60, n_rows).astype(np.int32)
    win = np.where(rng.random(n_rows) < 0.3,
                   rng.integers(2, 5, n_rows), 1).astype(np.int32)
    rings = build_windowed_rings(prev, level, win, base.cmd_scope,
                                 base.level_counts, base.level_offsets)
    return dataclasses.replace(
        base, ct_prev=prev, ct_next=nxt, ct_level=level, ct_lat=lat,
        ct_win=win, max_window=int(win.max()) if n_rows else 1, **rings)


def _random_issues(cspec, rng, n: int):
    counts = cspec.level_counts
    out = []
    for k in range(n):
        sub = [int(rng.integers(int(counts[i])))
               for i in range(1, len(counts))]
        out.append((int(rng.integers(cspec.n_cmds)), sub,
                    int(rng.integers(1, 120)) + 120 * k))
    return out


def _check_table_matches_reference(seed: int, n_rows: int, n_issues: int):
    rng = np.random.default_rng(seed)
    base = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    cspec = random_constraint_spec(base, rng, n_rows)
    dp = D.dyn_params(cspec)

    ref = DenseRingRef(cspec)
    state = D.init_state(cspec)
    for cmd, sub, clk in _random_issues(cspec, rng, n_issues):
        ref.issue(cmd, sub, clk)
        state = D.issue(cspec, dp, state, jnp.int32(cmd),
                        jnp.asarray(sub, jnp.int32), jnp.int32(3),
                        jnp.int32(clk), jnp.asarray(True))

    got = np.asarray(D.earliest_ready_table(cspec, dp, state), np.int64)
    want = ref.earliest_table()
    # the split engine clamps "no constraint" to NEG; the reference's
    # max() accumulation starts there too, so exact equality is required
    np.testing.assert_array_equal(got, want)


@needs_hypothesis
@given(seed=st.integers(0, 2**31 - 1), n_rows=st.integers(1, 24),
       n_issues=st.integers(1, 30))
def test_split_table_ring_matches_dense_ring_reference(seed, n_rows,
                                                       n_issues):
    _check_table_matches_reference(seed, n_rows, n_issues)


@pytest.mark.parametrize("seed", range(12))
def test_split_table_ring_matches_dense_ring_reference_seeded(seed):
    """Deterministic fallback sweep of the same property, so the reference
    comparison also runs where hypothesis is unavailable."""
    rng = np.random.default_rng(1000 + seed)
    _check_table_matches_reference(int(rng.integers(2**31)),
                                   int(rng.integers(1, 25)),
                                   int(rng.integers(1, 31)))


def test_scalar_earliest_ready_matches_reference(seed=7):
    rng = np.random.default_rng(seed)
    base = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    cspec = random_constraint_spec(base, rng, 16)
    dp = D.dyn_params(cspec)
    ref = DenseRingRef(cspec)
    state = D.init_state(cspec)
    for cmd, sub, clk in _random_issues(cspec, rng, 20):
        ref.issue(cmd, sub, clk)
        state = D.issue(cspec, dp, state, jnp.int32(cmd),
                        jnp.asarray(sub, jnp.int32), jnp.int32(3),
                        jnp.int32(clk), jnp.asarray(True))
    table = ref.earliest_table()
    counts = cspec.level_counts
    for _ in range(8):
        sub = [int(rng.integers(int(counts[i])))
               for i in range(1, len(counts))]
        bank = 0
        for i in range(1, len(counts)):
            bank = bank * int(counts[i]) + sub[i - 1]
        for cmd in range(cspec.n_cmds):
            got = int(D.earliest_ready(cspec, dp, state, jnp.int32(cmd),
                                       jnp.asarray(sub, jnp.int32)))
            assert got == int(table[cmd, bank]), (cmd, sub)


# ---------------------------------------------------------------------------
# Layout invariants + the carry-size claim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("std,org,tim", [
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
])
def test_ring_plan_invariants(std, org, tim):
    cs = compile_spec(std, org, tim)
    node_counts = np.cumprod(np.asarray(cs.level_counts, np.int64))
    total = 0
    for p, level, off, n_l in cs.ring_pairs:
        assert level <= int(cs.cmd_scope[p])
        assert n_l == int(node_counts[level])
        assert off == total                  # contiguous blocks, in order
        np.testing.assert_array_equal(cs.ring_cmd[off:off + n_l], p)
        np.testing.assert_array_equal(
            cs.ring_node[off:off + n_l],
            int(cs.level_offsets[level]) + np.arange(n_l))
        total += n_l
    assert cs.n_ring == total
    for i in range(len(cs.ct_prev)):
        if int(cs.ct_win[i]) > 1 \
                and int(cs.ct_level[i]) <= int(cs.cmd_scope[cs.ct_prev[i]]):
            assert cs.ct_ring[i] >= 0
        else:
            assert cs.ct_ring[i] == -1
        assert int(cs.ct_win[i]) <= cs.ring_depth or cs.ct_ring[i] == -1


@pytest.mark.parametrize("std,org,tim", [
    ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
])
def test_carry_bytes_reduced_at_least_3x(std, org, tim):
    """The acceptance criterion: DDR5/HBM3 timing-state carry shrinks >= 3x
    vs the dense-ring layout."""
    cs = compile_spec(std, org, tim)
    assert cs.max_window >= 4                # tFAW ring depth
    slim = D.carry_nbytes(cs)
    dense = D.dense_ring_nbytes(cs)
    assert dense >= 3 * slim, (std, dense, slim)
