"""Property tests: the vectorized JAX device model must agree with the
scalar numpy oracle (DeviceUnderTest) on arbitrary legal command sequences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DeviceUnderTest, compile_spec, get_standard
from repro.core import ControllerConfig
from repro.core import device as D

STANDARDS = [("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
             ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
             ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
             ("HBM3", "HBM3_16Gb", "HBM3_5200"),
             ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32")]


def _mirror(cspec, dut_cmds):
    """Replay a command sequence on the JAX device model."""
    dp = D.dyn_params(cspec)
    state = D.init_state(cspec)
    for clk, cmd, addr in dut_cmds:
        sub = jnp.asarray([addr[lv] for lv in cspec.levels[1:]], jnp.int32)
        state = D.issue(cspec, dp, state, jnp.int32(cspec.cmd_id(cmd)), sub,
                        jnp.int32(addr["row"]), jnp.int32(clk),
                        jnp.asarray(True))
    return dp, state


@pytest.mark.parametrize("std,org,tim", STANDARDS)
def test_earliest_ready_agrees_after_random_replay(std, org, tim):
    rng = np.random.default_rng(0)
    dut = DeviceUnderTest(std, org, tim)
    cspec = dut.cspec

    # issue a random but state-legal command sequence via the DUT
    clk = 0
    for _ in range(60):
        sub = {lv: int(rng.integers(int(cspec.level_counts[i + 1])))
               for i, lv in enumerate(cspec.levels[1:])}
        addr = dict(sub, row=int(rng.integers(64)), col=0)
        req = "WR" if rng.random() < 0.3 else "RD"
        r = dut.probe(req, addr, clk=clk)
        cmd = r.preq
        pr = dut.probe(cmd, addr, clk=clk)
        if pr.timing_OK:
            # ACT2 must target the pending row
            if cmd == "ACT2":
                addr = dict(addr, row=int(dut.act1_row[dut._bank(addr)]))
            dut.issue(cmd, addr, clk=clk)
        clk += int(rng.integers(1, 8))

    assert len(dut.history) > 10, "oracle never issued — test is vacuous"
    dp, state = _mirror(cspec, dut.history)

    # row states agree
    np.testing.assert_array_equal(np.asarray(state.row_state), dut.row_state)

    # earliest-ready agrees for every command at a set of probe addresses
    for _ in range(20):
        sub = {lv: int(rng.integers(int(cspec.level_counts[i + 1])))
               for i, lv in enumerate(cspec.levels[1:])}
        addr = dict(sub, row=int(rng.integers(64)), col=0)
        sub_v = jnp.asarray([addr[lv] for lv in cspec.levels[1:]], jnp.int32)
        for cmd in cspec.cmd_names:
            want = dut.earliest(cmd, addr)
            got = int(D.earliest_ready(cspec, dp, state,
                                       jnp.int32(cspec.cmd_id(cmd)), sub_v))
            assert got == want, (std, cmd, addr, got, want)


@settings(max_examples=20)     # deadline/derandomize come from the shared
@given(seed=st.integers(0, 2**31 - 1),    # profile in tests/conftest.py
       n=st.integers(5, 40))
def test_hypothesis_ddr4_replay(seed, n):
    rng = np.random.default_rng(seed)
    dut = DeviceUnderTest("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    cspec = dut.cspec
    clk = 0
    for _ in range(n):
        addr = dut.addr_vec(Rank=int(rng.integers(1)),
                            BankGroup=int(rng.integers(4)),
                            Bank=int(rng.integers(4)),
                            Row=int(rng.integers(32)), Column=0)
        cmd = dut.probe("RD" if rng.random() < 0.7 else "WR", addr, clk).preq
        if dut.probe(cmd, addr, clk).timing_OK:
            dut.issue(cmd, addr, clk=clk)
        clk += int(rng.integers(1, 20))
    if not dut.history:
        return
    dp, state = _mirror(cspec, dut.history)
    np.testing.assert_array_equal(np.asarray(state.row_state), dut.row_state)
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0,
                        Row=int(rng.integers(32)), Column=0)
    sub_v = jnp.asarray([addr[lv] for lv in cspec.levels[1:]], jnp.int32)
    for cmd in ("ACT", "RD", "WR", "PRE", "REFab"):
        want = dut.earliest(cmd, addr)
        got = int(D.earliest_ready(cspec, dp, state,
                                   jnp.int32(cspec.cmd_id(cmd)), sub_v))
        assert got == want


def test_prereq_parity_all_states():
    """prereq decisions agree between oracle and JAX model in every
    reachable bank state (closed / activating / open-hit / open-miss)."""
    for std, org, tim in STANDARDS:
        dut = DeviceUnderTest(std, org, tim)
        cspec = dut.cspec
        dp = D.dyn_params(cspec)
        addr = {lv: 0 for lv in cspec.levels[1:]}
        addr.update(row=5, col=0)
        seqs = {
            "closed": [],
            "open_hit": ([("ACT1", 0), ("ACT2", 4)] if cspec.split_activation
                         else [("ACT", 0)]),
            "open_miss": ([("ACT1", 0), ("ACT2", 4)] if cspec.split_activation
                          else [("ACT", 0)]),
        }
        if cspec.split_activation:
            seqs["activating"] = [("ACT1", 0)]
        for label, seq in seqs.items():
            d = DeviceUnderTest(std, org, tim)
            state = D.init_state(cspec)
            for cmd, clk in seq:
                a = dict(addr) if label != "open_miss" else dict(addr)
                d.issue(cmd, a, clk=clk)
                sub = jnp.asarray([a[lv] for lv in cspec.levels[1:]], jnp.int32)
                state = D.issue(cspec, dp, state, jnp.int32(cspec.cmd_id(cmd)),
                                sub, jnp.int32(a["row"]), jnp.int32(clk),
                                jnp.asarray(True))
            probe_addr = dict(addr, row=9) if label == "open_miss" else addr
            clk = 200
            want = d.probe("RD", probe_addr, clk=clk).preq
            sub = jnp.asarray([probe_addr[lv] for lv in cspec.levels[1:]],
                              jnp.int32)
            got_cmd, _, _ = D.prereq(cspec, dp, state, jnp.asarray(False),
                                     sub, jnp.int32(probe_addr["row"]),
                                     jnp.int32(clk))
            got = cspec.cmd_names[int(got_cmd)]
            assert got == want, (std, label, got, want)
