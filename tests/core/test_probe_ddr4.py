"""Fine-grained DDR4 probe test — reproduces the paper's Listing 2."""
import pytest

from repro.core import DeviceUnderTest

pytestmark = pytest.mark.device_timings


@pytest.fixture
def dut():
    return DeviceUnderTest("DDR4", org_preset="DDR4_8Gb_x8",
                           timing_preset="DDR4_2400R")


def test_listing2_rd_blocked_until_act_and_nrcd(dut):
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=12, Column=0)

    # Probe the states of the DRAM for a RD command at cycle 0
    closed = dut.probe("RD", addr, clk=0)
    assert closed.preq == "ACT"          # prerequisite command is ACT
    assert closed.timing_OK is True      # no ACT issued yet -> timing is OK
    assert closed.ready is False         # prerequisite not met

    dut.issue("ACT", addr, clk=0)

    # Before nRCD: row state correct for RD but timing still blocks it
    early = dut.probe("RD", addr, clk=dut.timings["nRCD"] - 1)
    assert early.preq == "RD"
    assert early.timing_OK is False
    assert early.ready is False
    assert early.row_hit is True
    assert early.row_open is True

    # At nRCD the same command becomes legal
    ontime = dut.probe("RD", addr, clk=dut.timings["nRCD"])
    assert ontime.preq == "RD"
    assert ontime.timing_OK is True
    assert ontime.ready is True


def test_row_miss_requires_precharge(dut):
    addr = dut.addr_vec(Rank=0, BankGroup=1, Bank=2, Row=7, Column=0)
    dut.issue("ACT", addr, clk=0)
    other = dict(addr, row=9)
    r = dut.probe("RD", other, clk=100)
    assert r.preq == "PRE"
    assert r.row_hit is False and r.row_open is True
    dut.issue("PRE", other, clk=100)
    r2 = dut.probe("RD", other, clk=100 + dut.timings["nRP"] - 1)
    assert r2.preq == "ACT"   # closed again


def test_nrc_act_to_act_same_bank(dut):
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=1, Row=1, Column=0)
    dut.issue("ACT", addr, clk=0)
    dut.issue("PRE", addr, clk=dut.timings["nRAS"])
    clk_ok = dut.timings["nRC"]
    assert dut.probe("ACT", addr, clk=clk_ok - 1).timing_OK is False
    assert dut.probe("ACT", addr, clk=clk_ok).timing_OK is True


def test_nfaw_window(dut):
    # 4 ACTs to distinct banks; the 5th must wait for nFAW
    t = 0
    for b in range(4):
        addr = dut.addr_vec(Rank=0, BankGroup=b, Bank=0, Row=1, Column=0)
        assert dut.probe("ACT", addr, clk=t).timing_OK
        dut.issue("ACT", addr, clk=t)
        t += dut.timings["nRRD_S"]
    fifth = dut.addr_vec(Rank=0, BankGroup=0, Bank=3, Row=1, Column=0)
    assert dut.probe("ACT", fifth, clk=t).timing_OK is False
    assert dut.probe("ACT", fifth, clk=dut.timings["nFAW"]).timing_OK is True


def test_bankgroup_ccd_long_vs_short(dut):
    a_same_bg = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=1, Column=0)
    b_same_bg = dut.addr_vec(Rank=0, BankGroup=0, Bank=1, Row=1, Column=0)
    c_diff_bg = dut.addr_vec(Rank=0, BankGroup=1, Bank=0, Row=1, Column=0)
    for addr in (a_same_bg, b_same_bg, c_diff_bg):
        dut.issue("ACT", addr, clk=0)
    t = dut.timings["nRCD"]
    dut.issue("RD", a_same_bg, clk=t)
    # same bank group: nCCD_L applies; different group: nCCD_S
    assert dut.probe("RD", b_same_bg, clk=t + dut.timings["nCCD_S"]).timing_OK is False
    assert dut.probe("RD", b_same_bg, clk=t + dut.timings["nCCD_L"]).timing_OK is True
    assert dut.probe("RD", c_diff_bg, clk=t + dut.timings["nCCD_S"]).timing_OK is True


def test_write_to_precharge(dut):
    addr = dut.addr_vec(Rank=0, BankGroup=2, Bank=0, Row=3, Column=0)
    dut.issue("ACT", addr, clk=0)
    t = dut.timings["nRCD"]
    dut.issue("WR", addr, clk=t)
    wait = dut.timings["nCWL"] + dut.timings["nBL"] + dut.timings["nWR"]
    assert dut.probe("PRE", addr, clk=t + wait - 1).timing_OK is False
    assert dut.probe("PRE", addr, clk=t + wait).timing_OK is True
