"""Address-mapper round-trip property tests (satellite of the
multi-channel memory-system refactor): ``addr -> fields -> addr`` and
``fields -> addr -> fields`` must be exact for every mapper order in
``MAPPERS``, across org presets and channel counts, and the traced
in-engine decode must agree with the host-side mapper."""
import numpy as np
import pytest

from repro.core import compile_spec
from repro.core.addrmap import MAPPERS, AddressMapper, make_layout

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def given(**kw):
        return lambda f: f

    class st:                           # noqa: N801 - mirrors the real name
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

PRESETS = [
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
    ("GDDR6", "GDDR6_8Gb_x16", "GDDR6_16"),
]


def _capacity_lines(mapper: AddressMapper) -> int:
    n = 1
    for _, count in mapper.layout:
        n *= count
    return n


@pytest.mark.parametrize("std,org,tim", PRESETS)
@pytest.mark.parametrize("order", MAPPERS)
@pytest.mark.parametrize("channels", [1, 2, 4])
def test_addr_fields_addr_roundtrip(std, org, tim, order, channels):
    cspec = compile_spec(std, org, tim, channels=channels)
    m = AddressMapper(cspec, order)
    cap = _capacity_lines(m)
    rng = np.random.default_rng(sum(map(ord, std + order)) + channels)
    lines = rng.integers(0, min(cap, 1 << 40), 4096)
    addrs = (lines.astype(np.int64) << m.tx_bits)
    fields = m.map(addrs)
    assert np.array_equal(m.encode(fields), addrs)
    # every field stays within its radix
    for (name, count) in m.layout:
        f = fields[name]
        assert (f >= 0).all() and (f < count).all(), (name, count)
    assert int(fields["channel"].max()) <= channels - 1


@pytest.mark.parametrize("order", MAPPERS)
def test_fields_addr_fields_roundtrip(order):
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
    m = AddressMapper(cspec, order)
    rng = np.random.default_rng(7)
    fields = {name: rng.integers(0, count, 2048)
              for name, count in m.layout}
    back = m.map(m.encode(fields))
    for name in fields:
        assert np.array_equal(back[name], fields[name]), name


def test_channel_field_width_follows_spec():
    """The docstring's old sin: the channel field was pinned to one.  It
    must now follow ``compile_spec(..., channels=N)``."""
    for channels in (1, 2, 8):
        cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                             channels=channels)
        layout = dict(make_layout(cspec, "RoBaRaCoCh"))
        assert layout["channel"] == channels


def test_engine_decode_matches_host_mapper():
    """The frontend's in-scan mixed-radix decode of the linear request
    counter must agree field-for-field with the host-side AddressMapper."""
    import jax
    import jax.numpy as jnp

    from repro.core import frontend as F

    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    order = "RoBaRaCoCh"
    m = AddressMapper(cspec, order)
    layout = make_layout(cspec, order)
    seqs = np.arange(0, 50_000, 17, dtype=np.int32)

    decode = jax.jit(jax.vmap(lambda q: F._seq_addr(cspec, layout, q)))
    chan, sub, row, col = decode(jnp.asarray(seqs))

    addrs = seqs.astype(np.int64) << m.tx_bits
    w_chan, w_sub, w_row, w_col = m.to_chan_sub_row_col(addrs)
    np.testing.assert_array_equal(np.asarray(chan), w_chan)
    np.testing.assert_array_equal(np.asarray(sub), w_sub)
    np.testing.assert_array_equal(np.asarray(row), w_row)
    np.testing.assert_array_equal(np.asarray(col), w_col)


@needs_hypothesis
@given(line=st.integers(0, (1 << 40) - 1), order=st.sampled_from(MAPPERS))
def test_roundtrip_hypothesis(line, order):
    """Hypothesis drives single-address round-trips under the shared
    profile from ``tests/conftest.py`` (no per-test settings needed:
    deadlines and CI derandomization are configured once, globally)."""
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
    m = AddressMapper(cspec, order)
    addr = np.asarray([(line % _capacity_lines(m)) << m.tx_bits], np.int64)
    fields = m.map(addr)
    assert np.array_equal(m.encode(fields), addr)
    for name, count in m.layout:
        assert 0 <= int(fields[name][0]) < count


def test_roundtrip_rng_fixture(rng):
    """Fallback sweep on the seeded ``rng`` fixture (explicit, stable
    per-test seed) where hypothesis is unavailable."""
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
    for order in MAPPERS:
        m = AddressMapper(cspec, order)
        lines = rng.integers(0, min(_capacity_lines(m), 1 << 40), 512)
        addrs = lines.astype(np.int64) << m.tx_bits
        assert np.array_equal(m.encode(m.map(addrs)), addrs)


def test_bad_order_rejected():
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    with pytest.raises(ValueError):
        make_layout(cspec, "RoBaRaCo")       # missing the channel token
    with pytest.raises(ValueError):
        make_layout(cspec, "RoBaRaCoCo")     # duplicate token
