"""System-level validation (paper §4, Fig. 1): latency-throughput knees."""
import numpy as np
import pytest

from repro.core import (FrontendConfig, Simulator, avg_probe_latency_ns,
                        peak_gbps, throughput_gbps)


@pytest.mark.slow
def test_knee_curve_ddr4():
    """Latency must be flat at low load and blow up near saturation, and
    achieved throughput must approach the theoretical peak."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    points = []
    for interval in (64.0, 16.0, 8.0, 4.0, 2.0, 1.0):
        stats = sim.run(20000, interval=interval, read_ratio=1.0)
        points.append((throughput_gbps(sim.cspec, stats),
                       avg_probe_latency_ns(sim.cspec, stats)))
    tput = [p[0] for p in points]
    lat = [p[1] for p in points]
    assert all(np.isfinite(lat)), points
    # monotone non-decreasing throughput as load rises
    assert all(tput[i] <= tput[i + 1] * 1.05 for i in range(len(tput) - 1))
    # knee: saturated latency well above idle latency
    assert lat[-1] > 2.0 * lat[0], points
    # peak achieved (probes + refresh cost a few %)
    assert tput[-1] >= 0.85 * peak_gbps(sim.cspec), points


def test_dse_batch_matches_single_runs():
    """vmap'd DSE engine == per-point runs (same seeds, same stats)."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    pts, batch = sim.run_batch(3000, intervals=[8.0, 2.0],
                               read_ratios=[1.0, 0.5])
    assert len(pts) == 4
    for i, (interval, rr) in enumerate(pts):
        single = sim.run(3000, interval=interval, read_ratio=rr)
        assert int(batch.reads_done[i]) == int(single.reads_done)
        assert int(batch.probe_lat_sum[i]) == int(single.probe_lat_sum)


def test_dse_batch_scales():
    sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200",
                    frontend=FrontendConfig(probes=False))
    pts, batch = sim.run_batch(1500, intervals=[16, 8, 4, 2, 1],
                               read_ratios=[1.0, 0.8, 0.6])
    assert batch.reads_done.shape == (15,)
    tp = [throughput_gbps(sim.cspec, _at(batch, i)) for i in range(15)]
    assert max(tp) > 0


def _at(stats, i):
    import jax
    return jax.tree.map(lambda a: a[i], stats)
