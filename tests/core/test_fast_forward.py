"""Event-horizon fast-forward: bit-exactness and horizon safety.

Two complementary oracles pin the fast-forward engine:

* the classic per-cycle scan (``fast_forward=False``) executes EVERY
  cycle, so whole-run equality of stats, dense command streams, and
  windowed telemetry proves no skipped cycle could have issued anything
  — across standards, random constraint tables, and bursty/paced replay
  streams;
* the scalar ``DeviceUnderTest`` cross-checks the horizon computation
  directly: for states reached through random legal command histories,
  every cycle below ``channel_horizon`` must be issue-incapable per the
  oracle's own ``earliest``/``prereq`` semantics (queue candidates and
  the refresh engine both).
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorator stand-ins so the
        return lambda f: f              # module still collects

    def given(**kw):
        return lambda f: f

    class st:                           # noqa: N801 - mirrors the real name
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

        @staticmethod
        def booleans(*a, **kw):
            return None

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

import jax
import jax.numpy as jnp

from repro.core import (ControllerConfig, DeviceUnderTest, FrontendConfig,
                        Simulator, compile_spec, compile_system)
from repro.core import controller as C
from repro.core import device as D
from repro.dse.spec import DEFAULT_SYSTEMS
from repro.trace import capture, to_replay

DDR4 = ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")


def _strip(stats) -> dict:
    """to_dict minus the step accounting (differs by design with ff on)."""
    d = stats.to_dict()
    d.pop("scan_steps")
    d.pop("skipped_cycles")
    return d


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _telemetry_equal(a, b):
    assert a.window == b.window and a.n_cycles == b.n_cycles
    np.testing.assert_array_equal(a.t_end, b.t_end)
    for ga, gb in zip(a.groups, b.groups):
        _trees_equal(dataclasses.asdict(ga), dataclasses.asdict(gb))


def _pair(*args, **kw):
    """(fast-forward, per-cycle) Simulator twins of one configuration."""
    return (Simulator(*args, fast_forward=True, **kw),
            Simulator(*args, fast_forward=False, **kw))


# ---------------------------------------------------------------------------
# whole-run equality vs the per-cycle engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_low_load_ff_equality_every_standard(standard):
    """Low-load run (the regime fast-forward targets): stats and the
    dense command stream must be bit-identical with ff on vs off for
    every registered standard, and ff must actually skip cycles."""
    org, tim = DEFAULT_SYSTEMS[standard]
    on, off = _pair(standard, org, tim,
                    controller=ControllerConfig(scheduler="FRFCFS"))
    n = 1500
    s_on, tr_on = on.run(n, interval=48.0, read_ratio=0.7, trace=True)
    s_off, tr_off = off.run(n, interval=48.0, read_ratio=0.7, trace=True)
    assert _strip(s_on) == _strip(s_off), standard
    _trees_equal(tr_on, tr_off)
    assert int(s_on.skipped_cycles) > 0, standard       # ff engaged
    assert int(s_on.scan_steps) + int(s_on.skipped_cycles) == n
    assert int(s_off.skipped_cycles) == 0
    assert int(s_off.scan_steps) == n


def test_ff_equality_four_channel_with_telemetry():
    msys = compile_system([dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
                                timing_preset="DDR4_2400R", channels=4)])
    on, off = _pair(system=msys, channel_shard=False)
    s_on, tr_on, tm_on = on.run(2000, interval=24.0, trace=True,
                                telemetry=256)
    s_off, tr_off, tm_off = off.run(2000, interval=24.0, trace=True,
                                    telemetry=256)
    assert _strip(s_on) == _strip(s_off)
    _trees_equal(tr_on, tr_off)
    _telemetry_equal(tm_on, tm_off)
    assert int(s_on.skipped_cycles) > 0


def test_ff_equality_hetero_with_telemetry():
    """DDR5 + CXL-attached DDR4: group-indexed scan, link-latency arrive
    gate in the horizon, merged-namespace telemetry."""
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=1),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=1, link_latency=40),
    ])
    on, off = _pair(system=msys, channel_shard=False)
    s_on, tr_on, tm_on = on.run(2000, interval=24.0, trace=True,
                                telemetry=256)
    s_off, tr_off, tm_off = off.run(2000, interval=24.0, trace=True,
                                    telemetry=256)
    assert _strip(s_on) == _strip(s_off)
    _trees_equal(tr_on, tr_off)
    _telemetry_equal(tm_on, tm_off)
    assert int(s_on.skipped_cycles) > 0


def test_ff_equality_probes_and_random_pattern():
    for fcfg in (FrontendConfig(probes=True),
                 FrontendConfig(pattern="random")):
        on, off = _pair(*DDR4, frontend=fcfg)
        s_on = on.run(2000, interval=48.0, seed=11)
        s_off = off.run(2000, interval=48.0, seed=11)
        assert _strip(s_on) == _strip(s_off), fcfg
        assert int(s_on.skipped_cycles) > 0, fcfg


def test_ff_saturated_load_still_exact():
    """At saturation there is nothing to skip — ff must degrade to the
    per-cycle program's results (near-zero skips, identical stats)."""
    on, off = _pair(*DDR4)
    s_on = on.run(2000, interval=1.0)
    s_off = off.run(2000, interval=1.0)
    assert _strip(s_on) == _strip(s_off)


def _check_constraint_table_ff_equality(drcd, drp, dras, drrd, interval):
    """Random constraint tables: inflate core timings by random deltas
    (stretching the earliest-ready horizon arbitrarily) — the ff run
    must still match the per-cycle oracle command for command."""
    base = compile_spec(*DDR4).timings
    ov = {"nRCD": int(base["nRCD"]) + drcd,
          "nRP": int(base["nRP"]) + drp,
          "nRAS": int(base["nRAS"]) + dras,
          "nRRD_S": int(base["nRRD_S"]) + drrd,
          "nRRD_L": int(base["nRRD_L"]) + drrd}
    on, off = _pair(*DDR4, timing_overrides=ov)
    s_on, tr_on = on.run(1500, interval=interval, read_ratio=0.7,
                         trace=True)
    s_off, tr_off = off.run(1500, interval=interval, read_ratio=0.7,
                            trace=True)
    assert _strip(s_on) == _strip(s_off), ov
    _trees_equal(tr_on, tr_off)


@pytest.mark.parametrize("drcd,drp,dras,drrd,interval", [
    (7, 3, 19, 2, 48.0),
    (0, 12, 0, 6, 96.0),
    (12, 0, 24, 0, 16.0),
])
def test_constraint_tables_ff_equality(drcd, drp, dras, drrd, interval):
    _check_constraint_table_ff_equality(drcd, drp, dras, drrd, interval)


@needs_hypothesis
@settings(max_examples=6)
@given(drcd=st.integers(0, 12), drp=st.integers(0, 12),
       dras=st.integers(0, 24), drrd=st.integers(0, 6),
       interval=st.sampled_from([16.0, 48.0, 96.0]))
def test_random_constraint_tables_ff_equality(drcd, drp, dras, drrd,
                                              interval):
    _check_constraint_table_ff_equality(drcd, drp, dras, drrd, interval)


def _check_bursty_paced_replay_ff_equality(seed, deps, src_interval):
    """Paced/dep'd ReplayStream traffic (bursty inter-arrival gaps from
    the source run): the arrival-horizon's paced term and the dep-hold
    no-skip rule must reproduce the per-cycle run exactly."""
    src = Simulator(*DDR4)
    _, dense = src.run(1200, interval=src_interval, read_ratio=0.5,
                       seed=seed, trace=True)
    tr = capture(src.cspec, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, src.cspec, deps=deps)
    on, off = _pair(*DDR4, replay=rs,
                    frontend=FrontendConfig(pattern="trace", probes=False))
    s_on, tr_on = on.run(3000, trace=True, seed=seed)
    s_off, tr_off = off.run(3000, trace=True, seed=seed)
    assert _strip(s_on) == _strip(s_off)
    _trees_equal(tr_on, tr_off)


@pytest.mark.parametrize("seed,deps,src_interval", [
    (3, True, 32.0), (41, False, 8.0)])
def test_bursty_paced_replay_ff_equality(seed, deps, src_interval):
    _check_bursty_paced_replay_ff_equality(seed, deps, src_interval)


@needs_hypothesis
@settings(max_examples=4)
@given(seed=st.integers(0, 2**31 - 1), deps=st.booleans(),
       src_interval=st.sampled_from([8.0, 32.0]))
def test_random_bursty_paced_replay_ff_equality(seed, deps, src_interval):
    _check_bursty_paced_replay_ff_equality(seed, deps, src_interval)


# ---------------------------------------------------------------------------
# horizon safety vs the scalar DeviceUnderTest oracle
# ---------------------------------------------------------------------------


def _random_dut_history(dut, rng, n=40):
    """Drive a random but state-legal command sequence through the DUT."""
    cspec = dut.cspec
    clk = 0
    for _ in range(n):
        sub = {lv: int(rng.integers(int(cspec.level_counts[i + 1])))
               for i, lv in enumerate(cspec.levels[1:])}
        addr = dict(sub, row=int(rng.integers(64)), col=0)
        req = "WR" if rng.random() < 0.3 else "RD"
        cmd = dut.probe(req, addr, clk=clk).preq
        if dut.probe(cmd, addr, clk=clk).timing_OK:
            if cmd == "ACT2":
                addr = dict(addr, row=int(dut.act1_row[dut._bank(addr)]))
            dut.issue(cmd, addr, clk=clk)
        clk += int(rng.integers(1, 6))
    return clk


def _mirror_state(cspec, history):
    dp = D.dyn_params(cspec)
    state = D.init_state(cspec)
    for clk, cmd, addr in history:
        sub = jnp.asarray([addr[lv] for lv in cspec.levels[1:]], jnp.int32)
        state = D.issue(cspec, dp, state, jnp.int32(cspec.cmd_id(cmd)), sub,
                        jnp.int32(addr["row"]), jnp.int32(clk),
                        jnp.asarray(True))
    return dp, state


def _check_horizon_never_skips_issuable_cycle(seed):
    """The core safety property, against the scalar oracle: from a state
    reached by a random legal history with a random pending queue, every
    cycle in ``[clk, channel_horizon)`` must be issue-incapable — no
    queue slot's candidate command is timing-ready per ``DUT.earliest``,
    and no refresh unit is both due and ready."""
    rng = np.random.default_rng(seed)
    dut = DeviceUnderTest(*DDR4)
    cspec = dut.cspec
    clk = _random_dut_history(dut, rng)
    assert len(dut.history) > 5, "oracle never issued — vacuous draw"
    dp, state = _mirror_state(cspec, dut.history)

    # random pending queue over random banks/rows
    depth = 8
    nsub = len(cspec.levels) - 1
    valid = np.zeros(depth, bool)
    is_write = np.zeros(depth, bool)
    subs = np.zeros((depth, nsub), np.int32)
    rows = np.zeros(depth, np.int32)
    slots = []
    for i in range(int(rng.integers(1, 6))):
        sub = {lv: int(rng.integers(int(cspec.level_counts[j + 1])))
               for j, lv in enumerate(cspec.levels[1:])}
        valid[i] = True
        is_write[i] = rng.random() < 0.3
        subs[i] = [sub[lv] for lv in cspec.levels[1:]]
        rows[i] = int(rng.integers(64))
        slots.append((dict(sub, row=int(rows[i]), col=0),
                      "WR" if is_write[i] else "RD"))

    cs = C.init_ctrl_state(cspec, depth)
    cs = cs._replace(
        dev=state,
        queue=cs.queue._replace(
            valid=jnp.asarray(valid), is_write=jnp.asarray(is_write),
            sub=jnp.asarray(subs), row=jnp.asarray(rows),
            arrive=jnp.full((depth,), clk, jnp.int32)))
    cfg = ControllerConfig()
    h = int(C.channel_horizon(cspec, dp, cfg, cs, jnp.int32(clk)))
    assert h >= clk

    nrefi = int(dut.timings["nREFI"])
    banks_per_ru = cspec.n_banks // cspec.n_refresh_units
    last_ref = np.asarray(state.last_ref)
    row_state = np.asarray(state.row_state)
    for t in range(clk, min(h, clk + 1200)):
        for addr, req in slots:
            cand = dut.probe(req, addr, clk=t).preq
            assert dut.earliest(cand, addr) > t, \
                (seed, t, h, req, cand, addr)
        for ru in range(cspec.n_refresh_units):
            if t < int(last_ref[ru]) + nrefi:
                continue                     # not due yet: cannot fire
            unit = row_state[ru * banks_per_ru:(ru + 1) * banks_per_ru]
            ref_cmd = "PREab" if (unit != D.ROW_CLOSED).any() else "REFab"
            rep = {lv: 0 for lv in cspec.levels[1:]}
            rep[cspec.levels[1]] = ru
            rep = dict(rep, row=0, col=0)
            assert dut.earliest(ref_cmd, rep) > t, (seed, t, h, ref_cmd, ru)


@pytest.mark.parametrize("seed", range(8))
def test_horizon_never_skips_issuable_cycle(seed):
    _check_horizon_never_skips_issuable_cycle(seed)


@needs_hypothesis
@settings(max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_horizon_never_skips_issuable_cycle(seed):
    _check_horizon_never_skips_issuable_cycle(seed)


def _check_horizon_conservative_not_stuck(seed):
    """Liveness companion: with a non-empty queue the horizon is finite
    (some candidate eventually becomes ready — the engine can never
    fast-forward to infinity past pending work)."""
    rng = np.random.default_rng(seed)
    dut = DeviceUnderTest(*DDR4)
    cspec = dut.cspec
    clk = _random_dut_history(dut, rng, n=20)
    dp, state = _mirror_state(cspec, dut.history)
    cs = C.init_ctrl_state(cspec, 4)
    cs = cs._replace(
        dev=state,
        queue=cs.queue._replace(valid=jnp.asarray([True, False, False,
                                                   False])))
    h = int(C.channel_horizon(cspec, dp, ControllerConfig(), cs,
                              jnp.int32(clk)))
    assert clk <= h < clk + 10 * int(dut.timings["nREFI"])


@pytest.mark.parametrize("seed", range(6))
def test_horizon_conservative_not_stuck(seed):
    _check_horizon_conservative_not_stuck(seed)


@needs_hypothesis
@settings(max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_horizon_conservative_not_stuck(seed):
    _check_horizon_conservative_not_stuck(seed)
