"""Sharded channel axis: the shard_map path must be a pure placement
refactor.  Under ``--xla_force_host_platform_device_count=4`` the
channel-sharded scan has to reproduce the golden command-stream hashes
and match the vmap path bit for bit — stats, dense trace, and windowed
telemetry included.

XLA device-count forcing only takes effect before the backend
initializes, so every multi-device check runs in a subprocess that sets
``XLA_FLAGS`` before importing jax (same idiom as
``tests/launch/test_dryrun_small.py``)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def _run(code: str, timeout: int = 900) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # the snippet pins its own device count
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import hashlib
import json
import numpy as np
import jax
from repro.core import ControllerConfig, Simulator, compile_system
from repro.core import engine as E
from repro.trace import capture
from repro.trace.capture import FIELDS

assert jax.device_count() == 4, jax.device_count()
GOLDEN = json.load(open("tests/trace/golden_hashes.json"))

def sha(tr, extra=()):
    h = hashlib.sha256()
    for f in FIELDS + tuple(extra):
        h.update(np.ascontiguousarray(getattr(tr, f), np.int32).tobytes())
    return h.hexdigest()
"""


def test_sharded_channels_bit_exact_four_devices():
    """DDR4@2ch golden hash on the sharded path, DDR4@4ch sharded==vmap
    (stats + trace + telemetry), hetero DDR5+CXL-DDR4 golden hash, and
    RunCache key/topology accounting — all under 4 forced host devices."""
    out = _run(PRELUDE + r"""
# ---- 2-channel DDR4: auto shard d=2 must reproduce the golden hash
sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                mapper="RoBaRaCoCh",
                controller=ControllerConfig(refresh_stagger=False))
assert sim._resolved_shard() == 2, sim._resolved_shard()
_, dense = sim.run(3000, interval=2.0, read_ratio=0.7, trace=True)
tr = capture(sim.cspec, dense)
want = GOLDEN["DDR4@2ch"]
assert len(tr) == want["n"], (len(tr), want["n"])
assert sha(tr) == want["sha256"], "DDR4@2ch sharded hash mismatch"

# ---- 4-channel: sharded vs vmap bit-exact incl. refresh stagger and
# windowed telemetry (the conservation checker must still balance)
sim4s = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
sim4v = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4,
                  channel_shard=False)
assert sim4s._resolved_shard() == 4
ss, ys_s, tel_s = sim4s.run(3000, interval=1.0, read_ratio=0.7, trace=True,
                            telemetry=256)
sv, ys_v, tel_v = sim4v.run(3000, interval=1.0, read_ratio=0.7, trace=True,
                            telemetry=256)
for f in ("cmd", "bank", "row", "arrive", "hit_ready"):
    assert np.array_equal(getattr(ys_s, f), getattr(ys_v, f)), f
for k in ("reads_done", "writes_done", "probe_lat_sum", "probe_cnt",
          "data_bus_busy", "deferred"):
    assert np.array_equal(getattr(ss.per_channel, k),
                          getattr(sv.per_channel, k)), k
assert np.array_equal(ss.cmd_counts, sv.cmd_counts)
assert int(ss.cycles) == int(sv.cycles)
tel_s.check(ss)

# ---- heterogeneous DDR5x2 + CXL-DDR4x2@80: groups shard in lockstep
msys = compile_system([
    dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
         timing_preset="DDR5_4800B", channels=2),
    dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
         timing_preset="DDR4_2400R", channels=2, link_latency=80),
])
simh = Simulator(system=msys, controller=ControllerConfig(scheduler="FRFCFS"))
assert simh._resolved_shard() == 2, simh._resolved_shard()
_, dense = simh.run(3000, interval=2.0, read_ratio=0.7, trace=True)
tr = capture(msys, dense)
want = GOLDEN["DDR5x2+DDR4x2@80"]
assert len(tr) == want["n"], (len(tr), want["n"])
assert sha(tr, ("group",)) == want["sha256"], "hetero sharded hash mismatch"

# ---- RunCache: sharded and vmapped programs must not alias, and the
# stats view must report the mesh topology
k_v = E.run_key(sim4v.cspec, sim4v.controller, sim4v.frontend, 3000, True,
                False)
k_s = E.run_key(sim4s.cspec, sim4s.controller, sim4s.frontend, 3000, True,
                False, shard=4)
assert k_v != k_s
st = E.RUN_CACHE.stats()
assert st["devices"] == 4
assert any(t.startswith("channels:") for t in st["shard_topologies"])
assert "vmap" in st["shard_topologies"]
print("SHARDED-OK")
""")
    assert "SHARDED-OK" in out


def test_single_device_auto_is_vmap_and_explicit_shard_raises():
    """On the default single-device backend auto-sharding stays on the
    vmap path, an explicit channel_shard=True raises a clear error, and
    batched runs refuse to compose with channel sharding."""
    from repro.core import ControllerConfig, Simulator
    from repro.core import engine as E
    import jax

    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    if jax.device_count() == 1:
        assert sim._resolved_shard() is None
        simr = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4,
                         channel_shard=True)
        with pytest.raises(ValueError, match="device"):
            simr.run(200)
    # single-channel systems can never channel-shard, whatever the mesh
    sim1 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    assert E.auto_channel_shard(sim1.cspec, n_devices=4) is None
    # shard= composes with scalar runs only
    with pytest.raises(ValueError, match="scalar"):
        E.RUN_CACHE.get(sim.cspec, sim.controller, sim.frontend, 200,
                        trace=False, batched=True, shard=2)


@pytest.mark.slow
def test_all_standards_sharded_vs_vmap_four_devices():
    """All registered standards: (a) the 1-channel golden hashes are
    untouched by multi-device visibility (auto shard stays on vmap),
    (b) at channels=4 the sharded and vmap paths agree bit for bit."""
    out = _run(PRELUDE + r"""
from repro.dse.spec import DEFAULT_SYSTEMS

for standard in sorted(DEFAULT_SYSTEMS):
    org, tim = DEFAULT_SYSTEMS[standard]
    # 1ch: auto-shard resolves to None; golden hash must be unchanged
    sim = Simulator(standard, org, tim,
                    controller=ControllerConfig(scheduler="FRFCFS"))
    assert sim._resolved_shard() is None
    _, dense = sim.run(3000, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(sim.cspec, dense)
    want = GOLDEN[standard]
    assert len(tr) == want["n"], (standard, len(tr), want["n"])
    assert sha(tr) == want["sha256"], standard

    # 4ch: sharded (d=4) vs vmap pairwise bit-exactness
    s4s = Simulator(standard, org, tim, channels=4)
    s4v = Simulator(standard, org, tim, channels=4, channel_shard=False)
    assert s4s._resolved_shard() == 4
    ss, ys_s = s4s.run(1500, interval=2.0, read_ratio=0.7, trace=True)
    sv, ys_v = s4v.run(1500, interval=2.0, read_ratio=0.7, trace=True)
    for f in ("cmd", "bank", "row", "arrive", "hit_ready"):
        assert np.array_equal(getattr(ys_s, f), getattr(ys_v, f)), \
            (standard, f)
    for k in ("reads_done", "writes_done", "data_bus_busy", "deferred"):
        assert np.array_equal(getattr(ss.per_channel, k),
                              getattr(sv.per_channel, k)), (standard, k)
    assert np.array_equal(ss.cmd_counts, sv.cmd_counts), standard
    print(standard, "ok", flush=True)
print("ALL-STANDARDS-OK")
""", timeout=3600)
    assert "ALL-STANDARDS-OK" in out
