"""Spec authoring + "codegen" (compile-to-tables) tests, incl. VRR Listing 1."""
import numpy as np
import pytest

from repro.core import all_standards, compile_spec, get_standard
from repro.core.compile import resolve_latency


def _first_presets(std):
    return next(iter(std.org_presets)), next(iter(std.timing_presets))


@pytest.mark.parametrize("name", sorted(all_standards()))
def test_compile_every_standard(name):
    std = get_standard(name)
    org, tim = _first_presets(std)
    cs = compile_spec(std, org, tim)
    assert cs.n_cmds == len(std.commands)
    assert cs.num_nodes >= 1 + cs.n_refresh_units + cs.n_banks
    assert len(cs.ct_prev) == len(cs.ct_lat) > 0
    assert (cs.ct_lat >= 0).all()
    assert cs.max_window >= 1
    assert cs.access_bytes > 0 and cs.peak_bytes_per_cycle > 0
    # every constraint references valid commands/levels
    assert cs.ct_prev.max() < cs.n_cmds and cs.ct_next.max() < cs.n_cmds
    assert cs.ct_level.max() < len(cs.levels)


def test_resolve_latency_expressions():
    t = {"nCL": 16, "nBL": 4, "nCWL": 12, "nWR": 18}
    assert resolve_latency("nCL", t) == 16
    assert resolve_latency("nCWL+nBL+nWR", t) == 34
    assert resolve_latency("nCL+nBL+2-nCWL", t) == 10
    assert resolve_latency("nBL+2", t) == 6
    assert resolve_latency(7, t) == 7
    with pytest.raises(ValueError):
        resolve_latency("", t)


def test_vrr_extension_listing1():
    """DDR5_VRR: the paper's 18-line extension pattern."""
    vrr = get_standard("DDR5_VRR")
    ddr5 = get_standard("DDR5")
    assert vrr.commands == ddr5.commands + ["VRR"]
    assert "nVRR" in vrr.timing_params
    assert len(vrr.timing_constraints) == len(ddr5.timing_constraints) + 3
    # nVRR derived from tCK per preset: ceil(280ns / tCK)
    for name, t in vrr.timing_presets.items():
        assert t["nVRR"] == -(-280_000 // t["tCK_ps"])
    cs = compile_spec(vrr, *_first_presets(vrr))
    assert "VRR" in cs.cmd_names


def test_vrr_timing_semantics():
    from repro.core import DeviceUnderTest
    dut = DeviceUnderTest("DDR5_VRR", "DDR5_16Gb_x8", "DDR5_4800B")
    addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=5, Column=0)
    dut.issue("VRR", addr, clk=0)
    nvrr = dut.timings["nVRR"]
    assert dut.probe("ACT", addr, clk=nvrr - 1).timing_OK is False
    assert dut.probe("ACT", addr, clk=nvrr).timing_OK is True


def test_user_extension_subclass():
    """Authoring a variant at runtime (paper §3.2) requires only appends."""
    from repro.core.spec import Command, TimingConstraint, KIND_ROW
    ddr4 = get_standard("DDR4")

    class DDR4_NOP(ddr4):
        name = "DDR4_NOP_test"
        command_meta = dict(ddr4.command_meta,
                            NOP=Command("NOP", "bank", KIND_ROW))
        commands = ddr4.commands + ["NOP"]
        timing_params = ddr4.timing_params + ["nNOP"]
        timing_constraints = list(ddr4.timing_constraints) + [
            TimingConstraint("bank", ["NOP"], ["ACT"], "nNOP")]
        timing_presets = {k: dict(v, nNOP=3)
                          for k, v in ddr4.timing_presets.items()}

    cs = compile_spec(DDR4_NOP, "DDR4_8Gb_x8", "DDR4_2400R")
    assert "NOP" in cs.cmd_names
    i = list(cs.ct_prev).index(cs.cmd_id("NOP"))
    assert cs.ct_lat[i] == 3


def test_timing_overrides():
    cs = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      timing_overrides={"nRCD": 99})
    assert cs.timings["nRCD"] == 99


def test_loc_table_spirit():
    """Standards must stay compact (the paper's Table-1 claim)."""
    import inspect
    for name in ("DDR5", "LPDDR5", "HBM3", "GDDR7"):
        src = inspect.getsource(get_standard(name))
        loc = len([l for l in src.splitlines()
                   if l.strip() and not l.strip().startswith("#")])
        assert loc < 60, f"{name} spec ballooned to {loc} lines"
