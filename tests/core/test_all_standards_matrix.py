"""Cross-standard timing matrix: the classic JEDEC constraints must hold on
EVERY modeled standard (fine-grained Listing-2 probes, parameterized)."""
import pytest

from repro.core import DeviceUnderTest, all_standards, get_standard

CASES = [(name, next(iter(std.org_presets)), next(iter(std.timing_presets)))
         for name, std in sorted(all_standards().items())]


def _dut(name, org, tim):
    return DeviceUnderTest(name, org, tim)


def _addr(dut, **kw):
    base = {lv: 0 for lv in dut.cspec.levels[1:]}
    base.update(row=kw.get("row", 3), col=0)
    for k, v in kw.items():
        if k in base:
            base[k] = v
    return base


def _open_row(dut, addr, clk=0):
    """Issue the (possibly split) activation; returns first legal RD clk."""
    cs = dut.cspec
    if cs.split_activation:
        dut.issue("ACT1", addr, clk=clk)
        t2 = clk + dut.timings["nAAD_MIN"]
        dut.issue("ACT2", addr, clk=t2)
        return t2 + dut.timings["nRCD"]
    dut.issue("ACT", addr, clk=clk)
    return clk + dut.timings["nRCD"]


@pytest.mark.device_timings
@pytest.mark.parametrize("name,org,tim", CASES)
class TestEveryStandard:
    def test_rd_needs_activation_then_nrcd(self, name, org, tim):
        dut = _dut(name, org, tim)
        addr = _addr(dut)
        r = dut.probe("RD", addr, clk=0)
        assert r.preq in ("ACT", "ACT1") and r.ready is False
        t = _open_row(dut, addr)
        assert dut.probe("RD", addr, clk=t - 1).timing_OK is False
        ok = dut.probe("RD", addr, clk=t)
        assert ok.timing_OK is True and ok.row_hit is True

    def test_row_conflict_needs_precharge(self, name, org, tim):
        dut = _dut(name, org, tim)
        addr = _addr(dut, row=3)
        _open_row(dut, addr)
        other = dict(addr, row=9)
        assert dut.probe("RD", other, clk=500).preq == "PRE"

    def test_precharge_respects_nras(self, name, org, tim):
        dut = _dut(name, org, tim)
        addr = _addr(dut)
        cs = dut.cspec
        opener_clk = 0
        if cs.split_activation:
            dut.issue("ACT1", addr, clk=0)
            opener_clk = dut.timings["nAAD_MIN"]
            dut.issue("ACT2", addr, clk=opener_clk)
        else:
            dut.issue("ACT", addr, clk=0)
        nras = dut.timings["nRAS"]
        assert dut.probe("PRE", addr, clk=opener_clk + nras - 1).timing_OK \
            is False
        assert dut.probe("PRE", addr, clk=opener_clk + nras).timing_OK is True

    def test_refresh_blocks_activation_for_nrfc(self, name, org, tim):
        dut = _dut(name, org, tim)
        addr = _addr(dut)
        dut.issue("REFab", addr, clk=0)
        opener = "ACT1" if dut.cspec.split_activation else "ACT"
        nrfc = dut.timings["nRFC"]
        assert dut.probe(opener, addr, clk=nrfc - 1).timing_OK is False
        assert dut.probe(opener, addr, clk=nrfc).timing_OK is True

    def test_faw_window_on_opener(self, name, org, tim):
        dut = _dut(name, org, tim)
        cs = dut.cspec
        opener = "ACT1" if cs.split_activation else "ACT"
        # 4 activations to distinct banks at the min legal spacing
        banks = []
        counts = {lv: int(cs.level_counts[i + 1])
                  for i, lv in enumerate(cs.levels[1:])}
        for bg in range(counts.get("bankgroup", 1)):
            for b in range(counts["bank"]):
                banks.append((bg, b))
        if len(banks) < 5:
            pytest.skip("not enough banks for a FAW test")
        t = 0
        for i in range(4):
            bg, b = banks[i]
            a = _addr(dut, bankgroup=bg, bank=b, row=1)
            while not dut.probe(opener, a, clk=t).timing_OK:
                t += 1
            dut.issue(opener, a, clk=t)
        bg, b = banks[4]
        fifth = _addr(dut, bankgroup=bg, bank=b, row=1)
        e = dut.earliest(opener, fifth)
        assert e >= dut.timings["nFAW"], (name, e)

    def test_write_read_turnaround(self, name, org, tim):
        dut = _dut(name, org, tim)
        addr = _addr(dut)
        t = _open_row(dut, addr)
        cs = dut.cspec
        if cs.data_clock_sync:   # bring the data clock up first
            sync = "RCKSTRT" if cs.id_RCKSTRT >= 0 else "CAS_WR"
            dut.issue(sync, addr, clk=t)
            t += dut.timings.get("nWCKEN", dut.timings.get("nRCKEN", 2))
        dut.issue("WR", addr, clk=t)
        wtr = dut.timings["nCWL"] + dut.timings["nBL"] + dut.timings["nWTR_S"]
        assert dut.probe("RD", addr, clk=t + wtr - 1).timing_OK is False

    def test_peak_bytes_positive_and_describe(self, name, org, tim):
        std = get_standard(name)
        info = std.describe()
        assert info["name"] == name
        assert info["n_constraints"] > 15
        dut = _dut(name, org, tim)
        assert dut.cspec.peak_bytes_per_cycle > 0
        # per-device access granularity: dq x burst / 8
        assert 8 <= dut.cspec.access_bytes <= 128
