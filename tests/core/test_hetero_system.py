"""Heterogeneous memory-system tests (PR 5 tentpole acceptance):
per-spec channel groups behind one mapper — compile-once, group-indexed
scan state, CXL link latency, group-correct metrics, mixed-radix system
address mapping, and the 1-group ≡ channels=N equivalence property."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ControllerConfig, FrontendConfig, MemorySystemSpec,
                        ReplayStream, Simulator, as_system,
                        channel_breakdown, compile_spec, compile_system,
                        peak_gbps, throughput_gbps)
from repro.core import engine as E
from repro.core.addrmap import MAPPERS, SystemAddressMapper
from repro.dse.spec import DEFAULT_SYSTEMS
from repro.trace import audit, capture, load, save, to_replay
from repro.trace.capture import FIELDS


def _ddr5_ddr4(link: int = 80, channels=(2, 2)) -> MemorySystemSpec:
    return compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=channels[0]),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=channels[1],
             link_latency=link),
    ])


# ---------------------------------------------------------------------------
# Acceptance: 2-group DDR5 + CXL-DDR4 system compiles once, runs under jit,
# audits clean per group
# ---------------------------------------------------------------------------

def test_hetero_system_compiles_once_and_audits_clean():
    E.RUN_CACHE.clear()
    msys = _ddr5_ddr4()
    sim = Simulator(system=msys)
    t0 = E.TRACE_COUNT
    stats, dense = sim.run(2500, interval=1.0, read_ratio=0.7, trace=True)
    assert E.TRACE_COUNT - t0 == 1          # one jax trace for the system
    # re-runs and REBUILT equal systems reuse the same compiled program
    sim.run(2500, interval=4.0, read_ratio=0.5, trace=True)
    Simulator(system=_ddr5_ddr4()).run(2500, interval=2.0, trace=True)
    assert E.TRACE_COUNT - t0 == 1

    # traffic reached every system channel of both groups
    assert (np.asarray(stats.per_channel.reads_done)
            + np.asarray(stats.per_channel.writes_done) > 0).all()
    assert stats.per_channel.reads_done.shape == (4,)
    assert len(stats.per_group) == 2
    assert stats.per_group[0].reads_done.shape == (2,)

    # zero-violation per-group audit: each channel replays against its
    # OWN group's constraint table
    tr = capture(msys, dense, controller=sim.controller,
                 frontend=sim.frontend)
    assert set(np.unique(tr.group)) == {0, 1}
    rep = audit(msys, tr)
    assert rep.ok, [str(v) for v in rep.violations[:5]]
    assert rep.by_group == {0: 0, 1: 0}
    assert rep.by_channel == {0: 0, 1: 0, 2: 0, 3: 0}
    assert rep.group_labels[1].startswith("DDR4")


def test_hetero_and_homogeneous_split_compile_cache():
    E.RUN_CACHE.clear()
    Simulator(system=_ddr5_ddr4(link=0)).run(200)
    Simulator(system=_ddr5_ddr4(link=80)).run(200)   # link splits the key
    Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4).run(200)
    assert E.RUN_CACHE.misses == 3


def test_merged_command_namespace_consistent():
    msys = _ddr5_ddr4()
    stats = Simulator(system=msys).run(1500, interval=1.0, read_ratio=0.7)
    # aggregate counts are the per-channel merged-namespace counts summed
    np.testing.assert_array_equal(
        np.asarray(stats.per_channel.cmd_counts).sum(axis=0),
        np.asarray(stats.cmd_counts))
    # and every group's native counts land on the right merged ids
    for g, ch in enumerate(stats.per_group):
        gmap = msys.group_cmd_maps[g]
        base = int(msys.chan_base[g])
        nch = msys.groups[g].channels
        lifted = np.asarray(stats.per_channel.cmd_counts)[base:base + nch]
        np.testing.assert_array_equal(lifted[:, gmap],
                                      np.asarray(ch.cmd_counts))


# ---------------------------------------------------------------------------
# Property: a 1-group MemorySystemSpec is bit-exact with the channels=N path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_one_group_system_bit_exact_with_channels_path(standard):
    org, tim = DEFAULT_SYSTEMS[standard]
    classic = Simulator(standard, org, tim, channels=2)
    grouped = Simulator(system=[dict(standard=standard, org_preset=org,
                                     timing_preset=tim, channels=2)])
    # both spellings alias ONE compiled program (same cache key) ...
    E.RUN_CACHE.clear()
    _, d1 = classic.run(1000, interval=2.0, read_ratio=0.7, trace=True)
    assert E.RUN_CACHE.misses == 1
    _, d2 = grouped.run(1000, interval=2.0, read_ratio=0.7, trace=True)
    assert E.RUN_CACHE.misses == 1 and E.RUN_CACHE.hits == 1
    # ... and the command streams are bit-exact column for column
    t1 = capture(classic.cspec, d1)
    t2 = capture(grouped.msys, d2)
    for f in FIELDS + ("group",):
        np.testing.assert_array_equal(getattr(t1, f), getattr(t2, f),
                                      err_msg=(standard, f))


# ---------------------------------------------------------------------------
# System address mapper: mixed-radix encode/decode roundtrip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", MAPPERS)
def test_system_addrmap_roundtrip_mixed_radix(order):
    """Address -> (chan, sub, row, col) -> address must round-trip across
    groups with different bank/row/col radices (DDR5 vs HBM3 vs DDR4)."""
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="HBM3", org_preset="HBM3_16Gb",
             timing_preset="HBM3_5200", channels=1),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=3, link_latency=64),
    ])
    m = SystemAddressMapper(msys, order)
    rng = np.random.default_rng(7)
    # addresses must stay inside every group's capacity (the MSB digit of
    # the owning group's mixed-radix layout must not wrap)
    cap = min(int(np.prod([c for _, c in lay])) for lay in m.sublayouts)
    q = rng.integers(0, cap, 5000)
    addrs = (q * msys.n_channels
             + rng.integers(0, msys.n_channels, 5000)) << m.tx_bits
    chan, sub, row, col = m.to_chan_sub_row_col(addrs)
    assert set(np.unique(chan)) == set(range(6))
    # fields stay within each owning group's radices
    for g, grp in enumerate(msys.groups):
        mk = msys.chan_group[chan] == g
        assert (row[mk] < grp.cspec.rows).all()
        assert (col[mk] < grp.cspec.columns).all()
        for i, lv in enumerate(grp.cspec.levels[1:]):
            assert (sub[mk, i] < int(grp.cspec.level_counts[i + 1])).all()
    back = m.encode(chan, sub, row, col)
    np.testing.assert_array_equal(back, addrs)

    # decode(encode(fields)) over explicit mixed-radix field draws
    n = 2000
    chan2 = rng.integers(0, msys.n_channels, n)
    gid = msys.chan_group[chan2]
    width = sub.shape[1]
    sub2 = np.zeros((n, width), np.int64)
    row2 = np.zeros(n, np.int64)
    col2 = np.zeros(n, np.int64)
    for g, grp in enumerate(msys.groups):
        mk = gid == g
        row2[mk] = rng.integers(0, grp.cspec.rows, int(mk.sum()))
        col2[mk] = rng.integers(0, grp.cspec.columns, int(mk.sum()))
        for i in range(len(grp.cspec.levels) - 1):
            sub2[mk, i] = rng.integers(
                0, int(grp.cspec.level_counts[i + 1]), int(mk.sum()))
    addr2 = m.encode(chan2, sub2, row2, col2)
    c3, s3, r3, k3 = m.to_chan_sub_row_col(addr2)
    np.testing.assert_array_equal(c3, chan2)
    np.testing.assert_array_equal(r3, row2)
    np.testing.assert_array_equal(k3, col2)
    np.testing.assert_array_equal(s3, sub2)


def test_system_mapper_rejects_channel_msb_orders_for_hetero():
    from repro.core.addrmap import make_system_layout
    msys = _ddr5_ddr4()
    with pytest.raises(ValueError, match="least"):
        make_system_layout(msys, "ChRoBaRaCo")


# ---------------------------------------------------------------------------
# CXL link latency: enqueue + completion boundaries
# ---------------------------------------------------------------------------

def test_link_latency_adds_round_trip_to_probe_latency():
    """A 1-group system behind a link must report probe latencies ~2L
    cycles above the same system without the link (request crosses in,
    data crosses back), with identical service otherwise."""
    mk = lambda ll: Simulator(system=[
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=1, link_latency=ll)])
    base = mk(0).run(4000, interval=8.0, read_ratio=1.0)
    linked = mk(100).run(4000, interval=8.0, read_ratio=1.0)
    lat0 = float(base.probe_lat_sum) / float(base.probe_cnt)
    lat1 = float(linked.probe_lat_sum) / float(linked.probe_cnt)
    assert lat1 - lat0 >= 2 * 100 * 0.8   # ≈ 2L (scheduling noise aside)
    assert int(linked.reads_done) > 0


def test_link_latency_splits_fingerprint():
    a = E.system_fingerprint(_ddr5_ddr4(link=0))
    b = E.system_fingerprint(_ddr5_ddr4(link=80))
    c = E.system_fingerprint(_ddr5_ddr4(link=160))
    assert a != b != c and a != c


def test_one_group_zero_link_fingerprint_is_spec_fingerprint():
    """Stored artifacts keyed on the historical spec fingerprint must
    stay verifiable: the 1-group zero-link system IS the bare spec."""
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2)
    msys = as_system(cspec)
    assert msys.homogeneous
    assert E.system_fingerprint(msys) == E.spec_fingerprint(cspec)


# ---------------------------------------------------------------------------
# Group-correct derived metrics (satellite: no homogeneous assumption)
# ---------------------------------------------------------------------------

def test_peak_gbps_group_aware():
    msys = _ddr5_ddr4()
    per_group = [peak_gbps(g.cspec) for g in msys.groups]
    assert abs(peak_gbps(msys) - sum(per_group)) < 1e-9
    # decidedly NOT one group's per-channel bandwidth times 4 channels
    wrong = 4 * peak_gbps(msys.groups[0].cspec) / 2
    assert abs(peak_gbps(msys) - wrong) > 1e-3


def test_throughput_and_breakdown_group_correct():
    msys = _ddr5_ddr4(link=0)
    sim = Simulator(system=msys, frontend=FrontendConfig(probes=False))
    stats = sim.run(3000, interval=0.5, read_ratio=1.0)
    tp = throughput_gbps(msys, stats)
    # group-correct total: each group's bytes on its own clock
    want = sum(
        float(np.asarray(ch.reads_done).sum()
              + np.asarray(ch.writes_done).sum()) * g.cspec.access_bytes
        / (float(stats.cycles) * g.cspec.tCK_ps * 1e-12) / 1e9
        for g, ch in zip(msys.groups, stats.per_group))
    assert abs(tp - want) < 1e-9
    assert tp <= peak_gbps(msys) * 1.001

    bd = channel_breakdown(msys, stats)
    assert [bd[c]["standard"] for c in range(4)] == \
        ["DDR5", "DDR5", "DDR4", "DDR4"]
    assert [bd[c]["group"] for c in range(4)] == [0, 0, 1, 1]
    assert all(0 <= bd[c]["bus_util"] <= 1 for c in bd)


def test_metrics_raise_on_spec_stats_mismatch():
    msys = _ddr5_ddr4()
    stats = Simulator(system=msys).run(500, interval=2.0)
    one_spec = compile_spec("DDR5", "DDR5_16Gb_x8", "DDR5_4800B",
                            channels=4)
    with pytest.raises(ValueError, match="different memory system"):
        throughput_gbps(one_spec, stats)
    with pytest.raises(ValueError, match="different memory system"):
        channel_breakdown(one_spec, stats)


# ---------------------------------------------------------------------------
# Trace artifacts: npz v3 group column; replay across a hetero system
# ---------------------------------------------------------------------------

def test_v3_artifact_roundtrip_and_reaudit(tmp_path):
    msys = _ddr5_ddr4(link=40)
    sim = Simulator(system=msys)
    _, dense = sim.run(2000, interval=1.0, read_ratio=0.7, trace=True)
    tr = capture(msys, dense, controller=sim.controller,
                 frontend=sim.frontend)
    back = load(save(tr, str(tmp_path / "t.npz")))
    for f in FIELDS + ("group",):
        np.testing.assert_array_equal(getattr(back, f), getattr(tr, f))
    assert back.meta == tr.meta and back.n_groups == 2
    # spec-free audit recompiles the SYSTEM from embedded provenance
    rep = audit(None, back)
    assert rep.ok and rep.by_group == {0: 0, 1: 0}
    with pytest.raises(ValueError, match="heterogeneous"):
        back.compiled_spec()


def test_hetero_capture_replay_roundtrip():
    msys = _ddr5_ddr4(link=40)
    src = Simulator(system=msys)
    _, dense = src.run(2000, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(msys, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, msys)
    assert set(np.unique(rs.chan)) == {0, 1, 2, 3}
    # sub is padded to the widest group's level count
    assert rs.sub.shape[1] == max(len(g.cspec.levels) - 1
                                  for g in msys.groups)
    sim = Simulator(system=msys,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    stats, dense2 = sim.run(2000, interval=2.0, trace=True)
    assert int(stats.reads_done) + int(stats.writes_done) > 100
    tr2 = capture(msys, dense2, controller=sim.controller,
                  frontend=sim.frontend)
    assert audit(msys, tr2).ok


def test_one_group_linked_system_capture_audit_roundtrip(tmp_path):
    """An all-CXL system (ONE group, link latency > 0) is not the plain
    spec: its identity is the system tuple.  Capture -> audit -> save ->
    load -> re-audit must round-trip (regression: capture used to embed
    the bare-spec fingerprint while audit fingerprinted the system)."""
    msys = compile_system([dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
                                timing_preset="DDR4_2400R", channels=1,
                                link_latency=40)])
    assert not msys.homogeneous
    sim = Simulator(system=msys)
    _, dense = sim.run(800, interval=4.0, read_ratio=0.7, trace=True)
    tr = capture(msys, dense, controller=sim.controller,
                 frontend=sim.frontend)
    assert "system" in tr.meta and tr.n_groups == 1
    assert audit(msys, tr).ok                    # same-system fingerprint
    back = load(save(tr, str(tmp_path / "cxl1.npz")))
    rep = audit(None, back)                      # provenance-recompiled
    assert rep.ok
    # the recompiled system preserves the link latency
    assert back.compiled_system().groups[0].link_latency == 40


def test_hetero_trace_accepts_per_group_dut_replay():
    """Independent oracle cross-check: replaying every (group, channel)
    slice of a heterogeneous capture through that group's OWN scalar
    DeviceUnderTest with check=True must never raise — auditor and
    oracle agree each group's engine issued legally against its own
    constraint table."""
    from repro.core import DeviceUnderTest
    msys = _ddr5_ddr4(link=40)
    sim = Simulator(system=msys)
    _, dense = sim.run(1500, interval=1.0, read_ratio=0.7, trace=True)
    tr = capture(msys, dense, controller=sim.controller,
                 frontend=sim.frontend)
    n_replayed = 0
    for g, grp in enumerate(msys.groups):
        to_local = {int(gi): li
                    for li, gi in enumerate(msys.group_cmd_maps[g])}
        for c in range(grp.channels):
            dut = DeviceUnderTest.from_compiled(grp.cspec)
            chan = int(msys.chan_base[g]) + c
            for i in np.nonzero(tr.chan == chan)[0]:
                cmd = grp.cspec.cmd_names[to_local[int(tr.cmd[i])]]
                bank = int(tr.bank[i])
                addr = {}
                for lv in reversed(grp.cspec.levels[1:]):
                    cnt = int(grp.cspec.level_counts[
                        grp.cspec.levels.index(lv)])
                    addr[lv] = bank % cnt
                    bank //= cnt
                addr["row"] = max(int(tr.row[i]), 0)
                addr["col"] = 0
                dut.issue(cmd, addr, clk=int(tr.clk[i]), check=True)
                n_replayed += 1
    assert n_replayed == len(tr)


# ---------------------------------------------------------------------------
# Replay RAW/WAR dependencies (ReplayStream.dep)
# ---------------------------------------------------------------------------

def test_to_replay_computes_raw_war_deps():
    src = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    mapper="RoBaRaCoCh")
    _, dense = src.run(2500, interval=2.0, read_ratio=0.5, trace=True)
    tr = capture(src.cspec, dense, controller=src.controller,
                 frontend=src.frontend)
    assert to_replay(tr, src.cspec).dep is None      # opt-in
    rs = to_replay(tr, src.cspec, deps=True)
    assert rs.dep is not None and int(np.sum(rs.dep >= 0)) > 0
    bank = np.zeros(len(rs), np.int64)
    counts = src.cspec.level_counts
    for i in range(1, len(counts)):
        bank = bank * int(counts[i]) + rs.sub[:, i - 1]
    for k in np.nonzero(rs.dep >= 0)[0][:200]:
        j = int(rs.dep[k])
        assert j < k                                 # producer precedes
        assert (rs.chan[j], bank[j], rs.row[j]) == \
            (rs.chan[k], bank[k], rs.row[k])         # same address (row)
        if rs.is_write[k]:
            assert not rs.is_write[j]                # WAR: write after read
        else:
            assert rs.is_write[j]                    # RAW: read after write
    # deps change the paced injection -> distinct compiled program
    assert rs.fingerprint != to_replay(tr, src.cspec).fingerprint


def test_replay_dep_holds_request_until_producer_served():
    """A read that depends on an earlier write to the same row must not
    inject (and hence not be served) before the write's final WR issued,
    even when its arrival pacing says it is long overdue."""
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    nsub = len(cspec.levels) - 1
    n = 8
    z = np.zeros(n, np.int32)
    # W@row7 then R@row7 (dep on the write), then unrelated filler rows
    rows = np.asarray([7, 7, 1, 2, 3, 4, 5, 6], np.int32)
    is_wr = np.asarray([1, 0, 0, 0, 0, 0, 0, 0], np.int32)
    dep = np.asarray([-1, 0, -1, -1, -1, -1, -1, -1], np.int32)
    mk = lambda d: ReplayStream(
        chan=z, sub=np.zeros((n, nsub), np.int32), row=rows,
        col=z, is_write=is_wr,
        arrive=np.arange(n, dtype=np.int32), dep=d)
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=mk(dep))
    _, dense = sim.run(600, trace=True)
    tr = capture(sim.cspec, dense)
    i_wr, i_rd = tr.cmd_names.index("WR"), tr.cmd_names.index("RD")
    wr_clk = tr.clk[(tr.cmd == i_wr)][0]
    # the dependent read ARRIVED (injected) only after the write issued
    rd_row7 = (tr.cmd == i_rd) & (tr.row == 7)
    assert rd_row7.any()
    assert int(tr.arrive[rd_row7][0]) > int(wr_clk)

    # control: without deps the same stream injects the read immediately
    sim0 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     frontend=FrontendConfig(pattern="trace", probes=False),
                     replay=mk(None))
    _, dense0 = sim0.run(600, trace=True)
    tr0 = capture(sim0.cspec, dense0)
    rd0 = (tr0.cmd == i_rd) & (tr0.row == 7)
    assert int(tr0.arrive[rd0][0]) <= 2
