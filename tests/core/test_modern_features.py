"""Paper §2 feature tests: split ACT-1/ACT-2, WCK/RCK sync, dual C/A."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceUnderTest, Simulator, ControllerConfig, FrontendConfig

pytestmark = pytest.mark.device_timings


# ---------------------------------------------------------------------------
# LPDDR5 split activation
# ---------------------------------------------------------------------------

class TestSplitActivation:
    @pytest.fixture
    def dut(self):
        return DeviceUnderTest("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400")

    def test_closed_bank_needs_act1(self, dut):
        addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=4, Column=0)
        assert dut.probe("RD", addr, clk=0).preq == "ACT1"

    def test_act1_then_act2_then_rd(self, dut):
        addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=4, Column=0)
        dut.issue("ACT1", addr, clk=0)
        # bank is Activating -> prerequisite is ACT2 (not ACT1, not RD)
        r = dut.probe("RD", addr, clk=1)
        assert r.preq == "ACT2"
        assert dut.probe("ACT2", addr, clk=dut.timings["nAAD_MIN"] - 1).timing_OK is False
        t2 = dut.timings["nAAD_MIN"]
        dut.issue("ACT2", addr, clk=t2)
        # nRCD counts from ACT2 (row becomes open)
        ok_clk = t2 + dut.timings["nRCD"]
        assert dut.probe("RD", addr, clk=ok_clk - 1).timing_OK is False
        ontime = dut.probe("RD", addr, clk=ok_clk)
        assert ontime.row_open is True and ontime.row_hit is True

    def test_engine_issues_act1_act2_pairs(self):
        sim = Simulator("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400")
        stats = sim.run(4000, interval=8.0, read_ratio=1.0)
        names = sim.cspec.cmd_names
        counts = dict(zip(names, stats.cmd_counts.tolist()))
        assert counts["ACT1"] > 0
        # every completed activation pairs ACT1 with exactly one ACT2
        assert abs(counts["ACT1"] - counts["ACT2"]) <= 1
        assert counts["RD"] > 0

    def test_act2_deadline_respected_in_engine(self):
        """No ACT1 may linger past its tAAD deadline before ACT2."""
        sim = Simulator("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400")
        stats, trace = sim.run(3000, interval=4.0, read_ratio=0.7, trace=True)
        cmds, banks = np.asarray(trace.cmd), np.asarray(trace.bank)
        names = sim.cspec.cmd_names
        i_act1, i_act2 = names.index("ACT1"), names.index("ACT2")
        pending = {}
        naad = sim.cspec.nAAD
        for t in range(cmds.shape[0]):
            for bus in range(cmds.shape[1]):
                c = cmds[t, bus]
                if c == i_act1:
                    pending[int(banks[t, bus])] = t
                elif c == i_act2:
                    b = int(banks[t, bus])
                    assert b in pending, "ACT2 without prior ACT1"
                    assert t - pending.pop(b) <= naad, \
                        f"ACT2 violated tAAD at clk {t}"
        # nothing left pending forever (allow in-flight at trace end)
        for b, t0 in pending.items():
            assert cmds.shape[0] - t0 <= naad + 2


# ---------------------------------------------------------------------------
# WCK / RCK data-clock sync
# ---------------------------------------------------------------------------

class TestDataClockSync:
    def test_wck_cas_required_when_clock_off(self):
        dut = DeviceUnderTest("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400")
        addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=4, Column=0)
        dut.issue("ACT1", addr, clk=0)
        dut.issue("ACT2", addr, clk=2)
        t = 2 + dut.timings["nRCD"]
        r = dut.probe("RD", addr, clk=t)
        assert r.preq == "CAS_RD"        # clock off -> sync required
        dut.issue("CAS_RD", addr, clk=t)
        t2 = t + dut.timings["nWCKEN"]
        r2 = dut.probe("RD", addr, clk=t2)
        assert r2.preq == "RD" and r2.timing_OK
        dut.issue("RD", addr, clk=t2)
        # clock stays on through the transfer: next RD needs no CAS
        r3 = dut.probe("RD", addr, clk=t2 + dut.timings["nCCD_L"])
        assert r3.preq == "RD"
        # after the idle window expires the clock drops again
        idle = t2 + dut.cspec.clock_idle + 1
        assert dut.probe("RD", addr, clk=idle).preq == "CAS_RD"

    def test_rck_for_gddr7(self):
        dut = DeviceUnderTest("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32")
        addr = dut.addr_vec(Rank=0, BankGroup=0, Bank=0, Row=4, Column=0)
        dut.issue("ACT", addr, clk=0)
        t = dut.timings["nRCD"]
        assert dut.probe("RD", addr, clk=t).preq == "RCKSTRT"
        dut.issue("RCKSTRT", addr, clk=t)
        t2 = t + dut.timings["nRCKEN"]
        assert dut.probe("RD", addr, clk=t2).preq == "RD"

    def test_engine_injects_sync_commands(self):
        sim = Simulator("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400",
                        frontend=FrontendConfig(interval=64.0, probe_gap=64))
        stats = sim.run(6000)
        counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
        # sparse traffic -> clock expires between bursts -> CAS commands flow
        assert counts["CAS_RD"] > 0
        assert counts["RD"] > 0


# ---------------------------------------------------------------------------
# HBM3 / GDDR7 dual C/A bus: parallel row/column issue
# ---------------------------------------------------------------------------

class TestDualCommandBus:
    @pytest.mark.parametrize("std,org,tim", [
        ("HBM3", "HBM3_16Gb", "HBM3_5200"),
        ("GDDR7", "GDDR7_16Gb_x32", "GDDR7_32"),
    ])
    def test_parallel_row_col_issue(self, std, org, tim):
        sim = Simulator(std, org, tim)
        stats, trace = sim.run(4000, interval=1.0, read_ratio=1.0, trace=True)
        cmds = np.asarray(trace.cmd)
        kind = sim.cspec.cmd_kind
        both = 0
        for t in range(cmds.shape[0]):
            c0, c1 = cmds[t]   # [col-bus, row-bus]
            if c0 >= 0:
                assert kind[c0] in (1, 3), f"row cmd on col bus at {t}"
            if c1 >= 0:
                assert kind[c1] in (0, 2), f"col cmd on row bus at {t}"
            if c0 >= 0 and c1 >= 0:
                both += 1
        assert both > 0, "dual C/A never issued row+col in the same cycle"

    def test_dual_ca_beats_single_ca(self):
        """Ablation: same device, dual C/A off -> worse random-probe latency
        when the column stream saturates the (single) command bus — the
        paper's motivation for separate row/column buses."""
        from repro.core import avg_probe_latency_ns
        import repro.core.standards.hbm3 as h3
        from repro.core.spec import register

        class HBM3_single(h3.HBM3):   # variant authored in 3 lines (§3.2)
            name = "HBM3_single_test"
            dual_command_bus = False
        register(HBM3_single)

        # nBL=1/nCCD=1: a saturated read stream needs a column command
        # every cycle, so on a single bus row commands (the probe's ACT)
        # must steal column slots.
        overrides = {"nBL": 1, "nCCD_S": 1, "nCCD_L": 1}
        lats = {}
        for name in ("HBM3", "HBM3_single_test"):
            sim = Simulator(name, "HBM3_16Gb", "HBM3_5200",
                            timing_overrides=overrides)
            stats = sim.run(12000, interval=1.0, read_ratio=1.0)
            assert int(stats.probe_cnt) > 3, name
            lats[name] = avg_probe_latency_ns(sim.cspec, stats)
        assert lats["HBM3"] <= lats["HBM3_single_test"], lats
