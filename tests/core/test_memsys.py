"""Multi-channel memory-system tests (the tentpole acceptance criteria):
one compiled program regardless of channel count, per-channel + aggregate
stats, per-channel trace audit with injected-violation sensitivity, the
trace-driven frontend, and channel-aware DSE sweeps."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ControllerConfig, FrontendConfig, ReplayStream,
                        Simulator, channel_breakdown, peak_gbps,
                        throughput_gbps)
from repro.core import engine as E
from repro.trace import audit, capture, to_replay


# ---------------------------------------------------------------------------
# Acceptance: 4-channel run compiles exactly once, per-channel stats
# ---------------------------------------------------------------------------

def test_four_channel_run_compiles_once():
    E.RUN_CACHE.clear()
    sim = Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=4)
    t0 = E.TRACE_COUNT
    stats = sim.run(3000, interval=1.0, read_ratio=1.0)
    assert E.TRACE_COUNT - t0 == 1          # one jax trace for 4 channels
    # load sweeps and re-runs reuse the same compiled program
    sim.run(3000, interval=4.0, read_ratio=0.5)
    Simulator("HBM3", "HBM3_16Gb", "HBM3_5200", channels=4).run(
        3000, interval=2.0)
    assert E.TRACE_COUNT - t0 == 1

    # per-channel breakdown present, consistent with the aggregates
    ch = stats.per_channel
    assert ch.reads_done.shape == (4,)
    assert ch.cmd_counts.shape == (4, sim.cspec.n_cmds)
    assert int(ch.reads_done.sum()) == int(stats.reads_done)
    assert int(ch.writes_done.sum()) == int(stats.writes_done)
    np.testing.assert_array_equal(ch.cmd_counts.sum(axis=0),
                                  stats.cmd_counts)
    # the channel-interleaving mapper spreads traffic onto every channel
    assert (ch.reads_done + ch.writes_done > 0).all()
    bd = channel_breakdown(sim.cspec, stats)
    assert set(bd) == {0, 1, 2, 3}
    assert all(0 <= v["bus_util"] <= 1 for v in bd.values())


def test_channel_count_splits_compile_cache():
    E.RUN_CACHE.clear()
    for c in (1, 2):
        Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=c).run(200)
    assert E.RUN_CACHE.misses == 2


def test_multi_channel_scales_throughput():
    """More channels => more aggregate bandwidth under a saturating load."""
    tp = {}
    for c in (1, 4):
        sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=c,
                        frontend=FrontendConfig(probes=False))
        stats = sim.run(6000, interval=0.25, read_ratio=1.0)
        tp[c] = throughput_gbps(sim.cspec, stats)
        assert tp[c] <= peak_gbps(sim.cspec) * 1.001
    assert tp[4] > 2.0 * tp[1], tp


def test_single_channel_unchanged_shapes():
    """channels=1 keeps the historical scalar-stats and [T, 2] trace
    contract."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    stats, dense = sim.run(800, interval=4.0, trace=True)
    assert stats.reads_done.shape == ()
    assert stats.per_channel.reads_done.shape == (1,)
    assert np.asarray(dense.cmd).shape == (800, 2)


@pytest.mark.parametrize("std,org,tim", [
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
])
def test_earliest_table_matches_scalar(std, org, tim):
    """The dense (n_cmds, n_banks) earliest table — the engine's hot path
    — must agree entry-for-entry with the scalar `earliest_ready` the
    oracle-parity tests validate."""
    import jax.numpy as jnp

    from repro.core import compile_spec
    from repro.core import device as D

    cspec = compile_spec(std, org, tim)
    dp = D.dyn_params(cspec)
    state = D.init_state(cspec)
    rng = np.random.default_rng(11)
    counts = cspec.level_counts
    for _ in range(80):
        sub = jnp.asarray([int(rng.integers(int(counts[i])))
                           for i in range(1, len(counts))], jnp.int32)
        cmd = int(rng.integers(cspec.n_cmds))
        state = D.issue(cspec, dp, state, jnp.int32(cmd), sub,
                        jnp.int32(int(rng.integers(64))),
                        jnp.int32(int(rng.integers(5000))),
                        jnp.asarray(True))
    table = np.asarray(D.earliest_ready_table(cspec, dp, state))
    assert table.shape == (cspec.n_cmds, cspec.n_banks)
    for bank in range(cspec.n_banks):
        sub = []
        b = bank
        for i in range(len(counts) - 1, 0, -1):
            sub.append(b % int(counts[i]))
            b //= int(counts[i])
        sub = jnp.asarray(sub[::-1], jnp.int32)
        for cmd in range(cspec.n_cmds):
            want = int(D.earliest_ready(cspec, dp, state, jnp.int32(cmd),
                                        sub))
            assert table[cmd, bank] == want, (std, cmd, bank)


# ---------------------------------------------------------------------------
# Acceptance: 2-channel capture -> per-channel audit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def two_channel_trace():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    mapper="RoBaRaCoCh")
    _, dense = sim.run(4000, interval=1.0, read_ratio=1.0, trace=True)
    tr = capture(sim.cspec, dense, controller=sim.controller,
                 frontend=sim.frontend)
    return sim, tr


def test_two_channel_trace_audits_clean_per_channel(two_channel_trace):
    sim, tr = two_channel_trace
    assert np.asarray(tr.chan).max() == 1    # both channels saw commands
    assert tr.meta["n_channels"] == 2
    rep = audit(sim.cspec, tr)
    assert rep.ok, [str(v) for v in rep.violations[:5]]
    # explicit zero-violation entry for EVERY channel
    assert rep.by_channel == {0: 0, 1: 0}
    assert "ch0: 0" in rep.summary() and "ch1: 0" in rep.summary()


def test_injected_cross_channel_violation_detected(two_channel_trace):
    """Moving a channel-1 RD inside its own channel's nRCD window must be
    flagged (with channel attribution), while the same-cycle traffic on
    channel 0 stays clean — channels are audited independently."""
    sim, tr = two_channel_trace
    names = tr.cmd_names
    i_act, i_rd = names.index("ACT"), names.index("RD")
    nrcd = sim.cspec.timings["nRCD"]
    a = int(np.nonzero((tr.cmd == i_act) & (tr.chan == 1))[0][0])
    r = int(np.nonzero((tr.cmd == i_rd) & (tr.chan == 1)
                       & (tr.bank == tr.bank[a])
                       & (tr.clk > tr.clk[a]))[0][0])
    clk = tr.clk.copy()
    clk[r] = tr.clk[a] + nrcd - 1            # one cycle early on channel 1
    order = np.argsort(clk, kind="stable")
    bad = dataclasses.replace(
        tr, clk=clk[order],
        **{f: getattr(tr, f)[order]
           for f in ("cmd", "bank", "row", "bus", "arrive", "hit_ready",
                     "chan")})
    rep = audit(sim.cspec, bad)
    assert not rep.ok
    assert rep.by_channel[1] > 0 and rep.by_channel[0] == 0
    hit = [v for v in rep.violations if v.chan == 1 and v.cmd == "RD"]
    assert hit and hit[0].slack == -1


def test_same_cycle_same_bank_across_channels_not_flagged():
    """An ACT on (ch0, bank0) and an ACT on (ch1, bank0) one cycle apart
    would violate nRRD within one channel — across channels it is legal
    parallelism and must NOT be flagged."""
    from repro.trace import CommandTrace
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      channels=2).cspec
    names = list(cspec.cmd_names)
    i_act = names.index("ACT")
    mk = lambda chans: CommandTrace(
        clk=np.asarray([10, 11], np.int32),
        cmd=np.asarray([i_act, i_act], np.int32),
        bank=np.zeros(2, np.int32), row=np.asarray([3, 5], np.int32),
        bus=np.zeros(2, np.int32), arrive=np.asarray([1, 2], np.int32),
        hit_ready=np.zeros(2, np.int32),
        chan=np.asarray(chans, np.int32), n_cycles=64, cmd_names=names,
        meta={"n_channels": 2})
    assert audit(cspec, mk([0, 1]), check_fingerprint=False).ok
    same = audit(cspec, mk([0, 0]), check_fingerprint=False)
    assert not same.ok                       # same channel: nRRD violated
    assert same.by_channel[0] > 0


# ---------------------------------------------------------------------------
# Trace-driven frontend (third request source)
# ---------------------------------------------------------------------------

def test_trace_driven_frontend_from_synthetic_addresses():
    cspec2 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                       channels=2).cspec
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 1 << 24, 4000).astype(np.int64) \
        * cspec2.access_bytes
    rs = ReplayStream.from_addresses(cspec2, addrs,
                                     is_write=rng.random(4000) < 0.25)
    assert len(rs) == 4000 and set(np.unique(rs.chan)) == {0, 1}
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    stats = sim.run(4000, interval=2.0)
    assert int(stats.reads_done) > 100 and int(stats.writes_done) > 30
    assert (stats.per_channel.reads_done > 0).all()


def test_trace_driven_frontend_from_captured_trace():
    """Capture a streaming run, derive a ReplayStream, re-drive the memory
    system with it — the replayed run must serve requests on the same
    channels the capture used and audit clean."""
    src = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    mapper="RoBaRaCoCh")
    _, dense = src.run(2500, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(src.cspec, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, src.cspec)
    assert set(np.unique(rs.chan)) == {0, 1}

    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    stats, dense2 = sim.run(2500, interval=2.0, trace=True)
    assert int(stats.reads_done) + int(stats.writes_done) > 100
    tr2 = capture(sim.cspec, dense2, controller=sim.controller,
                  frontend=sim.frontend)
    assert audit(sim.cspec, tr2).ok


def test_replay_fingerprint_keys_compile_cache():
    cspec2 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                       channels=2).cspec
    mk = lambda seed: ReplayStream.from_addresses(
        cspec2, np.random.default_rng(seed).integers(0, 1 << 20, 100) * 8)
    a, b, a2 = mk(0), mk(1), mk(0)
    assert a.fingerprint == a2.fingerprint != b.fingerprint
    fcfg = FrontendConfig(pattern="trace", probes=False)
    cc = ControllerConfig()
    k = lambda rs: E.run_key(cspec2, cc, fcfg, 100, False, False, rs)
    assert k(a) == k(a2) and k(a) != k(b)


def test_trace_pattern_without_replay_errors():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(pattern="trace"))
    with pytest.raises(ValueError, match="ReplayStream"):
        sim.run(100)


def test_replay_channel_out_of_range_rejected():
    """A replay stream captured on more channels than the target system
    has would livelock (its requests route nowhere) — reject loudly."""
    cspec4 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                       channels=4).cspec
    rs4 = ReplayStream.from_addresses(
        cspec4, np.arange(256, dtype=np.int64) * cspec4.access_bytes)
    assert int(rs4.chan.max()) == 3
    sim2 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                     frontend=FrontendConfig(pattern="trace",
                                             probes=False),
                     replay=rs4)
    with pytest.raises(ValueError, match="channel 3"):
        sim2.run(100)


def test_default_arg_bound_predicates_keyed_by_value():
    """The `def pred(..., t=t)` binding idiom must key the cache by the
    bound value, exactly like closure binding."""
    def mk(t):
        def pred(cspec, ctx, t=t):
            return ctx.cand_row < t
        return pred
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    key = lambda t: E.run_key(
        sim.cspec, ControllerConfig(extra_predicates=(mk(t),)),
        sim.frontend, 100, False, False)
    assert key(5) == key(5)
    assert key(5) != key(7)


def test_empty_replay_rejected():
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    rs = ReplayStream.from_addresses(cspec, np.asarray([], np.int64))
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    with pytest.raises(ValueError, match="empty"):
        sim.run(100)


def test_distinct_lambdas_get_distinct_cache_keys():
    """Two different inline lambdas share the '<lambda>' qualname and an
    empty closure — the cache key must still distinguish them (bytecode
    identity), or the second run would reuse the wrong predicate."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    a = ControllerConfig(extra_predicates=(lambda c, x: x.cand_row < 5,))
    b = ControllerConfig(extra_predicates=(lambda c, x: x.cand_row >= 5,))
    key = lambda cc: E.run_key(sim.cspec, cc, sim.frontend, 100, False,
                               False)
    assert key(a) != key(b)


def test_single_channel_fingerprint_unchanged():
    """Pre-multi-channel trace artifacts store single-channel
    fingerprints; the channel count may only extend the fingerprint when
    it is >1."""
    c1 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    c2 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2).cspec
    f1, f2 = E.spec_fingerprint(c1), E.spec_fingerprint(c2)
    assert f1 != f2
    assert len(f2) == len(f1) + 1 and f2[:len(f1)] == f1


# ---------------------------------------------------------------------------
# Per-channel refresh staggering
# ---------------------------------------------------------------------------

def _ref_clocks_per_channel(sim, tr):
    i_ref = tr.cmd_names.index("REFab")
    return {c: tr.clk[(tr.cmd == i_ref) & (tr.chan == c)]
            for c in range(sim.cspec.n_channels)}


def _all_channel_refresh_overlap(sim, tr, n_cycles):
    """Cycles during which EVERY channel sits inside a refresh (nRFC)
    window — the all-channel bandwidth dip refresh staggering removes."""
    nrfc = sim.cspec.timings["nRFC"]
    busy = np.zeros((sim.cspec.n_channels, n_cycles), bool)
    for c, clks in _ref_clocks_per_channel(sim, tr).items():
        for t in clks:
            busy[c, t:t + nrfc] = True
    return int(np.count_nonzero(busy.all(axis=0)))


def test_refresh_stagger_phase_shifts_channels():
    """Channel c's refresh epoch must lead by c*nREFI/C — REF issue clocks
    are phase-shifted instead of landing on one cycle; the simultaneous
    all-channel refresh window (the bandwidth dip) disappears."""
    n_cycles, C = 24000, 4
    mk = lambda stagger: Simulator(
        "DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=C,
        controller=ControllerConfig(refresh_stagger=stagger))
    sim = mk(True)
    _, dense = sim.run(n_cycles, interval=4.0, trace=True)
    tr = capture(sim.cspec, dense)
    nrefi = sim.cspec.timings["nREFI"]
    refs = _ref_clocks_per_channel(sim, tr)
    first = {c: int(refs[c][0]) for c in range(C)}
    for c in range(1, C):
        want_lead = c * nrefi // C
        got_lead = first[0] - first[c]
        # opportunistic refresh may slip a few cycles past due
        assert abs(got_lead - want_lead) <= 64, (c, got_lead, want_lead)
    # steady state keeps the phases apart too: no two channels refresh
    # within a quarter phase of each other
    for c in range(C):
        assert len(refs[c]) >= 2                 # periodic, not one-shot

    base = mk(False)
    _, dense0 = base.run(n_cycles, interval=4.0, trace=True)
    tr0 = capture(base.cspec, dense0)
    dip0 = _all_channel_refresh_overlap(base, tr0, n_cycles)
    dip1 = _all_channel_refresh_overlap(sim, tr, n_cycles)
    assert dip0 > 0, "in-phase baseline shows no all-channel refresh dip"
    assert dip1 < dip0, (dip1, dip0)
    assert dip1 == 0    # nREFI/C >> nRFC: staggered windows never align


# ---------------------------------------------------------------------------
# Replay pacing: captured inter-arrival gaps survive capture -> replay
# ---------------------------------------------------------------------------

def test_replay_honors_captured_arrival_gaps():
    src = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    mapper="RoBaRaCoCh")
    _, dense = src.run(2500, interval=5.0, read_ratio=0.7, trace=True)
    tr = capture(src.cspec, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, src.cspec)
    assert rs.arrive is not None
    assert (np.diff(rs.arrive) >= 0).all()       # arrival order

    # replay with a WILDLY different streaming interval: pacing must come
    # from the captured arrive deltas, not interval_fp
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    _, dense2 = sim.run(2500, interval=1.0, trace=True)
    tr2 = capture(sim.cspec, dense2, controller=sim.controller,
                  frontend=sim.frontend)
    rs2 = to_replay(tr2, sim.cspec)

    n = min(len(rs), len(rs2))
    assert n > 100
    d1 = np.diff(rs.arrive[:n] - rs.arrive[0])
    d2 = np.diff(rs2.arrive[:n] - rs2.arrive[0])
    # one injection per cycle max => at most 1 cycle of slip per request
    assert np.abs(d1 - d2).max() <= 1
    assert np.mean(d1 == d2) > 0.9
    # and decidedly NOT the replay sim's own interval of 1.0
    assert abs(float(np.mean(d2)) - float(np.mean(d1))) < 0.5
    assert float(np.mean(d2)) > 3.0


def test_replay_without_arrive_paces_by_interval():
    """ReplayStreams built from raw addresses (no captured arrivals) keep
    the historical streaming-interval pacing."""
    cspec2 = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                       channels=2).cspec
    rs = ReplayStream.from_addresses(
        cspec2, np.arange(4000, dtype=np.int64) * cspec2.access_bytes)
    assert rs.arrive is None
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    _, dense = sim.run(2000, interval=8.0, trace=True)
    tr = capture(sim.cspec, dense, controller=sim.controller,
                 frontend=sim.frontend)
    rs2 = to_replay(tr, sim.cspec)
    gaps = np.diff(np.sort(rs2.arrive))
    assert 7.0 <= float(np.mean(gaps[gaps > 0])) <= 9.0


def test_replay_unsorted_arrive_rejected():
    """Injection is index-ordered, so a non-monotone arrive column cannot
    honor its own gaps — reject loudly instead of pacing nonsense."""
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    rs = ReplayStream.from_addresses(
        cspec, np.arange(8, dtype=np.int64) * cspec.access_bytes)
    bad = dataclasses.replace(
        rs, arrive=np.asarray([0, 5, 3, 9, 12, 15, 18, 21], np.int32),
        fingerprint="")
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=bad)
    with pytest.raises(ValueError, match="non-decreasing"):
        sim.run(100)


def test_replay_arrive_in_fingerprint():
    """Two streams differing only in arrival pacing must not alias one
    compiled program."""
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    addrs = np.arange(64, dtype=np.int64) * cspec.access_bytes
    a = ReplayStream.from_addresses(cspec, addrs)
    b = dataclasses.replace(
        a, arrive=np.arange(64, dtype=np.int32) * 7, fingerprint="")
    c = dataclasses.replace(
        a, arrive=np.arange(64, dtype=np.int32) * 3, fingerprint="")
    assert a.fingerprint != b.fingerprint != c.fingerprint


# ---------------------------------------------------------------------------
# Channel-aware DSE sweeps
# ---------------------------------------------------------------------------

def test_sweep_channels_and_mappers_first_class():
    from repro.dse import SweepSpec, execute
    spec = SweepSpec(systems=("DDR4",), intervals=(8.0, 2.0),
                     read_ratios=(1.0,), channels=(1, 2),
                     mappers=("RoBaRaCoCh", "RoCoBaRaCh"), n_cycles=600)
    assert spec.grid_shape == (1, 1, 2, 2, 2, 1)
    res = execute(spec, cache=E.RunCache())
    # one compiled program per (channels, mapper) combination
    assert res.meta["n_groups"] == 4
    assert res.meta["compile_cache_misses"] == 4
    chans = {pt.n_channels for pt in res.points}
    maps = {pt.mapper for pt in res.points}
    assert chans == {1, 2} and maps == {"RoBaRaCoCh", "RoCoBaRaCh"}
    # curves split by channel count and mapper: 4 series of 2 load points
    cvs = res.curves()
    assert len(cvs) == 4
    assert {cv.n_channels for cv in cvs} == {1, 2}
    # 2-channel peak is twice the 1-channel peak
    pk = {cv.n_channels: cv.peak_gbps for cv in cvs}
    assert abs(pk[2] - 2 * pk[1]) < 1e-9


def test_sweep_result_roundtrip_preserves_channels(tmp_path):
    from repro.dse import SweepResult, SweepSpec, execute
    spec = SweepSpec(systems=("DDR4",), intervals=(4.0,), read_ratios=(1.0,),
                     channels=(2,), n_cycles=300)
    res = execute(spec, cache=E.RunCache())
    back = SweepResult.load(res.save(str(tmp_path / "s")))
    assert back.points[0].n_channels == 2
    assert back.points[0].mapper == res.points[0].mapper
